"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract. pytest compares every kernel against these under hypothesis
shape/dtype sweeps (python/tests/test_kernel.py)."""

import jax.numpy as jnp

from . import cms


def ner_scorer_ref(tokens, lens, emb, w, b):
    """Reference for kernels.ner_scorer.ner_scorer (no Pallas)."""
    vecs = jnp.take(emb, tokens, axis=0)  # [B, L, D]
    mask = (jnp.arange(tokens.shape[1])[None, :] < lens[:, None]).astype(vecs.dtype)
    summed = jnp.einsum("bld,bl->bd", vecs, mask)
    denom = jnp.maximum(lens.astype(vecs.dtype), 1.0)[:, None]
    pooled = summed / denom
    return pooled @ w + b[None, :]


def cms_update_ref(keys, weights):
    """Reference for kernels.cms.cms_update: explicit scatter-add."""
    keys = keys.astype(jnp.uint32)
    rows = []
    for r in range(cms.N_ROWS):
        idx = cms._hash_row(keys, cms._ROW_SALTS[r])
        row = jnp.zeros((cms.WIDTH,), jnp.float32).at[idx].add(weights)
        rows.append(row)
    return jnp.stack(rows)
