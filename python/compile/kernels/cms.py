"""L1 Pallas kernel: batched count-min-sketch update.

DR's heavy-hitter counting runs on the DRWs (rust side, §4); this kernel is
the *offload* variant: when the map-side UDF already runs on the
accelerator, folding the sampling sketch into the same AOT program makes
the DR tap free on the host. It also doubles as the paper's "sketch
baseline" compute for the micro-benchmarks.

TPU adaptation: a scatter-add over hash buckets is hostile to the MXU, so
each sketch row is built as a one-hot matmul —

    sketch[r, :] += onehot(h_r(keys)) ^T @ weights

which is a `[W, n] @ [n]` product the systolic array handles natively.
The grid runs one program instance per sketch row; `interpret=True` for
CPU PJRT (see ner_scorer.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_ROWS = 4
WIDTH = 1024

# Odd 32-bit multipliers for the per-row universal hash family (32-bit
# arithmetic: jax runs without the x64 flag in this build).
_ROW_SALTS = jnp.array(
    [0x9E3779B9, 0xC2B2AE3D, 0x165667B1, 0x27D4EB2F],
    dtype=jnp.uint32,
)


def _hash_row(keys, salt):
    """fmix32-style per-row hash of uint32 keys → bucket index [0, WIDTH)."""
    h = keys * salt
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return (h % jnp.uint32(WIDTH)).astype(jnp.int32)


def _cms_kernel(keys_ref, w_ref, salt_ref, out_ref):
    """One grid step: build one sketch row for the whole key batch."""
    keys = keys_ref[...].astype(jnp.uint32)  # [n]
    w = w_ref[...]  # [n] f32

    idx = _hash_row(keys, salt_ref[0])  # [n]

    # one-hot matmul instead of scatter-add (MXU-friendly)
    onehot = (idx[:, None] == jnp.arange(WIDTH)[None, :]).astype(jnp.float32)
    out_ref[...] = (w @ onehot)[None, :]  # block is [1, W]


@functools.partial(jax.jit, static_argnames=())
def cms_update(keys, weights):
    """Compute the CMS increment of a key batch.

    Args:
      keys:    [n] uint32 (hashed key ids; 32-bit to keep the artifact's
               input layout simple for the rust caller).
      weights: [n] f32 per-key weights (1.0 for counting).
    Returns:
      [N_ROWS, WIDTH] f32 sketch increments.
    """
    n = keys.shape[0]
    return pl.pallas_call(
        _cms_kernel,
        grid=(N_ROWS,),
        in_specs=[
            pl.BlockSpec((n,), lambda r: (0,)),
            pl.BlockSpec((n,), lambda r: (0,)),
            pl.BlockSpec((1,), lambda r: (r,)),  # this row's hash salt
        ],
        out_specs=pl.BlockSpec((1, WIDTH), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N_ROWS, WIDTH), jnp.float32),
        interpret=True,
    )(keys, weights, _ROW_SALTS)


def cms_query(sketch, keys):
    """Min-over-rows point query (host-side helper for tests)."""
    keys = keys.astype(jnp.uint32)
    ests = []
    for r in range(N_ROWS):
        idx = _hash_row(keys, _ROW_SALTS[r])
        ests.append(sketch[r, idx])
    return jnp.stack(ests).min(axis=0)
