"""L1 Pallas kernel: the NER entity scorer — the paper's §6 reducer UDF.

The §6 use case runs a named-entity-recognition model over the documents of
each host keygroup; NER cost is ~linear in text length, which is exactly
what makes skewed host partitions into stragglers. This kernel is that
per-document compute: embed tokens, masked mean-pool, linear classify.

    logits[b, c] = (mean_{l < len_b} emb[tok[b, l]]) @ w[:, c] + bias[c]

TPU-idiomatic layout (see DESIGN.md §Hardware adaptation):
- the grid tiles the *batch* dimension; each program instance handles a
  `TILE_B × L` block of tokens with the embedding table resident — the
  BlockSpec expresses the HBM→VMEM schedule;
- pooling + classification is a `[TILE_B, D] @ [D, C]` matmul (MXU), not a
  per-token loop;
- `interpret=True` is REQUIRED on CPU PJRT: real-TPU lowering emits a
  Mosaic custom-call that the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Model dimensions — must match rust/src/workload/ner.rs and runtime/.
VOCAB = 8192
MAX_LEN = 128
EMBED_DIM = 64
N_CLASSES = 9  # O + {PER, ORG, LOC, MISC} × {B, I}

DEFAULT_TILE_B = 32


def _scorer_kernel(tok_ref, len_ref, emb_ref, w_ref, b_ref, out_ref):
    """One grid step: score a [TILE_B, L] tile of token ids."""
    tok = tok_ref[...]  # [TB, L] int32
    lens = len_ref[...]  # [TB] int32
    emb = emb_ref[...]  # [V, D]

    # Gather token embeddings: [TB, L, D]. (On CPU-interpret this is a
    # plain take; on TPU Mosaic it lowers to dynamic-slice streams.)
    vecs = jnp.take(emb, tok, axis=0)

    # Masked mean-pool over the true length.
    mask = (jnp.arange(tok.shape[1])[None, :] < lens[:, None]).astype(vecs.dtype)
    summed = jnp.einsum("bld,bl->bd", vecs, mask)
    denom = jnp.maximum(lens.astype(vecs.dtype), 1.0)[:, None]
    pooled = summed / denom  # [TB, D]

    # MXU matmul + bias.
    out_ref[...] = pooled @ w_ref[...] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("tile_b",))
def ner_scorer(tokens, lens, emb, w, b, *, tile_b: int = DEFAULT_TILE_B):
    """Score a padded batch of documents.

    Args:
      tokens: [B, MAX_LEN] int32 token ids (0-padded).
      lens:   [B] int32 true lengths.
      emb:    [VOCAB, EMBED_DIM] f32 embedding table.
      w:      [EMBED_DIM, N_CLASSES] f32 classifier.
      b:      [N_CLASSES] f32 bias.
    Returns:
      [B, N_CLASSES] f32 logits.
    """
    bsz, seq = tokens.shape
    if bsz % tile_b != 0:
        raise ValueError(f"batch {bsz} not divisible by tile {tile_b}")
    grid = (bsz // tile_b,)
    return pl.pallas_call(
        _scorer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, seq), lambda i: (i, 0)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            # embedding table + weights resident across grid steps
            pl.BlockSpec(emb.shape, lambda i: (0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b, w.shape[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, w.shape[1]), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(tokens, lens, emb, w, b)


def make_params(seed: int = 0, vocab: int = VOCAB, dim: int = EMBED_DIM,
                classes: int = N_CLASSES):
    """Deterministic model parameters shared by AOT lowering and tests."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    emb = jax.random.normal(k1, (vocab, dim), jnp.float32) * 0.1
    w = jax.random.normal(k2, (dim, classes), jnp.float32) * 0.3
    b = jax.random.normal(k3, (classes,), jnp.float32) * 0.01
    return emb, w, b


def vmem_estimate_bytes(tile_b: int = DEFAULT_TILE_B, seq: int = MAX_LEN,
                        vocab: int = VOCAB, dim: int = EMBED_DIM,
                        classes: int = N_CLASSES) -> int:
    """Static VMEM footprint of one grid step (perf model for DESIGN.md
    §Perf — interpret mode gives no real TPU timings)."""
    f32 = 4
    tok = tile_b * seq * 4
    emb = vocab * dim * f32
    gathered = tile_b * seq * dim * f32
    pooled = tile_b * dim * f32
    wgt = dim * classes * f32 + classes * f32
    out = tile_b * classes * f32
    return tok + emb + gathered + pooled + wgt + out
