"""AOT lowering: jax → HLO *text* → artifacts/ for the rust PJRT runtime.

HLO text (not `.serialize()`d protos) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/ and
DESIGN.md.

Usage:  python -m compile.aot --out-dir ../artifacts
Writes one `<name>.hlo.txt` per model variant plus `manifest.tsv`
(name, input shapes/dtypes, output arity) that the rust runtime loads.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import export_params, model_variants


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def export_check_fixture(out_dir: str) -> None:
    """Cross-language numeric fixture: a deterministic ner_b32 input batch
    and its eager-model outputs. rust/tests/runtime_roundtrip.rs loads the
    AOT artifact, runs the same batch through PJRT, and asserts allclose —
    the end-to-end L1/L2/L3 numerics contract."""
    import numpy as np

    from .kernels import ner_scorer as k
    from .model import ner_window_model

    rng = np.random.default_rng(1234)
    tokens = rng.integers(0, k.VOCAB, size=(32, k.MAX_LEN), dtype=np.int32)
    lens = rng.integers(1, k.MAX_LEN + 1, size=(32,), dtype=np.int32)
    for i, l in enumerate(lens):
        tokens[i, l:] = 0
    emb, w, b = k.make_params(seed=0)
    logits, pred, hist = ner_window_model(tokens, lens, emb, w, b)

    np.asarray(tokens, dtype="<i4").tofile(os.path.join(out_dir, "check_tokens.bin"))
    np.asarray(lens, dtype="<i4").tofile(os.path.join(out_dir, "check_lens.bin"))
    np.asarray(logits, dtype="<f4").tofile(os.path.join(out_dir, "check_logits.bin"))
    np.asarray(pred, dtype="<i4").tofile(os.path.join(out_dir, "check_pred.bin"))
    np.asarray(hist, dtype="<f4").tofile(os.path.join(out_dir, "check_hist.bin"))
    print(f"wrote {out_dir}/check_*.bin (ner_b32 numerics fixture)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    for name, fn, example_args in model_variants():
        text = to_hlo_text(fn, example_args)
        if "constant({...})" in text:
            raise RuntimeError(
                f"{name}: HLO text contains elided large constants; "
                "large arrays must be runtime parameters (see model.py)"
            )
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_outputs = len(jax.eval_shape(fn, *example_args))
        inputs = ";".join(spec_str(s) for s in example_args)
        manifest_rows.append(f"{name}\t{inputs}\t{n_outputs}")
        print(f"wrote {path} ({len(text)} chars, {n_outputs} outputs)")

    for name, path in export_params(args.out_dir).items():
        print(f"wrote {path}")

    export_check_fixture(args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tinputs\tn_outputs\n")
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {args.out_dir}/manifest.tsv ({len(manifest_rows)} variants)")


if __name__ == "__main__":
    main()
