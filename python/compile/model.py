"""L2: the jax compute graph the rust coordinator executes per reducer
micro-batch — the §6 NER streaming application's UDF.

`ner_window_model` is what gets AOT-lowered: score a padded batch of
documents with the L1 Pallas kernel, reduce to per-document entity
predictions plus a per-class histogram of the window. The entity histogram
is what the §6 application aggregates per host over 60-minute windows
("calculate frequent mentions of the recognized entities").

Model parameters are runtime *parameters* of the artifact, with their
values exported once to `artifacts/ner_{emb,w,b}.bin` (f32 row-major).
They cannot be baked in as constants: the stablehlo→HLO-text conversion
elides large dense literals as `constant({...})`, which would silently
corrupt the program. The rust runtime loads the .bin files at startup and
passes them as the trailing execute() arguments — python stays off the
request path entirely.
"""

import jax
import jax.numpy as jnp

from .kernels import cms as cms_kernel
from .kernels import ner_scorer as k


def ner_window_model(tokens, lens, emb, w, b):
    """Score a document batch and summarize the window.

    Returns a 3-tuple (lowered with return_tuple=True):
      logits:      [B, C] f32 raw scores,
      pred:        [B] i32 argmax class per document,
      class_hist:  [C] f32 entity-class histogram over the *valid* docs
                   (len > 0), weighted by document length — the "frequent
                   mentions" statistic of §6.
    """
    logits = k.ner_scorer(tokens, lens, emb, w, b)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    valid = (lens > 0).astype(jnp.float32)
    weight = valid * lens.astype(jnp.float32)
    onehot = jax.nn.one_hot(pred, logits.shape[1], dtype=jnp.float32)
    class_hist = (onehot * weight[:, None]).sum(axis=0)
    return logits, pred, class_hist


def cms_tap_model(keys, weights):
    """The accelerator-side DR tap (see kernels/cms.py): one CMS increment
    per micro-batch. Returns a 1-tuple for uniform artifact handling."""
    return (cms_kernel.cms_update(keys, weights),)


def model_variants():
    """The artifact set: (name, fn, example_args) per compiled variant.

    One executable per batch size, mirroring how serving systems compile a
    small ladder of static shapes and bucket requests into them. NER
    variants take (tokens, lens, emb, w, b); the parameter values live in
    `artifacts/ner_*.bin` (see `export_params`).
    """
    variants = []
    emb_s = jax.ShapeDtypeStruct((k.VOCAB, k.EMBED_DIM), jnp.float32)
    w_s = jax.ShapeDtypeStruct((k.EMBED_DIM, k.N_CLASSES), jnp.float32)
    b_s = jax.ShapeDtypeStruct((k.N_CLASSES,), jnp.float32)
    for bsz in (32, 128, 512):
        tokens = jax.ShapeDtypeStruct((bsz, k.MAX_LEN), jnp.int32)
        lens = jax.ShapeDtypeStruct((bsz,), jnp.int32)
        variants.append(
            (f"ner_b{bsz}", ner_window_model, (tokens, lens, emb_s, w_s, b_s))
        )

    for n in (4096,):
        keys = jax.ShapeDtypeStruct((n,), jnp.uint32)
        weights = jax.ShapeDtypeStruct((n,), jnp.float32)
        variants.append((f"cms_n{n}", cms_tap_model, (keys, weights)))
    return variants


def export_params(out_dir: str, seed: int = 0):
    """Write the NER parameter values as raw little-endian f32 files."""
    import os

    import numpy as np

    emb, w, b = k.make_params(seed=seed)
    paths = {}
    for name, arr in (("ner_emb", emb), ("ner_w", w), ("ner_b", b)):
        path = os.path.join(out_dir, f"{name}.bin")
        np.asarray(arr, dtype="<f4").tofile(path)
        paths[name] = path
    return paths
