"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value ranges; assert_allclose against the
reference implementation is the core build-time correctness signal for
everything the rust runtime will execute.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import cms, ref
from compile.kernels import ner_scorer as k

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def make_batch(rng, bsz, seq=k.MAX_LEN, vocab=k.VOCAB):
    tokens = rng.integers(0, vocab, size=(bsz, seq), dtype=np.int32)
    lens = rng.integers(1, seq + 1, size=(bsz,), dtype=np.int32)
    # zero out padding like the rust batcher does
    for i, l in enumerate(lens):
        tokens[i, l:] = 0
    return jnp.asarray(tokens), jnp.asarray(lens)


class TestNerScorer:
    @given(bsz_tiles=st.integers(1, 4), seed=st.integers(0, 2**16))
    def test_matches_reference(self, bsz_tiles, seed):
        rng = np.random.default_rng(seed)
        bsz = bsz_tiles * k.DEFAULT_TILE_B
        tokens, lens = make_batch(rng, bsz)
        emb, w, b = k.make_params(seed=0)
        got = k.ner_scorer(tokens, lens, emb, w, b)
        want = ref.ner_scorer_ref(tokens, lens, emb, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(tile=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 100))
    def test_tile_size_invariance(self, tile, seed):
        rng = np.random.default_rng(seed)
        bsz = 64
        tokens, lens = make_batch(rng, bsz)
        emb, w, b = k.make_params(seed=1)
        got = k.ner_scorer(tokens, lens, emb, w, b, tile_b=tile)
        want = ref.ner_scorer_ref(tokens, lens, emb, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_padding_is_ignored(self):
        rng = np.random.default_rng(7)
        tokens, lens = make_batch(rng, 32)
        emb, w, b = k.make_params(seed=0)
        base = k.ner_scorer(tokens, lens, emb, w, b)
        # scribble on the padded region — logits must not change
        scribbled = np.array(tokens)
        for i, l in enumerate(np.array(lens)):
            scribbled[i, l:] = 1234
        got = k.ner_scorer(jnp.asarray(scribbled), lens, emb, w, b)
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)

    def test_zero_length_rows_are_safe(self):
        emb, w, b = k.make_params(seed=0)
        tokens = jnp.zeros((32, k.MAX_LEN), jnp.int32)
        lens = jnp.zeros((32,), jnp.int32)
        out = k.ner_scorer(tokens, lens, emb, w, b)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_batch_not_divisible_raises(self):
        emb, w, b = k.make_params(seed=0)
        tokens = jnp.zeros((33, k.MAX_LEN), jnp.int32)
        lens = jnp.ones((33,), jnp.int32)
        with pytest.raises(ValueError):
            k.ner_scorer(tokens, lens, emb, w, b)

    def test_length_sensitivity(self):
        # same tokens, different lengths → different pooling → different logits
        rng = np.random.default_rng(11)
        tokens = jnp.asarray(rng.integers(1, k.VOCAB, (32, k.MAX_LEN), dtype=np.int32))
        emb, w, b = k.make_params(seed=0)
        short = k.ner_scorer(tokens, jnp.full((32,), 4, jnp.int32), emb, w, b)
        long = k.ner_scorer(tokens, jnp.full((32,), k.MAX_LEN, jnp.int32), emb, w, b)
        assert not np.allclose(short, long)

    def test_vmem_estimate_within_tpu_budget(self):
        # one grid step must fit a 16 MiB VMEM comfortably (≤ 8 MiB here)
        assert k.vmem_estimate_bytes() <= 8 * 1024 * 1024


class TestCms:
    @given(n_pow=st.integers(6, 12), seed=st.integers(0, 2**16))
    def test_matches_reference(self, n_pow, seed):
        rng = np.random.default_rng(seed)
        n = 2**n_pow
        keys = jnp.asarray(rng.integers(0, 2**32, size=(n,), dtype=np.uint32))
        weights = jnp.asarray(rng.random(n, dtype=np.float32))
        got = cms.cms_update(keys, weights)
        want = ref.cms_update_ref(keys, weights)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_row_sums_equal_total_weight(self):
        rng = np.random.default_rng(3)
        keys = jnp.asarray(rng.integers(0, 2**32, size=(512,), dtype=np.uint32))
        weights = jnp.ones((512,), jnp.float32)
        sketch = cms.cms_update(keys, weights)
        np.testing.assert_allclose(np.array(sketch).sum(axis=1), 512.0, rtol=1e-5)

    def test_query_never_underestimates(self):
        rng = np.random.default_rng(4)
        keys_np = rng.integers(0, 2**32, size=(2048,), dtype=np.uint32)
        keys = jnp.asarray(keys_np)
        weights = jnp.ones((2048,), jnp.float32)
        sketch = cms.cms_update(keys, weights)
        uniq, counts = np.unique(keys_np, return_counts=True)
        est = np.array(cms.cms_query(jnp.asarray(sketch), jnp.asarray(uniq)))
        assert (est + 1e-5 >= counts).all()

    def test_heavy_key_estimated_accurately(self):
        keys_np = np.concatenate(
            [np.full(5000, 42, dtype=np.uint32),
             np.random.default_rng(5).integers(0, 2**32, 3192, dtype=np.uint32)]
        )
        sketch = cms.cms_update(jnp.asarray(keys_np), jnp.ones((8192,), jnp.float32))
        est = float(cms.cms_query(jnp.asarray(sketch), jnp.asarray([42], dtype=np.uint32))[0])
        # CMS error bound: e·N/W ≈ 2.7·8192/1024 ≈ 22
        assert 5000 <= est <= 5000 + 50
