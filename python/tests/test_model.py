"""L2 correctness: model composition + AOT lowering round-trip."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ner_scorer as k


def small_batch(bsz=32, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, k.VOCAB, size=(bsz, k.MAX_LEN), dtype=np.int32)
    lens = rng.integers(1, k.MAX_LEN + 1, size=(bsz,), dtype=np.int32)
    for i, l in enumerate(lens):
        tokens[i, l:] = 0
    return jnp.asarray(tokens), jnp.asarray(lens)


class TestNerWindowModel:
    def test_output_shapes(self):
        tokens, lens = small_batch()
        emb, w, b = k.make_params(0)
        logits, pred, hist = model.ner_window_model(tokens, lens, emb, w, b)
        assert logits.shape == (32, k.N_CLASSES)
        assert pred.shape == (32,)
        assert hist.shape == (k.N_CLASSES,)

    def test_pred_is_argmax(self):
        tokens, lens = small_batch(seed=1)
        emb, w, b = k.make_params(0)
        logits, pred, _ = model.ner_window_model(tokens, lens, emb, w, b)
        np.testing.assert_array_equal(np.array(pred), np.argmax(np.array(logits), axis=1))

    def test_hist_weighted_by_length(self):
        tokens, lens = small_batch(seed=2)
        emb, w, b = k.make_params(0)
        _, pred, hist = model.ner_window_model(tokens, lens, emb, w, b)
        manual = np.zeros(k.N_CLASSES, np.float32)
        for p, l in zip(np.array(pred), np.array(lens)):
            if l > 0:
                manual[p] += float(l)
        np.testing.assert_allclose(np.array(hist), manual, rtol=1e-5)

    def test_zero_length_docs_excluded_from_hist(self):
        tokens, lens = small_batch(seed=3)
        lens = lens.at[:16].set(0)
        emb, w, b = k.make_params(0)
        _, _, hist = model.ner_window_model(tokens, lens, emb, w, b)
        total = float(hist.sum())
        assert total == float(np.array(lens)[16:].sum())


class TestAot:
    def test_variants_cover_batch_ladder(self):
        names = [v[0] for v in model.model_variants()]
        assert names == ["ner_b32", "ner_b128", "ner_b512", "cms_n4096"]

    def test_hlo_text_roundtrips(self, tmp_path):
        name, fn, args = model.model_variants()[0]
        text = aot.to_hlo_text(fn, args)
        assert "HloModule" in text
        # must be parseable back by the same xla_client
        from jax._src.lib import xla_client as xc

        # basic sanity: entry computation mentions our parameter arity
        assert text.count("parameter(0)") >= 1
        assert text.count("parameter(1)") >= 1
        del xc

    def test_lowered_model_matches_eager(self):
        # lowering must not change semantics: compile the HLO via jax and
        # compare against the eager model on the same inputs
        name, fn, args = model.model_variants()[0]
        tokens, lens = small_batch()
        emb, w, b = k.make_params(0)
        eager = fn(tokens, lens, emb, w, b)
        jitted = jax.jit(fn)(tokens, lens, emb, w, b)
        for a, b_ in zip(eager, jitted):
            np.testing.assert_allclose(np.array(a), np.array(b_), rtol=1e-5, atol=1e-5)

    def test_no_elided_constants_in_artifacts(self):
        # large dense literals must never be baked in: the HLO text
        # converter elides them as `constant({...})`
        for name, fn, args in model.model_variants():
            text = aot.to_hlo_text(fn, args)
            assert "constant({...})" not in text, name

    def test_exported_params_roundtrip(self, tmp_path):
        paths = model.export_params(str(tmp_path))
        emb, w, b = k.make_params(0)
        got = np.fromfile(paths["ner_emb"], dtype="<f4").reshape(emb.shape)
        np.testing.assert_allclose(got, np.array(emb), rtol=1e-7)
        got_b = np.fromfile(paths["ner_b"], dtype="<f4")
        np.testing.assert_allclose(got_b, np.array(b), rtol=1e-7)

    def test_spec_str(self):
        s = jax.ShapeDtypeStruct((32, 128), jnp.int32)
        assert aot.spec_str(s) == "int32[32,128]"
