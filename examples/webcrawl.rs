//! The §6 web-crawl use case: 7 crawl rounds, fetch lists partitioned by
//! host, DR rebalancing per round (Fig 7 + Fig 8 left).
//!
//!     cargo run --release --example webcrawl

use dynrepart::figures::{fig7, fig8};

fn main() {
    let scale = std::env::var("CRAWL_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);

    println!("crawling 7 rounds at scale {scale} (64 seed news sites, depth 1)...\n");
    let rounds = fig7::run_crawl(scale, fig7::EXECUTORS * fig7::CORES, 99);
    println!("{:>5} {:>10} {:>12} {:>12} {:>9}", "round", "pages", "DR [s]", "hash [s]", "speedup");
    for (i, (with, without)) in rounds.iter().enumerate() {
        println!(
            "{:>5} {:>10} {:>12.2} {:>12.2} {:>8.2}x",
            i + 1,
            with.record_counts.iter().sum::<u64>(),
            with.makespan,
            without.makespan,
            without.makespan / with.makespan,
        );
    }
    let (with, without) = &rounds[6];
    println!(
        "\nround 7: record imbalance {:.2} (DR) vs {:.2} (hash); replayed {} records for the repartitioning",
        with.imbalance, without.imbalance, with.replayed_records,
    );
    let _ = fig8::left(scale); // exercises the Fig 8 (left) path too

    // The pipelined round sequence: the crawl is itself a Source, so with
    // DYNREPART_THREADS > 1 round k+1's frontier expansion runs while
    // round k's shuffle stage executes (watch source_wall_s disappear
    // into the stage's shadow as pipeline_occupancy exceeds 1).
    println!("\npipelined DR rounds over a CrawlSource (threads from DYNREPART_THREADS):");
    let job = dynrepart::ddps::BatchJob::new(
        fig7::engine_config(fig7::EXECUTORS * fig7::CORES),
        dynrepart::dr::DrConfig {
            counter_capacity_factor: 16,
            lambda: 4,
            ..Default::default()
        },
        dynrepart::dr::PartitionerChoice::Kip,
        99,
    );
    let mut source = dynrepart::workload::webcrawl::Crawl::with_defaults(99).into_source();
    for (i, r) in job.run_stream(&mut source, 0, 7).iter().enumerate() {
        println!(
            "  round {}: {:>10.2} virtual s  source {:>6.1} ms  occupancy {:.2}",
            i + 1,
            r.makespan,
            r.source_wall_s * 1e3,
            r.pipeline_occupancy,
        );
    }
}
