//! END-TO-END driver (the §6 NER streaming application, Fig 8 right):
//! exercises all three layers on a real small workload —
//!
//!   L1/L2  the Pallas NER scorer, AOT-compiled to artifacts/, executed
//!          through PJRT for every document batch (real compute, no stubs);
//!   L3     the micro-batch engine partitioned by host with Dynamic
//!          Repartitioning, windowed entity aggregation as reducer state.
//!
//! Requires `make artifacts`. Reports per-batch latency, throughput, the
//! DR speedup, and sample "frequent mentions" output. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example ner_streaming

use dynrepart::ddps::{EngineConfig, MicroBatchEngine};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::figures::fig8;
use dynrepart::ner::EntityWindows;
use dynrepart::runtime::{Artifacts, Error, NerExecutable, Result, Runtime};
use dynrepart::workload::ner::{Doc, NerGen};
use dynrepart::workload::webcrawl::Crawl;
use std::time::Instant;

fn main() -> Result<()> {
    // ---- L1/L2: load the AOT artifacts --------------------------------
    let arts = Artifacts::open_default()
        .map_err(|e| Error::msg(format!("{e}\nrun `make artifacts` first")))?;
    let rt = Runtime::cpu()?;
    let exe = NerExecutable::load(&rt, &arts, 128)?;
    println!("PJRT platform: {}; loaded ner_b128 artifact", rt.platform());

    // calibrate the engine's virtual-time cost from real kernel timings
    let per_doc = exe.calibrate_per_doc_cost(3)?;
    println!("calibrated scorer cost: {:.2} ms/doc\n", per_doc * 1e3);

    // ---- workload: crawl-round-7 host mix, heavy-tailed ----------------
    let n_docs = 4096;
    let mut crawl = Crawl::with_defaults(99);
    let lists = crawl.run();
    let mut freqs: Vec<(u64, f64)> = Crawl::host_freqs(&lists[6]).into_iter().collect();
    freqs.sort_unstable_by_key(|e| e.0);
    let mut gen = NerGen::new(&freqs, 99);
    let docs: Vec<Doc> = gen.docs(n_docs);

    // ---- L3: stream through the engine, scoring every batch on PJRT ---
    let cfg = EngineConfig {
        n_partitions: fig8::NER_EXECUTORS * fig8::NER_CORES,
        n_slots: fig8::NER_EXECUTORS * fig8::NER_CORES,
        reduce_cost: per_doc / dynrepart::workload::ner::MAX_LEN as f64,
        task_overhead: 5e-3,
        ..Default::default()
    };
    let mut windows = EntityWindows::new(3600);
    let mut engine = MicroBatchEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 99);

    let wall = Instant::now();
    let mut scored = 0usize;
    for (batch_no, chunk) in docs.chunks(512).enumerate() {
        let records: Vec<_> = chunk.iter().map(|d| d.to_record()).collect();
        let report = engine.run_batch(&records);

        // real compute: score the batch through the AOT executable
        let t = Instant::now();
        for sub in chunk.chunks(128) {
            let refs: Vec<&Doc> = sub.iter().collect();
            let out = exe.execute_docs(&refs)?;
            scored += sub.len();
            for (doc, _pred) in sub.iter().zip(&out.pred) {
                // fold per-host entity stats into the windowed reducer state
                let mut h = [0.0f32; dynrepart::ner::N_CLASSES];
                // batch-level hist attributed per doc weight share
                for (i, v) in out.class_hist.iter().enumerate() {
                    h[i] = v * (doc.weight() as f32
                        / sub.iter().map(|d| d.weight() as f32).sum::<f32>());
                }
                windows.fold_batch(doc.host, doc.ts, &h);
            }
        }
        println!(
            "batch {batch_no}: {} docs, pjrt {:.0} ms, vtime {:.3}s, imbalance {:.2} {}",
            chunk.len(),
            t.elapsed().as_secs_f64() * 1e3,
            report.makespan,
            report.imbalance,
            if report.repartitioned { "(repartitioned)" } else { "" },
        );
    }
    let elapsed = wall.elapsed().as_secs_f64();
    println!(
        "\nscored {scored} docs in {elapsed:.2}s wall ({:.0} docs/s through PJRT)",
        scored as f64 / elapsed
    );
    println!("hosts with state: {}", windows.n_hosts());
    let top_host = freqs.iter().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
    println!(
        "frequent mentions on the heaviest host: {:?}",
        windows.frequent_mentions(top_host, 1, 3)
    );

    // ---- headline: DR vs hash on this workload -------------------------
    let (t_dr, t_hash, speedup) =
        fig8::ner_batch_speedup(1.0, (per_doc / 128.0).max(1e-5));
    println!("\nNER job virtual time: DR {t_dr:.2}s vs hash {t_hash:.2}s => speedup {speedup:.2}x");
    Ok(())
}
