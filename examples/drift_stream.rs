//! Concept drift on a long-running *stateful* streaming job — the case the
//! paper argues no prior system handles (§1): the heavy-key set changes
//! over time and the partitioner must follow it, migrating operator state
//! at checkpoint barriers.
//!
//!     cargo run --release --example drift_stream

use dynrepart::ddps::{EngineConfig, StreamingEngine};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::workload::lfm::{Lfm, LfmConfig};

fn main() {
    let cfg = EngineConfig {
        n_partitions: 20,
        n_slots: 20,
        task_overhead: 0.0,
        ..Default::default()
    };
    let lfm_cfg = LfmConfig {
        head_replace_prob: 0.3, // aggressive drift: heavy tags churn fast
        ..Default::default()
    };

    for (label, dr, choice) in [
        ("hash ", DrConfig::disabled(), PartitionerChoice::Uhp),
        ("DR   ", DrConfig::default(), PartitionerChoice::Kip),
    ] {
        let mut engine = StreamingEngine::new(cfg, dr, choice, 7);
        // the engine pulls intervals from the drifting source itself
        // (unified pipelined loop; drift happens at each batch boundary)
        let mut source = Lfm::new(lfm_cfg.clone(), 7).drifting();
        println!("== {label} ==");
        for report in engine.run_stream(&mut source, 100_000, 15) {
            println!(
                "  interval {:>2}: {:>9.0} rec/s  imbalance {:.2}  migrated {:>5.2}%  {}",
                report.interval_no - 1,
                report.throughput,
                report.imbalance,
                report.migrated_fraction * 100.0,
                if report.repartitioned {
                    "barrier: new partitioner + state migration"
                } else {
                    ""
                },
            );
        }
        let m = engine.metrics();
        println!(
            "  => {:.0} rec/s overall, {} repartitionings, {:.1}% of vtime spent migrating\n",
            m.throughput(),
            m.repartition_count,
            100.0 * m.migration_vtime / m.total_vtime,
        );
    }
}
