//! Quickstart: run a skewed micro-batch stream with and without Dynamic
//! Repartitioning and print the speedup — the paper's headline effect in
//! ~30 lines.
//!
//!     cargo run --release --example quickstart

use dynrepart::ddps::{EngineConfig, MicroBatchEngine};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::workload::zipf::Zipf;

fn main() {
    let cfg = EngineConfig {
        n_partitions: 35,
        n_slots: 40,
        // DYNREPART_THREADS > 1 shards the executor AND pipelines the
        // drive loop (source ∥ decision point ∥ stage)
        ..EngineConfig::from_env()
    };

    let run = |with_dr: bool| {
        let (dr, choice) = if with_dr {
            (DrConfig::default(), PartitionerChoice::Kip)
        } else {
            (DrConfig::disabled(), PartitionerChoice::Uhp)
        };
        let mut engine = MicroBatchEngine::new(cfg, dr, choice, 42);
        let mut zipf = Zipf::new(100_000, 1.0, 42);
        // the engine pulls micro-batches from the source itself: the
        // unified pipelined drive loop
        for report in engine.run_stream(&mut zipf, 100_000, 10) {
            println!(
                "  [{}] batch {}: {:.3}s  imbalance {:.2}  {}",
                if with_dr { "DR  " } else { "hash" },
                report.batch_no,
                report.makespan,
                report.imbalance,
                if report.repartitioned { "(repartitioned)" } else { "" },
            );
        }
        engine.metrics().total_vtime
    };

    println!("== plain hash partitioning ==");
    let t_hash = run(false);
    println!("== with Dynamic Repartitioning (KIP) ==");
    let t_dr = run(true);
    println!("\ntotal: hash {t_hash:.3}s  DR {t_dr:.3}s  speedup {:.2}x", t_hash / t_dr);
}
