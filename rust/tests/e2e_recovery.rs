//! Crash / restore / replay end-to-end: a streaming run that fails
//! mid-stream and resumes from a recovery point must reproduce the
//! uninterrupted run **bitwise** — reports, epochs, virtual time,
//! checkpoint ids and the full StateStore contents (key order included) —
//! at every thread count. This is the contract the scenario harness's
//! `fail-restore` event verifies on every run; here it is pinned
//! directly, sequential and sharded, and across the two.

use dynrepart::ddps::{EngineConfig, IntervalReport, StreamingEngine};
use dynrepart::dr::{DeciderConfig, DeciderPolicy, DrConfig, PartitionerChoice};
use dynrepart::state::StateStore;
use dynrepart::workload::{zipf::Zipf, Generator, Record, ReplaySource};

fn cfg(num_threads: usize) -> EngineConfig {
    EngineConfig {
        n_partitions: 6,
        n_slots: 6,
        num_threads,
        ..Default::default()
    }
}

fn engine(num_threads: usize) -> StreamingEngine {
    StreamingEngine::new(cfg(num_threads), DrConfig::forced(), PartitionerChoice::Kip, 0xE2E)
}

fn batches(n: usize, per_batch: usize) -> Vec<Vec<Record>> {
    let mut z = Zipf::new(6_000, 1.25, 0xE2E);
    (0..n).map(|_| z.batch(per_batch)).collect()
}

#[track_caller]
fn assert_reports_bitwise(a: &IntervalReport, b: &IntervalReport) {
    assert_eq!(a.interval_no, b.interval_no);
    assert_eq!(a.epoch, b.epoch, "interval {}", a.interval_no);
    assert_eq!(a.repartitioned, b.repartitioned, "interval {}", a.interval_no);
    assert_eq!(
        a.decisions_adopted, b.decisions_adopted,
        "interval {}: adopt tally diverged",
        a.interval_no
    );
    assert_eq!(
        a.decisions_deferred, b.decisions_deferred,
        "interval {}: defer tally diverged",
        a.interval_no
    );
    for (what, x, y) in [
        ("elapsed", a.elapsed, b.elapsed),
        ("throughput", a.throughput, b.throughput),
        ("imbalance", a.imbalance, b.imbalance),
        ("migrated_fraction", a.migrated_fraction, b.migrated_fraction),
        ("migration_pause", a.migration_pause, b.migration_pause),
        ("bottleneck_ratio", a.bottleneck_ratio, b.bottleneck_ratio),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "interval {}: {what} diverged ({x} vs {y})",
            a.interval_no
        );
    }
}

/// Full bitwise state comparison: per partition, the same keys in the
/// same insertion order with identical records/weight/values.
#[track_caller]
fn assert_stores_bitwise(a: &[StateStore], b: &[StateStore]) {
    assert_eq!(a.len(), b.len(), "partition count");
    for (p, (sa, sb)) in a.iter().zip(b).enumerate() {
        assert_eq!(sa.n_keys(), sb.n_keys(), "partition {p} key count");
        assert_eq!(
            sa.total_weight().to_bits(),
            sb.total_weight().to_bits(),
            "partition {p} total weight"
        );
        for ((ka, va), (kb, vb)) in sa.iter().zip(sb.iter()) {
            assert_eq!(ka, kb, "partition {p}: key iteration order diverged");
            assert_eq!(va.records, vb.records, "partition {p} key {ka}");
            assert_eq!(
                va.weight.to_bits(),
                vb.weight.to_bits(),
                "partition {p} key {ka} weight"
            );
            let (xs, ys) = (va.values.as_slice(), vb.values.as_slice());
            assert_eq!(xs.len(), ys.len(), "partition {p} key {ka} value arity");
            for (x, y) in xs.iter().zip(ys) {
                assert_eq!(x.to_bits(), y.to_bits(), "partition {p} key {ka} value");
            }
        }
    }
}

/// The roundtrip: run 4 intervals, snapshot, lose an interval to the
/// crash, restore, replay the remaining 6 — and end bitwise-identical to
/// the run that never failed.
fn crash_restore_roundtrip(num_threads: usize) -> (StreamingEngine, Vec<IntervalReport>) {
    let all = batches(10, 12_000);

    let mut gold = engine(num_threads);
    let gold_reports =
        gold.run_stream(&mut ReplaySource::new(all.clone()), 12_000, all.len());
    assert_eq!(gold_reports.len(), 10);
    assert!(gold.epoch() >= 9, "forced DR must bump the epoch per barrier");

    let mut live = engine(num_threads);
    live.run_stream(&mut ReplaySource::new(all[..4].to_vec()), 12_000, 4);
    let point = live.recovery_point();
    assert_eq!(point.interval_no(), 4);
    // progress lost in the crash: one more interval runs, then the node dies
    live.run_stream(&mut ReplaySource::new(all[4..5].to_vec()), 12_000, 1);
    drop(live);

    let mut resumed = StreamingEngine::restore(&point);
    assert_eq!(resumed.vtime().to_bits(), point.vtime().to_bits());
    let resumed_reports =
        resumed.run_stream(&mut ReplaySource::new(all[4..].to_vec()), 12_000, 6);
    assert_eq!(resumed_reports.len(), 6);

    for (g, r) in gold_reports[4..].iter().zip(&resumed_reports) {
        assert_reports_bitwise(g, r);
    }
    assert_eq!(gold.epoch(), resumed.epoch());
    assert_eq!(gold.vtime().to_bits(), resumed.vtime().to_bits());
    assert_stores_bitwise(gold.stores(), resumed.stores());
    let (cg, cr) = (
        gold.checkpoints().latest().unwrap(),
        resumed.checkpoints().latest().unwrap(),
    );
    assert_eq!(cg.id, cr.id, "checkpoint numbering must resume seamlessly");
    assert_eq!(
        cg.total_state_weight().to_bits(),
        cr.total_state_weight().to_bits()
    );
    (gold, gold_reports)
}

#[test]
fn crash_restore_replay_reproduces_sequential() {
    crash_restore_roundtrip(1);
}

#[test]
fn crash_restore_replay_reproduces_sharded() {
    crash_restore_roundtrip(4);
}

#[test]
fn crash_restore_replay_reproduces_wide_pool() {
    // a pool wider than the typical core count: the crash drops an
    // engine mid-run while the process-wide pool lives on, and the
    // restored engine reuses the same parked workers bitwise
    crash_restore_roundtrip(8);
}

#[test]
fn recovery_is_thread_count_invariant() {
    // the whole crash/restore/replay story lands on identical bits
    // whether the executor is sequential or sharded
    let (e1, r1) = crash_restore_roundtrip(1);
    let (e4, r4) = crash_restore_roundtrip(4);
    for (a, b) in r1.iter().zip(&r4) {
        assert_reports_bitwise(a, b);
    }
    assert_eq!(e1.epoch(), e4.epoch());
    assert_eq!(e1.vtime().to_bits(), e4.vtime().to_bits());
    assert_stores_bitwise(e1.stores(), e4.stores());
}

/// A recovery point taken *inside* a CostModel cooldown must carry the
/// whole decider — EWMA drift history, remaining backoff barriers and
/// the adopt/defer tallies — so the restored run resumes the gate
/// bitwise and reproduces the uninterrupted run's verdict sequence.
#[test]
fn restore_mid_cooldown_resumes_the_decider_bitwise() {
    let all = batches(10, 12_000);
    let dr = DrConfig {
        decider: DeciderConfig {
            policy: DeciderPolicy::CostModel,
            // Always "drifted" and an enormous horizon: only the backoff
            // cooldown restrains the forced DRM, so cooldowns recur.
            drift_boundary: -1.0,
            backoff_factor: 3,
            horizon: 1e9,
            ..Default::default()
        },
        ..DrConfig::forced()
    };
    let mk = || StreamingEngine::new(cfg(1), dr, PartitionerChoice::Kip, 0xE2E);

    let mut gold = mk();
    let gold_reports: Vec<IntervalReport> =
        all.iter().map(|b| gold.run_interval(b)).collect();
    assert!(
        gold.decider().adopted() >= 2,
        "the gold run must adopt more than once (got {})",
        gold.decider().adopted()
    );

    // Drive interval by interval until the snapshot lands mid-cooldown —
    // robust to exactly which barrier the first adoption happens at.
    let mut live = mk();
    let mut cut = 0usize;
    for (i, b) in all.iter().enumerate() {
        live.run_interval(b);
        if live.decider().cooldown() > 0 && i + 1 < all.len() {
            cut = i + 1;
            break;
        }
    }
    assert!(cut > 0, "never entered a cooldown mid-stream");
    let point = live.recovery_point();
    let at_snapshot = *live.decider();
    assert!(at_snapshot.cooldown() > 0, "snapshot must be mid-cooldown");
    // progress lost in the crash: one more interval runs, then the node dies
    live.run_interval(&all[cut]);
    assert_ne!(live.decider().cooldown(), at_snapshot.cooldown());
    drop(live);

    let mut resumed = StreamingEngine::restore(&point);
    let d = resumed.decider();
    assert_eq!(d.policy(), DeciderPolicy::CostModel);
    assert_eq!(d.adopted(), at_snapshot.adopted(), "adopt tally lost in restore");
    assert_eq!(d.deferred(), at_snapshot.deferred(), "defer tally lost in restore");
    assert_eq!(d.cooldown(), at_snapshot.cooldown(), "backoff counter lost in restore");
    assert_eq!(
        d.ewma().map(f64::to_bits),
        at_snapshot.ewma().map(f64::to_bits),
        "EWMA drift history lost in restore"
    );

    // The replayed continuation reproduces the uninterrupted run bitwise,
    // verdicts included.
    let resumed_reports: Vec<IntervalReport> =
        all[cut..].iter().map(|b| resumed.run_interval(b)).collect();
    for (g, r) in gold_reports[cut..].iter().zip(&resumed_reports) {
        assert_reports_bitwise(g, r);
    }
    assert_eq!(gold.epoch(), resumed.epoch());
    assert_eq!(gold.decider().adopted(), resumed.decider().adopted());
    assert_eq!(gold.decider().deferred(), resumed.decider().deferred());
    assert_eq!(gold.decider().cooldown(), resumed.decider().cooldown());
    assert_eq!(
        gold.decider().ewma().map(f64::to_bits),
        resumed.decider().ewma().map(f64::to_bits)
    );
    assert_stores_bitwise(gold.stores(), resumed.stores());
}

#[test]
fn restore_discards_post_snapshot_progress() {
    // restoring must rewind: the restored engine re-runs interval 5 and
    // gets the same answer the gold run got, even though the crashed
    // engine had already processed a *different* continuation
    let all = batches(6, 8_000);
    let mut live = engine(1);
    live.run_stream(&mut ReplaySource::new(all[..3].to_vec()), 8_000, 3);
    let point = live.recovery_point();
    let w_at_snapshot = point.total_state_weight();
    // the doomed continuation processes different data (simulates
    // in-flight work that must not leak into the restored run)
    let mut doomed = Zipf::new(500, 0.5, 99);
    live.run_stream(&mut doomed, 8_000, 2);
    assert!(live.total_state_weight() > w_at_snapshot);
    drop(live);

    let resumed = StreamingEngine::restore(&point);
    assert_eq!(resumed.interval_no(), 3);
    assert_eq!(
        resumed.total_state_weight().to_bits(),
        w_at_snapshot.to_bits(),
        "no post-snapshot state may survive the restore"
    );
}
