//! Integration tests across modules: engines × DR × state × workloads,
//! plus failure injection (garbage histograms must never corrupt routing).

use dynrepart::ddps::{BatchJob, EngineConfig, MicroBatchEngine, StreamingEngine};
use dynrepart::dr::{DrConfig, DrMaster, PartitionerChoice};
use dynrepart::partitioner::GedikStrategy;
use dynrepart::sketch::Histogram;
use dynrepart::workload::{lfm::Lfm, zipf::Zipf, Generator};

// `num_threads` comes from DYNREPART_THREADS (default 1), so the CI matrix
// leg can run this whole suite against the sharded parallel executor —
// every assertion below must hold identically at any thread count.
fn cfg(n_partitions: usize, n_slots: usize) -> EngineConfig {
    EngineConfig {
        n_partitions,
        n_slots,
        ..EngineConfig::from_env()
    }
}

#[test]
fn microbatch_all_partitioner_families_run_end_to_end() {
    for choice in [
        PartitionerChoice::Kip,
        PartitionerChoice::Mixed,
        PartitionerChoice::Gedik(GedikStrategy::Scan),
        PartitionerChoice::Gedik(GedikStrategy::Readj),
        PartitionerChoice::Gedik(GedikStrategy::Redist),
        PartitionerChoice::Uhp,
    ] {
        let mut e = MicroBatchEngine::new(cfg(8, 8), DrConfig::forced(), choice, 3);
        let mut z = Zipf::new(10_000, 1.2, 3);
        let mut expected = 0.0;
        for _ in 0..4 {
            let b = z.batch(20_000);
            expected += b.iter().map(|r| r.weight).sum::<f64>();
            let r = e.run_batch(&b);
            assert!(r.makespan > 0.0);
            assert!((r.loads.iter().sum::<f64>() - 20_000.0).abs() < 1e-6);
        }
        assert!(
            (e.total_state_weight() - expected).abs() < 1e-6,
            "{:?}: state not conserved",
            choice.name()
        );
    }
}

#[test]
fn streaming_long_run_with_drift_stays_consistent() {
    let scfg = EngineConfig {
        n_partitions: 12,
        n_slots: 12,
        task_overhead: 0.0,
        ..EngineConfig::from_env()
    };
    let mut e = StreamingEngine::new(scfg, DrConfig::default(), PartitionerChoice::Kip, 5);
    let mut lfm = Lfm::with_defaults(5);
    let mut total = 0.0;
    for _ in 0..12 {
        let b = lfm.next_batch(30_000);
        total += b.iter().map(|r| r.weight).sum::<f64>();
        e.run_interval(&b);
    }
    assert!((e.total_state_weight() - total).abs() < 1e-6);
    assert!(e.metrics().repartition_count >= 1, "drift must trigger DR");
    // checkpoints retained and consistent
    let cp = e.checkpoints().latest().unwrap();
    assert_eq!(cp.id, 12);
    assert!((cp.total_state_weight() - total).abs() < 1e-6);
}

#[test]
fn batch_replay_beats_no_dr_on_skew_and_costs_show_up() {
    let mut z = Zipf::new(100_000, 1.0, 8);
    let recs = z.batch(300_000);
    let job = BatchJob::new(cfg(16, 16), DrConfig::default(), PartitionerChoice::Kip, 8);
    let (with, without) = job.compare(&recs);
    assert!(with.repartitioned && !without.repartitioned);
    assert!(with.replay_time > 0.0);
    assert!(with.makespan < without.makespan);
}

#[test]
fn failure_injection_garbage_histograms_never_break_routing() {
    // A DRM fed adversarial histograms (wrong mass, NaN-free but extreme)
    // must still emit total, in-range partitioners.
    let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 9);
    let cases = vec![
        Histogram::from_freqs(&[], 0.0),                         // empty
        Histogram::from_freqs(&[(1, 1.0)], 1.0),                 // one key = everything
        Histogram::from_freqs(&[(1, 0.9), (2, 0.9)], 1.0),       // mass > 1 (broken worker)
        Histogram::from_freqs(&(0..64u64).map(|k| (k, 1e-9)).collect::<Vec<_>>(), 1.0), // dust
    ];
    for hist in cases {
        let d = drm.decide(vec![hist]);
        let h = d.new_partitioner().unwrap_or_else(|| drm.handle());
        for k in 0..5_000u64 {
            assert!(h.partition(k) < 8, "routing broke on adversarial histogram");
        }
    }
}

#[test]
fn epochs_surface_in_every_engine_report() {
    // JobReport: the single mid-map decision is epoch 1.
    let mut z = Zipf::new(50_000, 1.2, 21);
    let recs = z.batch(100_000);
    let job = BatchJob::new(cfg(16, 16), DrConfig::forced(), PartitionerChoice::Kip, 21);
    let jr = job.run(&recs);
    assert!(jr.repartitioned);
    assert_eq!(jr.epoch, 1);

    // BatchReport: forced updates bump the epoch at every batch boundary.
    let mut mb = MicroBatchEngine::new(cfg(8, 8), DrConfig::forced(), PartitionerChoice::Kip, 22);
    let mut z2 = Zipf::new(20_000, 1.2, 22);
    let mut last = 0;
    for _ in 0..3 {
        let r = mb.run_batch(&z2.batch(20_000));
        assert_eq!(r.epoch, last + 1, "micro-batch epoch must be monotone");
        last = r.epoch;
    }

    // IntervalReport: barrier-aligned swaps, monotone across intervals.
    let scfg = EngineConfig {
        n_partitions: 8,
        n_slots: 8,
        task_overhead: 0.0,
        ..EngineConfig::from_env()
    };
    let mut st = StreamingEngine::new(scfg, DrConfig::forced(), PartitionerChoice::Kip, 23);
    let mut z3 = Zipf::new(20_000, 1.2, 23);
    let mut last = 0;
    for _ in 0..3 {
        let r = st.run_interval(&z3.batch(20_000));
        assert!(r.epoch > last, "streaming epoch must be monotone");
        last = r.epoch;
    }

    // Without DR nothing ever bumps.
    let mut off =
        MicroBatchEngine::new(cfg(8, 8), DrConfig::disabled(), PartitionerChoice::Uhp, 24);
    let mut z4 = Zipf::new(20_000, 1.2, 24);
    for _ in 0..3 {
        assert_eq!(off.run_batch(&z4.batch(20_000)).epoch, 0);
    }
}

#[test]
fn pipelined_stream_end_to_end_under_env_threads() {
    // The unified drive loop, end to end with `num_threads` from
    // DYNREPART_THREADS (the CI matrix runs this sharded): engines pull
    // from real sources, and the run must be indistinguishable — reports
    // and state — from the same engine fed pre-materialized batches.
    use dynrepart::workload::{Bounded, Source};

    // micro-batch over a bounded Zipf source
    let mut streamed =
        MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 31);
    let mut src = Bounded::new(Zipf::new(30_000, 1.2, 31), 100_000);
    let reports = streamed.run_stream(&mut src, 30_000, 100);
    assert_eq!(reports.len(), 4, "100k / 30k = 3 full + 1 partial batch");
    assert_eq!(streamed.metrics().records_processed, 100_000);

    let mut manual =
        MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 31);
    let mut buf = Vec::new();
    let mut bounded = Bounded::new(Zipf::new(30_000, 1.2, 31), 100_000);
    while bounded.next_batch_into(30_000, &mut buf) {
        manual.run_batch(&buf);
    }
    assert_eq!(
        manual.metrics().total_vtime.to_bits(),
        streamed.metrics().total_vtime.to_bits(),
        "pipelined vs manual drive diverged"
    );
    assert_eq!(
        manual.total_state_weight().to_bits(),
        streamed.total_state_weight().to_bits()
    );
    assert_eq!(manual.epoch(), streamed.epoch());
    // the pipelined drive consumed exactly 100k records from its source
    // (no over-pull by the prefetcher): the generator sits where a fresh
    // one lands after 100k draws
    let mut consumed = src.into_inner();
    assert_eq!(consumed.batch(10), {
        let mut z2 = Zipf::new(30_000, 1.2, 31);
        z2.batch(100_000);
        z2.batch(10)
    });

    // streaming over a drifting LFM source, with checkpoints
    let scfg = EngineConfig {
        n_partitions: 6,
        n_slots: 6,
        task_overhead: 0.0,
        ..EngineConfig::from_env()
    };
    let mut st = StreamingEngine::new(scfg, DrConfig::forced(), PartitionerChoice::Kip, 32);
    let mut lfm_src = Lfm::with_defaults(32).drifting();
    let intervals = st.run_stream(&mut lfm_src, 20_000, 5);
    assert_eq!(intervals.len(), 5);
    assert!(intervals.iter().all(|r| r.epoch > 0), "forced barrier swaps");
    assert_eq!(st.checkpoints().latest().unwrap().id, 5);
    assert!(st.metrics().pipeline_occupancy() > 0.0);
    assert!(st.metrics().source_wall_s >= 0.0);
}

#[test]
fn dr_overhead_is_negligible_when_data_is_uniform() {
    // §1: DR "improves the performance with negligible overhead" — on
    // uniform data the DR-enabled engine must stay within 2% of baseline.
    let mut with =
        MicroBatchEngine::new(cfg(16, 16), DrConfig::default(), PartitionerChoice::Kip, 10);
    let mut without =
        MicroBatchEngine::new(cfg(16, 16), DrConfig::disabled(), PartitionerChoice::Uhp, 10);
    let mut z = Zipf::new(100_000, 0.0, 10);
    let mut t_with = 0.0;
    let mut t_without = 0.0;
    for _ in 0..5 {
        let b = z.batch(50_000);
        t_with += with.run_batch(&b).makespan;
        t_without += without.run_batch(&b).makespan;
    }
    assert!(
        t_with <= t_without * 1.02,
        "DR overhead on uniform data: {t_with} vs {t_without}"
    );
}
