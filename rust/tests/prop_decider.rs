//! Property tests for the decider policy layer (DESIGN.md "Decider
//! policy layer"):
//!
//! 1. the default `Naive` policy reproduces the pre-decider eager path
//!    **bitwise** — reports, epochs, migration plans and the full keyed
//!    state — against a harness that still drives the raw
//!    `decision_point_sharded` → `adopt_decision` sequence;
//! 2. every policy is thread-count- and pipeline-invariant: lockstep
//!    sequential and pipelined sharded drives land on identical bits,
//!    adopt/defer tallies included, because verdicts only ever read
//!    virtual/modeled inputs;
//! 3. the CostModel backoff is a hard gate: after an adopted swap no
//!    epoch bump can occur within `backoff_factor` barriers;
//! 4. the Retentive cap binds exactly: every adopted swap's measured
//!    `migrated_fraction` is ≤ the configured cap, which only holds
//!    because the barrier's migration prediction equals the applied
//!    swap's measurement bitwise.
//!
//! Replay failures with `PROP_SEED=<seed> PROP_CASES=1`.

use dynrepart::ddps::{
    adopt_decision, decision_point_sharded, tap_records_sharded, DecisionOutcome, EngineConfig,
    EngineMetrics, MicroBatchEngine, Scheduling, ShuffleStage, StageReport, StreamingEngine,
    TapAssignment,
};
use dynrepart::dr::{DeciderConfig, DeciderPolicy, DrConfig, DrMaster, DrWorker, PartitionerChoice};
use dynrepart::partitioner::PartitionerEpoch;
use dynrepart::prop::{forall, Gen};
use dynrepart::state::StateStore;
use dynrepart::workload::{zipf::Zipf, Generator, Record, ReplaySource};

fn cfg(n_partitions: usize, n_slots: usize, num_threads: usize) -> EngineConfig {
    EngineConfig {
        n_partitions,
        n_slots,
        num_threads,
        ..Default::default()
    }
}

fn gen_batches(g: &mut Gen, n_batches: usize) -> (Vec<Vec<Record>>, u64) {
    let seed = g.u64(1..1 << 20);
    let keys = g.usize(500..5_000);
    let exponent = g.f64(0.0..1.6);
    let per_batch = g.usize(1_000..8_000);
    let mut z = Zipf::new(keys, exponent, seed);
    ((0..n_batches).map(|_| z.batch(per_batch)).collect(), seed)
}

/// Batches with per-interval key churn and rising skew: every interval
/// re-draws its key universe, so forced DR keeps finding genuinely
/// different candidates — the backoff test needs repeated adoptions.
fn gen_churn_batches(g: &mut Gen, n_batches: usize) -> (Vec<Vec<Record>>, u64) {
    let seed = g.u64(1..1 << 20);
    let keys = g.usize(1_000..4_000);
    let per_batch = g.usize(3_000..8_000);
    let batches = (0..n_batches)
        .map(|i| {
            let exponent = 0.5 + 0.12 * i as f64;
            Zipf::new(keys, exponent, seed + i as u64).batch(per_batch)
        })
        .collect();
    (batches, seed)
}

fn gen_dr(g: &mut Gen) -> DrConfig {
    if g.bool(0.5) {
        DrConfig::forced()
    } else {
        DrConfig::default()
    }
}

#[track_caller]
fn assert_bits(a: f64, b: f64, what: &str) {
    assert!(
        a.to_bits() == b.to_bits(),
        "{what} not bitwise-identical: {a} vs {b}"
    );
}

#[track_caller]
fn assert_vec_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (x, y) in a.iter().zip(b) {
        assert_bits(*x, *y, what);
    }
}

/// Full bitwise state comparison, key iteration order included.
#[track_caller]
fn assert_stores_bitwise(a: &[StateStore], b: &[StateStore], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: partition count");
    for (p, (sa, sb)) in a.iter().zip(b).enumerate() {
        assert_eq!(sa.n_keys(), sb.n_keys(), "{what}: partition {p} key count");
        for ((ka, va), (kb, vb)) in sa.iter().zip(sb.iter()) {
            assert_eq!(ka, kb, "{what}: partition {p} key order diverged");
            assert_eq!(va.records, vb.records, "{what}: partition {p} key {ka}");
            assert_bits(va.weight, vb.weight, what);
        }
    }
}

/// The pre-decider drive: the exact harvest → eager decide → adopt → tap
/// → stage sequence the engines ran before the policy layer existed,
/// built from the same public pieces (`decision_point_sharded` commits
/// any worthwhile candidate itself). Constructed exactly like
/// `EngineCore::new` so DRM/DRW seeding matches the engines bitwise.
struct Legacy {
    cfg: EngineConfig,
    drm: DrMaster,
    workers: Vec<DrWorker>,
    partitioner: PartitionerEpoch,
    stores: Vec<StateStore>,
    metrics: EngineMetrics,
}

impl Legacy {
    fn new(
        cfg: EngineConfig,
        dr: DrConfig,
        choice: PartitionerChoice,
        n_workers: usize,
        seed: u64,
    ) -> Self {
        let drm = DrMaster::with_sketch(dr, choice, cfg.n_partitions, seed, cfg.sketch);
        let workers = (0..n_workers)
            .map(|w| {
                DrWorker::with_sketch(
                    drm.worker_capacity(),
                    dr.sample_rate,
                    seed ^ (w as u64) << 8,
                    cfg.sketch,
                )
            })
            .collect();
        let partitioner = drm.handle();
        let stores = (0..cfg.n_partitions).map(|_| StateStore::new()).collect();
        Self {
            cfg,
            drm,
            workers,
            partitioner,
            stores,
            metrics: EngineMetrics::default(),
        }
    }

    /// Micro-batch order: decision point *before* the batch, chunked
    /// taps, wave-scheduled stage.
    fn step_microbatch(&mut self, records: &[Record]) -> (StageReport, DecisionOutcome) {
        let threads = self.cfg.num_threads;
        let decision = decision_point_sharded(&mut self.drm, &mut self.workers, threads);
        let outcome = adopt_decision(
            &self.cfg,
            decision,
            &mut self.partitioner,
            Some(self.stores.as_mut_slice()),
            &mut self.metrics,
        );
        tap_records_sharded(&mut self.workers, records, TapAssignment::Chunked, threads);
        let stage = ShuffleStage::new(&self.cfg, Scheduling::Wave).run(
            records,
            &self.partitioner,
            Some(self.stores.as_mut_slice()),
        );
        (stage, outcome)
    }

    /// Streaming order: round-robin taps, pinned stage, decision point at
    /// the barrier *after* the interval.
    fn step_streaming(&mut self, records: &[Record]) -> (StageReport, DecisionOutcome) {
        let threads = self.cfg.num_threads;
        tap_records_sharded(&mut self.workers, records, TapAssignment::RoundRobin, threads);
        let stage = ShuffleStage::new(&self.cfg, Scheduling::Pinned).run(
            records,
            &self.partitioner,
            Some(self.stores.as_mut_slice()),
        );
        let decision = decision_point_sharded(&mut self.drm, &mut self.workers, threads);
        let outcome = adopt_decision(
            &self.cfg,
            decision,
            &mut self.partitioner,
            Some(self.stores.as_mut_slice()),
            &mut self.metrics,
        );
        (stage, outcome)
    }
}

/// The biting-gates matrix for the invariance sweep: every policy, with
/// knobs set so its gates actually fire on these workloads.
fn decider_variants() -> [DeciderConfig; 4] {
    let base = DeciderConfig::default();
    [
        DeciderConfig {
            policy: DeciderPolicy::Naive,
            ..base
        },
        DeciderConfig {
            policy: DeciderPolicy::Threshold,
            histogram_threshold: 0.2,
            significant_change: 0.05,
            ..base
        },
        DeciderConfig {
            policy: DeciderPolicy::Retentive,
            max_migration: 0.3,
            retentive_weight: 1.0,
            ..base
        },
        DeciderConfig {
            policy: DeciderPolicy::CostModel,
            drift_boundary: 0.02,
            backoff_factor: 2,
            horizon: 16.0,
            ..base
        },
    ]
}

/// Naive == the pre-decider eager path, bitwise: same reports, same
/// epoch sequence, same migrations, same keyed state — for random
/// workloads, DR configs and thread counts, on both engine disciplines.
#[test]
fn naive_decider_reproduces_the_eager_path_bitwise() {
    forall(8, |g| {
        let n = g.usize(2..8);
        let threads = g.usize(1..5);
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);
        assert_eq!(
            dr.decider.policy,
            DeciderPolicy::Naive,
            "Naive must be the default policy"
        );

        // micro-batch: n_slots = n_partitions so the legacy harness's
        // worker count (slots for chunked taps) matches the engine's
        let mut eng = MicroBatchEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
        let mut old = Legacy::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, n, seed);
        let mut adopted = 0u64;
        for b in &batches {
            let r = eng.run_batch(b);
            let (stage, outcome) = old.step_microbatch(b);
            let tag = format!("microbatch batch {} ({threads} threads)", r.batch_no);
            assert_eq!(r.repartitioned, outcome.repartitioned, "{tag}");
            assert_eq!(r.epoch, outcome.epoch, "{tag}: epoch diverged");
            assert_bits(r.migration_time, outcome.migration.pause, &tag);
            assert_bits(r.migrated_fraction, outcome.migration.migrated_fraction, &tag);
            assert_bits(r.makespan, outcome.migration.pause + stage.stage_time, &tag);
            assert_bits(r.map_time, stage.map_time, &tag);
            assert_bits(r.reduce_time, stage.reduce_time, &tag);
            assert_vec_bits(&r.loads, &stage.loads, &tag);
            if r.repartitioned {
                adopted += 1;
            }
            assert_eq!(r.decisions_adopted, adopted, "{tag}: adopt tally");
            assert_eq!(r.decisions_deferred, 0, "{tag}: Naive never defers");
        }
        assert_eq!(eng.epoch(), old.partitioner.epoch());
        assert_eq!(eng.drm().decisions_made(), old.drm.decisions_made());
        assert_eq!(eng.drm().updates_issued(), old.drm.updates_issued());
        assert_stores_bitwise(eng.stores(), &old.stores, "microbatch state");

        // streaming: n_workers = n_partitions on both sides
        let mut eng = StreamingEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
        let mut old = Legacy::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, n, seed);
        let mut adopted = 0u64;
        for b in &batches {
            let r = eng.run_interval(b);
            let (stage, outcome) = old.step_streaming(b);
            let tag = format!("streaming interval {} ({threads} threads)", r.interval_no);
            assert_eq!(r.repartitioned, outcome.repartitioned, "{tag}");
            assert_eq!(r.epoch, outcome.epoch, "{tag}: epoch diverged");
            assert_bits(r.migration_pause, outcome.migration.pause, &tag);
            assert_bits(r.migrated_fraction, outcome.migration.migrated_fraction, &tag);
            assert_bits(r.elapsed, outcome.migration.pause + stage.stage_time, &tag);
            assert_vec_bits(&r.loads, &stage.loads, &tag);
            if r.repartitioned {
                adopted += 1;
            }
            assert_eq!(r.decisions_adopted, adopted, "{tag}: adopt tally");
            assert_eq!(r.decisions_deferred, 0, "{tag}: Naive never defers");
        }
        assert_eq!(eng.epoch(), old.partitioner.epoch());
        assert_eq!(eng.drm().decisions_made(), old.drm.decisions_made());
        assert_eq!(eng.drm().updates_issued(), old.drm.updates_issued());
        assert_stores_bitwise(eng.stores(), &old.stores, "streaming state");
    });
}

/// Every policy's verdicts ride only virtual inputs, so the lockstep
/// sequential drive and the pipelined sharded drive must land on
/// identical bits — epochs, migrations, loads and the adopt/defer
/// tallies themselves.
#[test]
fn every_policy_is_thread_count_and_pipeline_invariant() {
    forall(4, |g| {
        let n = g.usize(2..8);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 4);
        let dr_base = gen_dr(g);
        for dc in decider_variants() {
            let dr = DrConfig {
                decider: dc,
                ..dr_base
            };

            let mut seq =
                StreamingEngine::new(cfg(n, n, 1), dr, PartitionerChoice::Kip, seed);
            let mut par =
                StreamingEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
            let manual: Vec<_> = batches.iter().map(|b| seq.run_interval(b)).collect();
            let mut src = ReplaySource::new(batches.clone());
            let streamed = par.run_stream(&mut src, 0, batches.len());
            assert_eq!(manual.len(), streamed.len());
            for (a, b) in manual.iter().zip(&streamed) {
                let tag = format!(
                    "{} streaming interval {} ({threads} threads)",
                    dc.policy.name(),
                    a.interval_no
                );
                assert_eq!(a.interval_no, b.interval_no, "{tag}");
                assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
                assert_eq!(a.epoch, b.epoch, "{tag}");
                assert_eq!(a.decisions_adopted, b.decisions_adopted, "{tag}: adopted");
                assert_eq!(a.decisions_deferred, b.decisions_deferred, "{tag}: deferred");
                assert_bits(a.elapsed, b.elapsed, &tag);
                assert_bits(a.throughput, b.throughput, &tag);
                assert_bits(a.imbalance, b.imbalance, &tag);
                assert_bits(a.migrated_fraction, b.migrated_fraction, &tag);
                assert_bits(a.migration_pause, b.migration_pause, &tag);
                assert_bits(a.bottleneck_ratio, b.bottleneck_ratio, &tag);
                assert_vec_bits(&a.loads, &b.loads, &tag);
            }
            assert_eq!(seq.epoch(), par.epoch());
            assert_bits(seq.vtime(), par.vtime(), "streaming vtime");
            assert_stores_bitwise(seq.stores(), par.stores(), dc.policy.name());

            let mut seq =
                MicroBatchEngine::new(cfg(n, n, 1), dr, PartitionerChoice::Kip, seed);
            let mut par =
                MicroBatchEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
            let manual: Vec<_> = batches.iter().map(|b| seq.run_batch(b)).collect();
            let mut src = ReplaySource::new(batches.clone());
            let streamed = par.run_stream(&mut src, 0, batches.len());
            assert_eq!(manual.len(), streamed.len());
            for (a, b) in manual.iter().zip(&streamed) {
                let tag = format!(
                    "{} microbatch batch {} ({threads} threads)",
                    dc.policy.name(),
                    a.batch_no
                );
                assert_eq!(a.batch_no, b.batch_no, "{tag}");
                assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
                assert_eq!(a.epoch, b.epoch, "{tag}");
                assert_eq!(a.decisions_adopted, b.decisions_adopted, "{tag}: adopted");
                assert_eq!(a.decisions_deferred, b.decisions_deferred, "{tag}: deferred");
                assert_bits(a.makespan, b.makespan, &tag);
                assert_bits(a.map_time, b.map_time, &tag);
                assert_bits(a.reduce_time, b.reduce_time, &tag);
                assert_bits(a.migration_time, b.migration_time, &tag);
                assert_bits(a.imbalance, b.imbalance, &tag);
                assert_bits(a.migrated_fraction, b.migrated_fraction, &tag);
                assert_vec_bits(&a.loads, &b.loads, &tag);
            }
            assert_eq!(seq.epoch(), par.epoch());
            assert_bits(
                seq.total_state_weight(),
                par.total_state_weight(),
                "microbatch state weight",
            );
            assert_stores_bitwise(seq.stores(), par.stores(), dc.policy.name());
        }
    });
}

/// The backoff invariant: once the CostModel adopts, no epoch bump can
/// occur within `backoff_factor` barriers of the swap — and epoch bumps
/// happen on adoptions only. Drift detection is disabled downward
/// (`drift_boundary = -1`) and the horizon is enormous, so *only* the
/// cooldown restrains the forced DRM.
#[test]
fn cost_model_backoff_gates_epoch_bumps() {
    forall(6, |g| {
        let backoff = g.u64(1..4);
        let (batches, seed) = gen_churn_batches(g, 12);
        let dr = DrConfig {
            decider: DeciderConfig {
                policy: DeciderPolicy::CostModel,
                drift_boundary: -1.0,
                backoff_factor: backoff,
                horizon: 1e9,
                ..Default::default()
            },
            ..DrConfig::forced()
        };

        let mut eng = StreamingEngine::new(cfg(6, 6, 1), dr, PartitionerChoice::Kip, seed);
        let mut last_adopt: Option<u64> = None;
        let mut prev_adopted = 0u64;
        let mut prev_epoch = eng.epoch();
        for (i, b) in batches.iter().enumerate() {
            let r = eng.run_interval(b);
            let barrier = i as u64 + 1;
            if r.decisions_adopted > prev_adopted {
                assert!(r.repartitioned, "adoption without a swap at barrier {barrier}");
                assert!(r.epoch > prev_epoch, "adoption without an epoch bump");
                if let Some(last) = last_adopt {
                    assert!(
                        barrier - last > backoff,
                        "swap at barrier {barrier} inside the backoff window of {last} \
                         (backoff_factor {backoff})"
                    );
                }
                last_adopt = Some(barrier);
            } else {
                assert_eq!(
                    r.epoch, prev_epoch,
                    "epoch bump without an adoption at barrier {barrier}"
                );
                assert!(!r.repartitioned, "swap without an adoption at barrier {barrier}");
            }
            prev_adopted = r.decisions_adopted;
            prev_epoch = r.epoch;
        }
        assert!(
            prev_adopted >= 2,
            "churning forced workload must adopt repeatedly (got {prev_adopted})"
        );
        // Forced DR makes every proposal worthwhile: no barrier is
        // rejected, so the two tallies partition the barrier count.
        assert_eq!(
            prev_adopted + eng.decider().deferred(),
            batches.len() as u64,
            "adopted + deferred must cover every barrier"
        );

        // Same invariant on the micro-batch discipline (barrier before
        // the batch instead of after it).
        let mut eng = MicroBatchEngine::new(cfg(6, 6, 1), dr, PartitionerChoice::Kip, seed);
        let mut last_adopt: Option<u64> = None;
        let mut prev_adopted = 0u64;
        let mut prev_epoch = eng.epoch();
        for (i, b) in batches.iter().enumerate() {
            let r = eng.run_batch(b);
            let barrier = i as u64 + 1;
            if r.decisions_adopted > prev_adopted {
                if let Some(last) = last_adopt {
                    assert!(
                        barrier - last > backoff,
                        "microbatch swap at barrier {barrier} inside the backoff window"
                    );
                }
                last_adopt = Some(barrier);
            } else {
                assert_eq!(r.epoch, prev_epoch, "microbatch epoch bump without adoption");
            }
            prev_adopted = r.decisions_adopted;
            prev_epoch = r.epoch;
        }
    });
}

/// The Retentive cap binds exactly: every adopted swap's *measured*
/// migrated fraction stays ≤ the configured cap — which can only hold
/// because the barrier's store-walk prediction equals
/// `apply_epoch_swap`'s measurement bitwise (same stores, same order,
/// same accumulation).
#[test]
fn retentive_cap_binds_bitwise_on_every_adopted_swap() {
    forall(6, |g| {
        let cap = g.f64(0.15..0.5);
        let weight = g.f64(0.0..1.0);
        let seed = g.u64(1..1 << 20);
        let keys = g.usize(1_000..5_000);
        let exponent = g.f64(0.9..1.5);
        let per_batch = g.usize(3_000..8_000);
        let mut z = Zipf::new(keys, exponent, seed);
        let batches: Vec<Vec<Record>> = (0..8).map(|_| z.batch(per_batch)).collect();
        let dr = DrConfig {
            decider: DeciderConfig {
                policy: DeciderPolicy::Retentive,
                max_migration: cap,
                retentive_weight: weight,
                ..Default::default()
            },
            ..DrConfig::forced()
        };

        let mut eng = StreamingEngine::new(cfg(6, 6, 1), dr, PartitionerChoice::Kip, seed);
        let mut adopted = 0u64;
        for (i, b) in batches.iter().enumerate() {
            let r = eng.run_interval(b);
            if r.repartitioned {
                adopted += 1;
                assert!(
                    r.migrated_fraction <= cap,
                    "adopted swap at interval {} migrated {} > cap {cap}",
                    i + 1,
                    r.migrated_fraction
                );
            }
            assert_eq!(r.decisions_adopted, adopted, "adopt tally != swap count");
            // Forced DR: every barrier is worthwhile, so whatever is not
            // adopted is deferred — never silently dropped.
            assert_eq!(
                r.decisions_adopted + r.decisions_deferred,
                i as u64 + 1,
                "tallies must partition the barriers"
            );
        }

        let mut eng = MicroBatchEngine::new(cfg(6, 6, 1), dr, PartitionerChoice::Kip, seed);
        for b in &batches {
            let r = eng.run_batch(b);
            if r.repartitioned {
                assert!(
                    r.migrated_fraction <= cap,
                    "microbatch adopted swap migrated {} > cap {cap}",
                    r.migrated_fraction
                );
            }
        }
    });

    // Non-vacuity: with the cap and stickiness slack, the retentive
    // decider does adopt on a skewed stream — the forall above is not
    // quietly testing an engine that never swaps.
    let dr = DrConfig {
        decider: DeciderConfig {
            policy: DeciderPolicy::Retentive,
            max_migration: 1.0,
            retentive_weight: 0.0,
            ..Default::default()
        },
        ..DrConfig::forced()
    };
    let mut z = Zipf::new(4_000, 1.3, 7);
    let mut eng = StreamingEngine::new(cfg(6, 6, 1), dr, PartitionerChoice::Kip, 7);
    for _ in 0..8 {
        eng.run_interval(&z.batch(10_000));
    }
    assert!(
        eng.decider().adopted() >= 1,
        "a slack retentive gate must adopt on a skewed stream"
    );
}
