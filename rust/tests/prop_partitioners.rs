//! Property-based tests over the partitioner invariants (mini-prop
//! framework; replay failures with PROP_SEED=<seed> PROP_CASES=1).

use dynrepart::partitioner::*;
use dynrepart::prop::{forall, Gen};
use dynrepart::sketch::Histogram;

fn random_histogram(g: &mut Gen, max_keys: usize) -> Histogram {
    let n_keys = g.usize(0..max_keys);
    let mut freqs = Vec::with_capacity(n_keys);
    let mut remaining = 1.0f64;
    for i in 0..n_keys {
        let f = g.f64(0.0..remaining * 0.5);
        freqs.push((g.u64(0..1 << 48) ^ (i as u64) << 50, f));
        remaining -= f;
    }
    Histogram::from_freqs(&freqs, 1_000_000.0)
}

#[test]
fn every_partitioner_is_total_and_in_range() {
    forall(60, |g| {
        let n = g.usize(1..48);
        let hist = random_histogram(g, 4 * n);
        let seed = g.u64(0..1 << 32);
        let parts: Vec<Box<dyn Partitioner>> = vec![
            Box::new(Uhp::with_seed(n, seed)),
            Box::new(Kip::update(
                &Uhp::with_seed(n, seed),
                &WeightedHash::with_default_hosts(n, seed),
                &hist,
                KipConfig::default(),
            )),
            Box::new(
                GedikPartitioner::initial(GedikStrategy::Scan, n, GedikConfig::default(), seed)
                    .update(&hist),
            ),
            Box::new(
                GedikPartitioner::initial(GedikStrategy::Readj, n, GedikConfig::default(), seed)
                    .update(&hist),
            ),
            Box::new(
                GedikPartitioner::initial(GedikStrategy::Redist, n, GedikConfig::default(), seed)
                    .update(&hist),
            ),
            Box::new(Mixed::initial(n, seed).update(&hist)),
        ];
        for p in &parts {
            assert_eq!(p.n_partitions(), n);
            for _ in 0..50 {
                let k = g.u64(0..u64::MAX);
                assert!(p.partition(k) < n);
            }
            // determinism
            let k = g.u64(0..u64::MAX);
            assert_eq!(p.partition(k), p.partition(k));
            // tail shares are a distribution
            let shares = p.tail_shares();
            assert_eq!(shares.len(), n);
            let s: f64 = shares.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "tail shares sum {s}");
            assert!(shares.iter().all(|&x| x >= 0.0));
        }
    });
}

#[test]
fn kip_heavy_keys_always_explicit_and_within_histogram_budget() {
    forall(60, |g| {
        let n = g.usize(2..32);
        let hist = random_histogram(g, 4 * n);
        let kip = Kip::update(
            &Uhp::with_seed(n, 1),
            &WeightedHash::with_default_hosts(n, 2),
            &hist,
            KipConfig::default(),
        );
        assert_eq!(kip.explicit_routes(), hist.len());
        for e in hist.entries() {
            assert!(kip.explicit_table().contains_key(&e.key));
        }
    });
}

#[test]
fn migration_fraction_bounds_and_consistency() {
    forall(80, |g| {
        let n = g.usize(2..24);
        let a = Uhp::with_seed(n, g.u64(0..1000));
        let b = Uhp::with_seed(n, g.u64(0..1000));
        let sw: Vec<(u64, f64)> = (0..g.usize(1..500))
            .map(|i| (i as u64, g.f64(0.0..10.0)))
            .collect();
        let f = migration_fraction(&a, &b, &sw);
        assert!((0.0..=1.0).contains(&f), "fraction {f} out of bounds");
        // self-migration is zero
        assert_eq!(migration_fraction(&a, &a, &sw), 0.0);
        // plan count consistent with unweighted fraction
        let plan = migration_plan(&a, &b, sw.iter().map(|e| e.0));
        let unw: Vec<(u64, f64)> = sw.iter().map(|e| (e.0, 1.0)).collect();
        let fu = migration_fraction(&a, &b, &unw);
        assert!((plan.len() as f64 / sw.len() as f64 - fu).abs() < 1e-9);
    });
}

#[test]
fn kip_update_is_idempotent_under_stable_histogram() {
    forall(40, |g| {
        let n = g.usize(2..24);
        let hist = random_histogram(g, 2 * n);
        let k1 = Kip::update(
            &Uhp::with_seed(n, 3),
            &WeightedHash::with_default_hosts(n, 4),
            &hist,
            KipConfig::default(),
        );
        let k2 = k1.updated(&hist);
        let sw: Vec<(u64, f64)> = hist.entries().iter().map(|e| (e.key, e.freq)).collect();
        let f = migration_fraction(&k1, &k2, &sw);
        assert!(f < 1e-9, "stable histogram migrated {f} of heavy state");
    });
}

#[test]
fn epoch_swap_invariants() {
    use std::sync::Arc;
    forall(60, |g| {
        let n = g.usize(2..24);
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(n, g.u64(0..1000))));
        assert_eq!(ep.epoch(), 0);
        let keys: Vec<u64> = (0..g.usize(1..300))
            .map(|_| g.u64(0..1 << 40))
            .collect();
        let mut last_epoch = 0;
        for _ in 0..g.usize(1..4) {
            let swap = ep.install(Arc::new(Uhp::with_seed(n, g.u64(0..1000))));
            // epoch monotonicity across (possibly forced no-op) updates
            assert_eq!(swap.from_epoch(), last_epoch);
            assert_eq!(swap.to_epoch(), last_epoch + 1);
            assert_eq!(ep.epoch(), swap.to_epoch());
            last_epoch = ep.epoch();

            // plan keys = exactly the keys whose partition changed
            let plan = swap.plan(keys.iter().cloned());
            let planned: std::collections::HashSet<u64> = plan.iter().map(|e| e.0).collect();
            for &(k, from, to) in &plan {
                assert_eq!(from, swap.from.partition(k));
                assert_eq!(to, swap.to.partition(k));
                assert_ne!(from, to, "plan contains a non-moving key");
            }
            for &k in &keys {
                assert_eq!(
                    planned.contains(&k),
                    swap.from.partition(k) != swap.to.partition(k),
                    "plan keys must be exactly the keys whose partition changed"
                );
            }

            // migration_fraction ∈ [0, 1], and 0 iff the plan is empty
            let sw: Vec<(u64, f64)> = keys.iter().map(|&k| (k, g.f64(0.1..5.0))).collect();
            let f = swap.migration_fraction(&sw);
            assert!((0.0..=1.0).contains(&f), "fraction {f} out of bounds");
            let unique_moves = planned.len();
            assert_eq!(f == 0.0, unique_moves == 0);
        }
    });
}

#[test]
fn resized_epoch_swap_invariants() {
    // the scale-out/in path: installing a partitioner with a *different*
    // count must keep all the epoch-swap guarantees, with routes
    // in-range on each side of the swap
    use std::sync::Arc;
    forall(60, |g| {
        let old_n = g.usize(2..24);
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(old_n, g.u64(0..1000))));
        let keys: Vec<u64> = (0..g.usize(1..300)).map(|_| g.u64(0..1 << 40)).collect();
        let mut last_epoch = 0;
        let mut from_n = old_n;
        for _ in 0..g.usize(1..4) {
            // grow or shrink, never degenerate
            let to_n = g.usize(1..32);
            let swap = ep.install_resized(Arc::new(Uhp::with_seed(to_n, g.u64(0..1000))));
            assert_eq!(swap.from_epoch(), last_epoch);
            assert_eq!(swap.to_epoch(), last_epoch + 1);
            assert_eq!(ep.epoch(), swap.to_epoch());
            assert_eq!(ep.n_partitions(), to_n);
            last_epoch = ep.epoch();

            // the plan covers exactly the moved keys, each side in-range
            let plan = swap.plan(keys.iter().cloned());
            let planned: std::collections::HashSet<u64> = plan.iter().map(|e| e.0).collect();
            for &(k, from, to) in &plan {
                assert!(from < from_n, "source route {from} out of 0..{from_n}");
                assert!(to < to_n, "destination route {to} out of 0..{to_n}");
                assert_eq!(from, swap.from.partition(k));
                assert_eq!(to, swap.to.partition(k));
                assert_ne!(from, to, "plan contains a non-moving key");
            }
            for &k in &keys {
                assert_eq!(
                    planned.contains(&k),
                    swap.from.partition(k) != swap.to.partition(k),
                    "plan keys must be exactly the keys whose partition changed"
                );
            }

            // migration fraction stays a fraction across counts too
            let sw: Vec<(u64, f64)> = keys.iter().map(|&k| (k, g.f64(0.1..5.0))).collect();
            let f = swap.migration_fraction(&sw);
            assert!((0.0..=1.0).contains(&f), "fraction {f} out of bounds");
            assert_eq!(f == 0.0, planned.is_empty());
            from_n = to_n;
        }
    });
}

#[test]
fn drm_rescale_preserves_decision_continuity() {
    // scale events mid-run: the DRM rebuilds its candidate at the new
    // width from the blended history, epochs stay monotone, and routing
    // is total and in-range at every width
    use dynrepart::dr::{DrConfig, DrMaster, PartitionerChoice};
    forall(20, |g| {
        let n0 = g.usize(2..16);
        let choice = *g.pick(&[
            PartitionerChoice::Kip,
            PartitionerChoice::Mixed,
            PartitionerChoice::Uhp,
            PartitionerChoice::Gedik(GedikStrategy::Scan),
        ]);
        let mut drm = DrMaster::new(DrConfig::forced(), choice, n0, g.u64(0..100));
        let hist = random_histogram(g, 4 * n0);
        drm.decide(vec![hist.clone()]);
        let epoch_before = drm.epoch();
        let new_n = g.usize(1..24);
        let swap = drm.rescale(new_n);
        assert_eq!(swap.to_epoch(), epoch_before + 1);
        assert_eq!(drm.epoch(), epoch_before + 1);
        assert_eq!(drm.n_partitions(), new_n);
        let h = drm.handle();
        assert_eq!(h.n_partitions(), new_n);
        for _ in 0..50 {
            let k = g.u64(0..u64::MAX);
            assert!(h.partition(k) < new_n);
            assert_eq!(h.partition(k), swap.to.partition(k));
        }
        // decisions keep flowing after the rescale
        let d = drm.decide(vec![random_histogram(g, 4 * new_n.max(2))]);
        assert_eq!(d.epoch, drm.epoch());
    });
}

#[test]
fn drm_epochs_monotone_and_plans_match_under_forced_updates() {
    use dynrepart::dr::{DrConfig, DrMaster, PartitionerChoice};
    forall(20, |g| {
        let n = g.usize(2..16);
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, n, g.u64(0..100));
        assert_eq!(drm.epoch(), 0);
        let mut last = 0;
        for _ in 0..3 {
            let hist = random_histogram(g, 4 * n);
            let d = drm.decide(vec![hist]);
            let swap = d.swap.expect("forced update must install");
            assert_eq!(swap.from_epoch(), last);
            assert_eq!(swap.to_epoch(), last + 1);
            assert_eq!(d.epoch, swap.to_epoch());
            last = drm.epoch();
            // the installed epoch is the master's current handle
            let h = drm.handle();
            assert_eq!(h.epoch(), last);
            for _ in 0..30 {
                let k = g.u64(0..u64::MAX);
                assert_eq!(h.partition(k), swap.to.partition(k));
                assert!(h.partition(k) < n);
            }
        }
    });
}

#[test]
fn histogram_merge_preserves_mass_and_order() {
    forall(60, |g| {
        let n_locals = g.usize(1..6);
        let locals: Vec<Histogram> = (0..n_locals)
            .map(|_| {
                let counts: Vec<(u64, f64)> = (0..g.usize(1..50))
                    .map(|i| (g.u64(0..100) ^ (i as u64) << 32, g.f64(0.1..100.0)))
                    .collect();
                let total: f64 = counts.iter().map(|c| c.1).sum::<f64>() + g.f64(0.0..100.0);
                Histogram::from_counts(&counts, total, 32)
            })
            .collect();
        let merged = Histogram::merge(&locals, 16);
        assert!(merged.len() <= 16);
        assert!(merged.heavy_mass() <= 1.0 + 1e-9);
        let e = merged.entries();
        for w in e.windows(2) {
            assert!(w[0].freq >= w[1].freq - 1e-12, "not sorted");
        }
    });
}
