//! Cross-language numerics: the AOT artifact executed from rust via PJRT
//! must reproduce the python eager model bit-for-bit (within f32 noise).
//!
//! Requires the `pjrt` feature (the whole file is gated — without it the
//! runtime is a stub; see the `runtime` module docs) and `make artifacts`
//! (skips politely otherwise, so `cargo test` works on a fresh checkout).
#![cfg(feature = "pjrt")]

use dynrepart::runtime::{read_f32_file, read_i32_file, Artifacts, NerExecutable, Runtime};

fn artifacts() -> Option<Artifacts> {
    let dir = dynrepart::runtime::artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Artifacts::open(&dir).expect("manifest parses"))
}

#[test]
fn ner_b32_matches_python_fixture() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let exe = NerExecutable::load(&rt, &arts, 32).expect("load ner_b32");

    let tokens = read_i32_file(&arts.dir.join("check_tokens.bin")).unwrap();
    let lens = read_i32_file(&arts.dir.join("check_lens.bin")).unwrap();
    let want_logits = read_f32_file(&arts.dir.join("check_logits.bin")).unwrap();
    let want_pred = read_i32_file(&arts.dir.join("check_pred.bin")).unwrap();
    let want_hist = read_f32_file(&arts.dir.join("check_hist.bin")).unwrap();

    let out = exe.execute(&tokens, &lens).expect("execute");
    assert_eq!(out.logits.len(), want_logits.len());
    for (i, (a, b)) in out.logits.iter().zip(&want_logits).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 + 1e-4 * b.abs(),
            "logit {i}: rust={a} python={b}"
        );
    }
    assert_eq!(out.pred, want_pred, "argmax predictions diverge");
    for (i, (a, b)) in out.class_hist.iter().zip(&want_hist).enumerate() {
        assert!(
            (a - b).abs() <= 1e-2 + 1e-4 * b.abs(),
            "hist {i}: rust={a} python={b}"
        );
    }
}

#[test]
fn all_manifest_variants_compile() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    for name in arts.manifest.names() {
        rt.load_hlo_text(&arts.hlo_path(name))
            .unwrap_or_else(|e| panic!("{name} failed to compile: {e}"));
    }
}

#[test]
fn ladder_scores_arbitrary_doc_counts() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let ladder = dynrepart::runtime::ner_exec::NerLadder::load(&rt, &arts).expect("ladder");

    let hosts: Vec<(u64, f64)> = vec![(1, 0.5), (2, 0.5)];
    let mut gen = dynrepart::workload::ner::NerGen::new(&hosts, 7);
    for n in [1usize, 31, 33, 200] {
        let docs = gen.docs(n);
        let outs = ladder.score_all(&docs).expect("score");
        let scored: usize = outs.iter().map(|o| o.batch).sum();
        assert!(scored >= n, "scored {scored} < {n}");
        // histogram mass equals the total valid token weight
        let total_hist: f32 = outs.iter().flat_map(|o| o.class_hist.iter()).sum();
        let total_len: f64 = docs.iter().map(|d| d.weight()).sum();
        assert!(
            (total_hist as f64 - total_len).abs() < 1e-2 * total_len.max(1.0),
            "hist mass {total_hist} vs len {total_len}"
        );
    }
}

#[test]
fn calibration_returns_sane_cost() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let exe = NerExecutable::load(&rt, &arts, 32).expect("load");
    let cost = exe.calibrate_per_doc_cost(2).expect("calibrate");
    assert!(cost > 0.0 && cost < 1.0, "per-doc cost {cost}s out of range");
}
