//! Distributed-engine equivalence properties: a master plus N worker
//! *processes* — batches shuffled over Unix sockets, sketches harvested
//! at every barrier, keyed state migrating between workers on the wire —
//! must reproduce the single-process streaming engine **bitwise**, at
//! every worker count, under both decider families (plan-after-commit
//! and plan-before-judge), and straight through a mid-run worker crash
//! and wire-level restore.
//!
//! Workers are spawned from the real CLI binary
//! (`CARGO_BIN_EXE_dynrepart`) — the test harness binary has no `worker`
//! subcommand — so these tests exercise the full process boundary:
//! spawn, handshake, shuffle, harvest, migration, snapshot, restore.

use dynrepart::ddps::cluster::store_digest;
use dynrepart::ddps::{ClusterStats, EngineConfig, StreamingEngine};
use dynrepart::dr::DeciderPolicy;
use dynrepart::scenario::{
    ClusterRunOptions, Scenario, ScenarioConfig, ScenarioReport, ScriptedSource,
};
use std::path::{Path, PathBuf};

fn conf_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios"))
}

/// The shipped cluster conf, shrunk for test speed (same shape).
fn trimmed() -> ScenarioConfig {
    let mut cfg = ScenarioConfig::from_file(&conf_dir().join("cluster_hotspot_flip.conf"))
        .expect("shipped cluster conf must parse");
    cfg.batch_size = cfg.batch_size.min(8_000);
    cfg.n_keys = cfg.n_keys.min(5_000);
    cfg
}

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dynrepart"))
}

fn run_cluster(mut cfg: ScenarioConfig, workers: usize) -> (ScenarioReport, ClusterStats) {
    cfg.cluster_workers = Some(workers);
    let opts = ClusterRunOptions {
        worker_bin: Some(worker_bin()),
        ..Default::default()
    };
    run_cluster_opts(cfg, &opts)
}

fn run_cluster_opts(cfg: ScenarioConfig, opts: &ClusterRunOptions) -> (ScenarioReport, ClusterStats) {
    Scenario::new(cfg)
        .expect("cluster conf must validate")
        .run_cluster_with(opts)
        .expect("cluster run must complete")
}

/// The single-process oracle: the identical scenario with the cluster
/// knob cleared, run through [`StreamingEngine`] in this process.
fn run_oracle(mut cfg: ScenarioConfig) -> ScenarioReport {
    cfg.cluster_workers = None;
    Scenario::new(cfg).unwrap().run().unwrap()
}

/// Every deterministic column — virtual-time floats compared by bit
/// pattern, plus the rendered table the CLI would emit.
#[track_caller]
fn assert_reports_bitwise(cluster: &ScenarioReport, oracle: &ScenarioReport) {
    assert_eq!(cluster.rows.len(), oracle.rows.len());
    for (x, y) in cluster.rows.iter().zip(&oracle.rows) {
        assert_eq!(x.interval, y.interval);
        assert_eq!(x.epoch, y.epoch, "interval {}", x.interval);
        assert_eq!(x.repartitioned, y.repartitioned, "interval {}", x.interval);
        assert_eq!(x.adopted, y.adopted, "interval {}", x.interval);
        assert_eq!(x.deferred, y.deferred, "interval {}", x.interval);
        for (what, u, v) in [
            ("migrated", x.migrated_fraction, y.migrated_fraction),
            ("imbalance", x.imbalance, y.imbalance),
            ("elapsed", x.elapsed, y.elapsed),
            ("throughput", x.throughput, y.throughput),
            ("cum_migrated", x.cum_migrated, y.cum_migrated),
        ] {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "interval {}: {what} diverged ({u} vs {v})",
                x.interval
            );
        }
        assert_eq!(x.backlog.len(), y.backlog.len());
        for (p, (u, v)) in x.backlog.iter().zip(&y.backlog).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "interval {} backlog p{p}", x.interval);
        }
    }
    assert_eq!(cluster.final_epoch, oracle.final_epoch);
    assert_eq!(cluster.total_vtime.to_bits(), oracle.total_vtime.to_bits());
    assert_eq!(
        cluster.total_state_weight.to_bits(),
        oracle.total_state_weight.to_bits()
    );
    assert_eq!(cluster.table().to_tsv(), oracle.table().to_tsv());
}

/// The tentpole property: at worker counts 1, 2 and 4 the distributed
/// run reproduces the single-process rows bitwise, and the migration
/// plans and final state are worker-count-invariant (same digests).
#[test]
fn cluster_matches_single_process_at_1_2_4_workers() {
    let cfg = trimmed();
    let oracle = run_oracle(cfg.clone());
    assert!(
        oracle.rows.last().unwrap().adopted >= 1,
        "forced DR must repartition or the equivalence is vacuous"
    );
    let mut digests: Vec<(u64, u64)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let (rep, stats) = run_cluster(cfg.clone(), workers);
        assert_reports_bitwise(&rep, &oracle);
        assert_eq!(rep.recoveries_verified, 0, "no crash was injected");
        assert!(stats.shuffle_bytes > 0, "batches must cross the wire");
        assert!(
            stats.migration_bytes > 0,
            "adopted swaps must move state over the wire"
        );
        assert!(stats.snapshot_bytes > 0, "every barrier ships a snapshot");
        assert_eq!(stats.worker_restores, 0);
        digests.push((stats.plan_digest, stats.state_digest));
    }
    assert!(digests[0].0 != 0, "an adopting run must produce a plan digest");
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "plan/state digests must be worker-count-invariant: {digests:?}"
    );
}

/// The final wire-reported state is byte-for-byte the oracle's: driving
/// the in-process engine over the same scripted batches and digesting
/// its stores (slab order, f64 bits) reproduces the cluster's
/// `state_digest`.
#[test]
fn final_state_digest_matches_the_in_process_stores() {
    let cfg = trimmed();
    let (_, stats) = run_cluster(cfg.clone(), 2);

    let mut ecfg = EngineConfig::from_env();
    ecfg.n_partitions = cfg.n_partitions;
    ecfg.n_slots = cfg.n_slots;
    if let Some(t) = cfg.threads {
        ecfg.num_threads = t;
    }
    // the shipped conf pins the decider explicitly, so cfg.dr is exactly
    // what the cluster master ran with
    let mut engine = StreamingEngine::new(ecfg, cfg.dr, cfg.choice, cfg.seed);
    let mut src = ScriptedSource::new(&cfg);
    let reports = engine.run_stream(&mut src, cfg.batch_size, cfg.intervals);
    assert_eq!(reports.len(), cfg.intervals);
    assert_eq!(
        stats.state_digest,
        store_digest(engine.stores()),
        "the cluster's final state must be bitwise the oracle's stores"
    );
}

/// The plan-before-judge path: a migration-pricing decider (CostModel)
/// makes the master gather movers over the wire *before* judging, and
/// the predicted migration fed to the decider must still match the
/// oracle's store walk bitwise — verdicts, tallies and rows included.
#[test]
fn cost_model_decider_is_bitwise_identical_over_the_wire() {
    let mut cfg = trimmed();
    cfg.dr.decider.policy = DeciderPolicy::CostModel;
    cfg.decider_explicit = true;
    let oracle = run_oracle(cfg.clone());
    let (rep, _) = run_cluster(cfg, 2);
    assert_reports_bitwise(&rep, &oracle);
}

/// Crash-restore over the wire: worker 1 of 2 exits right after
/// receiving the batch of interval 4; the master detects the dropped
/// connection at harvest, respawns the worker, replays the last barrier
/// snapshot plus the retained batch — and the run's rows remain
/// bitwise-identical to both the uninterrupted cluster run and the
/// single-process oracle.
#[test]
fn mid_run_worker_crash_restores_bitwise() {
    let cfg = trimmed();
    assert!(cfg.intervals >= 6, "the crash must land mid-run");
    let oracle = run_oracle(cfg.clone());
    let (clean, clean_stats) = run_cluster(cfg.clone(), 2);
    let mut crashed_cfg = cfg;
    crashed_cfg.cluster_workers = Some(2);
    let (crashed, stats) = run_cluster_opts(
        crashed_cfg,
        &ClusterRunOptions {
            worker_bin: Some(worker_bin()),
            fail_at: Some((1, 4)),
            ..Default::default()
        },
    );
    assert_eq!(stats.worker_restores, 1, "exactly one worker must be revived");
    assert_eq!(crashed.recoveries_verified, 1);
    assert_reports_bitwise(&crashed, &oracle);
    assert_reports_bitwise(&crashed, &clean);
    assert_eq!(stats.plan_digest, clean_stats.plan_digest);
    assert_eq!(stats.state_digest, clean_stats.state_digest);
    assert!(
        stats.snapshot_bytes > clean_stats.snapshot_bytes,
        "the restore must replay a snapshot over the wire"
    );
}

/// The CLI end of the tentpole: `dynrepart master <conf>` on the
/// shipped cluster conf prints exactly the table the in-process cluster
/// run renders (same environment, same binary for the workers).
#[test]
fn cli_master_prints_the_in_process_table() {
    let conf = conf_dir().join("cluster_hotspot_flip.conf");
    let scenario = Scenario::from_file(&conf).unwrap();
    let opts = ClusterRunOptions {
        worker_bin: Some(worker_bin()),
        ..Default::default()
    };
    let (report, stats) = scenario.run_cluster_with(&opts).unwrap();

    let out = std::process::Command::new(worker_bin())
        .arg("master")
        .arg(&conf)
        .env_remove("DYNREPART_OUT")
        .output()
        .expect("the CLI master must spawn");
    assert!(
        out.status.success(),
        "dynrepart master failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains(&report.table().render()),
        "CLI table must match the in-process render; got:\n{stdout}"
    );
    assert!(stdout.contains("shuffle "), "wire accounting must be printed");
    assert_eq!(stats.worker_restores, 0);
}
