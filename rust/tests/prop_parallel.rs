//! Property tests for executor-sharding invariance: for every engine,
//! `num_threads = N` must reproduce the `num_threads = 1` reports exactly —
//! routing (loads / record counts), epochs and virtual times are compared
//! bitwise. Wall-clock fields (`wall_s`) are measurements and are the only
//! reported values allowed to differ. Replay failures with
//! `PROP_SEED=<seed> PROP_CASES=1`.

use dynrepart::ddps::{BatchJob, EngineConfig, MicroBatchEngine, StreamingEngine};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::prop::{forall, Gen};
use dynrepart::workload::{zipf::Zipf, Generator, Record};

fn cfg(n_partitions: usize, n_slots: usize, num_threads: usize) -> EngineConfig {
    EngineConfig {
        n_partitions,
        n_slots,
        num_threads,
        ..Default::default()
    }
}

fn gen_batches(g: &mut Gen, n_batches: usize) -> (Vec<Vec<Record>>, u64) {
    let seed = g.u64(1..1 << 20);
    let keys = g.usize(500..5_000);
    let exponent = g.f64(0.0..1.6);
    let per_batch = g.usize(1_000..8_000);
    let mut z = Zipf::new(keys, exponent, seed);
    ((0..n_batches).map(|_| z.batch(per_batch)).collect(), seed)
}

fn gen_dr(g: &mut Gen) -> DrConfig {
    if g.bool(0.5) {
        DrConfig::forced()
    } else {
        DrConfig::default()
    }
}

#[track_caller]
fn assert_bits(a: f64, b: f64, what: &str) {
    assert!(
        a.to_bits() == b.to_bits(),
        "{what} not bitwise-identical: {a} vs {b}"
    );
}

#[track_caller]
fn assert_vec_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (x, y) in a.iter().zip(b) {
        assert_bits(*x, *y, what);
    }
}

#[test]
fn microbatch_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n_partitions = g.usize(2..12);
        let n_slots = g.usize(2..12);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);
        let mut seq =
            MicroBatchEngine::new(cfg(n_partitions, n_slots, 1), dr, PartitionerChoice::Kip, seed);
        let mut par = MicroBatchEngine::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        for b in &batches {
            let rs = seq.run_batch(b);
            let rp = par.run_batch(b);
            assert_eq!(rs.batch_no, rp.batch_no);
            assert_eq!(rs.repartitioned, rp.repartitioned);
            assert_eq!(rs.epoch, rp.epoch, "epoch diverged at batch {}", rs.batch_no);
            assert_bits(rs.makespan, rp.makespan, "makespan");
            assert_bits(rs.map_time, rp.map_time, "map_time");
            assert_bits(rs.reduce_time, rp.reduce_time, "reduce_time");
            assert_bits(rs.migration_time, rp.migration_time, "migration_time");
            assert_bits(rs.imbalance, rp.imbalance, "imbalance");
            assert_bits(rs.migrated_fraction, rp.migrated_fraction, "migrated_fraction");
            assert_vec_bits(&rs.loads, &rp.loads, "loads");
        }
        assert_bits(seq.total_state_weight(), par.total_state_weight(), "state weight");
        assert_eq!(seq.epoch(), par.epoch());
        assert_bits(seq.metrics().total_vtime, par.metrics().total_vtime, "total_vtime");
    });
}

#[test]
fn streaming_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n = g.usize(2..10);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);
        let mut seq = StreamingEngine::new(cfg(n, n, 1), dr, PartitionerChoice::Kip, seed);
        let mut par = StreamingEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
        for b in &batches {
            let rs = seq.run_interval(b);
            let rp = par.run_interval(b);
            assert_eq!(rs.interval_no, rp.interval_no);
            assert_eq!(rs.repartitioned, rp.repartitioned);
            assert_eq!(rs.epoch, rp.epoch, "epoch diverged at interval {}", rs.interval_no);
            assert_bits(rs.elapsed, rp.elapsed, "elapsed");
            assert_bits(rs.throughput, rp.throughput, "throughput");
            assert_bits(rs.imbalance, rp.imbalance, "imbalance");
            assert_bits(rs.migrated_fraction, rp.migrated_fraction, "migrated_fraction");
            assert_bits(rs.migration_pause, rp.migration_pause, "migration_pause");
            assert_bits(rs.bottleneck_ratio, rp.bottleneck_ratio, "bottleneck_ratio");
        }
        assert_bits(seq.vtime(), par.vtime(), "vtime");
        assert_bits(seq.total_state_weight(), par.total_state_weight(), "state weight");
        assert_eq!(seq.epoch(), par.epoch());
    });
}

#[test]
fn batch_job_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n_partitions = g.usize(2..16);
        let n_slots = g.usize(2..16);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 1);
        let records = &batches[0];
        let dr = gen_dr(g);
        let decision_at = g.f64(0.05..0.5);
        let mut seq = BatchJob::new(
            cfg(n_partitions, n_slots, 1),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        seq.decision_at = decision_at;
        let mut par = BatchJob::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        par.decision_at = decision_at;

        let rs = seq.run(records);
        let rp = par.run(records);
        assert_eq!(rs.repartitioned, rp.repartitioned);
        assert_eq!(rs.epoch, rp.epoch);
        assert_eq!(rs.replayed_records, rp.replayed_records);
        assert_eq!(rs.record_counts, rp.record_counts);
        assert_bits(rs.makespan, rp.makespan, "makespan");
        assert_bits(rs.map_time, rp.map_time, "map_time");
        assert_bits(rs.reduce_time, rp.reduce_time, "reduce_time");
        assert_bits(rs.replay_time, rp.replay_time, "replay_time");
        assert_bits(rs.imbalance, rp.imbalance, "imbalance");
        assert_vec_bits(&rs.loads, &rp.loads, "loads");
    });
}
