//! Property tests for executor-, DRM- and pipeline-sharding invariance:
//! for every engine, `num_threads = N` must reproduce the
//! `num_threads = 1` reports exactly — routing (loads / record counts),
//! epochs, virtual times, DRM decisions and migration plans are compared
//! bitwise — and the pipelined drive loop (`run_stream` over a
//! [`Source`](dynrepart::workload::Source)) must reproduce the lockstep
//! per-batch loop over the same batches. Wall-clock fields (`wall_s`,
//! `decision_wall_s`, `source_wall_s`, `pipeline_occupancy`) are
//! measurements and are the only reported values allowed to differ.
//! Replay failures with `PROP_SEED=<seed> PROP_CASES=1`.

use dynrepart::ddps::{
    decision_point_sharded, pipeline, tap_records_sharded, BatchJob, Discipline, EngineConfig,
    EngineCore, MicroBatchEngine, StreamingEngine, TapAssignment,
};
use std::time::Instant;
use dynrepart::dr::{DrConfig, DrMaster, DrWorker, PartitionerChoice};
use dynrepart::partitioner::GedikStrategy;
use dynrepart::prop::{forall, Gen};
use dynrepart::sketch::SketchConfig;
use dynrepart::workload::{zipf::Zipf, Generator, Record, ReplaySource};

fn cfg(n_partitions: usize, n_slots: usize, num_threads: usize) -> EngineConfig {
    EngineConfig {
        n_partitions,
        n_slots,
        num_threads,
        ..Default::default()
    }
}

fn gen_batches(g: &mut Gen, n_batches: usize) -> (Vec<Vec<Record>>, u64) {
    let seed = g.u64(1..1 << 20);
    let keys = g.usize(500..5_000);
    let exponent = g.f64(0.0..1.6);
    let per_batch = g.usize(1_000..8_000);
    let mut z = Zipf::new(keys, exponent, seed);
    ((0..n_batches).map(|_| z.batch(per_batch)).collect(), seed)
}

fn gen_dr(g: &mut Gen) -> DrConfig {
    if g.bool(0.5) {
        DrConfig::forced()
    } else {
        DrConfig::default()
    }
}

#[track_caller]
fn assert_bits(a: f64, b: f64, what: &str) {
    assert!(
        a.to_bits() == b.to_bits(),
        "{what} not bitwise-identical: {a} vs {b}"
    );
}

#[track_caller]
fn assert_vec_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what} length");
    for (x, y) in a.iter().zip(b) {
        assert_bits(*x, *y, what);
    }
}

/// The DRM-sharding invariant: for random workloads, partitioner families
/// and thread counts, the sharded decision point (sharded harvests +
/// histogram tree-merge + key-range candidate preparation) produces
/// decisions, epoch sequences and migration plans bitwise-identical to
/// the sequential path.
#[test]
fn drm_decisions_epochs_and_plans_identical_across_thread_counts() {
    forall(8, |g| {
        let n_partitions = g.usize(2..12);
        let n_workers = g.usize(1..9);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 3);
        let dr = gen_dr(g);
        let choice = match g.usize(0..6) {
            0 => PartitionerChoice::Kip,
            1 => PartitionerChoice::Mixed,
            2 => PartitionerChoice::Gedik(GedikStrategy::Scan),
            3 => PartitionerChoice::Gedik(GedikStrategy::Readj),
            4 => PartitionerChoice::Gedik(GedikStrategy::Redist),
            _ => PartitionerChoice::Uhp,
        };
        let mut drm_seq = DrMaster::new(dr, choice, n_partitions, seed);
        let mut drm_par = DrMaster::new(dr, choice, n_partitions, seed);
        let make_workers = |drm: &DrMaster| -> Vec<DrWorker> {
            (0..n_workers)
                .map(|w| {
                    DrWorker::new(drm.worker_capacity(), dr.sample_rate, seed ^ (w as u64) << 8)
                })
                .collect()
        };
        let mut w_seq = make_workers(&drm_seq);
        let mut w_par = make_workers(&drm_par);
        for (round, b) in batches.iter().enumerate() {
            tap_records_sharded(&mut w_seq, b, TapAssignment::Chunked, 1);
            tap_records_sharded(&mut w_par, b, TapAssignment::Chunked, threads);
            let ds = decision_point_sharded(&mut drm_seq, &mut w_seq, 1);
            let dp = decision_point_sharded(&mut drm_par, &mut w_par, threads);
            let tag = format!("{} round {round}, {threads} threads", choice.name());
            assert_eq!(ds.repartitioned(), dp.repartitioned(), "{tag}");
            assert_eq!(ds.epoch, dp.epoch, "{tag}: epoch diverged");
            assert_eq!(
                ds.histogram.entries(),
                dp.histogram.entries(),
                "{tag}: merged histograms diverged"
            );
            assert_bits(ds.current_max_share, dp.current_max_share, "current_max_share");
            assert_bits(ds.planned_max_share, dp.planned_max_share, "planned_max_share");
            match (&ds.swap, &dp.swap) {
                (Some(ss), Some(sp)) => {
                    assert_eq!(ss.from_epoch(), sp.from_epoch(), "{tag}");
                    assert_eq!(ss.to_epoch(), sp.to_epoch(), "{tag}");
                    let keys = 0..5_000u64;
                    let plan_s = ss.plan(keys.clone());
                    let plan_p = sp.plan(keys.clone());
                    assert_eq!(plan_s, plan_p, "{tag}: migration plans diverged");
                    for k in keys {
                        assert_eq!(
                            ss.to.partition(k),
                            sp.to.partition(k),
                            "{tag}: routing diverged at key {k}"
                        );
                    }
                }
                (None, None) => {}
                _ => unreachable!("repartitioned() already compared"),
            }
            assert!(ds.decision_wall_s >= 0.0 && dp.decision_wall_s >= 0.0);
        }
        assert_eq!(drm_seq.epoch(), drm_par.epoch());
        assert_eq!(drm_seq.updates_issued(), drm_par.updates_issued());
        assert_eq!(drm_seq.decisions_made(), drm_par.decisions_made());
    });
}

#[test]
fn microbatch_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n_partitions = g.usize(2..12);
        let n_slots = g.usize(2..12);
        // occasionally exceed the core count: the persistent pool must be
        // exact at wide widths too, not just small ones
        let threads = if g.bool(0.25) { 8 } else { g.usize(2..6) };
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);
        let mut seq =
            MicroBatchEngine::new(cfg(n_partitions, n_slots, 1), dr, PartitionerChoice::Kip, seed);
        let mut par = MicroBatchEngine::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        for b in &batches {
            let rs = seq.run_batch(b);
            let rp = par.run_batch(b);
            assert_eq!(rs.batch_no, rp.batch_no);
            assert_eq!(rs.repartitioned, rp.repartitioned);
            assert_eq!(rs.epoch, rp.epoch, "epoch diverged at batch {}", rs.batch_no);
            assert_bits(rs.makespan, rp.makespan, "makespan");
            assert_bits(rs.map_time, rp.map_time, "map_time");
            assert_bits(rs.reduce_time, rp.reduce_time, "reduce_time");
            assert_bits(rs.migration_time, rp.migration_time, "migration_time");
            assert_bits(rs.imbalance, rp.imbalance, "imbalance");
            assert_bits(rs.migrated_fraction, rp.migrated_fraction, "migrated_fraction");
            assert_vec_bits(&rs.loads, &rp.loads, "loads");
        }
        assert_bits(seq.total_state_weight(), par.total_state_weight(), "state weight");
        assert_eq!(seq.epoch(), par.epoch());
        assert_bits(seq.metrics().total_vtime, par.metrics().total_vtime, "total_vtime");
    });
}

#[test]
fn streaming_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n = g.usize(2..10);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);
        let mut seq = StreamingEngine::new(cfg(n, n, 1), dr, PartitionerChoice::Kip, seed);
        let mut par = StreamingEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
        for b in &batches {
            let rs = seq.run_interval(b);
            let rp = par.run_interval(b);
            assert_eq!(rs.interval_no, rp.interval_no);
            assert_eq!(rs.repartitioned, rp.repartitioned);
            assert_eq!(rs.epoch, rp.epoch, "epoch diverged at interval {}", rs.interval_no);
            assert_bits(rs.elapsed, rp.elapsed, "elapsed");
            assert_bits(rs.throughput, rp.throughput, "throughput");
            assert_bits(rs.imbalance, rp.imbalance, "imbalance");
            assert_bits(rs.migrated_fraction, rp.migrated_fraction, "migrated_fraction");
            assert_bits(rs.migration_pause, rp.migration_pause, "migration_pause");
            assert_bits(rs.bottleneck_ratio, rp.bottleneck_ratio, "bottleneck_ratio");
        }
        assert_bits(seq.vtime(), par.vtime(), "vtime");
        assert_bits(seq.total_state_weight(), par.total_state_weight(), "state weight");
        assert_eq!(seq.epoch(), par.epoch());
    });
}

/// The pipelining invariant: for random workloads, DR configs and thread
/// counts, driving each engine through the pipelined loop (`run_stream`
/// over a replayed batch sequence) produces reports — virtual-time
/// fields, epochs, migration plans (via migrated fractions / pauses /
/// replay counts) — bitwise-identical to the lockstep per-batch loop
/// over the same batches, and leaves identical engine state behind.
#[test]
fn pipelined_run_stream_identical_to_lockstep_for_all_engines() {
    forall(8, |g| {
        let n_partitions = g.usize(2..10);
        let n_slots = n_partitions + g.usize(0..4);
        // 1 = sequential drive, >1 = overlapped lanes; both must pin.
        // Widths up to 8 exercise the pool past the physical core count.
        let threads = if g.bool(0.25) { 8 } else { g.usize(1..6) };
        let (batches, seed) = gen_batches(g, 4);
        let dr = gen_dr(g);

        // micro-batch
        let mut mb_seq =
            MicroBatchEngine::new(cfg(n_partitions, n_slots, 1), dr, PartitionerChoice::Kip, seed);
        let mut mb_par = MicroBatchEngine::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        let manual: Vec<_> = batches.iter().map(|b| mb_seq.run_batch(b)).collect();
        let mut src = ReplaySource::new(batches.clone());
        let streamed = mb_par.run_stream(&mut src, 0, batches.len());
        assert_eq!(manual.len(), streamed.len());
        for (a, b) in manual.iter().zip(&streamed) {
            let tag = format!("microbatch {} threads batch {}", threads, a.batch_no);
            assert_eq!(a.batch_no, b.batch_no, "{tag}");
            assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
            assert_eq!(a.epoch, b.epoch, "{tag}");
            assert_bits(a.makespan, b.makespan, &tag);
            assert_bits(a.map_time, b.map_time, &tag);
            assert_bits(a.reduce_time, b.reduce_time, &tag);
            assert_bits(a.migration_time, b.migration_time, &tag);
            assert_bits(a.imbalance, b.imbalance, &tag);
            assert_bits(a.migrated_fraction, b.migrated_fraction, &tag);
            assert_vec_bits(&a.loads, &b.loads, &tag);
            assert!(b.source_wall_s >= 0.0 && b.pipeline_occupancy >= 0.0, "{tag}");
        }
        assert_eq!(mb_seq.epoch(), mb_par.epoch());
        assert_eq!(mb_seq.drm().decisions_made(), mb_par.drm().decisions_made());
        assert_bits(
            mb_seq.total_state_weight(),
            mb_par.total_state_weight(),
            "microbatch state weight",
        );
        assert_bits(
            mb_seq.metrics().total_vtime,
            mb_par.metrics().total_vtime,
            "microbatch total_vtime",
        );

        // streaming
        let mut st_seq =
            StreamingEngine::new(cfg(n_partitions, n_slots, 1), dr, PartitionerChoice::Kip, seed);
        let mut st_par = StreamingEngine::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        let manual: Vec<_> = batches.iter().map(|b| st_seq.run_interval(b)).collect();
        let mut src = ReplaySource::new(batches.clone());
        let streamed = st_par.run_stream(&mut src, 0, batches.len());
        assert_eq!(manual.len(), streamed.len());
        for (a, b) in manual.iter().zip(&streamed) {
            let tag = format!("streaming {} threads interval {}", threads, a.interval_no);
            assert_eq!(a.interval_no, b.interval_no, "{tag}");
            assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
            assert_eq!(a.epoch, b.epoch, "{tag}");
            assert_bits(a.elapsed, b.elapsed, &tag);
            assert_bits(a.throughput, b.throughput, &tag);
            assert_bits(a.imbalance, b.imbalance, &tag);
            assert_bits(a.migrated_fraction, b.migrated_fraction, &tag);
            assert_bits(a.migration_pause, b.migration_pause, &tag);
            assert_bits(a.bottleneck_ratio, b.bottleneck_ratio, &tag);
        }
        assert_eq!(st_seq.epoch(), st_par.epoch());
        assert_bits(st_seq.vtime(), st_par.vtime(), "streaming vtime");
        assert_bits(
            st_seq.total_state_weight(),
            st_par.total_state_weight(),
            "streaming state weight",
        );
        // checkpoints are part of the barrier contract too
        assert_eq!(st_seq.checkpoints().len(), st_par.checkpoints().len());
        if let (Some(ca), Some(cb)) =
            (st_seq.checkpoints().latest(), st_par.checkpoints().latest())
        {
            assert_eq!(ca.id, cb.id);
            assert_bits(
                ca.total_state_weight(),
                cb.total_state_weight(),
                "checkpoint state weight",
            );
        }

        // batch jobs (round sequence)
        let decision_at = g.f64(0.05..0.5);
        let mut job_seq = BatchJob::new(
            cfg(n_partitions, n_slots, 1),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        job_seq.decision_at = decision_at;
        let mut job_par = BatchJob::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        job_par.decision_at = decision_at;
        let manual: Vec<_> = batches.iter().map(|b| job_seq.run(b)).collect();
        let mut src = ReplaySource::new(batches.clone());
        let streamed = job_par.run_stream(&mut src, 0, batches.len());
        assert_eq!(manual.len(), streamed.len());
        for (round, (a, b)) in manual.iter().zip(&streamed).enumerate() {
            let tag = format!("batch job {} threads round {round}", threads);
            assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
            assert_eq!(a.epoch, b.epoch, "{tag}");
            assert_eq!(a.replayed_records, b.replayed_records, "{tag}");
            assert_eq!(a.record_counts, b.record_counts, "{tag}");
            assert_bits(a.makespan, b.makespan, &tag);
            assert_bits(a.replay_time, b.replay_time, &tag);
            assert_bits(a.imbalance, b.imbalance, &tag);
            assert_vec_bits(&a.loads, &b.loads, &tag);
        }
    });
}

/// The bounded-sketch leg of the DRM invariant: with compaction,
/// size-boundary and take knobs all active, decisions are *still*
/// bitwise-identical across thread counts — compaction triggers on each
/// DRW's own observation count (the sharded tap replays each DRW's exact
/// sequential subsequence) and the bounded tree-merge truncates with the
/// same count-desc/key-asc comparator at every fold shape.
#[test]
fn bounded_sketch_decisions_identical_across_thread_counts() {
    forall(8, |g| {
        let n_partitions = g.usize(2..12);
        let n_workers = g.usize(1..9);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 3);
        let dr = gen_dr(g);
        let sketch = SketchConfig {
            compaction_interval: g.usize(100..1_000),
            size_boundary: g.usize(16..128),
            take_top_k: g.usize(8..64),
        };
        let mut drm_seq =
            DrMaster::with_sketch(dr, PartitionerChoice::Kip, n_partitions, seed, sketch);
        let mut drm_par =
            DrMaster::with_sketch(dr, PartitionerChoice::Kip, n_partitions, seed, sketch);
        let make_workers = |drm: &DrMaster| -> Vec<DrWorker> {
            (0..n_workers)
                .map(|w| {
                    DrWorker::with_sketch(
                        drm.worker_capacity(),
                        dr.sample_rate,
                        seed ^ (w as u64) << 8,
                        sketch,
                    )
                })
                .collect()
        };
        let mut w_seq = make_workers(&drm_seq);
        let mut w_par = make_workers(&drm_par);
        for (round, b) in batches.iter().enumerate() {
            tap_records_sharded(&mut w_seq, b, TapAssignment::Chunked, 1);
            tap_records_sharded(&mut w_par, b, TapAssignment::Chunked, threads);
            for (w1, w2) in w_seq.iter().zip(&w_par) {
                assert!(w1.footprint() <= sketch.size_boundary + sketch.compaction_interval);
                assert!(w2.footprint() <= sketch.size_boundary + sketch.compaction_interval);
            }
            let ds = decision_point_sharded(&mut drm_seq, &mut w_seq, 1);
            let dp = decision_point_sharded(&mut drm_par, &mut w_par, threads);
            let tag = format!("bounded round {round}, {threads} threads");
            assert_eq!(ds.repartitioned(), dp.repartitioned(), "{tag}");
            assert_eq!(ds.epoch, dp.epoch, "{tag}: epoch diverged");
            assert_eq!(
                ds.histogram.entries(),
                dp.histogram.entries(),
                "{tag}: merged histograms diverged"
            );
            assert_bits(ds.current_max_share, dp.current_max_share, "current_max_share");
            assert_bits(ds.planned_max_share, dp.planned_max_share, "planned_max_share");
            if let (Some(ss), Some(sp)) = (&ds.swap, &dp.swap) {
                let keys = 0..5_000u64;
                assert_eq!(
                    ss.plan(keys.clone()),
                    sp.plan(keys),
                    "{tag}: migration plans diverged"
                );
            }
        }
        assert_eq!(drm_seq.epoch(), drm_par.epoch());
        assert_eq!(drm_seq.decisions_made(), drm_par.decisions_made());
    });
}

/// `size_boundary = ∞` (the all-zero default) must reproduce the exact
/// decision path bitwise: same harvests, same merged histograms, same
/// epochs and routing as a DRM/DRW stack built without sketch knobs.
#[test]
fn default_sketch_reproduces_exact_decisions_bitwise() {
    forall(8, |g| {
        let n_partitions = g.usize(2..12);
        let n_workers = g.usize(1..9);
        let threads = g.usize(1..6);
        let (batches, seed) = gen_batches(g, 3);
        let dr = gen_dr(g);
        assert!(SketchConfig::default().is_unbounded());
        let mut drm_plain = DrMaster::new(dr, PartitionerChoice::Kip, n_partitions, seed);
        let mut drm_dflt = DrMaster::with_sketch(
            dr,
            PartitionerChoice::Kip,
            n_partitions,
            seed,
            SketchConfig::default(),
        );
        let mut w_plain: Vec<DrWorker> = (0..n_workers)
            .map(|w| {
                DrWorker::new(drm_plain.worker_capacity(), dr.sample_rate, seed ^ (w as u64) << 8)
            })
            .collect();
        let mut w_dflt: Vec<DrWorker> = (0..n_workers)
            .map(|w| {
                DrWorker::with_sketch(
                    drm_dflt.worker_capacity(),
                    dr.sample_rate,
                    seed ^ (w as u64) << 8,
                    SketchConfig::default(),
                )
            })
            .collect();
        for (round, b) in batches.iter().enumerate() {
            tap_records_sharded(&mut w_plain, b, TapAssignment::Chunked, threads);
            tap_records_sharded(&mut w_dflt, b, TapAssignment::Chunked, threads);
            let da = decision_point_sharded(&mut drm_plain, &mut w_plain, threads);
            let db = decision_point_sharded(&mut drm_dflt, &mut w_dflt, threads);
            let tag = format!("default-sketch round {round}");
            assert_eq!(da.epoch, db.epoch, "{tag}: epoch diverged");
            assert_eq!(
                da.histogram.entries(),
                db.histogram.entries(),
                "{tag}: merged histograms diverged"
            );
            assert_bits(da.current_max_share, db.current_max_share, "current_max_share");
            assert_bits(da.planned_max_share, db.planned_max_share, "planned_max_share");
            if let (Some(sa), Some(sb)) = (&da.swap, &db.swap) {
                for k in 0..2_000u64 {
                    assert_eq!(
                        sa.to.partition(k),
                        sb.to.partition(k),
                        "{tag}: routing diverged at key {k}"
                    );
                }
            }
        }
        assert_eq!(drm_plain.epoch(), drm_dflt.epoch());
        assert_eq!(drm_plain.updates_issued(), drm_dflt.updates_issued());
    });
}

#[test]
fn batch_job_reports_identical_across_thread_counts() {
    forall(10, |g| {
        let n_partitions = g.usize(2..16);
        let n_slots = g.usize(2..16);
        let threads = g.usize(2..6);
        let (batches, seed) = gen_batches(g, 1);
        let records = &batches[0];
        let dr = gen_dr(g);
        let decision_at = g.f64(0.05..0.5);
        let mut seq = BatchJob::new(
            cfg(n_partitions, n_slots, 1),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        seq.decision_at = decision_at;
        let mut par = BatchJob::new(
            cfg(n_partitions, n_slots, threads),
            dr,
            PartitionerChoice::Kip,
            seed,
        );
        par.decision_at = decision_at;

        let rs = seq.run(records);
        let rp = par.run(records);
        assert_eq!(rs.repartitioned, rp.repartitioned);
        assert_eq!(rs.epoch, rp.epoch);
        assert_eq!(rs.replayed_records, rp.replayed_records);
        assert_eq!(rs.record_counts, rp.record_counts);
        assert_bits(rs.makespan, rp.makespan, "makespan");
        assert_bits(rs.map_time, rp.map_time, "map_time");
        assert_bits(rs.reduce_time, rp.reduce_time, "reduce_time");
        assert_bits(rs.replay_time, rp.replay_time, "replay_time");
        assert_bits(rs.imbalance, rp.imbalance, "imbalance");
        assert_vec_bits(&rs.loads, &rp.loads, "loads");
    });
}

/// The measured decision-latency column is real, not the stage's
/// hardwired placeholder: every step reports a non-negative
/// `decision_wall_s`, the stage-level column agrees with the step's
/// bitwise, and the per-report values accumulate exactly into
/// [`EngineMetrics::decision_wall_s`].
///
/// [`EngineMetrics::decision_wall_s`]: dynrepart::ddps::EngineMetrics
#[test]
fn decision_wall_s_is_measured_and_threaded_through() {
    forall(6, |g| {
        let n = g.usize(2..8);
        let threads = g.usize(1..5);
        let (batches, seed) = gen_batches(g, 3);
        let dr = gen_dr(g);
        for disc in [Discipline::MicroBatch, Discipline::Streaming] {
            let mut core =
                EngineCore::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, n, seed);
            for b in &batches {
                let step =
                    pipeline::lockstep_step(&mut core, b, disc, 0.0, Instant::now(), &mut |_, _| {});
                assert!(
                    step.decision_wall_s >= 0.0,
                    "decision_wall_s must be a non-negative measurement"
                );
                assert_bits(
                    step.stage.decision_wall_s,
                    step.decision_wall_s,
                    "the stage column must mirror the decision point the step ran",
                );
            }
        }
        let mut eng = MicroBatchEngine::new(cfg(n, n, threads), dr, PartitionerChoice::Kip, seed);
        let mut sum = 0.0f64;
        for b in &batches {
            sum += eng.run_batch(b).decision_wall_s;
        }
        assert_bits(
            sum,
            eng.metrics().decision_wall_s,
            "per-report decision walls must accumulate into the metrics",
        );
        assert!(sum > 0.0, "three decision points take measurable wall time");
    });
}

/// The pool-replaces-scope invariant (PR 9): the persistent-pool executor
/// ([`route`] + [`shuffle_sharded`] on a shared
/// [`WorkerPool`](dynrepart::ddps::WorkerPool)) must reproduce — bitwise —
/// both the sequential loop and the per-call `thread::scope` executor it
/// replaced (kept below as a test-local reference implementation), for
/// random workloads, partition counts and thread widths.
///
/// [`route`]: dynrepart::ddps::exec::parallel::route
/// [`shuffle_sharded`]: dynrepart::ddps::exec::parallel::shuffle_sharded
#[test]
fn pooled_executor_matches_scoped_reference_and_sequential_bitwise() {
    use dynrepart::ddps::exec::parallel::{route, shard_ranges, shuffle_sharded};
    use dynrepart::partitioner::{EpochedPartitioner, PartitionerEpoch, Uhp};
    use dynrepart::state::StateStore;
    use std::sync::Arc;

    fn shard_chunk(n: usize, shards: usize) -> usize {
        n.div_ceil(shards.max(1)).max(1)
    }

    // The pre-pool executor: fresh `thread::scope` spawns per call, with
    // per-chunk route buckets concatenated in chunk order and per-shard
    // accumulators copy-merged in shard order.
    fn scoped_reference(
        records: &[Record],
        epoch: &PartitionerEpoch,
        n_partitions: usize,
        num_threads: usize,
    ) -> (Vec<f64>, Vec<u64>, Vec<StateStore>) {
        let rec_ranges = shard_ranges(records.len(), num_threads);
        let part_ranges = shard_ranges(n_partitions, num_threads);
        let n_shards = part_ranges.len();
        let pc = shard_chunk(n_partitions, num_threads);
        let mut routes: Vec<u32> = Vec::with_capacity(records.len());
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
        std::thread::scope(|s| {
            let handles: Vec<_> = rec_ranges
                .iter()
                .cloned()
                .map(|range| {
                    s.spawn(move || {
                        let mut routes = Vec::with_capacity(range.len());
                        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
                        for i in range {
                            let p = epoch.partition(records[i].key);
                            routes.push(p as u32);
                            buckets[p / pc].push(i as u32);
                        }
                        (routes, buckets)
                    })
                })
                .collect();
            for h in handles {
                let (r, buckets) = h.join().expect("scoped route worker");
                routes.extend_from_slice(&r);
                for (group, bucket) in groups.iter_mut().zip(buckets) {
                    group.extend_from_slice(&bucket);
                }
            }
        });
        let mut loads = vec![0.0f64; n_partitions];
        let mut counts = vec![0u64; n_partitions];
        let mut stores: Vec<StateStore> = Vec::with_capacity(n_partitions);
        std::thread::scope(|s| {
            let routes = &routes;
            let handles: Vec<_> = part_ranges
                .iter()
                .cloned()
                .enumerate()
                .map(|(s_idx, range)| {
                    let indices = &groups[s_idx];
                    s.spawn(move || {
                        let mut l = vec![0.0f64; range.len()];
                        let mut c = vec![0u64; range.len()];
                        let mut st: Vec<StateStore> =
                            (0..range.len()).map(|_| StateStore::new()).collect();
                        for &i in indices {
                            let r = &records[i as usize];
                            let p = routes[i as usize] as usize - range.start;
                            l[p] += r.weight;
                            c[p] += 1;
                            st[p].fold_count(r.key, r.weight);
                        }
                        (range, l, c, st)
                    })
                })
                .collect();
            for h in handles {
                let (range, l, c, st) = h.join().expect("scoped shuffle worker");
                loads[range.clone()].copy_from_slice(&l);
                counts[range].copy_from_slice(&c);
                stores.extend(st);
            }
        });
        (loads, counts, stores)
    }

    forall(8, |g| {
        let n_partitions = g.usize(2..24);
        let (batches, seed) = gen_batches(g, 1);
        let records = &batches[0];
        let epoch = EpochedPartitioner::new(Arc::new(Uhp::with_seed(n_partitions, seed))).current();

        let mut loads_seq = vec![0.0f64; n_partitions];
        let mut counts_seq = vec![0u64; n_partitions];
        let mut stores_seq: Vec<StateStore> =
            (0..n_partitions).map(|_| StateStore::new()).collect();
        for r in records {
            let p = epoch.partition(r.key);
            loads_seq[p] += r.weight;
            counts_seq[p] += 1;
            stores_seq[p].fold_count(r.key, r.weight);
        }

        for threads in [2usize, 3, 8] {
            let (loads_ref, counts_ref, stores_ref) =
                scoped_reference(records, &epoch, n_partitions, threads);
            let routed = route(records, &epoch, threads);
            let mut stores: Vec<StateStore> =
                (0..n_partitions).map(|_| StateStore::new()).collect();
            let (loads, counts) = shuffle_sharded(
                records,
                &routed,
                n_partitions,
                Some(stores.as_mut_slice()),
                threads,
            );
            let tag = format!("{threads} threads");
            assert_eq!(counts, counts_seq, "{tag}: counts vs sequential");
            assert_eq!(counts, counts_ref, "{tag}: counts vs scoped reference");
            assert_vec_bits(&loads, &loads_seq, &tag);
            assert_vec_bits(&loads, &loads_ref, &tag);
            for ((a, b), c) in stores.iter().zip(&stores_seq).zip(&stores_ref) {
                assert_eq!(a.n_keys(), b.n_keys(), "{tag}: state keys vs sequential");
                assert_eq!(a.n_keys(), c.n_keys(), "{tag}: state keys vs scoped reference");
                assert_bits(a.total_weight(), b.total_weight(), &tag);
                assert_bits(a.total_weight(), c.total_weight(), &tag);
                for k in b.keys() {
                    assert_eq!(a.get(k), b.get(k), "{tag}: key {k} vs sequential");
                    assert_eq!(a.get(k), c.get(k), "{tag}: key {k} vs scoped reference");
                }
            }
        }
    });
}
