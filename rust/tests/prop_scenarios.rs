//! Scenario determinism properties: the same scenario config + seed must
//! produce **bitwise-identical report tables** whether the executor runs
//! sequential or sharded — for the drift scripts, across scale events,
//! and through a mid-stream crash/restore. The scenarios load from the
//! same conf files the CLI runs (`scenarios/*.conf`), so the shipped
//! configs are themselves under test.

use dynrepart::dr::DrConfig;
use dynrepart::prop::forall;
use dynrepart::scenario::{ClusterRunOptions, EventKind, Scenario, ScenarioConfig, ScenarioReport};
use std::path::Path;

fn conf_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios"))
}

fn load(name: &str) -> ScenarioConfig {
    ScenarioConfig::from_file(&conf_dir().join(name))
        .unwrap_or_else(|e| panic!("shipped conf {name} must parse: {e}"))
}

/// Shrink a shipped conf for test speed without changing its shape.
fn trimmed(name: &str, seed: u64) -> ScenarioConfig {
    let mut cfg = load(name);
    cfg.seed = seed;
    cfg.batch_size = cfg.batch_size.min(8_000);
    cfg.n_keys = cfg.n_keys.min(5_000);
    cfg
}

fn run_with_threads(mut cfg: ScenarioConfig, threads: usize) -> ScenarioReport {
    cfg.threads = Some(threads);
    Scenario::new(cfg).unwrap().run().unwrap()
}

#[track_caller]
fn assert_reports_bitwise(a: &ScenarioReport, b: &ScenarioReport) {
    assert_eq!(a.rows.len(), b.rows.len());
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.interval, y.interval);
        assert_eq!(x.event, y.event, "interval {}", x.interval);
        assert_eq!(x.epoch, y.epoch, "interval {}", x.interval);
        assert_eq!(x.repartitioned, y.repartitioned, "interval {}", x.interval);
        for (what, u, v) in [
            ("migrated", x.migrated_fraction, y.migrated_fraction),
            ("imbalance", x.imbalance, y.imbalance),
            ("elapsed", x.elapsed, y.elapsed),
            ("throughput", x.throughput, y.throughput),
        ] {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "interval {}: {what} diverged ({u} vs {v})",
                x.interval
            );
        }
    }
    assert_eq!(a.recoveries_verified, b.recoveries_verified);
    assert_eq!(a.final_epoch, b.final_epoch);
    assert_eq!(a.total_vtime.to_bits(), b.total_vtime.to_bits());
    assert_eq!(a.total_state_weight.to_bits(), b.total_state_weight.to_bits());
    // the rendered table (what the CLI emits) must also match verbatim
    assert_eq!(a.table().to_tsv(), b.table().to_tsv());
}

#[test]
fn hotspot_flip_is_thread_invariant() {
    forall(3, |g| {
        let cfg = trimmed("hotspot_flip.conf", g.u64(1..1 << 20));
        let r1 = run_with_threads(cfg.clone(), 1);
        let r4 = run_with_threads(cfg, 4);
        assert!(r1.final_epoch >= 1, "forced DR must repartition");
        assert_reports_bitwise(&r1, &r4);
    });
}

#[test]
fn scale_out_in_is_thread_invariant() {
    forall(3, |g| {
        let cfg = trimmed("scale_out_in.conf", g.u64(1..1 << 20));
        let r1 = run_with_threads(cfg.clone(), 1);
        let r4 = run_with_threads(cfg, 4);
        // both scale events must be visible as epoch bumps on their rows
        let scale_rows: Vec<_> = r1.rows.iter().filter(|r| !r.event.is_empty()).collect();
        assert_eq!(scale_rows.len(), 2, "{:?}", r1.rows);
        assert_reports_bitwise(&r1, &r4);
    });
}

#[test]
fn zipf_drift_is_thread_invariant() {
    forall(2, |g| {
        let cfg = trimmed("zipf_drift.conf", g.u64(1..1 << 20));
        let r1 = run_with_threads(cfg.clone(), 1);
        let r4 = run_with_threads(cfg, 4);
        assert_reports_bitwise(&r1, &r4);
    });
}

#[test]
fn worker_failure_recovery_is_invisible_and_thread_invariant() {
    forall(2, |g| {
        let cfg = trimmed("worker_failure.conf", g.u64(1..1 << 20));
        let r1 = run_with_threads(cfg.clone(), 1);
        let r4 = run_with_threads(cfg.clone(), 4);
        assert!(r1.recoveries_verified >= 1, "the conf must exercise fail-restore");
        assert_reports_bitwise(&r1, &r4);
        // a verified recovery leaves no trace: dropping the fail-restore
        // event (keeping slowdown/restore) reproduces the same rows,
        // modulo the event label on the crash interval
        let mut clean = cfg;
        clean.events.retain(|(_, ev)| !matches!(ev, EventKind::FailRestore(_)));
        let rc = run_with_threads(clean, 1);
        assert_eq!(rc.recoveries_verified, 0);
        for (a, b) in r1.rows.iter().zip(&rc.rows) {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits());
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.imbalance.to_bits(), b.imbalance.to_bits());
        }
        assert_eq!(r1.total_vtime.to_bits(), rc.total_vtime.to_bits());
        assert_eq!(r1.total_state_weight.to_bits(), rc.total_state_weight.to_bits());
    });
}

/// The pool-lifecycle leg (PR 9): one process-wide persistent pool per
/// width must survive — and stay bitwise-exact through — a scenario that
/// rescales the partition count mid-run and another that crashes and
/// restores a worker, both at a thread width (8) past the typical core
/// count. Pools are created once per width and reused across every
/// interval of both runs, so any cross-interval scratch or handoff bug
/// shows up as a report diff here.
#[test]
fn pool_survives_rescale_and_recovery_at_wide_thread_counts() {
    let cfg = trimmed("scale_out_in.conf", 77);
    let r1 = run_with_threads(cfg.clone(), 1);
    let r8 = run_with_threads(cfg, 8);
    assert_reports_bitwise(&r1, &r8);

    let cfg = trimmed("worker_failure.conf", 78);
    let r1 = run_with_threads(cfg.clone(), 1);
    let r8 = run_with_threads(cfg, 8);
    assert!(r8.recoveries_verified >= 1, "the conf must exercise mid-run fail-restore");
    assert_reports_bitwise(&r1, &r8);
}

#[test]
fn diurnal_microbatch_is_thread_invariant() {
    let cfg = trimmed("diurnal_microbatch.conf", 1717);
    let r1 = run_with_threads(cfg.clone(), 1);
    let r4 = run_with_threads(cfg, 4);
    assert_reports_bitwise(&r1, &r4);
}

/// The backpressure story the burst event exists for: under a skewed
/// stream with a one-shot arrival burst, a pinned hash path (DR
/// disabled) holds a partition above its service capacity and the
/// backlog only grows — while the shipped Threshold-decider conf
/// flattens the skew, keeps headroom on every partition, and drains the
/// burst over the remaining intervals.
#[test]
fn threshold_decider_recovers_the_burst_backlog_a_pinned_path_cannot() {
    let cfg = trimmed("backpressure_burst.conf", 31);
    let gated = run_with_threads(cfg.clone(), 1);
    let mut pinned_cfg = cfg;
    pinned_cfg.dr = DrConfig::disabled();
    let pinned = run_with_threads(pinned_cfg, 1);

    let burst_at = gated
        .rows
        .iter()
        .position(|r| !r.event.is_empty())
        .expect("the conf ships a burst event");
    assert!(gated.rows[burst_at].event.starts_with("burst"), "{:?}", gated.rows[burst_at].event);

    // Pinned: the hot partition sits above capacity, so the standing
    // backlog keeps climbing after the burst instead of draining.
    let pinned_last = pinned.rows.last().unwrap().max_backlog();
    assert!(
        pinned_last > pinned.rows[burst_at].max_backlog(),
        "the pinned path's backlog must keep growing after the burst"
    );
    assert!(pinned_last > 0.0);

    // Gated: the burst shows up as a backlog spike, then drains.
    let gated_peak = gated.rows.iter().map(|r| r.max_backlog()).fold(0.0, f64::max);
    let gated_last = gated.rows.last().unwrap().max_backlog();
    assert!(gated_peak > 0.0, "the burst must charge a visible backlog");
    assert!(
        gated_last < gated_peak,
        "the gated path must drain the burst backlog (peak {gated_peak}, final {gated_last})"
    );
    assert!(
        gated_last < pinned_last,
        "restrained-but-adaptive routing must beat the pinned path \
         (gated {gated_last} vs pinned {pinned_last})"
    );
    assert!(
        gated.rows.last().unwrap().adopted >= 1,
        "the threshold decider must have adopted at least one swap"
    );
}

/// The decider matrix's headline contrast (EXPERIMENTS.md "Eager vs
/// restrained repartitioning"): on the identical hotspot-flip workload,
/// the CostModel conf adopts far fewer swaps and accumulates less
/// migration than the Naive conf, at comparable end-state imbalance.
#[test]
fn cost_model_beats_naive_on_cumulative_migration_for_the_flip_matrix() {
    let naive = run_with_threads(trimmed("decider_flip_naive.conf", 42), 1);
    let restrained = run_with_threads(trimmed("decider_flip_costmodel.conf", 42), 1);
    let ln = naive.rows.last().unwrap();
    let lr = restrained.rows.last().unwrap();
    // Forced DR + Naive adopts at every one of the 12 barriers.
    assert_eq!(ln.adopted, naive.rows.len() as u64, "naive must adopt every barrier");
    assert_eq!(ln.deferred, 0);
    assert!(
        lr.adopted < ln.adopted,
        "cost-model must adopt fewer swaps ({} vs {})",
        lr.adopted,
        ln.adopted
    );
    assert!(lr.deferred > 0, "restraint must be visible in the deferred tally");
    assert!(
        lr.cum_migrated < ln.cum_migrated,
        "cost-model must migrate less cumulative state ({} vs {})",
        lr.cum_migrated,
        ln.cum_migrated
    );
    assert!(
        lr.imbalance <= ln.imbalance * 1.5 + 0.1,
        "restraint must not wreck the end-state balance ({} vs {})",
        lr.imbalance,
        ln.imbalance
    );
}

#[test]
fn every_shipped_conf_parses_and_runs() {
    // each shipped scenario must stay loadable and complete end to end
    let mut seen = 0;
    for entry in std::fs::read_dir(conf_dir()).expect("scenarios/ must exist") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("conf") {
            continue;
        }
        seen += 1;
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        let cfg = trimmed(&name, 3);
        let report = if cfg.cluster_workers.is_some() {
            // cluster confs spawn worker processes; the test harness
            // binary has no `worker` subcommand, so point the master at
            // the real CLI binary (tests/prop_cluster.rs covers the
            // bitwise equivalence — here the conf just has to complete)
            let opts = ClusterRunOptions {
                worker_bin: Some(env!("CARGO_BIN_EXE_dynrepart").into()),
                ..Default::default()
            };
            let (report, _) = Scenario::new(cfg).unwrap().run_cluster_with(&opts).unwrap();
            report
        } else {
            run_with_threads(cfg, 1)
        };
        assert!(!report.rows.is_empty(), "{name} produced no rows");
        assert!(report.table().n_rows() > 0);
    }
    assert!(seen >= 10, "expected at least 10 shipped scenario configs, found {seen}");
}
