//! Pipeline overlap: wall clock of the unified drive loop, lockstep
//! (manual `batch` → `run_batch`, source on the critical path) vs
//! pipelined (`run_stream`, source + decision point overlapped with the
//! stage) at 1/2/4/8 threads. Virtual-time results are identical across
//! both drives and all thread counts by construction (pinned by
//! `tests/prop_parallel.rs`); this bench measures the real-time columns
//! — `wall_s`, `decision_wall_s`, `source_wall_s` — and the
//! pipeline-occupancy ratio. See EXPERIMENTS.md "Pipeline overlap".
use dynrepart::bench::{bench_with, black_box, header, BenchOpts};
use dynrepart::ddps::{EngineConfig, MicroBatchEngine, StreamingEngine};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::workload::{zipf::Zipf, Generator};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_batches = 6usize;
    let per_batch = if quick { 60_000 } else { 500_000 };
    let n_partitions = 32;
    let keys = 100_000;
    let opts = BenchOpts {
        budget_s: 1.0,
        ..Default::default()
    };

    header(&format!(
        "micro-batch drive: {n_batches} batches x {per_batch} records, {n_partitions} partitions"
    ));
    for threads in THREAD_SWEEP {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: 16,
            num_threads: threads,
            ..Default::default()
        };
        let lock = bench_with(
            &format!("lockstep  (batch; run_batch), {threads} thread(s)"),
            opts,
            &mut || {
                let mut e =
                    MicroBatchEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 7);
                let mut z = Zipf::new(keys, 1.1, 7);
                for _ in 0..n_batches {
                    black_box(e.run_batch(&z.batch(per_batch)));
                }
            },
        );
        println!("{}", lock.report());
        let mut occupancy = 0.0;
        let pipe = bench_with(
            &format!("pipelined (run_stream),       {threads} thread(s)"),
            opts,
            &mut || {
                let mut e =
                    MicroBatchEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 7);
                let mut z = Zipf::new(keys, 1.1, 7);
                black_box(e.run_stream(&mut z, per_batch, n_batches));
                occupancy = e.metrics().pipeline_occupancy();
            },
        );
        println!(
            "{}  overlap gain vs lockstep: {:.2}x  occupancy {:.2}",
            pipe.report(),
            lock.mean_ns / pipe.mean_ns,
            occupancy
        );
    }

    header("streaming drive (pinned stage, barrier decision overlapped)");
    for threads in THREAD_SWEEP {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: n_partitions,
            num_threads: threads,
            task_overhead: 0.0,
            ..Default::default()
        };
        let mut occupancy = 0.0;
        let m = bench_with(
            &format!("run_stream, {threads} thread(s)"),
            opts,
            &mut || {
                let mut e =
                    StreamingEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 9);
                let mut z = Zipf::new(keys, 1.1, 9);
                black_box(e.run_stream(&mut z, per_batch, n_batches));
                occupancy = e.metrics().pipeline_occupancy();
            },
        );
        println!("{}  occupancy {:.2}", m.report(), occupancy);
    }

    // Identity assertion: the 8-thread pipelined drive must reproduce the
    // sequential lockstep reports bitwise (virtual columns + state).
    let seq_cfg = EngineConfig {
        n_partitions,
        n_slots: 16,
        ..Default::default()
    };
    let par_cfg = EngineConfig {
        num_threads: 8,
        ..seq_cfg
    };
    let mut seq = MicroBatchEngine::new(seq_cfg, DrConfig::default(), PartitionerChoice::Kip, 11);
    let mut zs = Zipf::new(keys, 1.1, 11);
    let manual: Vec<_> = (0..n_batches).map(|_| seq.run_batch(&zs.batch(per_batch))).collect();
    let mut par = MicroBatchEngine::new(par_cfg, DrConfig::default(), PartitionerChoice::Kip, 11);
    let mut zp = Zipf::new(keys, 1.1, 11);
    let streamed = par.run_stream(&mut zp, per_batch, n_batches);
    assert_eq!(manual.len(), streamed.len());
    for (a, b) in manual.iter().zip(&streamed) {
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(a.repartitioned, b.repartitioned);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.loads, b.loads);
    }
    assert_eq!(
        seq.total_state_weight().to_bits(),
        par.total_state_weight().to_bits()
    );
    println!("\n8-thread pipelined drive bitwise-identical to sequential lockstep: ok");
}
