//! Fig 8: DR speedup per crawl round + NER streaming processing time.
//! The NER reduce cost is calibrated from the real PJRT scorer when
//! artifacts are present.
use dynrepart::figures::fig8;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 0.3 } else { 1.0 };
    fig8::left(scale).emit("fig8_left");
    let cost = fig8::calibrated_reduce_cost();
    println!("calibrated NER reduce cost: {:.3e} s/token\n", cost);
    fig8::right(scale, cost.max(1e-5)).emit("fig8_right");
}
