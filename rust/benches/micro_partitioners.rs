//! §5 "the cost of KIP update is significantly less than that of the other
//! partitioning methods" + partition() hot-path latency (the per-record
//! cost that makes DR overhead "negligible").
use dynrepart::bench::{bench, black_box, header};
use dynrepart::partitioner::*;
use dynrepart::sketch::Histogram;
use dynrepart::workload::{zipf::Zipf, Generator};

fn main() {
    let n = 20;
    let mut z = Zipf::new(100_000, 1.0, 1);
    let recs = z.batch(400_000);
    let hist = Histogram::exact(&recs, 4 * n);

    header("partitioner update cost (20 partitions, B=80 heavy keys)");
    let uhp = Uhp::new(n);
    let base = WeightedHash::with_default_hosts(n, 2);
    let kip0 = Kip::update(&uhp, &base, &hist, KipConfig::default());
    println!("{}", bench("KIP update (Algorithm 1)", || {
        black_box(kip0.updated(&hist));
    }).report());
    for strat in [GedikStrategy::Readj, GedikStrategy::Redist, GedikStrategy::Scan] {
        let g = GedikPartitioner::initial(strat, n, GedikConfig::default(), 3).update(&hist);
        println!("{}", bench(&format!("{} update", strat.name()), || {
            black_box(g.update(&hist));
        }).report());
    }
    let m = Mixed::initial(n, 4).update(&hist);
    println!("{}", bench("Mixed update (incl. theta optimization loop)", || {
        black_box(m.update(&hist));
    }).report());

    header("partition() hot path (per record)");
    let keys: Vec<u64> = (0..10_000u64).collect();
    let kip = kip0.updated(&hist);
    let meas = bench("KIP partition() x10k keys", || {
        let mut acc = 0usize;
        for &k in &keys {
            acc ^= kip.partition(black_box(k));
        }
        black_box(acc);
    });
    println!("{}", meas.report());
    println!("  => {:.1} ns/record", meas.mean_ns / keys.len() as f64);
    let meas = bench("UHP partition() x10k keys", || {
        let mut acc = 0usize;
        for &k in &keys {
            acc ^= uhp.partition(black_box(k));
        }
        black_box(acc);
    });
    println!("{}", meas.report());
    println!("  => {:.1} ns/record", meas.mean_ns / keys.len() as f64);

    header("DRW sampling tap (per record)");
    let mut w = dynrepart::dr::DrWorker::new(160, 1.0, 7);
    let meas = bench("DrWorker observe x10k", || {
        for &k in &keys {
            w.observe(black_box(k), 1.0);
        }
    });
    println!("{}", meas.report());
    println!("  => {:.1} ns/record", meas.mean_ns / keys.len() as f64);
}
