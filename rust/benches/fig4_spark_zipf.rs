//! Fig 4: Spark ± DR over the Zipf exponent — imbalance + total time for
//! 10M records (35 partitions, 40 slots, 1M keys).
use dynrepart::figures::fig4;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 0.1 } else { 1.0 };
    let (left, right) = fig4::tables(scale);
    left.emit("fig4_left");
    right.emit("fig4_right");
}
