//! Fig 6: Flink ± DR — relative throughput increase (parallelism 14/28)
//! and running time for 10M records (parallelism 28).
use dynrepart::figures::fig6;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 0.1 } else { 1.0 };
    let (left, right) = fig6::tables(scale);
    left.emit("fig6_left");
    right.emit("fig6_right");
}
