//! DRM decision-point latency: wall clock of the sharded decision point —
//! histogram tree-merge, blending, candidate construction — at 1/2/4/8
//! threads, for the KIP and Gedik families. Decisions are
//! bitwise-identical across thread counts by construction (pinned by
//! `tests/prop_parallel.rs`; the bench spot-checks it too); this measures
//! the real-time cost of the step the paper calls negligible. See
//! EXPERIMENTS.md "Decision latency".
use dynrepart::bench::{bench_with, black_box, header, BenchOpts};
use dynrepart::ddps::{EngineConfig, MicroBatchEngine};
use dynrepart::dr::{parallel, DrConfig, DrMaster, PartitionerChoice};
use dynrepart::partitioner::GedikStrategy;
use dynrepart::sketch::Histogram;
use dynrepart::workload::{zipf::Zipf, Generator, Record};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// One exact local histogram per DRW, as the harvests would deliver them.
fn worker_histograms(records: &[Record], n_workers: usize, top_k: usize) -> Vec<Histogram> {
    let per = records.len().div_ceil(n_workers).max(1);
    records
        .chunks(per)
        .map(|c| Histogram::exact(c, top_k))
        .collect()
}

fn drm(choice: PartitionerChoice, n_partitions: usize) -> DrMaster {
    let cfg = DrConfig {
        lambda: 4,
        force_updates: true, // construct + install a candidate every call
        ..Default::default()
    };
    DrMaster::new(cfg, choice, n_partitions, 1)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_records = if quick { 200_000 } else { 2_000_000 };
    let n_partitions = 64;
    let n_workers = 32;
    let probe = drm(PartitionerChoice::Kip, n_partitions);
    let top_k = probe.histogram_size(); // λN = 256
    let mut z = Zipf::new(200_000, 1.1, 1);
    let records = z.batch(n_records);
    let hists = worker_histograms(&records, n_workers, top_k);
    let opts = BenchOpts {
        budget_s: 1.0,
        ..Default::default()
    };

    header(&format!(
        "histogram tree-merge wall clock: {n_workers} locals, top-{top_k}"
    ));
    for threads in THREAD_SWEEP {
        let m = bench_with(&format!("merge_histograms_tree, {threads} thread(s)"), opts, &mut || {
            black_box(parallel::merge_histograms_tree(hists.clone(), top_k, threads));
        });
        println!("{}", m.report());
    }

    for choice in [
        PartitionerChoice::Kip,
        PartitionerChoice::Gedik(GedikStrategy::Scan),
    ] {
        header(&format!(
            "full decision point ({}): merge + blend + candidate + install",
            choice.name()
        ));
        let mut base_ns = 0.0;
        for threads in THREAD_SWEEP {
            // One long-lived DRM per thread count, as in a long-running
            // job: the past-histogram window fills and every decide
            // constructs + installs a candidate (force_updates). The
            // per-iteration hists.clone() is a fixed cost common to all
            // thread counts.
            let mut master = drm(choice, n_partitions);
            let m = bench_with(&format!("decide_sharded, {threads} thread(s)"), opts, &mut || {
                black_box(master.decide_sharded(hists.clone(), threads));
            });
            if threads == 1 {
                base_ns = m.mean_ns;
            }
            println!(
                "{}  speedup vs 1 thread: {:.2}x",
                m.report(),
                base_ns / m.mean_ns
            );
        }
    }

    // Engine-level decision-latency budget: the cumulative
    // decision_wall_s / wall_s ratio of a DR-on micro-batch run — the
    // paper's "negligible overhead" claim as one number (EXPERIMENTS.md
    // "Decision latency" records this cell).
    header("engine-level decision-latency budget (micro-batch, DR on)");
    for threads in THREAD_SWEEP {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: 16,
            num_threads: threads,
            ..Default::default()
        };
        let mut engine = MicroBatchEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 7);
        for chunk in records.chunks(records.len().div_ceil(8).max(1)) {
            black_box(engine.run_batch(chunk));
        }
        let m = engine.metrics();
        println!(
            "{threads} thread(s): decision_wall_s / wall_s = {:.4}  ({:.3} ms / {:.3} ms)",
            m.decision_wall_s / m.wall_s.max(f64::MIN_POSITIVE),
            m.decision_wall_s * 1e3,
            m.wall_s * 1e3
        );
    }

    // Determinism spot check: sharded decisions must be bitwise-identical
    // to the sequential path.
    let mut seq = drm(PartitionerChoice::Kip, n_partitions);
    let mut par = drm(PartitionerChoice::Kip, n_partitions);
    let ds = seq.decide_sharded(hists.clone(), 1);
    let dp = par.decide_sharded(hists, 8);
    assert_eq!(ds.epoch, dp.epoch);
    assert_eq!(ds.histogram.entries(), dp.histogram.entries());
    let (ps, pp) = (
        ds.new_partitioner().expect("forced"),
        dp.new_partitioner().expect("forced"),
    );
    for k in 0..100_000u64 {
        assert_eq!(ps.partition(k), pp.partition(k), "routing diverged at key {k}");
    }
    println!("\n8-thread decision bitwise-identical to sequential: ok");
}
