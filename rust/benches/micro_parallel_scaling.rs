//! Parallel ShuffleStage scaling: wall clock of the sharded executor at
//! 1/2/4/8 threads on a large skewed micro-batch, plus an engine-level
//! run. Virtual-time results are identical across thread counts by
//! construction (pinned by `tests/prop_parallel.rs`); this bench measures
//! the real-time column. See EXPERIMENTS.md "Parallel scaling".
use dynrepart::bench::{bench_with, black_box, header, BenchOpts};
use dynrepart::ddps::{EngineConfig, MicroBatchEngine, Scheduling, ShuffleStage};
use dynrepart::dr::{DrConfig, PartitionerChoice};
use dynrepart::partitioner::{EpochedPartitioner, Uhp};
use dynrepart::workload::{zipf::Zipf, Generator};
use std::sync::Arc;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_records = if quick { 200_000 } else { 2_000_000 };
    let n_partitions = 64;
    let mut z = Zipf::new(200_000, 1.1, 1);
    let records = z.batch(n_records);
    let epoch = EpochedPartitioner::new(Arc::new(Uhp::with_seed(n_partitions, 1))).current();
    let opts = BenchOpts {
        budget_s: 1.0,
        ..Default::default()
    };

    header(&format!(
        "ShuffleStage wall clock: {n_records} records, {n_partitions} partitions"
    ));
    let mut base_ns = 0.0;
    for threads in THREAD_SWEEP {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: 16,
            num_threads: threads,
            ..Default::default()
        };
        let stage = ShuffleStage::new(&cfg, Scheduling::Wave);
        let m = bench_with(
            &format!("route + keyed reduce, {threads} thread(s)"),
            opts,
            &mut || {
                black_box(stage.run(&records, &epoch, None));
            },
        );
        if threads == 1 {
            base_ns = m.mean_ns;
        }
        println!(
            "{}  speedup vs 1 thread: {:.2}x",
            m.report(),
            base_ns / m.mean_ns
        );
    }

    header("micro-batch engine wall clock (DR on, taps + harvests sharded)");
    for threads in THREAD_SWEEP {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: 16,
            num_threads: threads,
            ..Default::default()
        };
        let m = bench_with(&format!("run_batch, {threads} thread(s)"), opts, &mut || {
            let mut e = MicroBatchEngine::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 7);
            for chunk in records.chunks(records.len().div_ceil(4)) {
                black_box(e.run_batch(chunk));
            }
        });
        println!("{}", m.report());
    }

    // Determinism spot check: sharded loads must be bitwise-identical to
    // the sequential reference.
    let seq_cfg = EngineConfig {
        n_partitions,
        n_slots: 16,
        ..Default::default()
    };
    let par_cfg = EngineConfig {
        num_threads: 8,
        ..seq_cfg
    };
    let seq = ShuffleStage::new(&seq_cfg, Scheduling::Wave).run(&records, &epoch, None);
    let par = ShuffleStage::new(&par_cfg, Scheduling::Wave).run(&records, &epoch, None);
    assert_eq!(seq.loads, par.loads);
    assert_eq!(seq.record_counts, par.record_counts);
    println!("\n8-thread loads bitwise-identical to sequential: ok");
}
