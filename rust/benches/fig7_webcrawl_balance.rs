//! Fig 7: web-crawl round 7 — per-partition record balance and processing
//! time, Spark ± DR (8 executors × 8 cores).
use dynrepart::figures::fig7;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 0.3 } else { 1.0 };
    fig7::left(scale).emit("fig7_left");
    fig7::right(scale).emit("fig7_right");
}
