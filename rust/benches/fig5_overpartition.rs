//! Fig 5: over-partitioning study — time + imbalance vs #partitions,
//! Spark ± DR, 40 slots.
use dynrepart::figures::fig5;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let scale = if quick { 0.1 } else { 1.0 };
    let (left, right) = fig5::tables(scale);
    left.emit("fig5_left");
    right.emit("fig5_right");
}
