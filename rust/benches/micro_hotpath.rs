//! Millions-of-keys hot path: throughput and memory of the three
//! per-record/per-decision paths at 10^5 → 10^7 live keys.
//!
//! - **route** — `PartitionerEpoch::partition` through the flat-array
//!   fast path vs the `dyn Partitioner` call it lowers (bitwise-equal
//!   routing, asserted here).
//! - **update** — `StateStore::fold_count` into the open-addressing
//!   arena; reports records/sec *and* bytes/key (asserted ≤ 256 for
//!   count-only states).
//! - **decide** — the sharded DRM decision point with bounded sketches
//!   (`SketchConfig` at the reference knobs) vs the exact path, plus the
//!   identity assertion that bounded-sketch-*off* equals exact bitwise.
//!
//! `BENCH_QUICK=1` trims the key sweep to 10^6 (the CI mode); the full
//! sweep ends at 10^7. See EXPERIMENTS.md "Hot path".

use dynrepart::bench::{bench_with, black_box, header, BenchOpts};
use dynrepart::dr::{DrConfig, DrMaster, DrWorker, PartitionerChoice};
use dynrepart::hash::fmix64;
use dynrepart::partitioner::{Kip, KipConfig, Partitioner, PartitionerEpoch, Uhp, WeightedHash};
use dynrepart::sketch::{Histogram, SketchConfig};
use dynrepart::state::StateStore;
use dynrepart::workload::Key;
use std::sync::Arc;

/// Bounding knobs scaled to this bench's λN — the same three-knob shape
/// as the original system's repartitioning.conf (histogram-compaction /
/// histogram-size-boundary / take).
const BOUNDED: SketchConfig = SketchConfig {
    compaction_interval: 1250,
    size_boundary: 1024,
    take_top_k: 128,
};

const N_PARTITIONS: usize = 64;

/// A deterministic stream of `n` keys drawn uniformly from `n_keys` live
/// keys — millions of live keys without a workload-generator table.
fn keystream(n_keys: usize, n: usize, seed: u64) -> Vec<Key> {
    (0..n as u64).map(|i| fmix64(i ^ seed) % n_keys as u64).collect()
}

fn fmt_rate(records_per_s: f64) -> String {
    if records_per_s >= 1e6 {
        format!("{:.1} Mrec/s", records_per_s / 1e6)
    } else {
        format!("{:.0} krec/s", records_per_s / 1e3)
    }
}

/// A KIP epoch with λN explicit heavy routes over `n_keys` live keys.
fn kip_epoch(n_keys: usize) -> PartitionerEpoch {
    let cfg = KipConfig::default();
    let b = cfg.histogram_size(N_PARTITIONS);
    // heavy keys spread across the key space, 30% of total mass
    let freqs: Vec<(Key, f64)> = (0..b as u64)
        .map(|i| (fmix64(i) % n_keys as u64, 0.3 / b as f64))
        .collect();
    let mut dedup = freqs;
    dedup.sort_unstable_by_key(|&(k, _)| k);
    dedup.dedup_by_key(|&mut (k, _)| k);
    let hist = Histogram::from_freqs(&dedup, 1.0);
    let kip = Kip::update(
        &Uhp::new(N_PARTITIONS),
        &WeightedHash::with_default_hosts(N_PARTITIONS, 3),
        &hist,
        cfg,
    );
    PartitionerEpoch::new(1, Arc::new(kip))
}

fn route_bench(sweep: &[usize], opts: BenchOpts, batch: usize) {
    header("route: PartitionerEpoch::partition, KIP flat vs dyn");
    for &n_keys in sweep {
        let ep = kip_epoch(n_keys);
        let keys = keystream(n_keys, batch, 0x5EED);

        // identity: the flat fast path must route bitwise like the dyn
        // partitioner it was lowered from
        for &k in keys.iter().take(100_000) {
            assert_eq!(
                ep.partition(k),
                ep.as_dyn().partition(k),
                "flat/dyn routing diverged at key {k}"
            );
        }

        let m = bench_with(&format!("route/flat, {n_keys} keys"), opts, &mut || {
            let mut acc = 0usize;
            for &k in &keys {
                acc += ep.partition(k);
            }
            black_box(acc);
        });
        println!("{}  {}", m.report(), fmt_rate(m.throughput(batch as f64)));

        let m = bench_with(&format!("route/dyn, {n_keys} keys"), opts, &mut || {
            let mut acc = 0usize;
            for &k in &keys {
                acc += ep.as_dyn().partition(k);
            }
            black_box(acc);
        });
        println!("{}  {}", m.report(), fmt_rate(m.throughput(batch as f64)));
    }
}

fn update_bench(sweep: &[usize], opts: BenchOpts, batch: usize) {
    header("update: StateStore::fold_count, open-addressing arena");
    for &n_keys in sweep {
        let mut store = StateStore::new();
        for k in 0..n_keys as u64 {
            store.fold_count(k, 1.0);
        }
        assert_eq!(store.n_keys(), n_keys);
        let bytes_per_key = store.footprint_bytes() as f64 / n_keys as f64;
        // count-only states must stay inline: no per-key heap Vec
        assert!(
            bytes_per_key <= 256.0,
            "{n_keys} keys: {bytes_per_key:.1} bytes/key exceeds the inline budget"
        );

        let keys = keystream(n_keys, batch, 0xF01D);
        let m = bench_with(&format!("update/fold, {n_keys} keys"), opts, &mut || {
            for &k in &keys {
                store.fold_count(k, 1.0);
            }
            black_box(store.total_weight());
        });
        println!(
            "{}  {}  {:.1} bytes/key",
            m.report(),
            fmt_rate(m.throughput(batch as f64)),
            bytes_per_key
        );
    }
}

fn drm(sketch: SketchConfig) -> DrMaster {
    // generous exact-path counters (16× λN) so the bounded knobs above
    // actually bite: boundary < capacity, take < histogram size
    let cfg = DrConfig {
        lambda: 4,
        counter_capacity_factor: 16,
        force_updates: true,
        ..Default::default()
    };
    DrMaster::with_sketch(cfg, PartitionerChoice::Kip, N_PARTITIONS, 1, sketch)
}

/// Local histograms as `n_workers` DRWs would deliver them after
/// observing the stream, under the given sketch knobs.
fn worker_histograms(
    master: &DrMaster,
    keys: &[Key],
    n_workers: usize,
    sketch: SketchConfig,
) -> Vec<Histogram> {
    let dr = *master.config();
    let per = keys.len().div_ceil(n_workers).max(1);
    keys.chunks(per)
        .enumerate()
        .map(|(w, chunk)| {
            let mut drw = DrWorker::with_sketch(
                master.worker_capacity(),
                dr.sample_rate,
                1 ^ (w as u64) << 8,
                sketch,
            );
            for &k in chunk {
                drw.observe(k, 1.0);
            }
            if sketch.size_boundary > 0 {
                assert!(
                    drw.footprint() <= sketch.size_boundary + sketch.compaction_interval,
                    "worker sketch exceeded its bound"
                );
            }
            drw.harvest(master.ship_size())
        })
        .collect()
}

fn decide_bench(sweep: &[usize], opts: BenchOpts, batch: usize, threads: usize) {
    header(&format!(
        "decide: sharded DRM decision point, {threads} threads, bounded vs exact"
    ));
    for &n_keys in sweep {
        let keys = keystream(n_keys, batch, 0xDEC1);
        for (label, sketch) in [("exact", SketchConfig::unbounded()), ("bounded", BOUNDED)] {
            let mut master = drm(sketch);
            let hists = worker_histograms(&master, &keys, 8, sketch);
            let ship: usize = hists.iter().map(|h| h.len()).sum();
            let m = bench_with(&format!("decide/{label}, {n_keys} keys"), opts, &mut || {
                black_box(master.decide_sharded(hists.clone(), threads));
            });
            println!("{}  ship={ship} entries", m.report());
        }
    }
}

/// Bounded-sketch-*off* must reproduce the exact decision path bitwise.
fn identity_check(batch: usize) {
    let keys = keystream(500_000, batch, 0x1DE4);
    let mut exact = drm(SketchConfig::unbounded());
    let mut dflt = drm(SketchConfig::default());
    let h_exact = worker_histograms(&exact, &keys, 8, SketchConfig::unbounded());
    let h_dflt = worker_histograms(&dflt, &keys, 8, SketchConfig::default());
    for (a, b) in h_exact.iter().zip(&h_dflt) {
        assert_eq!(a.entries(), b.entries(), "default sketch altered a DRW harvest");
    }
    let da = exact.decide_sharded(h_exact, 4);
    let db = dflt.decide_sharded(h_dflt, 4);
    assert_eq!(da.epoch, db.epoch);
    assert_eq!(da.histogram.entries(), db.histogram.entries());
    let (pa, pb) = (
        da.new_partitioner().expect("forced"),
        db.new_partitioner().expect("forced"),
    );
    for k in 0..200_000u64 {
        assert_eq!(pa.partition(k), pb.partition(k), "routing diverged at key {k}");
    }
    println!("\ndefault SketchConfig bitwise-identical to the exact path: ok");

    // and with bounding on, the merged histogram honours the take cut
    let mut bounded = drm(BOUNDED);
    let hb = worker_histograms(&bounded, &keys, 8, BOUNDED);
    assert!(hb.iter().all(|h| h.len() <= BOUNDED.take_top_k));
    let d = bounded.decide_sharded(hb, 4);
    assert!(d.histogram.len() <= bounded.histogram_size());
    println!("bounded sketch honours ship/take bounds: ok");
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let sweep: &[usize] = if quick {
        &[100_000, 1_000_000]
    } else {
        &[100_000, 1_000_000, 10_000_000]
    };
    let batch = if quick { 500_000 } else { 2_000_000 };
    let opts = BenchOpts {
        budget_s: if quick { 0.3 } else { 1.0 },
        max_iters: if quick { 50 } else { 10_000 },
        ..Default::default()
    };

    route_bench(sweep, opts, batch);
    update_bench(sweep, opts, batch);
    decide_bench(sweep, opts, batch, 4);
    identity_check(batch);
}
