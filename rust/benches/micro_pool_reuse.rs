//! Pool reuse: per-interval overhead of the persistent worker pool
//! (parked threads + recycled [`RoutedBatch`] scratch) vs the
//! per-call-spawn executor it replaced. The baseline below reimplements
//! the old `thread::scope` route + shuffle verbatim — fresh OS threads
//! and fresh `Vec<Vec<u32>>` buckets every interval — so the speedup
//! column isolates exactly what the pool removes: thread creation,
//! bucket allocation and the shard-accumulator copy-merge. Results are
//! bitwise-identical across all three paths (asserted at the end).
//! See EXPERIMENTS.md "Pool reuse".
use dynrepart::bench::{bench_with, black_box, header, BenchOpts};
use dynrepart::ddps::exec::parallel::{route_into, shard_ranges, shuffle_sharded};
use dynrepart::ddps::exec::pool::WorkerPool;
use dynrepart::partitioner::{EpochedPartitioner, PartitionerEpoch, Uhp};
use dynrepart::state::StateStore;
use dynrepart::workload::{zipf::Zipf, Generator, Record};
use std::sync::Arc;

/// The shard width `shard_ranges` derives from (private in the library;
/// replicated here so the baseline buckets by the same decomposition).
fn shard_chunk(n: usize, shards: usize) -> usize {
    n.div_ceil(shards.max(1)).max(1)
}

/// The pre-pool executor, preserved as the baseline: one `thread::scope`
/// spawn set per routing pass (per-chunk `Vec<Vec<u32>>` buckets,
/// concatenated in chunk order) and another per reduce pass (per-shard
/// accumulators copy-merged into the output in shard order). Every call
/// pays thread creation and every allocation afresh — exactly what each
/// interval paid before the pool.
fn scoped_route_shuffle(
    records: &[Record],
    epoch: &PartitionerEpoch,
    n_partitions: usize,
    num_threads: usize,
) -> (Vec<f64>, Vec<u64>) {
    let rec_ranges = shard_ranges(records.len(), num_threads);
    let part_ranges = shard_ranges(n_partitions, num_threads);
    let n_shards = part_ranges.len();
    let pc = shard_chunk(n_partitions, num_threads);

    let mut routes: Vec<u32> = Vec::with_capacity(records.len());
    let mut shard_indices: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
    std::thread::scope(|s| {
        let handles: Vec<_> = rec_ranges
            .iter()
            .cloned()
            .map(|range| {
                s.spawn(move || {
                    let mut routes = Vec::with_capacity(range.len());
                    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
                    for i in range {
                        let p = epoch.partition(records[i].key);
                        routes.push(p as u32);
                        buckets[p / pc].push(i as u32);
                    }
                    (routes, buckets)
                })
            })
            .collect();
        for h in handles {
            let (r, buckets) = h.join().expect("scoped route worker panicked");
            routes.extend_from_slice(&r);
            for (group, bucket) in shard_indices.iter_mut().zip(buckets) {
                group.extend_from_slice(&bucket);
            }
        }
    });

    let mut loads = vec![0.0f64; n_partitions];
    let mut record_counts = vec![0u64; n_partitions];
    std::thread::scope(|s| {
        let routes = &routes;
        let handles: Vec<_> = part_ranges
            .iter()
            .cloned()
            .enumerate()
            .map(|(s_idx, range)| {
                let indices = &shard_indices[s_idx];
                s.spawn(move || {
                    let mut l = vec![0.0f64; range.len()];
                    let mut c = vec![0u64; range.len()];
                    for &i in indices {
                        let r = &records[i as usize];
                        let p = routes[i as usize] as usize - range.start;
                        l[p] += r.weight;
                        c[p] += 1;
                    }
                    (range, l, c)
                })
            })
            .collect();
        for h in handles {
            let (range, l, c) = h.join().expect("scoped shuffle worker panicked");
            loads[range.clone()].copy_from_slice(&l);
            record_counts[range].copy_from_slice(&c);
        }
    });
    (loads, record_counts)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_partitions = 32;
    let keys = 50_000;
    let sizes: &[usize] = if quick {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    let opts = BenchOpts {
        budget_s: if quick { 0.4 } else { 1.0 },
        ..Default::default()
    };

    let ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(n_partitions, 7))).current();
    for &n_records in sizes {
        let mut z = Zipf::new(keys, 1.1, 7);
        let recs = z.batch(n_records);
        header(&format!("route + shuffle, {n_records} records x {n_partitions} partitions"));
        for threads in [4usize, 8] {
            let base = bench_with(
                &format!("per-call spawn (old), {threads} thread(s)"),
                opts,
                &mut || {
                    black_box(scoped_route_shuffle(&recs, &ep, n_partitions, threads));
                },
            );
            println!(
                "{}  ({:.2} Mrec/s)",
                base.report(),
                base.throughput(n_records as f64) / 1e6
            );
            let pool = WorkerPool::for_threads(threads);
            let pooled = bench_with(
                &format!("persistent pool,      {threads} thread(s)"),
                opts,
                &mut || {
                    let mut routed = pool.take_routed();
                    route_into(&mut routed, &recs, &ep, threads);
                    black_box(shuffle_sharded(&recs, &routed, n_partitions, None, threads));
                    pool.put_routed(routed);
                },
            );
            println!(
                "{}  ({:.2} Mrec/s)  spawn overhead removed: {:.2}x",
                pooled.report(),
                pooled.throughput(n_records as f64) / 1e6,
                base.mean_ns / pooled.mean_ns
            );
        }
    }

    // Identity assertion: pooled, per-call-spawn and sequential must agree
    // bitwise on loads, counts and keyed state.
    let mut z = Zipf::new(keys, 1.2, 13);
    let recs = z.batch(40_007);
    let mut loads_seq = vec![0.0f64; n_partitions];
    let mut counts_seq = vec![0u64; n_partitions];
    let mut stores_seq: Vec<StateStore> = (0..n_partitions).map(|_| StateStore::new()).collect();
    for r in &recs {
        let p = ep.partition(r.key);
        loads_seq[p] += r.weight;
        counts_seq[p] += 1;
        stores_seq[p].fold_count(r.key, r.weight);
    }
    for threads in [4usize, 8] {
        let (loads_old, counts_old) = scoped_route_shuffle(&recs, &ep, n_partitions, threads);
        let pool = WorkerPool::for_threads(threads);
        let mut routed = pool.take_routed();
        route_into(&mut routed, &recs, &ep, threads);
        let mut stores: Vec<StateStore> = (0..n_partitions).map(|_| StateStore::new()).collect();
        let (loads, counts) =
            shuffle_sharded(&recs, &routed, n_partitions, Some(stores.as_mut_slice()), threads);
        pool.put_routed(routed);
        assert_eq!(counts, counts_seq, "{threads} threads: counts");
        assert_eq!(counts, counts_old, "{threads} threads: counts vs old executor");
        for (p, (a, b)) in loads.iter().zip(&loads_seq).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads: load bits, partition {p}");
        }
        for (a, b) in loads.iter().zip(&loads_old) {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads: load bits vs old executor");
        }
        for (s, r) in stores.iter().zip(&stores_seq) {
            assert_eq!(s.n_keys(), r.n_keys(), "{threads} threads: state keys");
            assert_eq!(
                s.total_weight().to_bits(),
                r.total_weight().to_bits(),
                "{threads} threads: state weight bits"
            );
            for k in r.keys() {
                assert_eq!(s.get(k), r.get(k), "{threads} threads: key {k} state");
            }
        }
    }
    println!("\npooled executor bitwise-identical to per-call spawn and sequential: ok");
}
