//! Fig 3: imbalance + relative state migration over the drifting LFM
//! stream (20 batches × 100K, 20 partitions, state window 5, forced
//! updates, avg of 10 iterations).
use dynrepart::figures::fig3;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (iters, scale) = if quick { (2, 0.2) } else { (10, 1.0) };
    let (left, right) = fig3::tables(iters, scale);
    left.emit("fig3_left");
    right.emit("fig3_right");
    fig3::summary(iters, scale).emit("fig3_summary");
}
