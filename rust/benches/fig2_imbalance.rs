//! Fig 2: load imbalance vs parallelism (component experiment).
//! Paper setup: ZIPF exp 1.0, 100K keys, avg of 100 runs, λ=2 + λ sweep.
use dynrepart::figures::fig2;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (repeats, scale) = if quick { (3, 0.25) } else { (20, 1.0) };
    fig2::left(repeats, scale).emit("fig2_left");
    fig2::right(repeats, scale).emit("fig2_right");
}
