//! Mini property-based testing framework (proptest substitute — crates.io
//! is unreachable in this image; see DESIGN.md "Substitutions").
//!
//! Usage (doctest disabled: doctest binaries don't inherit the
//! libxla_extension rpath in this offline image):
//! ```text
//! use dynrepart::prop::{forall, Gen};
//! forall(100, |g: &mut Gen| {
//!     let xs = g.vec(0..50, |g| g.u64(0..1000));
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert!(sorted.len() == xs.len());
//! });
//! ```
//!
//! Each case runs with a fresh deterministic seed derived from a base seed
//! (override with env `PROP_SEED`); on panic the failing case's seed is
//! printed so the exact case can be replayed with `PROP_SEED=<seed>
//! PROP_CASES=1`.

use crate::util::Rng;

/// Case-local generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.next_below(range.end - range.start)
    }

    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn f64(&mut self, range: std::ops::Range<f64>) -> f64 {
        range.start + self.rng.next_f64() * (range.end - range.start)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.next_f64() < p_true
    }

    pub fn vec<T>(
        &mut self,
        len: std::ops::Range<usize>,
        mut item: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| item(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0..xs.len())]
    }

    /// Direct access for distributions the helpers don't cover.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `property` on `cases` generated cases. Panics (with the case seed)
/// on the first failure. `PROP_SEED` overrides the base seed; `PROP_CASES`
/// overrides the case count.
pub fn forall(cases: usize, mut property: impl FnMut(&mut Gen)) {
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE_5EED);
    let cases: usize = std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {i}/{cases}; replay with PROP_SEED={seed} PROP_CASES=1"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        forall(200, |g| {
            let x = g.u64(10..20);
            assert!((10..20).contains(&x));
            let f = g.f64(-1.0..1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec(0..5, |g| g.usize(0..3));
            assert!(v.len() < 5);
            assert!(v.iter().all(|&x| x < 3));
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        forall(10, |g| first.push(g.u64(0..1_000_000)));
        let mut second: Vec<u64> = Vec::new();
        forall(10, |g| second.push(g.u64(0..1_000_000)));
        assert_eq!(first, second);
    }

    #[test]
    fn failure_is_reported() {
        let r = std::panic::catch_unwind(|| {
            forall(50, |g| {
                let x = g.u64(0..100);
                assert!(x < 90, "intentional failure");
            });
        });
        assert!(r.is_err());
    }

    #[test]
    fn pick_stays_in_slice() {
        forall(100, |g| {
            let xs = [1, 2, 3];
            assert!(xs.contains(g.pick(&xs)));
        });
    }
}
