//! The §6 NER streaming application: host-partitioned entity recognition
//! with windowed frequent-mention aggregation.
//!
//! "a NER model is used to calculate frequent mentions of the recognized
//! entities in 60-minute time windows. Here, we partition by host ...
//! Calculating frequent mentions requires sorting of entities within the
//! time window and a mutable update of state per domain key."
//!
//! [`EntityWindows`] is the reducer state: per-host, per-window class
//! histograms with top-k "frequent mentions" queries. The heavy compute
//! (the scorer) is the AOT artifact executed through
//! [`crate::runtime::NerExecutable`]; this module is pure L3 state logic
//! and therefore testable without artifacts.

use crate::workload::Key;
use std::collections::HashMap;

pub const N_CLASSES: usize = 9;

/// Human-readable class names (BIO tagging over 4 entity types).
pub const CLASS_NAMES: [&str; N_CLASSES] = [
    "O", "B-PER", "I-PER", "B-ORG", "I-ORG", "B-LOC", "I-LOC", "B-MISC", "I-MISC",
];

/// Windowed per-host entity statistics — the mutable reducer state.
#[derive(Debug, Clone)]
pub struct EntityWindows {
    /// Window length in event-time units.
    window: u64,
    /// (host, window index) -> class histogram.
    state: HashMap<(Key, u64), [f64; N_CLASSES]>,
    /// Documents folded per host (all windows).
    docs_per_host: HashMap<Key, u64>,
}

impl EntityWindows {
    pub fn new(window: u64) -> Self {
        assert!(window > 0);
        Self {
            window,
            state: HashMap::new(),
            docs_per_host: HashMap::new(),
        }
    }

    pub fn window_of(&self, ts: u64) -> u64 {
        ts / self.window
    }

    /// Fold one scored document (class histogram contribution) into the
    /// host's current window.
    pub fn fold(&mut self, host: Key, ts: u64, class_hist: &[f64; N_CLASSES]) {
        let w = self.window_of(ts);
        let slot = self.state.entry((host, w)).or_insert([0.0; N_CLASSES]);
        for (a, b) in slot.iter_mut().zip(class_hist) {
            *a += b;
        }
        *self.docs_per_host.entry(host).or_insert(0) += 1;
    }

    /// Fold a batch-level histogram (from `NerOutput.class_hist`).
    pub fn fold_batch(&mut self, host: Key, ts: u64, class_hist: &[f32]) {
        assert_eq!(class_hist.len(), N_CLASSES);
        let mut h = [0.0f64; N_CLASSES];
        for (i, v) in class_hist.iter().enumerate() {
            h[i] = *v as f64;
        }
        self.fold(host, ts, &h);
    }

    /// "Frequent mentions": the top-k classes of a host's window, sorted
    /// by mention weight (requires sorting within the window — the paper's
    /// stateful, compute-heavy reducer behaviour).
    pub fn frequent_mentions(&self, host: Key, ts: u64, k: usize) -> Vec<(&'static str, f64)> {
        let w = self.window_of(ts);
        let Some(hist) = self.state.get(&(host, w)) else {
            return Vec::new();
        };
        let mut v: Vec<(usize, f64)> = hist
            .iter()
            .cloned()
            .enumerate()
            .filter(|&(c, x)| c != 0 && x > 0.0) // class 0 is "O" (non-entity)
            .collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v.into_iter().map(|(c, x)| (CLASS_NAMES[c], x)).collect()
    }

    pub fn n_hosts(&self) -> usize {
        self.docs_per_host.len()
    }

    pub fn docs_for(&self, host: Key) -> u64 {
        self.docs_per_host.get(&host).cloned().unwrap_or(0)
    }

    /// Drop windows older than `ts - retain` (event-time GC).
    pub fn evict_before(&mut self, ts: u64, retain: u64) {
        let min_w = self.window_of(ts.saturating_sub(retain));
        self.state.retain(|&(_, w), _| w >= min_w);
    }

    /// State weight for migration accounting: linear in entries.
    pub fn state_weight(&self) -> f64 {
        self.state.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(class: usize, w: f64) -> [f64; N_CLASSES] {
        let mut h = [0.0; N_CLASSES];
        h[class] = w;
        h
    }

    #[test]
    fn fold_and_query() {
        let mut ew = EntityWindows::new(3600);
        ew.fold(1, 100, &hist(1, 5.0)); // B-PER
        ew.fold(1, 200, &hist(3, 9.0)); // B-ORG
        ew.fold(1, 300, &hist(1, 2.0));
        let top = ew.frequent_mentions(1, 300, 2);
        assert_eq!(top, vec![("B-ORG", 9.0), ("B-PER", 7.0)]);
    }

    #[test]
    fn windows_are_disjoint() {
        let mut ew = EntityWindows::new(100);
        ew.fold(1, 50, &hist(1, 1.0));
        ew.fold(1, 150, &hist(1, 10.0));
        assert_eq!(ew.frequent_mentions(1, 50, 5), vec![("B-PER", 1.0)]);
        assert_eq!(ew.frequent_mentions(1, 150, 5), vec![("B-PER", 10.0)]);
    }

    #[test]
    fn o_class_excluded_from_mentions() {
        let mut ew = EntityWindows::new(100);
        ew.fold(7, 10, &hist(0, 100.0)); // O
        ew.fold(7, 10, &hist(2, 1.0)); // I-PER
        assert_eq!(ew.frequent_mentions(7, 10, 5), vec![("I-PER", 1.0)]);
    }

    #[test]
    fn hosts_are_isolated() {
        let mut ew = EntityWindows::new(100);
        ew.fold(1, 10, &hist(1, 1.0));
        ew.fold(2, 10, &hist(3, 1.0));
        assert_eq!(ew.frequent_mentions(1, 10, 5)[0].0, "B-PER");
        assert_eq!(ew.frequent_mentions(2, 10, 5)[0].0, "B-ORG");
        assert_eq!(ew.n_hosts(), 2);
    }

    #[test]
    fn eviction_drops_old_windows() {
        let mut ew = EntityWindows::new(100);
        ew.fold(1, 10, &hist(1, 1.0));
        ew.fold(1, 1000, &hist(1, 1.0));
        assert_eq!(ew.state_weight(), 2.0);
        ew.evict_before(1000, 200);
        assert_eq!(ew.state_weight(), 1.0);
        assert!(ew.frequent_mentions(1, 10, 5).is_empty());
    }

    #[test]
    fn fold_batch_f32_bridge() {
        let mut ew = EntityWindows::new(100);
        let mut h = [0.0f32; N_CLASSES];
        h[5] = 4.5; // B-LOC
        ew.fold_batch(9, 42, &h);
        assert_eq!(ew.frequent_mentions(9, 42, 1), vec![("B-LOC", 4.5)]);
    }
}
