//! dynrepart CLI — run experiments and inspect the system from one binary.
//!
//!   dynrepart fig <2|3|4|5|6|7|8>   regenerate a paper figure (quick scale)
//!   dynrepart bench-partitioners    micro-bench partitioner updates
//!   dynrepart quickstart            the README demo
//!   dynrepart scenario <conf>       run an operational scenario end to end
//!   dynrepart master <conf>         run a cluster scenario as the master process
//!   dynrepart worker --connect <ep> --id <n>   run one worker process (spawned by master)
//!   dynrepart artifacts             check AOT artifacts + PJRT runtime

use dynrepart::figures::*;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("fig") => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("");
            let scale: f64 = args
                .get(2)
                .and_then(|s| s.parse().ok())
                .unwrap_or(0.25);
            match which {
                "2" => {
                    fig2::left(5, scale).emit("fig2_left");
                    fig2::right(5, scale).emit("fig2_right");
                }
                "3" => {
                    let (l, r) = fig3::tables(3, scale);
                    l.emit("fig3_left");
                    r.emit("fig3_right");
                    fig3::summary(3, scale).emit("fig3_summary");
                }
                "4" => {
                    let (l, r) = fig4::tables(scale);
                    l.emit("fig4_left");
                    r.emit("fig4_right");
                }
                "5" => {
                    let (l, r) = fig5::tables(scale);
                    l.emit("fig5_left");
                    r.emit("fig5_right");
                }
                "6" => {
                    let (l, r) = fig6::tables(scale);
                    l.emit("fig6_left");
                    r.emit("fig6_right");
                }
                "7" => {
                    fig7::left(scale).emit("fig7_left");
                    fig7::right(scale).emit("fig7_right");
                }
                "8" => {
                    fig8::left(scale).emit("fig8_left");
                    let c = fig8::calibrated_reduce_cost();
                    fig8::right(scale, c.max(1e-5)).emit("fig8_right");
                }
                _ => {
                    eprintln!("usage: dynrepart fig <2..8> [scale]");
                    std::process::exit(2);
                }
            }
        }
        Some("artifacts") => match dynrepart::runtime::Artifacts::open_default() {
            Ok(arts) => {
                println!("artifacts dir: {}", arts.dir.display());
                for name in arts.manifest.names() {
                    let e = arts.manifest.get(name).unwrap();
                    println!("  {name}: {} inputs, {} outputs", e.inputs.len(), e.n_outputs);
                }
                match dynrepart::runtime::Runtime::cpu() {
                    Ok(rt) => println!("PJRT: {} OK", rt.platform()),
                    Err(e) => println!("PJRT unavailable: {e}"),
                }
            }
            Err(e) => {
                eprintln!("no artifacts ({e}); run `make artifacts`");
                std::process::exit(1);
            }
        },
        Some("quickstart") => {
            let cfg = dynrepart::ddps::EngineConfig {
                n_partitions: 35,
                n_slots: 40,
                // executor threads from DYNREPART_THREADS (1 = sequential)
                ..dynrepart::ddps::EngineConfig::from_env()
            };
            for (label, dr, choice) in [
                (
                    "hash",
                    dynrepart::dr::DrConfig::disabled(),
                    dynrepart::dr::PartitionerChoice::Uhp,
                ),
                ("DR", dynrepart::dr::DrConfig::default(), dynrepart::dr::PartitionerChoice::Kip),
            ] {
                let mut engine = dynrepart::ddps::MicroBatchEngine::new(cfg, dr, choice, 1);
                let mut z = dynrepart::workload::zipf::Zipf::new(100_000, 1.0, 1);
                // unified loop: source generation overlaps the stages
                // when DYNREPART_THREADS > 1
                engine.run_stream(&mut z, 100_000, 8);
                let m = engine.metrics();
                println!(
                    "{label}: {:.3} virtual s  (pipeline occupancy {:.2})",
                    m.total_vtime,
                    m.pipeline_occupancy()
                );
            }
        }
        Some("scenario") => {
            let Some(path) = args.get(1) else {
                eprintln!("usage: dynrepart scenario <conf-path>");
                eprintln!("  e.g.: dynrepart scenario scenarios/hotspot_flip.conf");
                std::process::exit(2);
            };
            let conf = std::path::Path::new(path);
            let scenario = match dynrepart::scenario::Scenario::from_file(conf) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("invalid scenario {path}: {e}");
                    std::process::exit(2);
                }
            };
            match scenario.run() {
                Ok(report) => {
                    let slug = format!("scenario_{}", report.name.replace('-', "_"));
                    report.table().emit(&slug);
                    if report.recoveries_verified > 0 {
                        println!(
                            "recovery verified: {} replayed interval(s) bitwise-identical",
                            report.recoveries_verified
                        );
                    }
                    println!(
                        "final epoch {}  total vtime {:.3}s  state weight {:.1}",
                        report.final_epoch, report.total_vtime, report.total_state_weight
                    );
                }
                Err(e) => {
                    eprintln!("scenario failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        Some("master") => {
            let Some(path) = args.get(1) else {
                eprintln!("usage: dynrepart master <conf-path>");
                eprintln!("  e.g.: dynrepart master scenarios/cluster_hotspot_flip.conf");
                std::process::exit(2);
            };
            let conf = std::path::Path::new(path);
            let scenario = match dynrepart::scenario::Scenario::from_file(conf) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("invalid scenario {path}: {e}");
                    std::process::exit(2);
                }
            };
            if scenario.config().cluster_workers.is_none() {
                eprintln!("scenario {path} has no `cluster.workers` — not a cluster scenario");
                std::process::exit(2);
            }
            let opts = dynrepart::scenario::ClusterRunOptions::default();
            match scenario.run_cluster_with(&opts) {
                Ok((report, stats)) => {
                    let slug = format!("cluster_{}", report.name.replace('-', "_"));
                    report.table().emit(&slug);
                    if stats.worker_restores > 0 {
                        println!("workers restored: {}", stats.worker_restores);
                    }
                    println!(
                        "shuffle {} B  migration {} B  snapshots {} B",
                        stats.shuffle_bytes, stats.migration_bytes, stats.snapshot_bytes
                    );
                    println!(
                        "final epoch {}  total vtime {:.3}s  state weight {:.1}",
                        report.final_epoch, report.total_vtime, report.total_state_weight
                    );
                }
                Err(e) => {
                    eprintln!("cluster scenario failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        Some("worker") => {
            let mut endpoint = None;
            let mut worker_id: Option<u32> = None;
            let mut fail_at: Option<u64> = None;
            let mut i = 1;
            while i < args.len() {
                match args[i].as_str() {
                    "--connect" if i + 1 < args.len() => {
                        endpoint =
                            Some(dynrepart::ddps::cluster::Endpoint::parse(&args[i + 1]));
                        i += 2;
                    }
                    "--id" if i + 1 < args.len() => {
                        worker_id = args[i + 1].parse().ok();
                        i += 2;
                    }
                    "--fail-at" if i + 1 < args.len() => {
                        fail_at = args[i + 1].parse().ok();
                        i += 2;
                    }
                    other => {
                        eprintln!("unknown worker argument: {other}");
                        eprintln!(
                            "usage: dynrepart worker --connect <endpoint> --id <n> [--fail-at <interval>]"
                        );
                        std::process::exit(2);
                    }
                }
            }
            let (Some(endpoint), Some(worker_id)) = (endpoint, worker_id) else {
                eprintln!(
                    "usage: dynrepart worker --connect <endpoint> --id <n> [--fail-at <interval>]"
                );
                std::process::exit(2);
            };
            let opts = dynrepart::ddps::cluster::WorkerOptions {
                endpoint,
                worker_id,
                fail_at,
            };
            match dynrepart::ddps::cluster::run_worker(&opts) {
                Ok(dynrepart::ddps::cluster::WorkerOutcome::Finished) => {}
                Ok(dynrepart::ddps::cluster::WorkerOutcome::FailInjected) => {
                    std::process::exit(3);
                }
                Err(e) => {
                    eprintln!("worker {worker_id} failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            eprintln!("dynrepart — System-aware dynamic partitioning (Zvara et al. 2021)");
            eprintln!(
                "usage: dynrepart <fig 2..8 [scale] | artifacts | quickstart | scenario <conf> | master <conf> | worker ...>"
            );
            std::process::exit(2);
        }
    }
}
