//! Sliding state window — Fig 3's experimental setup keeps keygroup state
//! "in a sliding state window of size 5": the state that must migrate at a
//! partitioner update is the total keygroup weight of the last W batches.

use crate::util::keymap::{key_map, KeyMap};
use crate::workload::Key;
use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct SlidingStateWindow {
    window: usize,
    /// Per-batch keygroup weights, most recent at the back. Keyed by the
    /// fmix64 [`KeyMap`] — these accumulators are on the per-batch path
    /// and never see attacker-controlled keys.
    batches: VecDeque<KeyMap<f64>>,
}

impl SlidingStateWindow {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        Self {
            window,
            batches: VecDeque::with_capacity(window + 1),
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Push one batch's keygroup weights; evicts the oldest beyond W.
    pub fn push_batch(&mut self, keygroup_weights: KeyMap<f64>) {
        self.batches.push_back(keygroup_weights);
        while self.batches.len() > self.window {
            self.batches.pop_front();
        }
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    /// Current state weight per key: sum over the window.
    pub fn state_weights(&self) -> Vec<(Key, f64)> {
        let mut acc: KeyMap<f64> = key_map();
        for b in &self.batches {
            for (&k, &w) in b {
                *acc.entry(k).or_insert(0.0) += w;
            }
        }
        acc.into_iter().collect()
    }

    pub fn total_weight(&self) -> f64 {
        self.batches.iter().map(|b| b.values().sum::<f64>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(pairs: &[(Key, f64)]) -> KeyMap<f64> {
        pairs.iter().cloned().collect()
    }

    #[test]
    fn eviction_after_window() {
        let mut w = SlidingStateWindow::new(2);
        w.push_batch(batch(&[(1, 1.0)]));
        w.push_batch(batch(&[(1, 2.0)]));
        w.push_batch(batch(&[(1, 4.0)]));
        assert_eq!(w.n_batches(), 2);
        let sw = w.state_weights();
        assert_eq!(sw, vec![(1, 6.0)]); // 2 + 4, first batch evicted
    }

    #[test]
    fn weights_sum_over_window() {
        let mut w = SlidingStateWindow::new(5);
        for i in 0..5 {
            w.push_batch(batch(&[(1, 1.0), (2, i as f64)]));
        }
        let m: KeyMap<f64> = w.state_weights().into_iter().collect();
        assert!((m[&1] - 5.0).abs() < 1e-12);
        assert!((m[&2] - 10.0).abs() < 1e-12);
        assert!((w.total_weight() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn keys_disappear_when_cold() {
        let mut w = SlidingStateWindow::new(2);
        w.push_batch(batch(&[(42, 1.0)]));
        w.push_batch(batch(&[(7, 1.0)]));
        w.push_batch(batch(&[(7, 1.0)]));
        let m: KeyMap<f64> = w.state_weights().into_iter().collect();
        assert!(!m.contains_key(&42));
    }

    #[test]
    #[should_panic]
    fn zero_window_panics() {
        SlidingStateWindow::new(0);
    }
}
