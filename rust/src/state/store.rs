//! Per-partition keyed state store.
//!
//! Each reducer task owns the state of the keygroup currently routed to it.
//! The paper assumes "states ... linear in the size of the corresponding
//! keygroups" (Fig 3), so [`KeyState`] tracks both an application value and
//! its weight (bytes proxy). Migration extracts whole keygroups.

use crate::workload::Key;
use crate::util::keymap::KeyMap;
use std::collections::hash_map::Entry;

/// State attached to one key: an opaque accumulator plus bookkeeping that
/// the engines and the migration planner need.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyState {
    /// Running aggregate (count, sum, or app-defined scalar vector).
    pub values: Vec<f64>,
    /// Number of records folded into this state.
    pub records: u64,
    /// State size proxy (e.g. bytes). Linear in keygroup size per Fig 3.
    pub weight: f64,
}

impl KeyState {
    pub fn new() -> Self {
        Self {
            values: Vec::new(),
            records: 0,
            weight: 0.0,
        }
    }
}

impl Default for KeyState {
    fn default() -> Self {
        Self::new()
    }
}

/// The state store of one partition (one parallel operator instance).
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    states: KeyMap<KeyState>,
    /// Incrementally maintained sum of all per-key weights: every fold
    /// ([`StateStore::update`]) and migration step ([`StateStore::extract`]
    /// / [`StateStore::install`]) adjusts it by the delta, so
    /// [`StateStore::total_weight`] is O(1) — the engines read it per
    /// report and at every epoch-swap barrier, which must never cost
    /// O(keys). Pinned against the recomputed sum by
    /// `cached_total_weight_tracks_recomputed_sum_through_migrations`.
    total_weight: f64,
}

impl StateStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one record into a key's state. `update` mutates the state and
    /// returns the weight *delta* it caused.
    pub fn update<F: FnOnce(&mut KeyState) -> f64>(&mut self, key: Key, update: F) {
        let st = self.states.entry(key).or_default();
        st.records += 1;
        let dw = update(st);
        st.weight += dw;
        self.total_weight += dw;
    }

    /// Standard counting update: +1 record, +`w` weight.
    pub fn fold_count(&mut self, key: Key, w: f64) {
        self.update(key, |_| w);
    }

    pub fn get(&self, key: Key) -> Option<&KeyState> {
        self.states.get(&key)
    }

    pub fn n_keys(&self) -> usize {
        self.states.len()
    }

    /// Total state weight of this partition — the incrementally cached
    /// sum, O(1) (see the field docs; never recomputed over the keys).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Recompute the total weight from scratch, O(keys). Test/debug
    /// oracle for the cached [`StateStore::total_weight`].
    pub fn recomputed_total_weight(&self) -> f64 {
        self.states.values().map(|s| s.weight).sum()
    }

    pub fn keys(&self) -> impl Iterator<Item = Key> + '_ {
        self.states.keys().cloned()
    }

    pub fn iter(&self) -> impl Iterator<Item = (Key, &KeyState)> {
        self.states.iter().map(|(&k, v)| (k, v))
    }

    /// Remove and return a key's state (migration source side).
    pub fn extract(&mut self, key: Key) -> Option<KeyState> {
        let st = self.states.remove(&key)?;
        self.total_weight -= st.weight;
        Some(st)
    }

    /// Install a migrated state (migration target side). Merges if the key
    /// already has local state (can happen after batch replay).
    pub fn install(&mut self, key: Key, incoming: KeyState) {
        self.total_weight += incoming.weight;
        match self.states.entry(key) {
            Entry::Vacant(e) => {
                e.insert(incoming);
            }
            Entry::Occupied(mut e) => {
                let st = e.get_mut();
                st.records += incoming.records;
                st.weight += incoming.weight;
                if st.values.len() < incoming.values.len() {
                    st.values.resize(incoming.values.len(), 0.0);
                }
                for (i, v) in incoming.values.iter().enumerate() {
                    st.values[i] += v;
                }
            }
        }
    }

    /// Per-key state weights — the input to `migration_fraction`.
    pub fn state_weights(&self) -> Vec<(Key, f64)> {
        self.states.iter().map(|(&k, s)| (k, s.weight)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_accumulates() {
        let mut s = StateStore::new();
        s.fold_count(1, 2.0);
        s.fold_count(1, 3.0);
        s.fold_count(2, 1.0);
        let st = s.get(1).unwrap();
        assert_eq!(st.records, 2);
        assert!((st.weight - 5.0).abs() < 1e-12);
        assert_eq!(s.n_keys(), 2);
        assert!((s.total_weight() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn extract_removes_and_adjusts_total() {
        let mut s = StateStore::new();
        s.fold_count(1, 4.0);
        s.fold_count(2, 1.0);
        let st = s.extract(1).unwrap();
        assert!((st.weight - 4.0).abs() < 1e-12);
        assert_eq!(s.n_keys(), 1);
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
        assert!(s.extract(1).is_none());
    }

    #[test]
    fn install_fresh_and_merge() {
        let mut a = StateStore::new();
        a.update(7, |st| {
            st.values = vec![1.0, 2.0];
            10.0
        });
        let moved = a.extract(7).unwrap();

        let mut b = StateStore::new();
        b.install(7, moved.clone());
        assert_eq!(b.get(7).unwrap().values, vec![1.0, 2.0]);
        assert!((b.total_weight() - 10.0).abs() < 1e-12);

        // merge path
        b.install(7, moved);
        let st = b.get(7).unwrap();
        assert_eq!(st.values, vec![2.0, 4.0]);
        assert_eq!(st.records, 2);
        assert!((b.total_weight() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn state_weights_reflect_store() {
        let mut s = StateStore::new();
        s.fold_count(1, 2.0);
        s.fold_count(2, 8.0);
        let mut sw = s.state_weights();
        sw.sort_by_key(|e| e.0);
        assert_eq!(sw, vec![(1, 2.0), (2, 8.0)]);
    }

    #[test]
    fn cached_total_weight_tracks_recomputed_sum_through_migrations() {
        // the O(1) cached total must equal the O(keys) recomputed sum at
        // every point of a fold → extract → install (migration) history,
        // including merge-installs and removed keys
        let mut stores = vec![StateStore::new(), StateStore::new(), StateStore::new()];
        let check = |stores: &[StateStore], when: &str| {
            for (i, s) in stores.iter().enumerate() {
                assert!(
                    (s.total_weight() - s.recomputed_total_weight()).abs() < 1e-9,
                    "store {i} {when}: cached {} vs recomputed {}",
                    s.total_weight(),
                    s.recomputed_total_weight()
                );
            }
        };
        for k in 0..300u64 {
            stores[(k % 3) as usize].fold_count(k, 0.5 + (k % 7) as f64);
        }
        check(&stores, "after folds");
        // migrate every key whose id is even from its store to store (p+1)%3
        for p in 0..3usize {
            let keys: Vec<Key> = stores[p].keys().filter(|k| k % 2 == 0).collect();
            for k in keys {
                let st = stores[p].extract(k).unwrap();
                stores[(p + 1) % 3].install(k, st);
            }
            check(&stores, "mid-migration");
        }
        // merge-install: move a key onto a partition that already has it
        let st = stores[1].extract(1).or_else(|| stores[2].extract(1)).or_else(|| stores[0].extract(1)).unwrap();
        stores[0].fold_count(1, 2.0);
        stores[0].install(1, st);
        check(&stores, "after merge-install");
        // keep folding after migration
        for k in 0..50u64 {
            stores[0].fold_count(k * 3, 1.25);
        }
        check(&stores, "after post-migration folds");
    }

    #[test]
    fn weight_conservation_under_migration() {
        // total weight across stores is invariant under extract+install
        let mut stores = vec![StateStore::new(), StateStore::new()];
        for k in 0..100u64 {
            stores[(k % 2) as usize].fold_count(k, k as f64);
        }
        let before: f64 = stores.iter().map(|s| s.total_weight()).sum();
        // move all even keys to store 1
        let keys: Vec<Key> = stores[0].keys().collect();
        for k in keys {
            let st = stores[0].extract(k).unwrap();
            stores[1].install(k, st);
        }
        let after: f64 = stores.iter().map(|s| s.total_weight()).sum();
        assert!((before - after).abs() < 1e-9);
        assert_eq!(stores[0].n_keys(), 0);
    }
}
