//! Per-partition keyed state store.
//!
//! Each reducer task owns the state of the keygroup currently routed to it.
//! The paper assumes "states ... linear in the size of the corresponding
//! keygroups" (Fig 3), so [`KeyState`] tracks both an application value and
//! its weight (bytes proxy). Migration extracts whole keygroups.
//!
//! Layout (PR 6, the millions-of-keys hot path): an open-addressing index
//! of `u32` slot numbers over a dense slab of [`KeyState`]s, probed by the
//! fmix64 of the key — one cache line of index probes plus one slab access
//! per `fold_count`, no per-key `Box`/`Vec` allocations for count-only
//! workloads ([`ValueVec`] stores up to two values inline). Iteration
//! (`keys` / `iter` / `state_weights` — the keygroup extract side of a
//! migration) walks the contiguous slab in insertion order, which is a
//! deterministic function of the operation sequence: the sharded executor
//! replays each store's exact sequential operation subsequence, so
//! sequential and sharded runs see identical orders and stay
//! bitwise-identical.

use crate::hash::fmix64;
use crate::workload::Key;

/// Inline-first value storage for [`KeyState`]: up to two `f64`s live
/// inside the state itself; only a third value promotes to a heap `Vec`.
/// Count-only workloads (`fold_count`) therefore never allocate per key.
/// Derefs to `[f64]`, so reads look exactly like the old `Vec<f64>`.
#[derive(Clone)]
pub struct ValueVec {
    repr: Repr,
}

const INLINE_CAP: usize = 2;

#[derive(Clone)]
enum Repr {
    Inline { len: u8, vals: [f64; INLINE_CAP] },
    Heap(Vec<f64>),
}

impl ValueVec {
    pub const fn new() -> Self {
        Self {
            repr: Repr::Inline {
                len: 0,
                vals: [0.0; INLINE_CAP],
            },
        }
    }

    pub fn as_slice(&self) -> &[f64] {
        match &self.repr {
            Repr::Inline { len, vals } => &vals[..*len as usize],
            Repr::Heap(v) => v,
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        match &mut self.repr {
            Repr::Inline { len, vals } => &mut vals[..*len as usize],
            Repr::Heap(v) => v,
        }
    }

    pub fn push(&mut self, v: f64) {
        match &mut self.repr {
            Repr::Inline { len, vals } => {
                if (*len as usize) < INLINE_CAP {
                    vals[*len as usize] = v;
                    *len += 1;
                } else {
                    let mut heap = vals.to_vec();
                    heap.push(v);
                    self.repr = Repr::Heap(heap);
                }
            }
            Repr::Heap(h) => h.push(v),
        }
    }

    /// `Vec::resize` semantics: grow fills with `fill`, shrink truncates.
    pub fn resize(&mut self, n: usize, fill: f64) {
        match &mut self.repr {
            Repr::Inline { len, vals } => {
                if n <= INLINE_CAP {
                    for v in vals.iter_mut().take(n).skip(*len as usize) {
                        *v = fill;
                    }
                    *len = n as u8;
                } else {
                    let mut heap = vals[..*len as usize].to_vec();
                    heap.resize(n, fill);
                    self.repr = Repr::Heap(heap);
                }
            }
            Repr::Heap(h) => h.resize(n, fill),
        }
    }

    /// Heap bytes held beyond the inline representation (0 unless a key
    /// outgrew [`INLINE_CAP`] values) — the bench's bytes/key accounting.
    pub fn heap_bytes(&self) -> usize {
        match &self.repr {
            Repr::Inline { .. } => 0,
            Repr::Heap(h) => h.capacity() * std::mem::size_of::<f64>(),
        }
    }
}

impl Default for ValueVec {
    fn default() -> Self {
        Self::new()
    }
}

impl std::ops::Deref for ValueVec {
    type Target = [f64];

    fn deref(&self) -> &[f64] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for ValueVec {
    fn deref_mut(&mut self) -> &mut [f64] {
        self.as_mut_slice()
    }
}

impl std::fmt::Debug for ValueVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl PartialEq for ValueVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f64>> for ValueVec {
    fn eq(&self, other: &Vec<f64>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl From<Vec<f64>> for ValueVec {
    fn from(v: Vec<f64>) -> Self {
        if v.len() <= INLINE_CAP {
            let mut out = Self::new();
            for x in v {
                out.push(x);
            }
            out
        } else {
            Self { repr: Repr::Heap(v) }
        }
    }
}

impl FromIterator<f64> for ValueVec {
    fn from_iter<I: IntoIterator<Item = f64>>(iter: I) -> Self {
        let mut out = Self::new();
        for x in iter {
            out.push(x);
        }
        out
    }
}

/// State attached to one key: an opaque accumulator plus bookkeeping that
/// the engines and the migration planner need.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyState {
    /// Running aggregate (count, sum, or app-defined scalar vector).
    /// Inline up to two values — see [`ValueVec`].
    pub values: ValueVec,
    /// Number of records folded into this state.
    pub records: u64,
    /// State size proxy (e.g. bytes). Linear in keygroup size per Fig 3.
    pub weight: f64,
}

impl KeyState {
    /// Allocation-free: the zero-value state lives entirely inline.
    pub const fn new() -> Self {
        Self {
            values: ValueVec::new(),
            records: 0,
            weight: 0.0,
        }
    }
}

impl Default for KeyState {
    fn default() -> Self {
        Self::new()
    }
}

/// Index sentinel: free table cell.
const EMPTY: u32 = u32::MAX;
/// Index sentinel: deleted table cell (probe chains continue through it).
const TOMB: u32 = u32::MAX - 1;

/// One slab entry: the key plus its state, stored densely.
#[derive(Debug, Clone)]
struct Slot {
    key: Key,
    state: KeyState,
}

/// The state store of one partition (one parallel operator instance).
///
/// Open-addressing arena: `table` holds `u32` slot numbers (power-of-two
/// sized, linear probing on `fmix64(key)`), `slots` is the dense slab of
/// live states in insertion order. Removals tombstone the index cell and
/// `swap_remove` the slab, so both sides stay compact at 10^7+ live keys:
/// 4 index bytes per table cell plus one `Slot` per live key, no per-key
/// heap allocation until a state holds more than two values.
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    table: Vec<u32>,
    slots: Vec<Slot>,
    tombstones: usize,
    /// Incrementally maintained sum of all per-key weights: every fold
    /// ([`StateStore::update`]) and migration step ([`StateStore::extract`]
    /// / [`StateStore::install`]) adjusts it by the delta, so
    /// [`StateStore::total_weight`] is O(1) — the engines read it per
    /// report and at every epoch-swap barrier, which must never cost
    /// O(keys). Pinned against the recomputed sum by
    /// `cached_total_weight_tracks_recomputed_sum_through_migrations`.
    total_weight: f64,
}

impl StateStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Slot index of `key`, if present.
    fn find(&self, key: Key) -> Option<usize> {
        if self.table.is_empty() {
            return None;
        }
        let mask = self.table.len() - 1;
        let mut i = fmix64(key) as usize & mask;
        loop {
            match self.table[i] {
                EMPTY => return None,
                TOMB => {}
                s => {
                    if self.slots[s as usize].key == key {
                        return Some(s as usize);
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// Slot index of `key`, inserting a fresh [`KeyState`] if absent.
    fn find_or_insert(&mut self, key: Key) -> usize {
        self.ensure_capacity();
        let mask = self.table.len() - 1;
        let mut i = fmix64(key) as usize & mask;
        let mut first_tomb = None;
        loop {
            match self.table[i] {
                EMPTY => {
                    let cell = match first_tomb {
                        Some(t) => {
                            self.tombstones -= 1;
                            t
                        }
                        None => i,
                    };
                    let s = self.slots.len();
                    self.table[cell] = s as u32;
                    self.slots.push(Slot {
                        key,
                        state: KeyState::new(),
                    });
                    return s;
                }
                TOMB => {
                    if first_tomb.is_none() {
                        first_tomb = Some(i);
                    }
                }
                s => {
                    if self.slots[s as usize].key == key {
                        return s as usize;
                    }
                }
            }
            i = (i + 1) & mask;
        }
    }

    /// Keep the index at ≤ 7/8 occupancy (live + tombstones) so probe
    /// chains stay short and `find` always terminates on an `EMPTY` cell.
    fn ensure_capacity(&mut self) {
        if self.table.is_empty() {
            self.table = vec![EMPTY; 16];
            return;
        }
        if (self.slots.len() + self.tombstones + 1) * 8 <= self.table.len() * 7 {
            return;
        }
        // Live load forces a doubling; otherwise tombstones alone pushed
        // occupancy over the line and a same-size rehash purges them.
        let new_len = if (self.slots.len() + 1) * 8 > self.table.len() * 7 {
            self.table.len() * 2
        } else {
            self.table.len()
        };
        let mut table = vec![EMPTY; new_len];
        let mask = new_len - 1;
        for (s, slot) in self.slots.iter().enumerate() {
            let mut i = fmix64(slot.key) as usize & mask;
            while table[i] != EMPTY {
                i = (i + 1) & mask;
            }
            table[i] = s as u32;
        }
        self.table = table;
        self.tombstones = 0;
    }

    /// Fold one record into a key's state. `update` mutates the state and
    /// returns the weight *delta* it caused.
    pub fn update<F: FnOnce(&mut KeyState) -> f64>(&mut self, key: Key, update: F) {
        let s = self.find_or_insert(key);
        let st = &mut self.slots[s].state;
        st.records += 1;
        let dw = update(st);
        st.weight += dw;
        self.total_weight += dw;
    }

    /// Standard counting update: +1 record, +`w` weight.
    #[inline]
    pub fn fold_count(&mut self, key: Key, w: f64) {
        self.update(key, |_| w);
    }

    pub fn get(&self, key: Key) -> Option<&KeyState> {
        self.find(key).map(|s| &self.slots[s].state)
    }

    pub fn n_keys(&self) -> usize {
        self.slots.len()
    }

    /// Total state weight of this partition — the incrementally cached
    /// sum, O(1) (see the field docs; never recomputed over the keys).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Recompute the total weight from scratch, O(keys). Test/debug
    /// oracle for the cached [`StateStore::total_weight`].
    pub fn recomputed_total_weight(&self) -> f64 {
        self.slots.iter().map(|s| s.state.weight).sum()
    }

    pub fn keys(&self) -> impl Iterator<Item = Key> + '_ {
        self.slots.iter().map(|s| s.key)
    }

    pub fn iter(&self) -> impl Iterator<Item = (Key, &KeyState)> {
        self.slots.iter().map(|s| (s.key, &s.state))
    }

    /// Remove and return a key's state (migration source side).
    pub fn extract(&mut self, key: Key) -> Option<KeyState> {
        if self.table.is_empty() {
            return None;
        }
        let mask = self.table.len() - 1;
        let mut i = fmix64(key) as usize & mask;
        let s = loop {
            match self.table[i] {
                EMPTY => return None,
                TOMB => {}
                s => {
                    if self.slots[s as usize].key == key {
                        break s as usize;
                    }
                }
            }
            i = (i + 1) & mask;
        };
        self.table[i] = TOMB;
        self.tombstones += 1;
        let slot = self.slots.swap_remove(s);
        if s < self.slots.len() {
            // The formerly-last slot moved into position `s`: re-point its
            // index cell (it is live, so the probe always finds it).
            let moved = self.slots.len() as u32;
            let mut j = fmix64(self.slots[s].key) as usize & mask;
            while self.table[j] != moved {
                j = (j + 1) & mask;
            }
            self.table[j] = s as u32;
        }
        self.total_weight -= slot.state.weight;
        Some(slot.state)
    }

    /// Install a migrated state (migration target side). Merges if the key
    /// already has local state (can happen after batch replay).
    pub fn install(&mut self, key: Key, incoming: KeyState) {
        self.total_weight += incoming.weight;
        match self.find(key) {
            None => {
                let s = self.find_or_insert(key);
                self.slots[s].state = incoming;
            }
            Some(s) => {
                let st = &mut self.slots[s].state;
                st.records += incoming.records;
                st.weight += incoming.weight;
                if st.values.len() < incoming.values.len() {
                    st.values.resize(incoming.values.len(), 0.0);
                }
                for (i, v) in incoming.values.iter().enumerate() {
                    st.values[i] += v;
                }
            }
        }
    }

    /// Per-key state weights — the input to `migration_fraction`.
    pub fn state_weights(&self) -> Vec<(Key, f64)> {
        self.slots.iter().map(|s| (s.key, s.state.weight)).collect()
    }

    /// Overwrite the cached total weight verbatim — the wire-restore
    /// step. A snapshot ships the cache's exact bits (its value is a
    /// function of the store's += / −= history, which a rebuilt store
    /// cannot replay), so restore installs the states and then sets the
    /// cache to the sender's bits.
    pub fn set_cached_total_weight(&mut self, w: f64) {
        self.total_weight = w;
    }

    /// FNV-1a digest over every key's full state — (key, records, weight
    /// bits, value bits) in slab insertion order. Two stores with the
    /// same operation history digest identically; any divergence down to
    /// a single f64 bit or a reordered slot changes the digest. This is
    /// the per-partition state pin the distributed engine's final-state
    /// check compares against the in-process oracle.
    pub fn fingerprint(&self) -> u64 {
        fn fnv(mut h: u64, x: u64) -> u64 {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for slot in &self.slots {
            h = fnv(h, slot.key);
            h = fnv(h, slot.state.records);
            h = fnv(h, slot.state.weight.to_bits());
            h = fnv(h, slot.state.values.len() as u64);
            for v in slot.state.values.iter() {
                h = fnv(h, v.to_bits());
            }
        }
        h
    }

    /// Resident bytes of this store: index table + slab capacity + any
    /// heap-promoted value vectors. The `micro_hotpath` bench divides
    /// this by `n_keys` for its bytes/key column.
    pub fn footprint_bytes(&self) -> usize {
        self.table.capacity() * std::mem::size_of::<u32>()
            + self.slots.capacity() * std::mem::size_of::<Slot>()
            + self.slots.iter().map(|s| s.state.values.heap_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_accumulates() {
        let mut s = StateStore::new();
        s.fold_count(1, 2.0);
        s.fold_count(1, 3.0);
        s.fold_count(2, 1.0);
        let st = s.get(1).unwrap();
        assert_eq!(st.records, 2);
        assert!((st.weight - 5.0).abs() < 1e-12);
        assert_eq!(s.n_keys(), 2);
        assert!((s.total_weight() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn extract_removes_and_adjusts_total() {
        let mut s = StateStore::new();
        s.fold_count(1, 4.0);
        s.fold_count(2, 1.0);
        let st = s.extract(1).unwrap();
        assert!((st.weight - 4.0).abs() < 1e-12);
        assert_eq!(s.n_keys(), 1);
        assert!((s.total_weight() - 1.0).abs() < 1e-12);
        assert!(s.extract(1).is_none());
    }

    #[test]
    fn install_fresh_and_merge() {
        let mut a = StateStore::new();
        a.update(7, |st| {
            st.values = vec![1.0, 2.0].into();
            10.0
        });
        let moved = a.extract(7).unwrap();

        let mut b = StateStore::new();
        b.install(7, moved.clone());
        assert_eq!(b.get(7).unwrap().values, vec![1.0, 2.0]);
        assert!((b.total_weight() - 10.0).abs() < 1e-12);

        // merge path
        b.install(7, moved);
        let st = b.get(7).unwrap();
        assert_eq!(st.values, vec![2.0, 4.0]);
        assert_eq!(st.records, 2);
        assert!((b.total_weight() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn state_weights_reflect_store() {
        let mut s = StateStore::new();
        s.fold_count(1, 2.0);
        s.fold_count(2, 8.0);
        let mut sw = s.state_weights();
        sw.sort_by_key(|e| e.0);
        assert_eq!(sw, vec![(1, 2.0), (2, 8.0)]);
    }

    #[test]
    fn cached_total_weight_tracks_recomputed_sum_through_migrations() {
        // the O(1) cached total must equal the O(keys) recomputed sum at
        // every point of a fold → extract → install (migration) history,
        // including merge-installs and removed keys
        let mut stores = vec![StateStore::new(), StateStore::new(), StateStore::new()];
        let check = |stores: &[StateStore], when: &str| {
            for (i, s) in stores.iter().enumerate() {
                assert!(
                    (s.total_weight() - s.recomputed_total_weight()).abs() < 1e-9,
                    "store {i} {when}: cached {} vs recomputed {}",
                    s.total_weight(),
                    s.recomputed_total_weight()
                );
            }
        };
        for k in 0..300u64 {
            stores[(k % 3) as usize].fold_count(k, 0.5 + (k % 7) as f64);
        }
        check(&stores, "after folds");
        // migrate every key whose id is even from its store to store (p+1)%3
        for p in 0..3usize {
            let keys: Vec<Key> = stores[p].keys().filter(|k| k % 2 == 0).collect();
            for k in keys {
                let st = stores[p].extract(k).unwrap();
                stores[(p + 1) % 3].install(k, st);
            }
            check(&stores, "mid-migration");
        }
        // merge-install: move a key onto a partition that already has it
        let st = stores[1].extract(1).or_else(|| stores[2].extract(1)).or_else(|| stores[0].extract(1)).unwrap();
        stores[0].fold_count(1, 2.0);
        stores[0].install(1, st);
        check(&stores, "after merge-install");
        // keep folding after migration
        for k in 0..50u64 {
            stores[0].fold_count(k * 3, 1.25);
        }
        check(&stores, "after post-migration folds");
    }

    #[test]
    fn weight_conservation_under_migration() {
        // total weight across stores is invariant under extract+install
        let mut stores = vec![StateStore::new(), StateStore::new()];
        for k in 0..100u64 {
            stores[(k % 2) as usize].fold_count(k, k as f64);
        }
        let before: f64 = stores.iter().map(|s| s.total_weight()).sum();
        // move all even keys to store 1
        let keys: Vec<Key> = stores[0].keys().collect();
        for k in keys {
            let st = stores[0].extract(k).unwrap();
            stores[1].install(k, st);
        }
        let after: f64 = stores.iter().map(|s| s.total_weight()).sum();
        assert!((before - after).abs() < 1e-9);
        assert_eq!(stores[0].n_keys(), 0);
    }

    #[test]
    fn iteration_follows_insertion_order() {
        // the slab iterates in insertion order — the property the sharded
        // executor's bitwise guarantees lean on
        let mut s = StateStore::new();
        for k in [9u64, 2, 40, 17, 3] {
            s.fold_count(k, 1.0);
        }
        assert_eq!(s.keys().collect::<Vec<_>>(), vec![9, 2, 40, 17, 3]);
        // removing from the middle swaps the last slot into its place
        s.extract(2);
        assert_eq!(s.keys().collect::<Vec<_>>(), vec![9, 3, 40, 17]);
    }

    #[test]
    fn survives_churn_through_growth_and_tombstones() {
        // interleaved inserts and removals force growth, tombstone reuse
        // and same-size purges; membership must stay exact throughout
        let mut s = StateStore::new();
        for round in 0u64..6 {
            for k in 0..2_000u64 {
                s.fold_count(k * 7 + round, 1.0);
            }
            for k in 0..1_000u64 {
                assert!(s.extract(k * 7 + round).is_some(), "round {round} key {k}");
            }
            for k in 0..1_000u64 {
                assert!(s.extract(k * 7 + round).is_none());
            }
        }
        assert_eq!(s.n_keys(), 6 * 1_000);
        assert!((s.total_weight() - 6_000.0).abs() < 1e-9);
        for round in 0u64..6 {
            for k in 1_000..2_000u64 {
                let st = s.get(k * 7 + round).expect("live key");
                assert_eq!(st.records, 1);
            }
        }
    }

    #[test]
    fn fingerprint_pins_order_and_bits() {
        let mut a = StateStore::new();
        let mut b = StateStore::new();
        for k in [9u64, 2, 40] {
            a.fold_count(k, 1.5);
            b.fold_count(k, 1.5);
        }
        assert_eq!(a.fingerprint(), b.fingerprint());
        // a zero-weight fold changes only the record count — still visible
        b.fold_count(2, 0.0);
        assert_ne!(a.fingerprint(), b.fingerprint());
        // same states inserted in a different slab order digest differently
        let mut c = StateStore::new();
        for k in [2u64, 9, 40] {
            c.fold_count(k, 1.5);
        }
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn count_only_states_stay_inline() {
        let mut s = StateStore::new();
        for k in 0..10_000u64 {
            s.fold_count(k, 1.0);
        }
        let heap: usize = s.iter().map(|(_, st)| st.values.heap_bytes()).sum();
        assert_eq!(heap, 0, "fold_count must not heap-allocate per key");
        // generous bound: index cell + slot + capacity slack
        let per_key = s.footprint_bytes() / s.n_keys();
        assert!(per_key <= 256, "bytes/key {per_key}");
    }

    #[test]
    fn value_vec_inline_to_heap_promotion() {
        let mut v = ValueVec::new();
        assert_eq!(v.len(), 0);
        assert_eq!(v.heap_bytes(), 0);
        v.push(1.0);
        v.push(2.0);
        assert_eq!(v.heap_bytes(), 0, "two values stay inline");
        assert_eq!(v, vec![1.0, 2.0]);
        v.push(3.0);
        assert!(v.heap_bytes() > 0, "third value promotes to heap");
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        v[0] = 9.0;
        assert_eq!(v.as_slice(), &[9.0, 2.0, 3.0]);
        // resize within inline, then across the boundary
        let mut w = ValueVec::new();
        w.resize(2, 5.0);
        assert_eq!(w, vec![5.0, 5.0]);
        assert_eq!(w.heap_bytes(), 0);
        w.resize(1, 0.0);
        assert_eq!(w, vec![5.0]);
        w.resize(4, 7.0);
        assert_eq!(w, vec![5.0, 7.0, 7.0, 7.0]);
        assert!(w.heap_bytes() > 0);
        let from: ValueVec = vec![1.0, 2.0].into();
        assert_eq!(from.heap_bytes(), 0, "short From<Vec> re-inlines");
    }
}
