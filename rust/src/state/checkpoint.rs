//! Checkpoints — the streaming engine's consistency mechanism.
//!
//! Flink-style asynchronous distributed snapshots [3]: on a barrier, each
//! task snapshots its state store; DR injects new partitioners exactly at
//! these points so state migration composes with the snapshot (§3: "in our
//! Flink implementation, we make use of the Asynchronous Distributed
//! Snapshot mechanism used for fault tolerance").

use super::store::StateStore;

/// A consistent snapshot of all partition state stores at a barrier.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub id: u64,
    /// Records processed up to the barrier (per partition).
    pub records_at: Vec<u64>,
    pub stores: Vec<StateStore>,
}

impl Checkpoint {
    pub fn total_state_weight(&self) -> f64 {
        self.stores.iter().map(|s| s.total_weight()).sum()
    }

    pub fn total_keys(&self) -> usize {
        self.stores.iter().map(|s| s.n_keys()).sum()
    }

    /// The restore path: rebuild the per-partition stores exactly as they
    /// were at this barrier. A plain clone of the snapshot — `StateStore`
    /// iterates in insertion order, so a restored store replays every
    /// later operation (folds, migrations, plans) bitwise-identically to
    /// the store it was snapshotted from.
    pub fn restore_stores(&self) -> Vec<StateStore> {
        self.stores.clone()
    }
}

/// Retains the last `retain` checkpoints (Flink keeps a small number).
/// `Clone` snapshots the whole retention window — recovery points carry
/// one so a restored engine presents the same checkpoint history.
#[derive(Debug, Clone, Default)]
pub struct CheckpointStore {
    retain: usize,
    checkpoints: Vec<Checkpoint>,
}

impl CheckpointStore {
    pub fn new(retain: usize) -> Self {
        assert!(retain > 0);
        Self {
            retain,
            checkpoints: Vec::new(),
        }
    }

    pub fn save(&mut self, cp: Checkpoint) {
        self.checkpoints.push(cp);
        while self.checkpoints.len() > self.retain {
            self.checkpoints.remove(0);
        }
    }

    pub fn latest(&self) -> Option<&Checkpoint> {
        self.checkpoints.last()
    }

    pub fn get(&self, id: u64) -> Option<&Checkpoint> {
        self.checkpoints.iter().find(|c| c.id == id)
    }

    pub fn len(&self) -> usize {
        self.checkpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.checkpoints.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp(id: u64, weight: f64) -> Checkpoint {
        let mut store = StateStore::new();
        store.fold_count(1, weight);
        Checkpoint {
            id,
            records_at: vec![1],
            stores: vec![store],
        }
    }

    #[test]
    fn retention_evicts_oldest() {
        let mut cs = CheckpointStore::new(2);
        cs.save(cp(1, 1.0));
        cs.save(cp(2, 2.0));
        cs.save(cp(3, 3.0));
        assert_eq!(cs.len(), 2);
        assert!(cs.get(1).is_none());
        assert_eq!(cs.latest().unwrap().id, 3);
    }

    #[test]
    fn checkpoint_totals() {
        let c = cp(1, 5.0);
        assert!((c.total_state_weight() - 5.0).abs() < 1e-12);
        assert_eq!(c.total_keys(), 1);
    }

    #[test]
    fn restore_stores_reproduces_snapshot_and_detaches() {
        let mut store = StateStore::new();
        store.fold_count(7, 2.0);
        store.fold_count(9, 3.0);
        let c = Checkpoint {
            id: 4,
            records_at: vec![2],
            stores: vec![store],
        };
        let mut restored = c.restore_stores();
        assert_eq!(restored.len(), 1);
        assert!((restored[0].total_weight() - 5.0).abs() < 1e-12);
        let keys: Vec<_> = restored[0].keys().collect();
        assert_eq!(keys, vec![7, 9], "insertion order must survive restore");
        // mutating the restored copy leaves the snapshot untouched
        restored[0].fold_count(7, 100.0);
        assert!((c.total_state_weight() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cloned_store_keeps_history() {
        let mut cs = CheckpointStore::new(3);
        cs.save(cp(1, 1.0));
        cs.save(cp(2, 2.0));
        let snap = cs.clone();
        cs.save(cp(3, 3.0));
        assert_eq!(snap.len(), 2);
        assert_eq!(snap.latest().unwrap().id, 2);
        assert_eq!(cs.latest().unwrap().id, 3);
    }

    #[test]
    fn restore_semantics_round_trip() {
        // snapshot → mutate → restore gives the snapshot's state back
        let mut store = StateStore::new();
        store.fold_count(1, 1.0);
        let mut cs = CheckpointStore::new(1);
        cs.save(Checkpoint {
            id: 1,
            records_at: vec![1],
            stores: vec![store.clone()],
        });
        store.fold_count(1, 100.0);
        let restored = &cs.latest().unwrap().stores[0];
        assert!((restored.total_weight() - 1.0).abs() < 1e-12);
        assert!((store.total_weight() - 101.0).abs() < 1e-12);
    }
}
