//! Keyed operator state: per-partition stores, sliding state windows,
//! checkpoints, and migration — the substrate that makes repartitioning
//! *stateful* operators possible (§1: "state migration that existing
//! streaming skew mitigation methods cannot handle").

pub mod checkpoint;
pub mod store;
pub mod window;

pub use checkpoint::{Checkpoint, CheckpointStore};
pub use store::{KeyState, StateStore, ValueVec};
pub use window::SlidingStateWindow;
