//! Measurement harness (criterion substitute — crates.io is unreachable
//! in this image; see DESIGN.md "Substitutions").
//!
//! Implements the same discipline criterion uses: warmup iterations, then
//! N timed iterations, reporting mean ± σ and median; `black_box` guards
//! against the optimizer deleting the measured work.

use crate::util::{percentile, Online};
use std::time::Instant;

/// Prevent the compiler from optimizing away a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.median_ns),
            self.iters
        )
    }

    /// Per-element throughput given elements processed per iteration.
    pub fn throughput(&self, elems_per_iter: f64) -> f64 {
        elems_per_iter / (self.mean_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to a time budget.
pub fn bench(name: &str, mut f: impl FnMut()) -> Measurement {
    bench_with(name, BenchOpts::default(), &mut f)
}

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Soft time budget for the measurement phase, seconds.
    pub budget_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget_s: 2.0,
        }
    }
}

pub fn bench_with(name: &str, opts: BenchOpts, f: &mut dyn FnMut()) -> Measurement {
    // warmup + calibration
    let mut cal = Online::new();
    for _ in 0..opts.warmup_iters.max(1) {
        let t = Instant::now();
        f();
        cal.push(t.elapsed().as_nanos() as f64);
    }
    let est = cal.mean().max(1.0);
    let iters = ((opts.budget_s * 1e9 / est) as usize)
        .clamp(opts.min_iters, opts.max_iters);

    let mut samples = Vec::with_capacity(iters);
    let mut online = Online::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_nanos() as f64;
        samples.push(ns);
        online.push(ns);
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: online.mean(),
        std_ns: online.std(),
        median_ns: percentile(&samples, 50.0),
        min_ns: online.min(),
    }
}

/// Print the standard header for a group of measurements.
pub fn header(group: &str) {
    println!("\n== {group} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "std", "median"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench_with(
            "spin",
            BenchOpts {
                warmup_iters: 1,
                min_iters: 5,
                max_iters: 20,
                budget_s: 0.01,
            },
            &mut || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 5 && m.iters <= 20);
        assert!(m.median_ns > 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9, // 1 second per iter
            std_ns: 0.0,
            median_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((m.throughput(1000.0) - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
