//! Minimal error plumbing for the runtime layer (anyhow substitute —
//! crates.io is unreachable in this image; see DESIGN.md "Substitutions").
//!
//! Mirrors the small slice of `anyhow` the runtime needs: a string-backed
//! error, `Result<T>` alias, a blanket `From<E: std::error::Error>` so
//! `?` converts io/parse errors, and `bail!`/`ensure!` macros.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

/// String-backed error. Deliberately does **not** implement
/// `std::error::Error` so the blanket `From` below stays coherent
/// (the same trick `anyhow::Error` uses).
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self(e.to_string())
    }
}

/// Return early with a formatted [`Error`].
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::runtime::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::runtime::Error::msg(format!($($arg)*)));
        }
    };
}

// `ensure` is imported across the runtime modules; `bail` is part of the
// same mini-API even though the current callers all use `ensure`.
#[allow(unused_imports)]
pub(crate) use bail;
pub(crate) use ensure;

#[cfg(test)]
mod tests {
    use super::*;

    fn parses(s: &str) -> Result<usize> {
        Ok(s.parse::<usize>()?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parses("42").unwrap(), 42);
        let e = parses("nope").unwrap_err();
        assert!(format!("{e}").contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_format() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(format!("{}", f(-1).unwrap_err()).contains("positive"));
        assert!(format!("{}", f(200).unwrap_err()).contains("too large"));
    }
}
