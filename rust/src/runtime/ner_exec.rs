//! The NER scorer executable — the §6 reducer UDF running on PJRT.
//!
//! Loads one `ner_b{N}.hlo.txt` artifact per batch size, stages the model
//! parameters (`ner_*.bin`) as device buffers **once**, and then serves
//! `execute()` calls from the reducer hot path with only the token batch
//! crossing the host→device boundary.
//!
//! Compiled against the real `xla` crate only with the `pjrt` feature;
//! otherwise API-compatible stubs return errors and callers fall back
//! (see `runtime` module docs).

use super::error::Result;
use super::{Artifacts, Runtime};
use crate::workload::ner::Doc;

/// The compiled batch-size ladder (must match python/compile/model.py).
pub const NER_BATCH_SIZES: [usize; 3] = [32, 128, 512];

/// One batch's outputs (see model.ner_window_model).
#[derive(Debug, Clone)]
pub struct NerOutput {
    /// [batch, N_CLASSES] row-major logits.
    pub logits: Vec<f32>,
    /// [batch] argmax class per document.
    pub pred: Vec<i32>,
    /// [N_CLASSES] length-weighted class histogram of the batch window.
    pub class_hist: Vec<f32>,
    pub batch: usize,
}

#[cfg(feature = "pjrt")]
mod real {
    use super::super::error::{ensure, Result};
    use super::super::{read_f32_file, Artifacts, Runtime};
    use super::{NerOutput, NER_BATCH_SIZES};
    use crate::workload::ner::{Doc, MAX_LEN, VOCAB};
    use std::time::Instant;

    const EMBED_DIM: usize = 64;
    const N_CLASSES: usize = 9;

    /// A loaded `ner_b{N}` executable with staged parameters.
    pub struct NerExecutable {
        exe: xla::PjRtLoadedExecutable,
        client: xla::PjRtClient,
        emb: xla::PjRtBuffer,
        w: xla::PjRtBuffer,
        b: xla::PjRtBuffer,
        batch: usize,
    }

    impl NerExecutable {
        /// Load the artifact for one batch size and stage the parameters.
        pub fn load(rt: &Runtime, arts: &Artifacts, batch: usize) -> Result<Self> {
            ensure!(
                NER_BATCH_SIZES.contains(&batch),
                "no ner artifact for batch size {batch}"
            );
            let name = format!("ner_b{batch}");
            ensure!(
                arts.manifest.get(&name).is_some(),
                "{name} missing from manifest — run `make artifacts`"
            );
            let exe = rt.load_hlo_text(&arts.hlo_path(&name))?;

            let emb_host = read_f32_file(&arts.bin_path("ner_emb"))?;
            ensure!(emb_host.len() == VOCAB * EMBED_DIM, "ner_emb.bin size");
            let w_host = read_f32_file(&arts.bin_path("ner_w"))?;
            ensure!(w_host.len() == EMBED_DIM * N_CLASSES, "ner_w.bin size");
            let b_host = read_f32_file(&arts.bin_path("ner_b"))?;
            ensure!(b_host.len() == N_CLASSES, "ner_b.bin size");

            let client = rt.client().clone();
            let emb = client.buffer_from_host_buffer(&emb_host, &[VOCAB, EMBED_DIM], None)?;
            let w = client.buffer_from_host_buffer(&w_host, &[EMBED_DIM, N_CLASSES], None)?;
            let b = client.buffer_from_host_buffer(&b_host, &[N_CLASSES], None)?;
            Ok(Self {
                exe,
                client,
                emb,
                w,
                b,
                batch,
            })
        }

        pub fn batch(&self) -> usize {
            self.batch
        }

        /// Execute one padded batch. `tokens` is `[batch × MAX_LEN]`
        /// row-major, `lens` is `[batch]` (0 marks an empty slot).
        pub fn execute(&self, tokens: &[i32], lens: &[i32]) -> Result<NerOutput> {
            ensure!(tokens.len() == self.batch * MAX_LEN, "tokens shape");
            ensure!(lens.len() == self.batch, "lens shape");
            let tok_buf = self
                .client
                .buffer_from_host_buffer(tokens, &[self.batch, MAX_LEN], None)?;
            let len_buf = self
                .client
                .buffer_from_host_buffer(lens, &[self.batch], None)?;

            let args = [&tok_buf, &len_buf, &self.emb, &self.w, &self.b];
            let result = self.exe.execute_b(&args)?;
            let tuple = result[0][0].to_literal_sync()?;
            let (logits_l, pred_l, hist_l) = tuple.to_tuple3()?;
            Ok(NerOutput {
                logits: logits_l.to_vec::<f32>()?,
                pred: pred_l.to_vec::<i32>()?,
                class_hist: hist_l.to_vec::<f32>()?,
                batch: self.batch,
            })
        }

        /// Execute a slice of documents (padded/truncated into this batch).
        pub fn execute_docs(&self, docs: &[&Doc]) -> Result<NerOutput> {
            let (tokens, lens) = crate::workload::ner::pad_batch(docs, self.batch);
            self.execute(&tokens, &lens)
        }

        /// Measure mean wall-clock seconds per *document* over `iters` runs
        /// of a representative batch — the calibration source for the
        /// engines' `reduce_cost` (DESIGN.md: the virtual timeline is
        /// anchored to measured compute).
        pub fn calibrate_per_doc_cost(&self, iters: usize) -> Result<f64> {
            let tokens: Vec<i32> = (0..self.batch * MAX_LEN)
                .map(|i| (crate::hash::fmix64(i as u64) % VOCAB as u64) as i32)
                .collect();
            let lens = vec![MAX_LEN as i32; self.batch];
            // warmup
            self.execute(&tokens, &lens)?;
            let t = Instant::now();
            for _ in 0..iters.max(1) {
                self.execute(&tokens, &lens)?;
            }
            Ok(t.elapsed().as_secs_f64() / (iters.max(1) * self.batch) as f64)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::NerExecutable;

/// Stub compiled without the `pjrt` feature: `load` reports the runtime
/// as unavailable; the remaining methods exist so callers typecheck but
/// are unreachable (the struct cannot be constructed).
#[cfg(not(feature = "pjrt"))]
pub struct NerExecutable {
    never: Never,
}

#[cfg(not(feature = "pjrt"))]
#[derive(Clone, Copy)]
enum Never {}

#[cfg(not(feature = "pjrt"))]
impl NerExecutable {
    pub fn load(_rt: &Runtime, _arts: &Artifacts, _batch: usize) -> Result<Self> {
        Err(super::Error::msg(
            "NER scorer not built: enable the `pjrt` feature (requires a vendored `xla` crate)",
        ))
    }

    pub fn batch(&self) -> usize {
        match self.never {}
    }

    pub fn execute(&self, _tokens: &[i32], _lens: &[i32]) -> Result<NerOutput> {
        match self.never {}
    }

    pub fn execute_docs(&self, _docs: &[&Doc]) -> Result<NerOutput> {
        match self.never {}
    }

    pub fn calibrate_per_doc_cost(&self, _iters: usize) -> Result<f64> {
        match self.never {}
    }
}

/// A ladder of NER executables; picks the smallest batch that fits.
/// Shared across the real and stub backends (it only uses the
/// [`NerExecutable`] surface).
pub struct NerLadder {
    rungs: Vec<NerExecutable>,
}

impl NerLadder {
    pub fn load(rt: &Runtime, arts: &Artifacts) -> Result<Self> {
        let rungs = NER_BATCH_SIZES
            .iter()
            .map(|&b| NerExecutable::load(rt, arts, b))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { rungs })
    }

    pub fn pick(&self, n_docs: usize) -> &NerExecutable {
        self.rungs
            .iter()
            .find(|e| e.batch() >= n_docs)
            .unwrap_or_else(|| self.rungs.last().expect("non-empty ladder"))
    }

    /// Score an arbitrary number of documents, chunking through the ladder.
    pub fn score_all(&self, docs: &[Doc]) -> Result<Vec<NerOutput>> {
        let mut out = Vec::new();
        let max_b = self.rungs.last().expect("ladder").batch();
        let mut i = 0;
        while i < docs.len() {
            let n = (docs.len() - i).min(max_b);
            let chunk: Vec<&Doc> = docs[i..i + n].iter().collect();
            out.push(self.pick(n).execute_docs(&chunk)?);
            i += n;
        }
        Ok(out)
    }
}
