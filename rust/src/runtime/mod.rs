//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) built by
//! `make artifacts` and executes them from the L3 hot path via the `xla`
//! crate (PJRT CPU client). Python never runs here.
//!
//! The interchange format is HLO *text*: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! **Feature gating:** the `xla` crate is not vendored in this image, so
//! the PJRT-backed [`Runtime`]/executables are compiled only with the
//! `pjrt` cargo feature (which requires vendoring `xla` first). Without
//! it, API-compatible stubs return errors and callers fall back (e.g.
//! `fig8::calibrated_reduce_cost` uses its measured constant). Artifact
//! manifests and binary fixture IO are std-only and always available.

pub mod artifacts;
pub mod error;
pub mod ner_exec;

pub use artifacts::{Artifacts, InputSpec, Manifest, ManifestEntry};
pub use error::{Error, Result};
pub use ner_exec::{NerExecutable, NerLadder, NerOutput, NER_BATCH_SIZES};

use error::ensure;
use std::path::Path;

/// Wrapper around the PJRT CPU client plus the loaded executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact into a PJRT executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::msg(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Stub shown when the crate is built without the `pjrt` feature: the
/// constructor reports the runtime as unavailable so callers (CLI
/// `artifacts` command, fig8 calibration) degrade gracefully.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        Err(Error::msg(
            "PJRT runtime not built: enable the `pjrt` feature (requires a vendored `xla` crate)",
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the pjrt feature)".to_string()
    }
}

/// Read a little-endian f32 binary file (the exported parameter format).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian i32 binary file (check fixtures).
pub fn read_i32_file(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path)?;
    ensure!(bytes.len() % 4 == 0, "{}: bad length", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Locate the artifacts directory: `$DYNREPART_ARTIFACTS`, else
/// `./artifacts` relative to the workspace root.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("DYNREPART_ARTIFACTS") {
        return d.into();
    }
    // tests and benches run from the workspace root
    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for base in [&cwd, &cwd.join("..")] {
        let cand = base.join("artifacts");
        if cand.join("manifest.tsv").exists() {
            return cand;
        }
    }
    cwd.join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_f32_roundtrip() {
        let dir = std::env::temp_dir().join("dynrepart_test_f32");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), vals);
    }

    #[test]
    fn read_f32_rejects_truncated() {
        let dir = std::env::temp_dir().join("dynrepart_test_f32b");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(read_f32_file(&p).is_err());
    }

    #[test]
    fn artifacts_dir_env_override() {
        // NB: set_var is process-global; this test only checks the default
        // path resolution logic doesn't panic.
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let e = Runtime::cpu().unwrap_err();
        assert!(format!("{e}").contains("pjrt"));
    }
}
