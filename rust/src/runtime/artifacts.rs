//! Artifact manifest: what `make artifacts` produced and how to call it.
//! Pure std — available with or without the `pjrt` feature.

use super::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One input spec, e.g. `int32[32,128]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl InputSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| Error::msg(format!("bad input spec {s:?}")))?;
        let dims = rest.trim_end_matches(']');
        let shape = if dims.is_empty() {
            Vec::new()
        } else {
            dims.split(',')
                .map(|d| d.parse::<usize>())
                .collect::<std::result::Result<_, _>>()?
        };
        Ok(Self {
            dtype: dtype.to_string(),
            shape,
        })
    }

    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub inputs: Vec<InputSpec>,
    pub n_outputs: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let name = parts
                .next()
                .ok_or_else(|| Error::msg("empty manifest line"))?
                .to_string();
            let inputs = parts
                .next()
                .ok_or_else(|| Error::msg(format!("{name}: missing inputs")))?
                .split(';')
                .map(InputSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let n_outputs: usize = parts
                .next()
                .ok_or_else(|| Error::msg(format!("{name}: missing n_outputs")))?
                .parse()?;
            entries.insert(
                name.clone(),
                ManifestEntry {
                    name,
                    inputs,
                    n_outputs,
                },
            );
        }
        Ok(Self { entries })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.tsv"))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The artifacts directory with its manifest.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifacts {
    pub fn open(dir: &Path) -> Result<Self> {
        Ok(Self {
            dir: dir.to_path_buf(),
            manifest: Manifest::load(dir)?,
        })
    }

    pub fn open_default() -> Result<Self> {
        Self::open(&super::artifacts_dir())
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn bin_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.bin"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# name\tinputs\tn_outputs\n\
        ner_b32\tint32[32,128];int32[32];float32[8192,64];float32[64,9];float32[9]\t3\n\
        cms_n4096\tuint32[4096];float32[4096]\t1\n";

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get("ner_b32").unwrap();
        assert_eq!(e.inputs.len(), 5);
        assert_eq!(e.inputs[0].dtype, "int32");
        assert_eq!(e.inputs[0].shape, vec![32, 128]);
        assert_eq!(e.inputs[2].n_elems(), 8192 * 64);
        assert_eq!(e.n_outputs, 3);
    }

    #[test]
    fn parse_scalar_spec() {
        let s = InputSpec::parse("float32[]").unwrap();
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.n_elems(), 1);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(InputSpec::parse("float32").is_err());
        assert!(InputSpec::parse("float32[a,b]").is_err());
        assert!(Manifest::parse("name_only\n").is_err());
    }

    #[test]
    fn names_sorted() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.names(), vec!["cms_n4096", "ner_b32"]);
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration-ish: if `make artifacts` ran, the real manifest parses
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.tsv").exists() {
            let a = Artifacts::open(&dir).unwrap();
            assert!(a.manifest.get("ner_b32").is_some());
            assert!(a.hlo_path("ner_b32").exists());
        }
    }
}
