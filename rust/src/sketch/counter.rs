//! The paper's counter-based heavy-hitter heuristic (reconstruction).
//!
//! §4: "when experimenting with these methods, we observed either high
//! memory footprint or low performance in improving partitioning balance.
//! For this reason, we implemented a counter-based heuristic algorithm
//! that we describe in our extended paper." The extended paper is not in
//! the provided text, so this is our reconstruction, designed around the
//! two properties the paper emphasises (see DESIGN.md "Reconstructed
//! components"):
//!
//! 1. **low memory footprint** — a bounded map of `capacity` counters;
//!    on overflow the *minimum* counter is evicted **without** count
//!    inheritance (unlike SpaceSaving). This biases estimates low for
//!    newly-arrived keys but never inflates a cold key to the top of the
//!    histogram — precisely what matters when the histogram feeds a
//!    partitioner (a false heavy key triggers a useless migration, while
//!    a briefly-underestimated one merely delays isolation by one update);
//! 2. **drift tracking** — counts decay by γ at each harvest boundary,
//!    so mass reflects the current distribution, exponentially weighted.

use super::{HeavyHitter, MergeableSketch};
use crate::workload::Key;
use crate::util::keymap::{key_map_with_capacity, KeyMap};

#[derive(Debug, Clone)]
pub struct FreqCounter {
    capacity: usize,
    decay: f64,
    counts: KeyMap<f64>,
    total: f64,
}

impl FreqCounter {
    /// `capacity` ≈ c·λN (the paper gathers B = λN global keys; locals keep
    /// a small multiple); `decay` γ ∈ (0,1] applied at `decay_now`, 0.5 by
    /// convention here.
    pub fn new(capacity: usize, decay: f64) -> Self {
        assert!(capacity > 0);
        assert!(decay > 0.0 && decay <= 1.0);
        Self {
            capacity,
            decay,
            counts: key_map_with_capacity(capacity + 1),
            total: 0.0,
        }
    }

    pub fn with_capacity(capacity: usize) -> Self {
        Self::new(capacity, 0.5)
    }

    /// The counter bound this sketch was created with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Apply exponential decay — call when a histogram is harvested so the
    /// next interval's observations dominate (concept-drift tracking).
    pub fn decay_now(&mut self) {
        self.total *= self.decay;
        for c in self.counts.values_mut() {
            *c *= self.decay;
        }
        // drop counters that decayed to noise to free budget for new keys
        let floor = self.total / (self.capacity as f64 * 100.0);
        self.counts.retain(|_, c| *c > floor);
    }

    /// The decay factor γ this sketch was created with.
    pub fn decay(&self) -> f64 {
        self.decay
    }

    /// The live counters in ascending key order — the wire-snapshot form
    /// (map iteration order never reaches the wire).
    pub fn entries_sorted(&self) -> Vec<(Key, f64)> {
        let mut v: Vec<(Key, f64)> = self.counts.iter().map(|(&k, &c)| (k, c)).collect();
        v.sort_unstable_by_key(|e| e.0);
        v
    }

    /// Rebuild a counter from a snapshot ([`FreqCounter::entries_sorted`] +
    /// [`HeavyHitter::total`]). Value-exact: per-key counts and the total
    /// carry their bits verbatim, and no observable behaviour depends on
    /// map iteration order (eviction, compaction and harvest all rank
    /// with key tie-breaks), so the rebuilt counter is indistinguishable
    /// from the original.
    pub fn from_parts(capacity: usize, decay: f64, total: f64, entries: &[(Key, f64)]) -> Self {
        let mut fc = Self::new(capacity, decay);
        fc.counts.extend(entries.iter().copied());
        fc.total = total;
        fc
    }

    /// Evict the minimum counter, ties broken by ascending key — the same
    /// tie-break every other ranking in this sketch uses, so eviction is
    /// a function of the counter values alone, never of map iteration
    /// order (which differs between an original and a wire-rebuilt map).
    fn evict_min(&mut self) {
        if let Some((&k, _)) = self
            .counts
            .iter()
            .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(b.0)))
        {
            self.counts.remove(&k);
        }
    }

    /// Compact down to the `bound` largest counters — the
    /// `histogram-compaction` step of the original system, triggered by
    /// [`DrWorker`](crate::dr::DrWorker) every `compaction_interval`
    /// observations. Ranks on counts with ties broken by ascending key
    /// (the [`Histogram::from_counts`](super::Histogram::from_counts)
    /// comparator), so the surviving set is independent of map iteration
    /// order. Like `evict_min`, dropped counters carry no inheritance:
    /// `total` keeps the full observed mass, so estimates never inflate.
    pub fn compact_to(&mut self, bound: usize) {
        if bound == 0 || self.counts.len() <= bound {
            return;
        }
        let mut v: Vec<(Key, f64)> = self.counts.iter().map(|(&k, &c)| (k, c)).collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(bound);
        self.counts = key_map_with_capacity(self.capacity + 1);
        self.counts.extend(v);
    }
}

impl MergeableSketch for FreqCounter {
    /// Sum per-key counts and totals, then evict smallest counters until
    /// the capacity bound is re-established. Because eviction carries no
    /// inheritance, the merge (like `observe`) never overestimates.
    fn merge_from(&mut self, other: &Self) {
        self.total += other.total;
        for (&k, &c) in other.counts.iter() {
            *self.counts.entry(k).or_insert(0.0) += c;
        }
        while self.counts.len() > self.capacity {
            self.evict_min();
        }
    }
}

impl HeavyHitter for FreqCounter {
    fn observe(&mut self, key: Key, w: f64) {
        debug_assert!(w >= 0.0);
        self.total += w;
        if let Some(c) = self.counts.get_mut(&key) {
            *c += w;
            return;
        }
        if self.counts.len() >= self.capacity {
            self.evict_min();
        }
        self.counts.insert(key, w); // no inheritance — never overestimates
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn estimates(&self) -> Vec<(Key, f64)> {
        self.counts.iter().map(|(&k, &c)| (k, c)).collect()
    }

    fn footprint(&self) -> usize {
        self.counts.len()
    }

    fn clear(&mut self) {
        self.counts.clear();
        self.total = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator};

    #[test]
    fn never_overestimates() {
        let mut fc = FreqCounter::with_capacity(20);
        let mut z = Zipf::new(5_000, 1.0, 1);
        let n = 50_000;
        let mut exact: std::collections::HashMap<_, f64> = Default::default();
        for _ in 0..n {
            let r = z.next_record();
            *exact.entry(r.key).or_insert(0.0) += 1.0;
            fc.observe(r.key, 1.0);
        }
        for (k, est) in fc.estimates() {
            assert!(est <= exact[&k] + 1e-9, "overestimated key {k}");
        }
    }

    #[test]
    fn capacity_bounded() {
        let mut fc = FreqCounter::with_capacity(16);
        for i in 0..10_000u64 {
            fc.observe(i, 1.0);
        }
        assert!(fc.footprint() <= 16);
    }

    #[test]
    fn heavy_keys_tracked_accurately() {
        let mut fc = FreqCounter::with_capacity(100);
        let mut z = Zipf::new(100_000, 1.5, 2);
        let n = 100_000;
        for _ in 0..n {
            fc.observe(z.next_record().key, 1.0);
        }
        let est: std::collections::HashMap<_, _> = fc.estimates().into_iter().collect();
        // heaviest key (~29% at exp 1.5): estimate within 10% of truth
        let top = z.key_of_rank(0);
        let freq = est.get(&top).cloned().unwrap_or(0.0) / fc.total();
        assert!(freq > 0.2, "top-key freq estimate too low: {freq}");
    }

    #[test]
    fn decay_tracks_drift() {
        // Key A dominates interval 1; key B dominates interval 2. After
        // decay + interval 2, B must rank above A.
        let mut fc = FreqCounter::with_capacity(10);
        for _ in 0..1000 {
            fc.observe(100, 1.0);
        }
        fc.decay_now();
        for _ in 0..600 {
            fc.observe(200, 1.0);
        }
        let h = fc.harvest(2);
        assert_eq!(h.entries()[0].key, 200);
    }

    #[test]
    fn decay_preserves_relative_order_within_interval() {
        let mut fc = FreqCounter::with_capacity(10);
        for _ in 0..100 {
            fc.observe(1, 1.0);
        }
        for _ in 0..50 {
            fc.observe(2, 1.0);
        }
        fc.decay_now();
        let est: std::collections::HashMap<_, _> = fc.estimates().into_iter().collect();
        assert!(est[&1] > est[&2]);
    }

    #[test]
    fn compact_keeps_top_counts_and_total() {
        let mut fc = FreqCounter::with_capacity(64);
        for k in 0..32u64 {
            for _ in 0..=k {
                fc.observe(k, 1.0);
            }
        }
        let total_before = fc.total();
        fc.compact_to(8);
        assert_eq!(fc.footprint(), 8);
        assert!((fc.total() - total_before).abs() < 1e-12, "total must survive compaction");
        let kept: std::collections::HashSet<_> =
            fc.estimates().into_iter().map(|(k, _)| k).collect();
        for k in 24..32u64 {
            assert!(kept.contains(&k), "heavy key {k} evicted");
        }
        // bound 0 and already-small footprints are no-ops
        fc.compact_to(0);
        assert_eq!(fc.footprint(), 8);
        fc.compact_to(100);
        assert_eq!(fc.footprint(), 8);
    }

    #[test]
    fn compact_breaks_ties_by_key() {
        let mut a = FreqCounter::with_capacity(64);
        let mut b = FreqCounter::with_capacity(64);
        // same multiset of tied counts, observed in different orders
        for k in [5u64, 3, 9, 7] {
            a.observe(k, 2.0);
        }
        for k in [7u64, 9, 3, 5] {
            b.observe(k, 2.0);
        }
        a.compact_to(2);
        b.compact_to(2);
        let mut ea = a.estimates();
        let mut eb = b.estimates();
        ea.sort_unstable_by(|x, y| x.0.cmp(&y.0));
        eb.sort_unstable_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(ea, eb);
        assert_eq!(ea.iter().map(|e| e.0).collect::<Vec<_>>(), vec![3, 5]);
    }

    #[test]
    fn evict_min_breaks_ties_by_key() {
        // same tied multiset observed in different orders: on overflow
        // both counters must evict the same (lowest) key
        let mut a = FreqCounter::with_capacity(4);
        let mut b = FreqCounter::with_capacity(4);
        for k in [5u64, 3, 9, 7] {
            a.observe(k, 2.0);
        }
        for k in [7u64, 9, 3, 5] {
            b.observe(k, 2.0);
        }
        a.observe(100, 1.0);
        b.observe(100, 1.0);
        let mut ea = a.estimates();
        let mut eb = b.estimates();
        ea.sort_unstable_by_key(|e| e.0);
        eb.sort_unstable_by_key(|e| e.0);
        assert_eq!(ea, eb);
        assert!(!ea.iter().any(|e| e.0 == 3), "tied minimum must evict key 3: {ea:?}");
    }

    #[test]
    fn from_parts_roundtrip_is_behavior_exact() {
        let mut orig = FreqCounter::with_capacity(12);
        let mut z = Zipf::new(2_000, 1.2, 9);
        for _ in 0..5_000 {
            orig.observe(z.next_record().key, 1.0);
        }
        orig.decay_now();
        let mut rebuilt = FreqCounter::from_parts(
            orig.capacity(),
            orig.decay(),
            orig.total(),
            &orig.entries_sorted(),
        );
        assert_eq!(orig.total().to_bits(), rebuilt.total().to_bits());
        assert_eq!(orig.entries_sorted(), rebuilt.entries_sorted());
        // continue both with the identical suffix (forcing evictions and
        // a decay) — harvests must stay bitwise-identical
        for _ in 0..5_000 {
            let k = z.next_record().key;
            orig.observe(k, 1.0);
            rebuilt.observe(k, 1.0);
        }
        let (ho, hr) = (orig.harvest(8), rebuilt.harvest(8));
        assert_eq!(ho.entries(), hr.entries());
        assert_eq!(ho.total_weight().to_bits(), hr.total_weight().to_bits());
        assert_eq!(orig.entries_sorted(), rebuilt.entries_sorted());
    }

    #[test]
    fn harvest_relative_freqs() {
        let mut fc = FreqCounter::with_capacity(10);
        for _ in 0..75 {
            fc.observe(1, 1.0);
        }
        for _ in 0..25 {
            fc.observe(2, 1.0);
        }
        let h = fc.harvest(10);
        assert!((h.entries()[0].freq - 0.75).abs() < 1e-12);
        assert!((h.entries()[1].freq - 0.25).abs() < 1e-12);
    }
}
