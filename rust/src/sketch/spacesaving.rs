//! SpaceSaving (Metwally, Agrawal, El Abbadi, ICDT 2005) — baseline [22].
//!
//! Fixed budget of `capacity` counters. On a miss with a full table, the
//! minimum counter is reassigned to the new key, inheriting its count
//! (overestimate bounded by min-count). Implemented with a hash map plus a
//! lazily-maintained min tracking; capacity is small (O(λN)) so the
//! occasional O(capacity) min-scan is cheap and keeps the code simple.

use super::{HeavyHitter, MergeableSketch};
use crate::workload::Key;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct SpaceSaving {
    capacity: usize,
    counts: HashMap<Key, f64>,
    /// Per-key maximum overestimation (the inherited count at takeover).
    errors: HashMap<Key, f64>,
    total: f64,
}

impl SpaceSaving {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            counts: HashMap::with_capacity(capacity + 1),
            errors: HashMap::with_capacity(capacity + 1),
            total: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Guaranteed-count lower bound for a tracked key.
    pub fn lower_bound(&self, key: Key) -> f64 {
        self.counts.get(&key).cloned().unwrap_or(0.0)
            - self.errors.get(&key).cloned().unwrap_or(0.0)
    }

    fn min_entry(&self) -> Option<(Key, f64)> {
        self.counts
            .iter()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(&k, &c)| (k, c))
    }
}

impl MergeableSketch for SpaceSaving {
    /// Mergeable-summaries combine (Agarwal et al.): sum counts and error
    /// bounds keywise, then keep the largest `capacity` counters. A key
    /// *absent* from one side may have been observed there up to that
    /// side's minimum counter before eviction, so absent-side mass is
    /// absorbed as `min counter` into both count and error — preserving
    /// SpaceSaving's never-underestimate guarantee across the merge (a
    /// side that never filled its table evicted nothing: bound 0).
    fn merge_from(&mut self, other: &Self) {
        let bound = |s: &Self| {
            if s.counts.len() >= s.capacity {
                s.min_entry().map(|e| e.1).unwrap_or(0.0)
            } else {
                0.0
            }
        };
        let self_bound = bound(self);
        let other_bound = bound(other);
        self.total += other.total;
        for (k, c) in self.counts.iter_mut() {
            match other.counts.get(k) {
                Some(&oc) => {
                    *c += oc;
                    let oe = other.errors.get(k).cloned().unwrap_or(0.0);
                    *self.errors.entry(*k).or_insert(0.0) += oe;
                }
                None => {
                    *c += other_bound;
                    *self.errors.entry(*k).or_insert(0.0) += other_bound;
                }
            }
        }
        for (&k, &c) in other.counts.iter() {
            if !self.counts.contains_key(&k) {
                let oe = other.errors.get(&k).cloned().unwrap_or(0.0);
                self.counts.insert(k, c + self_bound);
                self.errors.insert(k, oe + self_bound);
            }
        }
        while self.counts.len() > self.capacity {
            let (min_key, _) = self.min_entry().expect("capacity > 0");
            self.counts.remove(&min_key);
            self.errors.remove(&min_key);
        }
    }
}

impl HeavyHitter for SpaceSaving {
    fn observe(&mut self, key: Key, w: f64) {
        debug_assert!(w >= 0.0);
        self.total += w;
        if let Some(c) = self.counts.get_mut(&key) {
            *c += w;
            return;
        }
        if self.counts.len() < self.capacity {
            self.counts.insert(key, w);
            self.errors.insert(key, 0.0);
            return;
        }
        // evict-min with count inheritance
        let (min_key, min_count) = self.min_entry().expect("capacity > 0");
        self.counts.remove(&min_key);
        self.errors.remove(&min_key);
        self.counts.insert(key, min_count + w);
        self.errors.insert(key, min_count);
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn estimates(&self) -> Vec<(Key, f64)> {
        self.counts.iter().map(|(&k, &c)| (k, c)).collect()
    }

    fn footprint(&self) -> usize {
        self.counts.len()
    }

    fn clear(&mut self) {
        self.counts.clear();
        self.errors.clear();
        self.total = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator};

    #[test]
    fn capacity_never_exceeded() {
        let mut ss = SpaceSaving::new(10);
        let mut z = Zipf::new(10_000, 0.5, 1); // near-uniform: worst case
        for _ in 0..50_000 {
            ss.observe(z.next_record().key, 1.0);
        }
        assert!(ss.footprint() <= 10);
    }

    #[test]
    fn overestimates_only_and_bounded() {
        // SpaceSaving estimate >= truth, and error <= total/capacity.
        let cap = 50;
        let mut ss = SpaceSaving::new(cap);
        let mut z = Zipf::new(1000, 1.5, 2);
        let n = 50_000;
        let mut exact: std::collections::HashMap<_, f64> = Default::default();
        for _ in 0..n {
            let r = z.next_record();
            *exact.entry(r.key).or_insert(0.0) += 1.0;
            ss.observe(r.key, 1.0);
        }
        for (k, est) in ss.estimates() {
            let truth = exact.get(&k).cloned().unwrap_or(0.0);
            assert!(est + 1e-9 >= truth, "must overestimate");
            assert!(
                est - truth <= n as f64 / cap as f64 + 1e-9,
                "error beyond N/m bound"
            );
        }
    }

    #[test]
    fn heavy_hitters_survive() {
        // top-5 keys of a skewed stream must be tracked with capacity 50.
        let mut ss = SpaceSaving::new(50);
        let mut z = Zipf::new(100_000, 1.2, 3);
        for _ in 0..100_000 {
            ss.observe(z.next_record().key, 1.0);
        }
        let tracked: std::collections::HashSet<_> =
            ss.estimates().iter().map(|e| e.0).collect();
        for rank in 0..5 {
            assert!(tracked.contains(&z.key_of_rank(rank)), "rank {rank} lost");
        }
    }

    #[test]
    fn lower_bound_is_sound() {
        let mut ss = SpaceSaving::new(2);
        for _ in 0..10 {
            ss.observe(1, 1.0);
        }
        ss.observe(2, 1.0);
        ss.observe(3, 1.0); // evicts key 2 (count 1), inherits
        assert!(ss.lower_bound(3) <= 1.0 + 1e-12);
        assert!((ss.lower_bound(1) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn clear_resets() {
        let mut ss = SpaceSaving::new(4);
        ss.observe(1, 2.0);
        ss.clear();
        assert_eq!(ss.footprint(), 0);
        assert_eq!(ss.total(), 0.0);
    }
}
