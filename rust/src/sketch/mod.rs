//! Approximate heavy-hitter counting and histogram machinery.
//!
//! DR needs a *distributed top-k histogram*: each DRW samples its local
//! key stream with a low-memory counter, the DRM merges the local
//! histograms and keeps the global top B = λN keys with relative frequency
//! estimates (§4). The paper evaluates Lossy Counting [21] and SpaceSaving
//! [22] as baselines and uses its own counter-based heuristic (details
//! deferred to the extended paper; reconstructed here — see DESIGN.md).

pub mod counter;
pub mod histogram;
pub mod lossy;
pub mod spacesaving;

pub use counter::FreqCounter;
pub use histogram::{Histogram, HistogramEntry};
pub use lossy::LossyCounting;
pub use spacesaving::SpaceSaving;

use crate::workload::Key;

/// Common interface of all heavy-hitter counters: observe weighted keys,
/// then harvest a local histogram of (key, estimated count) pairs.
pub trait HeavyHitter {
    /// Observe one occurrence of `key` with weight `w` (w = 1 for counting).
    fn observe(&mut self, key: Key, w: f64);

    /// Total weight observed so far (including evicted/expired mass).
    fn total(&self) -> f64;

    /// Current estimates, *unsorted*: (key, estimated weight).
    fn estimates(&self) -> Vec<(Key, f64)>;

    /// Number of counters held (memory footprint proxy).
    fn footprint(&self) -> usize;

    /// Reset for the next sampling interval.
    fn clear(&mut self);

    /// Harvest a top-k local histogram (sorted by decreasing frequency,
    /// relative to `total()`).
    fn harvest(&self, k: usize) -> Histogram {
        Histogram::from_counts(&self.estimates(), self.total(), k)
    }
}
