//! Approximate heavy-hitter counting and histogram machinery.
//!
//! DR needs a *distributed top-k histogram*: each DRW samples its local
//! key stream with a low-memory counter, the DRM merges the local
//! histograms and keeps the global top B = λN keys with relative frequency
//! estimates (§4). The paper evaluates Lossy Counting [21] and SpaceSaving
//! [22] as baselines and uses its own counter-based heuristic (details
//! deferred to the extended paper; reconstructed here — see DESIGN.md).

pub mod counter;
pub mod histogram;
pub mod lossy;
pub mod spacesaving;

pub use counter::FreqCounter;
pub use histogram::{Histogram, HistogramEntry};
pub use lossy::LossyCounting;
pub use spacesaving::SpaceSaving;

/// Bounding knobs for the DRW sketches and the DRM merge — the
/// reproduction of the original system's `repartitioning.conf` triple
/// (`histogram-compaction = 1250`, `histogram-size-boundary = 5000`,
/// `take = 1000`). All three default to `0` = disabled/unbounded, which
/// reproduces the exact path bit-for-bit (the bitwise pins in
/// `tests/prop_parallel.rs` run with this default).
///
/// With bounding enabled, every truncation ranks on accumulated absolute
/// counts with ties broken by ascending key — the same comparator as
/// [`Histogram::from_counts`] — and compaction triggers on per-DRW
/// *observation* counts, so decisions stay deterministic across thread
/// counts and fold shapes (see DESIGN.md "Bounded sketches").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchConfig {
    /// Compact each DRW counter down to its bound every this many
    /// observations (`histogram-compaction`). 0 = never compact.
    pub compaction_interval: usize,
    /// Hard cap on sketch/histogram entries: DRW counter capacity and the
    /// per-step size of the DRM tree-merge (`histogram-size-boundary`).
    /// 0 = unbounded (exact path).
    pub size_boundary: usize,
    /// Worker→master shipping cut: each harvest sends only the top this
    /// many entries (`take`). 0 = ship the full λN histogram.
    pub take_top_k: usize,
}

impl SketchConfig {
    /// Unbounded: every path identical to the exact implementation.
    pub fn unbounded() -> Self {
        Self {
            compaction_interval: 0,
            size_boundary: 0,
            take_top_k: 0,
        }
    }

    /// True when no knob is set — the default, bit-identical exact path.
    pub fn is_unbounded(&self) -> bool {
        *self == Self::unbounded()
    }

    /// Read the `DYNREPART_SKETCH_COMPACTION` / `DYNREPART_SKETCH_BOUND` /
    /// `DYNREPART_SKETCH_TAKE` overrides. Unset or empty leaves the knob
    /// disabled (CI legs pass empty strings to switch bounding off); a
    /// malformed value aborts with an error naming the variable instead
    /// of silently disabling the knob — same strict parser as
    /// `DYNREPART_THREADS` ([`crate::util::env`]). An explicit `0` is a
    /// valid way to spell "disabled".
    pub fn from_env() -> Self {
        fn knob(name: &str) -> usize {
            crate::util::env::knob_from_env(name, 0).unwrap_or(0)
        }
        Self {
            compaction_interval: knob("DYNREPART_SKETCH_COMPACTION"),
            size_boundary: knob("DYNREPART_SKETCH_BOUND"),
            take_top_k: knob("DYNREPART_SKETCH_TAKE"),
        }
    }
}

impl Default for SketchConfig {
    fn default() -> Self {
        Self::unbounded()
    }
}

#[cfg(test)]
mod config_tests {
    use super::SketchConfig;

    #[test]
    fn default_is_unbounded() {
        let cfg = SketchConfig::default();
        assert!(cfg.is_unbounded());
        assert_eq!(cfg, SketchConfig::unbounded());
        assert_eq!(cfg.compaction_interval, 0);
        assert_eq!(cfg.size_boundary, 0);
        assert_eq!(cfg.take_top_k, 0);
    }

    #[test]
    fn sketch_env_parse_paths_are_strict() {
        use crate::util::env::parse_knob;
        // the exact rules from_env applies, as pure functions (no env
        // mutation — that would race the parallel test harness)
        assert_eq!(parse_knob("DYNREPART_SKETCH_BOUND", None, 0), Ok(None));
        assert_eq!(parse_knob("DYNREPART_SKETCH_BOUND", Some(""), 0), Ok(None));
        assert_eq!(parse_knob("DYNREPART_SKETCH_BOUND", Some("0"), 0), Ok(Some(0)));
        assert_eq!(parse_knob("DYNREPART_SKETCH_BOUND", Some("5000"), 0), Ok(Some(5000)));
        assert!(parse_knob("DYNREPART_SKETCH_BOUND", Some("5k"), 0).is_err());
        assert!(parse_knob("DYNREPART_SKETCH_TAKE", Some("-1"), 0).is_err());
    }

    #[test]
    fn any_knob_marks_bounded() {
        for cfg in [
            SketchConfig { compaction_interval: 1250, ..Default::default() },
            SketchConfig { size_boundary: 5000, ..Default::default() },
            SketchConfig { take_top_k: 1000, ..Default::default() },
        ] {
            assert!(!cfg.is_unbounded(), "{cfg:?}");
        }
    }
}

#[cfg(test)]
mod merge_tests {
    use super::*;

    // Cross-sketch property: merging locals matches observing the union
    // stream, up to each sketch's own error model.
    #[test]
    fn merged_totals_add_for_every_sketch() {
        let mut a = FreqCounter::with_capacity(32);
        let mut b = FreqCounter::with_capacity(32);
        let mut sa = SpaceSaving::new(32);
        let mut sb = SpaceSaving::new(32);
        let mut la = LossyCounting::new(0.02);
        let mut lb = LossyCounting::new(0.02);
        for k in 0..1000u64 {
            a.observe(k % 40, 1.0);
            sa.observe(k % 40, 1.0);
            la.observe(k % 40, 1.0);
            b.observe(k % 7, 2.0);
            sb.observe(k % 7, 2.0);
            lb.observe(k % 7, 2.0);
        }
        a.merge_from(&b);
        sa.merge_from(&sb);
        la.merge_from(&lb);
        for (name, total) in
            [("counter", a.total()), ("spacesaving", sa.total()), ("lossy", la.total())]
        {
            assert!((total - 3000.0).abs() < 1e-9, "{name}: total {total}");
        }
    }

    #[test]
    fn merged_heavy_key_rises_to_top() {
        // key 9 is moderate in each local but heavy in the union
        let mut locals: Vec<FreqCounter> = (0..4).map(|_| FreqCounter::with_capacity(16)).collect();
        for (w, fc) in locals.iter_mut().enumerate() {
            for i in 0..1000u64 {
                let k = if i % 3 == 0 { 9 } else { (w as u64 + 1) * 1000 + i };
                fc.observe(k, 1.0);
            }
        }
        let mut merged = locals.remove(0);
        for fc in &locals {
            merged.merge_from(fc);
        }
        let h = merged.harvest(4);
        assert_eq!(h.entries()[0].key, 9);
        assert!((h.entries()[0].freq - 1.0 / 3.0).abs() < 0.05);
    }
}

use crate::workload::Key;

/// Common interface of all heavy-hitter counters: observe weighted keys,
/// then harvest a local histogram of (key, estimated count) pairs.
pub trait HeavyHitter {
    /// Observe one occurrence of `key` with weight `w` (w = 1 for counting).
    fn observe(&mut self, key: Key, w: f64);

    /// Total weight observed so far (including evicted/expired mass).
    fn total(&self) -> f64;

    /// Current estimates, *unsorted*: (key, estimated weight).
    fn estimates(&self) -> Vec<(Key, f64)>;

    /// Number of counters held (memory footprint proxy).
    fn footprint(&self) -> usize;

    /// Reset for the next sampling interval.
    fn clear(&mut self);

    /// Harvest a top-k local histogram (sorted by decreasing frequency,
    /// relative to `total()`).
    fn harvest(&self, k: usize) -> Histogram {
        Histogram::from_counts(&self.estimates(), self.total(), k)
    }
}

/// Sketches whose worker-local instances combine into one summary of the
/// union of their input streams — the mergeable-summary property the DRM
/// path relies on (DRWs sketch locally, the DRM merges globally).
///
/// Contract:
/// - `total()` of the merge equals the sum of the parts' totals;
/// - every key's estimate stays within the parts' summed error bounds
///   (a key absent from one side absorbs that side's eviction/prune
///   bound, so per-sketch guarantees survive the merge);
/// - bounded-memory sketches re-establish their capacity bound after the
///   merge (evicting smallest counters, as in mergeable SpaceSaving).
///
/// The DRM decision point merges the DRW locals through this trait, as
/// a deterministic pairwise tree that parallelizes without changing a
/// bit ([`merge_histograms_tree`](crate::dr::parallel::merge_histograms_tree));
/// `merge_from`'s ranking is on accumulated absolute counts with ties
/// broken by key, so no fold shape can reorder tied heavy hitters.
/// [`Histogram::merge`] is the *batch* form of the fold — one
/// accumulation pass over all locals, used to blend the few past
/// histograms — with a test (`merge_from_matches_batch_merge`) pinning
/// the two equivalent.
pub trait MergeableSketch {
    /// Fold `other`'s observations into `self`.
    fn merge_from(&mut self, other: &Self);
}
