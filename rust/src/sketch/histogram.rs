//! The global top-B histogram object of §4.
//!
//! `Hist` in Algorithm 1: the approximate heaviest keys ordered by
//! decreasing **relative** frequency (fractions of all input; frequencies
//! of keys not in the histogram make up the remainder to 1). Obtained by
//! merging worker-local histograms computed during sampling.

use crate::util::keymap::{key_map, KeyMap};
use crate::workload::Key;

#[derive(Debug, Clone, PartialEq)]
pub struct HistogramEntry {
    pub key: Key,
    /// Relative frequency estimate in [0, 1].
    pub freq: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Histogram {
    entries: Vec<HistogramEntry>,
    /// Total absolute weight this histogram was computed from (for merges).
    total_weight: f64,
}

impl Histogram {
    pub fn empty() -> Self {
        Self::default()
    }

    /// Build from absolute (key, count) estimates: keep the top `k` by
    /// count, convert to relative frequencies against `total`.
    pub fn from_counts(counts: &[(Key, f64)], total: f64, k: usize) -> Self {
        let mut v: Vec<(Key, f64)> = counts.iter().filter(|e| e.1 > 0.0).cloned().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        if total <= 0.0 {
            return Self::empty();
        }
        Self {
            entries: v
                .into_iter()
                .map(|(key, c)| HistogramEntry {
                    key,
                    freq: (c / total).min(1.0),
                })
                .collect(),
            total_weight: total,
        }
    }

    /// Build directly from relative frequencies (already sorted or not).
    pub fn from_freqs(freqs: &[(Key, f64)], total_weight: f64) -> Self {
        let mut entries: Vec<HistogramEntry> = freqs
            .iter()
            .map(|&(key, freq)| HistogramEntry { key, freq })
            .collect();
        entries.sort_by(|a, b| b.freq.total_cmp(&a.freq).then(a.key.cmp(&b.key)));
        Self {
            entries,
            total_weight,
        }
    }

    /// Rebuild a histogram from entries already in histogram order — the
    /// wire-deserialization form. Unlike [`Histogram::from_freqs`] this
    /// does **not** re-sort: `heavy_mass` and the DRM's load projections
    /// accumulate in entry order, so a reconstructed histogram must carry
    /// the sender's exact entry sequence (and f64 bits) to stay
    /// bitwise-identical.
    pub fn from_sorted_entries(entries: Vec<HistogramEntry>, total_weight: f64) -> Self {
        debug_assert!(
            entries.windows(2).all(|w| {
                w[1].freq < w[0].freq || (w[1].freq == w[0].freq && w[0].key < w[1].key)
            }),
            "entries must already be in histogram order"
        );
        Self {
            entries,
            total_weight,
        }
    }

    /// Merge worker-local histograms into a global one, keeping top `k`.
    ///
    /// Locals carry absolute totals, so the merge weights each local's
    /// relative frequencies by its share of the global weight. A key absent
    /// from one local but present in another contributes only the observed
    /// part — the standard mergeable-summary behaviour (underestimates are
    /// bounded by each local's top-k cutoff).
    ///
    /// This is the batch form of
    /// [`MergeableSketch::merge_from`](crate::sketch::MergeableSketch):
    /// one accumulation pass over all locals, where the pairwise trait
    /// fold re-sorts per node. The DRM decision point merges the DRW
    /// locals through the pairwise form — as a deterministic,
    /// parallelizable tree
    /// ([`merge_histograms_tree`](crate::dr::parallel::merge_histograms_tree))
    /// — and keeps this batch form for blending the few past histograms.
    /// `merge_from_matches_batch_merge` pins the two equivalent.
    pub fn merge(locals: &[Histogram], k: usize) -> Self {
        let total: f64 = locals.iter().map(|h| h.total_weight).sum();
        if total <= 0.0 {
            return Self::empty();
        }
        // fmix64-keyed accumulator (keys are not attacker-controlled);
        // bit-safe because from_counts fully re-sorts with key tie-breaks,
        // so map iteration order never reaches the result
        let mut acc: KeyMap<f64> = key_map();
        for h in locals {
            for e in &h.entries {
                *acc.entry(e.key).or_insert(0.0) += e.freq * h.total_weight;
            }
        }
        let counts: Vec<(Key, f64)> = acc.into_iter().collect();
        Self::from_counts(&counts, total, k)
    }

    /// Keep only the heaviest `k` entries. Entries are always held in
    /// decreasing-frequency order, so this is a suffix drop — the
    /// re-bounding step after pairwise [`merge_from`] folds
    /// (`Histogram::merge` applies it implicitly via its top-`k` build).
    ///
    /// [`merge_from`]: crate::sketch::MergeableSketch::merge_from
    pub fn truncate_top(&mut self, k: usize) {
        self.entries.truncate(k);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[HistogramEntry] {
        &self.entries
    }

    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Sum of the tracked heavy-key frequencies (Σᵢ Hist[i].freq ≤ 1).
    pub fn heavy_mass(&self) -> f64 {
        self.entries.iter().map(|e| e.freq).sum()
    }

    /// Frequency of the heaviest key (Hist[1].freq in the paper, 0 if empty).
    pub fn top_freq(&self) -> f64 {
        self.entries.first().map(|e| e.freq).unwrap_or(0.0)
    }

    pub fn contains(&self, key: Key) -> bool {
        self.entries.iter().any(|e| e.key == key)
    }

    /// Exact histogram from a batch of records — the oracle used in tests
    /// and in component experiments where the paper measures partitioning
    /// quality in isolation from sketch error (Fig 2).
    pub fn exact<'a, I: IntoIterator<Item = &'a crate::workload::Record>>(
        records: I,
        k: usize,
    ) -> Self {
        let mut counts: KeyMap<f64> = key_map();
        let mut total = 0.0;
        for r in records {
            *counts.entry(r.key).or_insert(0.0) += r.weight;
            total += r.weight;
        }
        let v: Vec<(Key, f64)> = counts.into_iter().collect();
        Self::from_counts(&v, total, k)
    }
}

impl super::MergeableSketch for Histogram {
    /// Union-merge on absolute weights: each local's relative frequencies
    /// are weighted by its share of the combined total. A key absent from
    /// one local but present in another contributes only the observed
    /// part — the standard mergeable-summary behaviour (underestimates
    /// are bounded by each local's top-k cutoff). Keeps *all* surviving
    /// keys so no mass is lost mid-fold; callers re-bound the footprint
    /// with [`Histogram::truncate_top`] once the fold is done (exactly
    /// what [`Histogram::merge`]'s top-`k` build does implicitly).
    ///
    /// Ranking is on the accumulated *absolute* counts (ties broken by
    /// key), not on the rounded relative frequencies: two distinct counts
    /// can round to the same `c / total`, and ranking on the rounded
    /// values would let division rounding — which varies with the fold
    /// shape — reorder tied heavy hitters between a pairwise fold and the
    /// batch merge. Count-space ranking is exactly what
    /// [`Histogram::merge`]'s `from_counts` build sorts on, so any fold
    /// shape (left fold, tree reduction) agrees with the batch merge on
    /// ranking whenever it agrees on the counts. The DRM's parallel
    /// tree-merge ([`crate::dr::parallel::merge_histograms_tree`]) relies
    /// on this.
    fn merge_from(&mut self, other: &Self) {
        let total = self.total_weight + other.total_weight;
        if total <= 0.0 {
            return;
        }
        // fmix64-keyed accumulator; per-key accumulation order is entry
        // order (self then other) and the sort below re-establishes the
        // ranking, so the map never influences a bit of the result
        let mut acc: KeyMap<f64> = key_map();
        for e in &self.entries {
            *acc.entry(e.key).or_insert(0.0) += e.freq * self.total_weight;
        }
        for e in &other.entries {
            *acc.entry(e.key).or_insert(0.0) += e.freq * other.total_weight;
        }
        let mut counts: Vec<(Key, f64)> = acc.into_iter().filter(|&(_, c)| c > 0.0).collect();
        counts.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        self.entries = counts
            .into_iter()
            .map(|(key, c)| HistogramEntry {
                key,
                freq: (c / total).min(1.0),
            })
            .collect();
        self.total_weight = total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::MergeableSketch;
    use crate::workload::Record;

    #[test]
    fn from_counts_sorts_and_truncates() {
        let h = Histogram::from_counts(&[(1, 10.0), (2, 30.0), (3, 20.0), (4, 5.0)], 100.0, 3);
        assert_eq!(h.len(), 3);
        assert_eq!(h.entries()[0].key, 2);
        assert!((h.entries()[0].freq - 0.3).abs() < 1e-12);
        assert_eq!(h.entries()[2].key, 1);
        assert!(!h.contains(4));
    }

    #[test]
    fn heavy_mass_and_top() {
        let h = Histogram::from_counts(&[(1, 50.0), (2, 25.0)], 100.0, 10);
        assert!((h.heavy_mass() - 0.75).abs() < 1e-12);
        assert!((h.top_freq() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::empty();
        assert_eq!(h.top_freq(), 0.0);
        assert_eq!(h.heavy_mass(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn merge_weights_by_local_totals() {
        // local A: key 1 at 50% of 100; local B: key 1 at 10% of 300
        let a = Histogram::from_counts(&[(1, 50.0)], 100.0, 5);
        let b = Histogram::from_counts(&[(1, 30.0), (2, 60.0)], 300.0, 5);
        let m = Histogram::merge(&[a, b], 5);
        // key1: (50+30)/400 = 0.2 ; key2: 60/400 = 0.15
        assert_eq!(m.entries()[0].key, 1);
        assert!((m.entries()[0].freq - 0.2).abs() < 1e-12);
        assert!((m.entries()[1].freq - 0.15).abs() < 1e-12);
        assert!((m.total_weight() - 400.0).abs() < 1e-12);
    }

    #[test]
    fn merge_empty_is_empty() {
        assert!(Histogram::merge(&[], 5).is_empty());
        assert!(Histogram::merge(&[Histogram::empty()], 5).is_empty());
    }

    #[test]
    fn exact_matches_manual_count() {
        let recs = vec![
            Record::unit(1, 0),
            Record::unit(1, 1),
            Record::unit(2, 2),
            Record::new(3, 3, 2.0),
        ];
        let h = Histogram::exact(&recs, 10);
        // weights: k1=2, k3=2, k2=1, total 5
        assert_eq!(h.len(), 3);
        assert!((h.heavy_mass() - 1.0).abs() < 1e-12);
        assert!((h.top_freq() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn merge_from_matches_batch_merge() {
        let a = Histogram::from_counts(&[(1, 50.0), (3, 10.0)], 100.0, 5);
        let b = Histogram::from_counts(&[(1, 30.0), (2, 60.0)], 300.0, 5);
        let c = Histogram::from_counts(&[(2, 5.0), (4, 20.0)], 50.0, 5);
        // k = 2 exercises the truncation regime: the fold keeps all keys
        // until truncate_top re-bounds it, and must agree with the batch
        // merge's top-k build.
        for k in [2usize, 10] {
            let batch = Histogram::merge(&[a.clone(), b.clone(), c.clone()], k);
            let mut folded = Histogram::empty();
            folded.merge_from(&a);
            folded.merge_from(&b);
            folded.merge_from(&c);
            folded.truncate_top(k);
            assert_eq!(batch.len(), folded.len(), "k={k}");
            assert!((batch.total_weight() - folded.total_weight()).abs() < 1e-9);
            for (x, y) in batch.entries().iter().zip(folded.entries()) {
                assert_eq!(x.key, y.key, "k={k}");
                assert!((x.freq - y.freq).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn merge_from_ranking_is_fold_shape_independent() {
        // Three locals with dyadic counts over power-of-two totals, so
        // every non-root fold step divides exactly and both shapes hand
        // the root the same exact integer counts; keys 5/6/7 tie at 8.
        // The left fold and the right fold must produce identical
        // entries, and tied counts must break by key — never by which
        // merge step happened to see them first. (Ranking is compared in
        // count space *before* the root's division, so the tie survives
        // even where `c / total` rounds.)
        let locals = [
            Histogram::from_counts(&[(7, 8.0), (1, 16.0)], 64.0, 8),
            Histogram::from_counts(&[(5, 4.0), (2, 32.0)], 64.0, 8),
            Histogram::from_counts(&[(6, 8.0), (5, 4.0), (3, 2.0)], 64.0, 8),
        ];
        // left fold: (l0 + l1) + l2
        let mut left = locals[0].clone();
        left.merge_from(&locals[1]);
        left.merge_from(&locals[2]);
        // right fold: l0 + (l1 + l2)
        let mut tail = locals[1].clone();
        tail.merge_from(&locals[2]);
        let mut right = locals[0].clone();
        right.merge_from(&tail);
        assert_eq!(left.entries(), right.entries(), "fold shape reordered ranking");
        // counts: 1→16, 2→32, 3→2, 5→8, 6→8, 7→8; ties 5/6/7 rank by key
        let keys: Vec<Key> = left.entries().iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![2, 1, 5, 6, 7, 3]);
        // and the batch merge agrees (it ranks on counts too)
        let batch = Histogram::merge(&locals, 8);
        let bkeys: Vec<Key> = batch.entries().iter().map(|e| e.key).collect();
        assert_eq!(keys, bkeys, "fold and batch merge rank differently");
    }

    #[test]
    fn from_sorted_entries_preserves_order_and_bits() {
        let h = Histogram::from_counts(&[(1, 10.0), (2, 30.0), (3, 20.0), (3000, 20.0)], 95.0, 4);
        let r = Histogram::from_sorted_entries(h.entries().to_vec(), h.total_weight());
        assert_eq!(h.entries(), r.entries());
        assert_eq!(h.total_weight().to_bits(), r.total_weight().to_bits());
        assert_eq!(h.heavy_mass().to_bits(), r.heavy_mass().to_bits());
    }

    #[test]
    fn ties_break_deterministically() {
        let a = Histogram::from_counts(&[(5, 10.0), (3, 10.0), (9, 10.0)], 30.0, 2);
        let b = Histogram::from_counts(&[(9, 10.0), (5, 10.0), (3, 10.0)], 30.0, 2);
        assert_eq!(a.entries(), b.entries());
    }
}
