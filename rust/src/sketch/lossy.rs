//! Lossy Counting (Manku & Motwani, VLDB 2002) — baseline sketch [21].
//!
//! Deterministic ε-deficient counting: maintains (count, Δ) per tracked
//! key, prunes at bucket boundaries of width ⌈1/ε⌉. Guarantees: every key
//! with true frequency ≥ ε·N is reported, and estimates underestimate by
//! at most ε·N. Generalised to weighted items (bucket boundaries advance
//! on accumulated weight).

use super::{HeavyHitter, MergeableSketch};
use crate::workload::Key;
use std::collections::HashMap;

#[derive(Debug, Clone)]
struct Entry {
    count: f64,
    delta: f64,
}

#[derive(Debug, Clone)]
pub struct LossyCounting {
    epsilon: f64,
    bucket_width: f64,
    entries: HashMap<Key, Entry>,
    total: f64,
    current_bucket: f64,
}

impl LossyCounting {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        Self {
            epsilon,
            bucket_width: 1.0 / epsilon,
            entries: HashMap::new(),
            total: 0.0,
            current_bucket: 1.0,
        }
    }

    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    fn prune(&mut self) {
        let b = self.current_bucket;
        self.entries.retain(|_, e| e.count + e.delta > b - 1.0);
    }
}

impl MergeableSketch for LossyCounting {
    /// Keywise sum of counts and error terms (Δ) — the standard
    /// lossy-counting merge, where the ε-deficiency bounds add. A key
    /// *absent* from one side may have been pruned there with up to that
    /// side's `bucket − 1` mass, so its Δ absorbs that side's prune bound;
    /// otherwise a key heavy in the union could be dropped by the
    /// post-merge prune despite exceeding the ε·N guarantee. The bucket
    /// cursor then advances to the merged total and a prune re-establishes
    /// the footprint bound.
    fn merge_from(&mut self, other: &Self) {
        // Hard assert (not debug): merging incompatible epsilons silently
        // corrupts both sketches' bounds, and merges are cold-path.
        assert!(
            (self.bucket_width - other.bucket_width).abs() < 1e-9,
            "merging lossy counters with different epsilon ({} vs {}) voids both bounds",
            self.epsilon,
            other.epsilon
        );
        let self_bound = (self.current_bucket - 1.0).max(0.0);
        let other_bound = (other.current_bucket - 1.0).max(0.0);
        self.total += other.total;
        for (k, m) in self.entries.iter_mut() {
            match other.entries.get(k) {
                Some(e) => {
                    m.count += e.count;
                    m.delta += e.delta;
                }
                None => m.delta += other_bound,
            }
        }
        for (&k, e) in other.entries.iter() {
            self.entries.entry(k).or_insert_with(|| Entry {
                count: e.count,
                delta: e.delta + self_bound,
            });
        }
        self.current_bucket = (self.total / self.bucket_width).ceil().max(1.0);
        self.prune();
    }
}

impl HeavyHitter for LossyCounting {
    fn observe(&mut self, key: Key, w: f64) {
        debug_assert!(w >= 0.0);
        self.total += w;
        let bucket = self.current_bucket;
        self.entries
            .entry(key)
            .and_modify(|e| e.count += w)
            .or_insert(Entry {
                count: w,
                delta: bucket - 1.0,
            });
        let new_bucket = (self.total / self.bucket_width).ceil().max(1.0);
        if new_bucket > self.current_bucket {
            self.current_bucket = new_bucket;
            self.prune();
        }
    }

    fn total(&self) -> f64 {
        self.total
    }

    fn estimates(&self) -> Vec<(Key, f64)> {
        self.entries
            .iter()
            .map(|(&k, e)| (k, e.count + e.delta))
            .collect()
    }

    fn footprint(&self) -> usize {
        self.entries.len()
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.total = 0.0;
        self.current_bucket = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::{zipf::Zipf, Generator};

    #[test]
    fn finds_all_true_heavy_hitters() {
        // ε = 0.001; any key with freq >= 1% must be present.
        let mut lc = LossyCounting::new(0.001);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let n = 100_000;
        let mut exact: std::collections::HashMap<_, u32> = Default::default();
        for _ in 0..n {
            let r = z.next_record();
            *exact.entry(r.key).or_insert(0) += 1;
            lc.observe(r.key, 1.0);
        }
        let tracked: std::collections::HashSet<_> =
            lc.estimates().iter().map(|e| e.0).collect();
        for (k, c) in exact {
            if c as f64 >= 0.01 * n as f64 {
                assert!(tracked.contains(&k), "missing heavy key {k} count {c}");
            }
        }
    }

    #[test]
    fn estimate_error_bounded_by_epsilon_n() {
        let eps = 0.005;
        let mut lc = LossyCounting::new(eps);
        let mut z = Zipf::new(1_000, 1.0, 2);
        let n = 50_000;
        let mut exact: std::collections::HashMap<_, f64> = Default::default();
        for _ in 0..n {
            let r = z.next_record();
            *exact.entry(r.key).or_insert(0.0) += 1.0;
            lc.observe(r.key, 1.0);
        }
        for (k, est) in lc.estimates() {
            let truth = exact.get(&k).cloned().unwrap_or(0.0);
            assert!(est <= truth + eps * n as f64 + 1e-9, "overestimate beyond bound");
            assert!(est >= truth - eps * n as f64 - 1e-9, "underestimate beyond bound");
        }
    }

    #[test]
    fn memory_stays_bounded() {
        let mut lc = LossyCounting::new(0.01);
        let mut rng = Rng::new(3);
        for _ in 0..200_000 {
            lc.observe(rng.next_u64(), 1.0); // all-distinct adversary
        }
        // classic bound: (1/eps) * log(eps*N) counters
        let bound = (1.0 / 0.01) * (0.01f64 * 200_000.0).ln();
        assert!(
            (lc.footprint() as f64) < bound * 2.0,
            "footprint={} bound={bound}",
            lc.footprint()
        );
    }

    #[test]
    fn weighted_observations() {
        let mut lc = LossyCounting::new(0.1);
        lc.observe(1, 10.0);
        lc.observe(2, 1.0);
        let est: std::collections::HashMap<_, _> = lc.estimates().into_iter().collect();
        assert!(est[&1] >= 10.0);
        assert!((lc.total() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn clear_resets() {
        let mut lc = LossyCounting::new(0.1);
        lc.observe(1, 5.0);
        lc.clear();
        assert_eq!(lc.footprint(), 0);
        assert_eq!(lc.total(), 0.0);
    }

    #[test]
    fn harvest_is_sorted_topk() {
        let mut lc = LossyCounting::new(0.001);
        for i in 0..100u64 {
            for _ in 0..=i {
                lc.observe(i, 1.0);
            }
        }
        let h = lc.harvest(5);
        assert_eq!(h.len(), 5);
        assert_eq!(h.entries()[0].key, 99);
        for w in h.entries().windows(2) {
            assert!(w[0].freq >= w[1].freq);
        }
    }
}
