//! The **Mixed** partitioning strategy of Fang et al., "Parallel stream
//! processing against workload skewness and variance" [9] — the second
//! baseline family of Fig 2.
//!
//! Mixed splits keys into a *tracked head* (explicit placement, histogram
//! bounded by A_max) and a *hashed tail* (plain uniform hashing, unlike
//! Gedik's consistent ring). Head placement is greedy under a user-supplied
//! load bound θ_max; the paper obtained θ_max "through an extra
//! optimization loop", which we reproduce with a bisection on θ_max until
//! the greedy placement is feasible and tight. Plain-uniform tail balance
//! explains Fig 2's ordering: Mixed sits between the ring-based Gedik
//! functions and KIP (whose host re-packing also balances the tail).

use super::{Partitioner, Uhp};
use crate::sketch::Histogram;
use crate::workload::Key;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Mixed {
    explicit: HashMap<Key, u32>,
    tail: Uhp,
    /// θ_max found by the last optimization loop (for inspection/tests).
    theta_max: f64,
}

impl Mixed {
    pub fn initial(n: usize, seed: u64) -> Self {
        Self {
            explicit: HashMap::new(),
            tail: Uhp::with_seed(n, seed),
            theta_max: f64::INFINITY,
        }
    }

    pub fn theta_max(&self) -> f64 {
        self.theta_max
    }

    /// Greedy head placement under absolute per-partition bound `cap`.
    /// Returns planned loads on success.
    fn try_place(
        &self,
        hist: &Histogram,
        cap: f64,
    ) -> Option<(HashMap<Key, u32>, Vec<f64>)> {
        let n = self.tail.n_partitions();
        // tail is uniformly hashed: residual spreads ~evenly
        let residual = (1.0 - hist.heavy_mass()).max(0.0);
        let mut load = vec![residual / n as f64; n];
        let mut explicit = HashMap::with_capacity(hist.len());
        for e in hist.entries() {
            let p = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .expect("n > 0");
            if load[p] + e.freq > cap {
                return None;
            }
            load[p] += e.freq;
            explicit.insert(e.key, p as u32);
        }
        Some((explicit, load))
    }

    /// Update with the paper's "extra optimization loop": bisect the load
    /// bound θ_max down to the tightest feasible greedy placement.
    pub fn update(&self, hist: &Histogram) -> Self {
        let n = self.tail.n_partitions();
        if hist.is_empty() {
            return Self {
                explicit: HashMap::new(),
                tail: self.tail.clone(),
                theta_max: f64::INFINITY,
            };
        }
        let ideal = (1.0 / n as f64).max(hist.top_freq());
        // bisection over cap in [ideal, 2·ideal + heavy mass]
        let mut lo = ideal;
        let mut hi = ideal * 2.0 + hist.heavy_mass();
        let mut best = None;
        for _ in 0..32 {
            let mid = 0.5 * (lo + hi);
            match self.try_place(hist, mid) {
                Some(sol) => {
                    best = Some((sol, mid));
                    hi = mid;
                }
                None => lo = mid,
            }
        }
        // ensure at least the loose bound works
        let ((explicit, _), cap) = match best {
            Some((sol, cap)) => (sol, cap),
            None => {
                let cap = hi * 2.0;
                (
                    self.try_place(hist, cap)
                        .expect("loose bound must be feasible"),
                    cap,
                )
            }
        };
        Self {
            explicit,
            tail: self.tail.clone(),
            theta_max: cap / ideal - 1.0,
        }
    }
}

impl Partitioner for Mixed {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        match self.explicit.get(&key) {
            Some(&p) => p as usize,
            None => self.tail.partition(key),
        }
    }

    fn n_partitions(&self) -> usize {
        self.tail.n_partitions()
    }

    fn explicit_routes(&self) -> usize {
        self.explicit.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::partition_loads;
    use crate::util::load_imbalance;
    use crate::workload::{zipf::Zipf, Generator};

    #[test]
    fn beats_plain_hash_on_skew() {
        let n = 16;
        let mut z = Zipf::new(50_000, 1.0, 3);
        let recs = z.batch(300_000);
        let hist = Histogram::exact(&recs, 2 * n);
        let mut kw: HashMap<Key, f64> = HashMap::new();
        for r in &recs {
            *kw.entry(r.key).or_insert(0.0) += 1.0;
        }
        let kw: Vec<(Key, f64)> = kw.into_iter().collect();
        let m0 = Mixed::initial(n, 1);
        let before = load_imbalance(&partition_loads(&m0, &kw));
        let m1 = m0.update(&hist);
        let after = load_imbalance(&partition_loads(&m1, &kw));
        assert!(after < before, "after={after} before={before}");
    }

    #[test]
    fn optimization_loop_tightens_theta() {
        let n = 8;
        let freqs: Vec<(Key, f64)> = (0..16u64).map(|k| (k, 0.04)).collect();
        let hist = Histogram::from_freqs(&freqs, 1.0);
        let m = Mixed::initial(n, 2).update(&hist);
        // 16 keys × 0.04 over 8 partitions on top of 0.36 tail: a tight
        // bound exists; θ_max should come out small
        assert!(m.theta_max() < 0.5, "theta_max={}", m.theta_max());
    }

    #[test]
    fn empty_histogram_resets_head() {
        let n = 4;
        let hist = Histogram::from_freqs(&[(1, 0.5)], 1.0);
        let m = Mixed::initial(n, 3).update(&hist);
        assert_eq!(m.explicit_routes(), 1);
        let m2 = m.update(&Histogram::empty());
        assert_eq!(m2.explicit_routes(), 0);
    }

    #[test]
    fn head_placement_respects_found_bound() {
        let n = 8;
        let mut z = Zipf::new(10_000, 1.3, 4);
        let recs = z.batch(100_000);
        let hist = Histogram::exact(&recs, 2 * n);
        let m = Mixed::initial(n, 4).update(&hist);
        let ideal = (1.0 / n as f64).max(hist.top_freq());
        let cap = ideal * (1.0 + m.theta_max());
        // verify planned head+tail load under cap
        let residual = (1.0 - hist.heavy_mass()).max(0.0);
        let mut load = vec![residual / n as f64; n];
        for e in hist.entries() {
            load[m.partition(e.key)] += e.freq;
        }
        for (p, l) in load.iter().enumerate() {
            assert!(*l <= cap + 1e-9, "partition {p}: {l} > cap {cap}");
        }
    }

    #[test]
    fn tail_uniform_hash() {
        let m = Mixed::initial(10, 5);
        let kw: Vec<(Key, f64)> = (0..100_000u64).map(|k| (k, 1.0)).collect();
        let imb = load_imbalance(&partition_loads(&m, &kw));
        assert!(imb < 1.05, "imb={imb}");
    }
}
