//! The **Key Isolator Partitioner** and its update rule — Algorithm 1 of
//! the paper, implemented line by line.
//!
//! KIP is "a heuristic combination of an explicit hashing for the heaviest
//! keys and a weighted hash partitioner for filling up the partitions to
//! roughly the same load", with updates that "make minimal modifications
//! to the previous partitioner to reduce migration costs".

use super::route::{FlatRoutes, RouteTable};
use super::{Partitioner, WeightedHash};
use crate::sketch::Histogram;
use crate::workload::Key;

#[derive(Debug, Clone, Copy)]
pub struct KipConfig {
    /// Global histogram scale factor λ: the DRM gathers the top B = λN keys
    /// (§4). The paper sets λ = 2 in its experiments and sweeps {1,2,3,4}
    /// in Fig 2 (right).
    pub lambda: usize,
    /// Slack ε on the ideal maximal load (Algorithm 1, line 1).
    pub epsilon: f64,
    /// Hosts per partition for the weighted hash (H = this × N, H ≫ N).
    pub hosts_per_partition: usize,
}

impl Default for KipConfig {
    fn default() -> Self {
        Self {
            lambda: 2,
            epsilon: 0.01,
            hosts_per_partition: super::weighted::DEFAULT_HOSTS_PER_PARTITION,
        }
    }
}

impl KipConfig {
    pub fn histogram_size(&self, n_partitions: usize) -> usize {
        self.lambda * n_partitions
    }
}

#[derive(Debug, Clone)]
pub struct Kip {
    /// Explicit routing table for the isolated heavy keys — O(λN) entries
    /// in a sorted flat array (hot path: one binary search per record,
    /// cache-resident at λN entries).
    explicit: RouteTable,
    /// Weighted hash for everything else.
    hash: WeightedHash,
    cfg: KipConfig,
}

impl Kip {
    /// The partitioner before any histogram is known: empty routing table,
    /// balanced host map — behaviourally a uniform hash partitioner.
    pub fn initial(n_partitions: usize, cfg: KipConfig, seed: u64) -> Self {
        Self {
            explicit: RouteTable::default(),
            hash: WeightedHash::balanced(
                n_partitions,
                n_partitions * cfg.hosts_per_partition,
                seed,
            ),
            cfg,
        }
    }

    pub fn config(&self) -> KipConfig {
        self.cfg
    }

    pub fn weighted_hash(&self) -> &WeightedHash {
        &self.hash
    }

    pub fn explicit_table(&self) -> &RouteTable {
        &self.explicit
    }

    /// **KIPUPDATE** (Algorithm 1).
    ///
    /// * `prev` — KI, the partitioner of the previous stage (line 4 reads
    ///   key locations from it; on the very first update this is the UHP).
    /// * `hash` — the weighted hash whose host map the update starts from
    ///   and rebalances (lines 11–15).
    /// * `hist` — the merged global histogram, decreasing frequency.
    ///
    /// The per-key location reads (lines 4 and 7) and the host→partition
    /// bucketing (lines 11–13) are pure; this entry point computes them
    /// inline and hands them to [`Kip::update_with_locations`], which the
    /// sharded decision point ([`crate::dr::parallel::kip_candidate`])
    /// also drives with the same tables precomputed on pool workers —
    /// so the sequential and sharded constructions are the same
    /// operation sequence, bitwise.
    pub fn update(
        prev: &dyn Partitioner,
        hash: &WeightedHash,
        hist: &Histogram,
        cfg: KipConfig,
    ) -> Self {
        assert_eq!(
            prev.n_partitions(),
            hash.n_partitions(),
            "partition count change not supported here"
        );
        let prev_locs: Vec<u32> = hist
            .entries()
            .iter()
            .map(|e| prev.partition(e.key) as u32)
            .collect();
        let hash_locs: Vec<u32> = hist
            .entries()
            .iter()
            .map(|e| hash.partition(e.key) as u32)
            .collect();
        Self::update_with_locations(
            &prev_locs,
            &hash_locs,
            hash.hosts_by_partition(),
            hash,
            hist,
            cfg,
        )
    }

    /// The order-sensitive core of **KIPUPDATE**, with every pure lookup
    /// already tabulated: `prev_locs[i]` / `hash_locs[i]` are the line-4 /
    /// line-7 locations of `hist.entries()[i]`, and `hosts_in` is
    /// [`WeightedHash::hosts_by_partition`] of `hash`. The greedy heavy-key
    /// placement and host bin-packing below run unchanged from the
    /// sequential algorithm — parallelism lives entirely in *producing*
    /// the tables (see DESIGN.md "Sharded DRM decision point" for why the
    /// greedy itself must not be split).
    pub fn update_with_locations(
        prev_locs: &[u32],
        hash_locs: &[u32],
        mut hosts_in: Vec<Vec<usize>>,
        hash: &WeightedHash,
        hist: &Histogram,
        cfg: KipConfig,
    ) -> Self {
        let n = hash.n_partitions();
        let h = hash.n_hosts() as f64;
        debug_assert_eq!(prev_locs.len(), hist.len());
        debug_assert_eq!(hash_locs.len(), hist.len());
        debug_assert_eq!(hosts_in.len(), n);

        // line 1: allowed level
        let maxload = (1.0 / n as f64).max(hist.top_freq()) + cfg.epsilon;
        // line 2: average host load
        let hostload = (1.0 - hist.heavy_mass()).max(0.0) / h;

        let mut load = vec![0.0f64; n];
        // the greedy only ever *appends* routes (histogram keys are
        // distinct, and no placement reads the table), so routes collect
        // into a Vec and sort into the flat table once at the end
        let mut routes: Vec<(Key, u32)> = Vec::with_capacity(hist.len());

        // lines 3–10: place heavy keys by decreasing frequency
        for (i, e) in hist.entries().iter().enumerate() {
            let (k, f) = (e.key, e.freq);
            // line 4: try to place k into the same partition as before
            let p = prev_locs[i] as usize;
            if load[p] < maxload - f {
                load[p] += f;
                routes.push((k, p as u32));
                continue;
            }
            // line 7: try the hash location (its future home if it cools
            // down) to reduce potential migration later
            let p = hash_locs[i] as usize;
            if load[p] < maxload - f {
                load[p] += f;
                routes.push((k, p as u32));
                continue;
            }
            // line 10: put k explicitly into the lowest-load partition
            let (p, _) = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .expect("n > 0");
            load[p] += f;
            routes.push((k, p as u32));
        }
        let explicit = RouteTable::from_pairs(routes);

        // lines 11–13: add tail mass — HOSTLOAD × hosts mapped to p
        let mut new_hash = hash.clone();
        for p in 0..n {
            load[p] += hostload * hosts_in[p].len() as f64;
        }

        // lines 14–15: greedy bin packing — move hosts off overloaded
        // partitions into partitions with room (load < MAXLOAD − HOSTLOAD).
        // Hosts are popped in canonical (descending-index) order so that
        // successive updates under similar loads move the *same* hosts —
        // placement hysteresis that keeps tail-state migration low (Fig 3).
        if hostload > 0.0 {
            for h in hosts_in.iter_mut() {
                h.sort_unstable();
            }
            for p in 0..n {
                while load[p] > maxload && !hosts_in[p].is_empty() {
                    // lowest-load target with room for one more host
                    let target = (0..n)
                        .filter(|&q| q != p)
                        .min_by(|&a, &b| load[a].total_cmp(&load[b]))
                        .filter(|&q| load[q] < maxload - hostload);
                    let Some(q) = target else { break };
                    let host = hosts_in[p].pop().expect("non-empty");
                    new_hash.set_host(host, q);
                    hosts_in[q].push(host);
                    load[p] -= hostload;
                    load[q] += hostload;
                }
            }
            // Even filling: "a weighted hash partitioner for filling up the
            // partitions to roughly the same load" (§4). Keep moving single
            // hosts from the fullest to the emptiest partition while the
            // spread exceeds a hysteresis band: tight enough for Fig 2's
            // flat balance (band ≈ ε keeps imbalance ≤ 1 + εN), wide enough
            // that drift/sampling wiggle in the heavy-key frequencies does
            // not re-shuffle hosts at every update (Fig 3 migration). Each
            // move shifts ~HOSTLOAD → O(H) termination.
            let band = (3.0 * hostload).max(cfg.epsilon);
            loop {
                let pmax = (0..n).max_by(|&a, &b| load[a].total_cmp(&load[b])).unwrap();
                let pmin = (0..n).min_by(|&a, &b| load[a].total_cmp(&load[b])).unwrap();
                if load[pmax] - load[pmin] <= band || hosts_in[pmax].is_empty() {
                    break;
                }
                let host = hosts_in[pmax].pop().expect("non-empty");
                new_hash.set_host(host, pmin);
                hosts_in[pmin].push(host);
                load[pmax] -= hostload;
                load[pmin] += hostload;
            }
        }

        // line 16: the new partitioning function
        Self {
            explicit,
            hash: new_hash,
            cfg,
        }
    }

    /// Update using `self` as the previous partitioner (the common case in
    /// a long-running job).
    pub fn updated(&self, hist: &Histogram) -> Self {
        Self::update(self, &self.hash, hist, self.cfg)
    }

    /// Planned per-partition load this update computed for itself, given a
    /// histogram (recomputed; used by tests and the DRM's decision logic).
    pub fn planned_loads(&self, hist: &Histogram) -> Vec<f64> {
        let n = self.n_partitions();
        let mut load = vec![0.0; n];
        for e in hist.entries() {
            if let Some(p) = self.explicit.get(&e.key) {
                load[p as usize] += e.freq;
            } else {
                load[self.hash.partition(e.key)] += e.freq;
            }
        }
        let hostload = (1.0 - hist.heavy_mass()).max(0.0) / self.hash.n_hosts() as f64;
        for (p, &c) in self.hash.hosts_per_partition().iter().enumerate() {
            load[p] += hostload * c as f64;
        }
        load
    }
}

impl Partitioner for Kip {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        match self.explicit.get(&key) {
            Some(p) => p as usize,
            None => self.hash.partition(key),
        }
    }

    fn n_partitions(&self) -> usize {
        self.hash.n_partitions()
    }

    fn explicit_routes(&self) -> usize {
        self.explicit.len()
    }

    fn tail_shares(&self) -> Vec<f64> {
        self.hash.tail_shares()
    }

    fn flat_routes(&self) -> Option<FlatRoutes> {
        // explicit table is already flat; the tail is the weighted hash's
        // host table verbatim — the lowering is exact by construction
        Some(FlatRoutes::new(
            self.explicit.clone(),
            self.hash.host_map().to_vec(),
            self.hash.seed(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{migration_fraction, partition_loads, Uhp};
    use crate::util::load_imbalance;
    use crate::workload::{zipf::Zipf, Generator, Record};

    fn zipf_records(n_keys: usize, exp: f64, n: usize, seed: u64) -> Vec<Record> {
        let mut z = Zipf::new(n_keys, exp, seed);
        z.batch(n)
    }

    fn key_weights(recs: &[Record]) -> Vec<(Key, f64)> {
        let mut m: std::collections::HashMap<Key, f64> = Default::default();
        for r in recs {
            *m.entry(r.key).or_insert(0.0) += r.weight;
        }
        m.into_iter().collect()
    }

    #[test]
    fn initial_kip_behaves_like_hash() {
        let kip = Kip::initial(8, KipConfig::default(), 1);
        assert_eq!(kip.explicit_routes(), 0);
        let kw: Vec<(Key, f64)> = (0..100_000u64).map(|k| (k, 1.0)).collect();
        let imb = load_imbalance(&partition_loads(&kip, &kw));
        assert!(imb < 1.05, "imb={imb}");
    }

    #[test]
    fn update_isolates_heavy_keys() {
        let n = 10;
        let cfg = KipConfig::default();
        let recs = zipf_records(10_000, 1.2, 200_000, 2);
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let prev = Uhp::new(n);
        let base = WeightedHash::with_default_hosts(n, 3);
        let kip = Kip::update(&prev, &base, &hist, cfg);
        assert_eq!(kip.explicit_routes(), hist.len());
        // all heavy keys routed to a valid partition
        for e in hist.entries() {
            assert!(kip.partition(e.key) < n);
        }
    }

    #[test]
    fn planned_load_within_maxload_when_feasible() {
        // exp 1.0, many keys: top freq << 1, so a near-perfect packing exists
        let n = 10;
        let cfg = KipConfig { lambda: 4, ..Default::default() };
        let recs = zipf_records(100_000, 1.0, 400_000, 4);
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let kip = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 5),
            &hist,
            cfg,
        );
        let maxload = (1.0 / n as f64).max(hist.top_freq()) + cfg.epsilon;
        let hostload = (1.0 - hist.heavy_mass()).max(0.0)
            / kip.weighted_hash().n_hosts() as f64;
        for (p, l) in kip.planned_loads(&hist).iter().enumerate() {
            assert!(
                *l <= maxload + hostload + 1e-9,
                "partition {p} planned load {l} > maxload {maxload}"
            );
        }
    }

    #[test]
    fn beats_hash_on_skewed_data() {
        let n = 20;
        let cfg = KipConfig::default();
        let recs = zipf_records(100_000, 1.0, 400_000, 6);
        let kw = key_weights(&recs);
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let uhp = Uhp::new(n);
        let kip = Kip::update(&uhp, &WeightedHash::with_default_hosts(n, 7), &hist, cfg);
        let imb_hash = load_imbalance(&partition_loads(&uhp, &kw));
        let imb_kip = load_imbalance(&partition_loads(&kip, &kw));
        assert!(
            imb_kip < imb_hash - 0.3,
            "KIP {imb_kip} not clearly better than hash {imb_hash}"
        );
        // the heaviest key alone forces imbalance ≥ top_freq·N ≈ 1.65 here;
        // KIP should be close to that floor
        assert!(imb_kip < 2.0, "imb_kip={imb_kip}");
    }

    #[test]
    fn stable_histogram_causes_no_migration() {
        // Two consecutive updates with the same histogram: the second must
        // keep every heavy key in place (line 4 always succeeds) and not
        // touch the host map.
        let n = 8;
        let cfg = KipConfig::default();
        let recs = zipf_records(50_000, 1.1, 200_000, 8);
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let kip1 = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 9),
            &hist,
            cfg,
        );
        let kip2 = kip1.updated(&hist);
        let kw = key_weights(&recs);
        let mig = migration_fraction(&kip1, &kip2, &kw);
        assert!(
            mig < 1e-9,
            "stationary distribution migrated {mig} of state"
        );
    }

    #[test]
    fn heaviest_key_gets_isolated_partition_when_dominant() {
        // One key with 60% mass: MAXLOAD ≈ 0.6+ε, so nothing else fits
        // beside it only if loads stay under; tail hosts must drain away
        // from its partition.
        let n = 4;
        let cfg = KipConfig::default();
        let mut kw: Vec<(Key, f64)> = vec![(42, 0.6)];
        for k in 0..1000u64 {
            kw.push((k + 100, 0.4 / 1000.0));
        }
        let hist = Histogram::from_freqs(&[(42, 0.6)], 1.0);
        let kip = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 10),
            &hist,
            cfg,
        );
        let p_heavy = kip.partition(42);
        let loads = partition_loads(&kip, &kw);
        // heavy partition should carry ~0.6 and little tail
        assert!(loads[p_heavy] < 0.7, "heavy partition overfilled: {loads:?}");
        // others share the 0.4 tail
        let others: f64 = loads
            .iter()
            .enumerate()
            .filter(|(p, _)| *p != p_heavy)
            .map(|(_, l)| *l)
            .sum();
        assert!(others > 0.3, "tail not spread: {loads:?}");
    }

    #[test]
    fn flat_routes_match_dyn_partition() {
        let n = 12;
        let cfg = KipConfig::default();
        let recs = zipf_records(50_000, 1.1, 200_000, 21);
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let kip = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 22),
            &hist,
            cfg,
        );
        let flat = kip.flat_routes().expect("KIP has a flat form");
        assert_eq!(flat.explicit().len(), kip.explicit_routes());
        for k in 0..50_000u64 {
            assert_eq!(flat.partition(k), kip.partition(k), "key {k}");
        }
    }

    #[test]
    fn empty_histogram_update_is_identity_ish() {
        let n = 6;
        let cfg = KipConfig::default();
        let base = WeightedHash::with_default_hosts(n, 11);
        let kip = Kip::update(&Uhp::new(n), &base, &Histogram::empty(), cfg);
        assert_eq!(kip.explicit_routes(), 0);
        assert_eq!(kip.weighted_hash(), &base);
    }

    #[test]
    fn drifted_histogram_reroutes_minimally() {
        // Old heavy key cools down, new heavy key appears; the cooled key
        // must leave the explicit table, the hot one must enter.
        let n = 8;
        let cfg = KipConfig::default();
        let hist1 = Histogram::from_freqs(&[(1, 0.3), (2, 0.2)], 1.0);
        let kip1 = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 12),
            &hist1,
            cfg,
        );
        let hist2 = Histogram::from_freqs(&[(3, 0.3), (1, 0.2)], 1.0);
        let kip2 = kip1.updated(&hist2);
        assert!(kip2.explicit_table().contains_key(&3));
        assert!(kip2.explicit_table().contains_key(&1));
        assert!(!kip2.explicit_table().contains_key(&2));
        // key 1 should not have moved (line 4 keeps it in place)
        assert_eq!(kip1.partition(1), kip2.partition(1));
    }

    #[test]
    fn higher_lambda_improves_balance() {
        // Fig 2 (right): KIP reaches better load balance for higher λ.
        // Averaged over seeds at n=8 where the top key does not pin the
        // max load (beyond ~1/top_freq partitions no λ can help — the
        // heaviest key alone sets the floor).
        let n = 8;
        let mut avg = [0.0f64; 2];
        for seed in 0..5u64 {
            let recs = zipf_records(100_000, 1.0, 400_000, 13 + seed);
            let kw = key_weights(&recs);
            for (i, lambda) in [1usize, 4].into_iter().enumerate() {
                let cfg = KipConfig { lambda, ..Default::default() };
                let hist = Histogram::exact(&recs, cfg.histogram_size(n));
                let kip = Kip::update(
                    &Uhp::new(n),
                    &WeightedHash::with_default_hosts(n, 14),
                    &hist,
                    cfg,
                );
                avg[i] += load_imbalance(&partition_loads(&kip, &kw)) / 5.0;
            }
        }
        assert!(
            avg[1] <= avg[0] + 0.02,
            "λ=4 ({}) should not be worse than λ=1 ({})",
            avg[1],
            avg[0]
        );
    }
}
