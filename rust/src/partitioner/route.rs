//! Flat-array routing — the immutable per-epoch fast path.
//!
//! The hot loop of every engine calls `partition(key)` once per record.
//! Behind a `dyn Partitioner` that is a virtual call into a hash-map
//! probe (KIP's explicit table) plus a second hash for the tail. At
//! millions of keys per second the indirections dominate, so each
//! partitioner that *has* a flat form lowers itself into a [`FlatRoutes`]
//! snapshot at epoch construction: one sorted explicit-route array (a
//! binary search over two dense `Vec`s — no pointer chasing, no hasher
//! state) plus the precomputed host→partition table the tail hash indexes
//! directly. The snapshot is immutable and swapped atomically with the
//! epoch, so the per-record path never takes a lock and never observes a
//! half-updated table.
//!
//! Lowering is exact, not approximate: [`FlatRoutes::partition`] returns
//! bit-for-bit the same partition as the `dyn Partitioner` it was built
//! from (same fmix64 hash, same fixed-point bucket, same explicit
//! routes), so routing, migration plans, and every pinned determinism
//! test are unchanged — only the constant factor moves.

use crate::hash::{bucket, hash_u64};
use crate::workload::Key;

/// A sorted flat routing table: explicit key→partition routes stored as
/// two parallel dense arrays (structure-of-arrays), looked up by binary
/// search. Immutable after construction — updates build a new table.
///
/// For KIP the table holds O(λN) heavy keys, so the search touches ≤
/// ~log2(λN) cache lines of a contiguous key array; the per-record cost
/// is independent of how many *live* keys the workload has.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RouteTable {
    keys: Vec<Key>,
    parts: Vec<u32>,
}

impl RouteTable {
    /// Build from (key, partition) pairs; keys must be distinct.
    pub fn from_pairs(mut pairs: Vec<(Key, u32)>) -> Self {
        pairs.sort_unstable_by_key(|&(k, _)| k);
        debug_assert!(
            pairs.windows(2).all(|w| w[0].0 < w[1].0),
            "duplicate keys in route table"
        );
        Self {
            keys: pairs.iter().map(|&(k, _)| k).collect(),
            parts: pairs.iter().map(|&(_, p)| p).collect(),
        }
    }

    #[inline]
    pub fn get(&self, key: &Key) -> Option<u32> {
        self.keys.binary_search(key).ok().map(|i| self.parts[i])
    }

    pub fn contains_key(&self, key: &Key) -> bool {
        self.keys.binary_search(key).is_ok()
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Routes in ascending key order.
    pub fn iter(&self) -> impl Iterator<Item = (Key, u32)> + '_ {
        self.keys.iter().copied().zip(self.parts.iter().copied())
    }
}

impl FromIterator<(Key, u32)> for RouteTable {
    fn from_iter<I: IntoIterator<Item = (Key, u32)>>(iter: I) -> Self {
        Self::from_pairs(iter.into_iter().collect())
    }
}

/// The flat-array lowering of a whole partitioning function: explicit
/// routes first, then one hash into a dense host→partition table. This is
/// exactly the two-level shape of KIP (explicit heavies + weighted-hash
/// tail); UHP lowers to an empty table over the identity host map.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatRoutes {
    explicit: RouteTable,
    host_to_partition: Vec<u32>,
    seed: u64,
}

impl FlatRoutes {
    pub fn new(explicit: RouteTable, host_to_partition: Vec<u32>, seed: u64) -> Self {
        assert!(!host_to_partition.is_empty(), "need at least one host");
        Self {
            explicit,
            host_to_partition,
            seed,
        }
    }

    /// Route one key. Bitwise-identical to the partitioner this snapshot
    /// was lowered from: same explicit routes, same fmix64+bucket tail.
    #[inline]
    pub fn partition(&self, key: Key) -> usize {
        match self.explicit.get(&key) {
            Some(p) => p as usize,
            None => {
                let h = bucket(hash_u64(key, self.seed), self.host_to_partition.len());
                self.host_to_partition[h] as usize
            }
        }
    }

    pub fn explicit(&self) -> &RouteTable {
        &self.explicit
    }

    pub fn n_hosts(&self) -> usize {
        self.host_to_partition.len()
    }

    /// The dense host→partition table — the tail-hash side of the wire
    /// form (a flat snapshot serializes as explicit pairs + this table +
    /// the seed, and reconstructs bit-for-bit).
    pub fn hosts(&self) -> &[u32] {
        &self.host_to_partition
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_table_sorts_and_finds() {
        let t = RouteTable::from_pairs(vec![(9, 1), (2, 0), (40, 3), (17, 2)]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.get(&9), Some(1));
        assert_eq!(t.get(&2), Some(0));
        assert_eq!(t.get(&40), Some(3));
        assert_eq!(t.get(&17), Some(2));
        assert_eq!(t.get(&3), None);
        assert!(t.contains_key(&40));
        assert!(!t.contains_key(&41));
        let order: Vec<(Key, u32)> = t.iter().collect();
        assert_eq!(order, vec![(2, 0), (9, 1), (17, 2), (40, 3)]);
    }

    #[test]
    fn empty_table_misses_everything() {
        let t = RouteTable::default();
        assert!(t.is_empty());
        assert_eq!(t.get(&0), None);
        assert!(!t.contains_key(&7));
    }

    #[test]
    fn flat_routes_explicit_overrides_hash() {
        let t = RouteTable::from_pairs(vec![(5, 3)]);
        let f = FlatRoutes::new(t, (0..4).collect(), 11);
        assert_eq!(f.partition(5), 3);
        // non-explicit keys land in the host table's range
        for k in 0..1000u64 {
            assert!(f.partition(k) < 4);
        }
    }

    #[test]
    fn identity_host_table_matches_uhp() {
        use crate::partitioner::{Partitioner, Uhp};
        let n = 7;
        let seed = 42;
        let uhp = Uhp::with_seed(n, seed);
        let f = FlatRoutes::new(RouteTable::default(), (0..n as u32).collect(), seed);
        for k in 0..10_000u64 {
            assert_eq!(f.partition(k), uhp.partition(k));
        }
    }
}
