//! State-migration cost between two partitioning functions.
//!
//! When the partitioner changes, every key routed to a different partition
//! drags its operator state with it (§3: "repartitioning incurs state
//! migration, hence the gains for repartitioning should exceed state
//! migration costs"). Fig 3 (right) reports **relative state migration**:
//! the fraction of total state weight that moves at an update. The paper
//! assumes "states linear in the size of the corresponding keygroups".

use super::Partitioner;
use crate::workload::Key;

/// Fraction of state (by weight) that must move when switching from `old`
/// to `new`, over the given per-key state weights.
///
/// `old` and `new` need not share a partition count: a scale-out/in event
/// swaps to a function over a *different* count, and a key moves exactly
/// when its route changes (source in `0..old.n_partitions()`, destination
/// in `0..new.n_partitions()`). Same-count swaps are the special case.
pub fn migration_fraction<A: Partitioner + ?Sized, B: Partitioner + ?Sized>(
    old: &A,
    new: &B,
    state_weights: &[(Key, f64)],
) -> f64 {
    let mut total = 0.0;
    let mut moved = 0.0;
    for &(k, w) in state_weights {
        total += w;
        if old.partition(k) != new.partition(k) {
            moved += w;
        }
    }
    if total <= 0.0 {
        0.0
    } else {
        moved / total
    }
}

/// Detailed migration plan: which keys move where (used by the streaming
/// engine to actually transfer state at a checkpoint barrier). Like
/// [`migration_fraction`], this is defined across differing partition
/// counts: every `from` is in-range of `old`, every `to` in-range of `new`.
pub fn migration_plan<A: Partitioner + ?Sized, B: Partitioner + ?Sized>(
    old: &A,
    new: &B,
    keys: impl IntoIterator<Item = Key>,
) -> Vec<(Key, usize, usize)> {
    let mut plan = Vec::new();
    for k in keys {
        let (from, to) = (old.partition(k), new.partition(k));
        if from != to {
            plan.push((k, from, to));
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::Uhp;

    #[test]
    fn identical_partitioners_zero_migration() {
        let p = Uhp::new(8);
        let sw: Vec<(Key, f64)> = (0..1000u64).map(|k| (k, 1.0)).collect();
        assert_eq!(migration_fraction(&p, &p, &sw), 0.0);
        assert!(migration_plan(&p, &p, 0..1000u64).is_empty());
    }

    #[test]
    fn different_seeds_move_most_state() {
        let a = Uhp::with_seed(8, 1);
        let b = Uhp::with_seed(8, 2);
        let sw: Vec<(Key, f64)> = (0..10_000u64).map(|k| (k, 1.0)).collect();
        let f = migration_fraction(&a, &b, &sw);
        // expected: 7/8 of keys move
        assert!((f - 0.875).abs() < 0.03, "f={f}");
    }

    #[test]
    fn weights_are_respected() {
        let a = Uhp::with_seed(4, 1);
        let b = Uhp::with_seed(4, 2);
        // find one key that moves, one that stays
        let moved_key = (0..1000u64).find(|&k| a.partition(k) != b.partition(k)).unwrap();
        let stay_key = (0..1000u64).find(|&k| a.partition(k) == b.partition(k)).unwrap();
        let f = migration_fraction(&a, &b, &[(moved_key, 3.0), (stay_key, 1.0)]);
        assert!((f - 0.75).abs() < 1e-12);
    }

    #[test]
    fn plan_matches_fraction() {
        let a = Uhp::with_seed(6, 3);
        let b = Uhp::with_seed(6, 4);
        let keys: Vec<Key> = (0..500).collect();
        let plan = migration_plan(&a, &b, keys.iter().cloned());
        let sw: Vec<(Key, f64)> = keys.iter().map(|&k| (k, 1.0)).collect();
        let f = migration_fraction(&a, &b, &sw);
        assert!((plan.len() as f64 / 500.0 - f).abs() < 1e-12);
        for (k, from, to) in plan {
            assert_eq!(from, a.partition(k));
            assert_eq!(to, b.partition(k));
            assert_ne!(from, to);
        }
    }

    #[test]
    fn empty_state_is_zero() {
        let p = Uhp::new(4);
        assert_eq!(migration_fraction(&p, &p, &[]), 0.0);
    }

    #[test]
    fn cross_count_plan_routes_in_range_of_each_side() {
        let old = Uhp::with_seed(4, 1);
        let new = Uhp::with_seed(6, 1);
        let keys: Vec<Key> = (0..3000).collect();
        let plan = migration_plan(&old, &new, keys.iter().cloned());
        assert!(!plan.is_empty(), "scale-out must move some keys");
        let planned: std::collections::HashSet<Key> = plan.iter().map(|e| e.0).collect();
        for &(k, from, to) in &plan {
            assert!(from < 4, "source out of range of the old count");
            assert!(to < 6, "destination out of range of the new count");
            assert_eq!(from, old.partition(k));
            assert_eq!(to, new.partition(k));
            assert_ne!(from, to);
        }
        for &k in &keys {
            assert_eq!(planned.contains(&k), old.partition(k) != new.partition(k));
        }
    }

    #[test]
    fn cross_count_fraction_bounded_and_matches_plan() {
        for (o, n) in [(4usize, 8usize), (8, 4), (5, 7), (16, 3)] {
            let old = Uhp::with_seed(o, 11);
            let new = Uhp::with_seed(n, 11);
            let keys: Vec<Key> = (0..2000).collect();
            let sw: Vec<(Key, f64)> = keys.iter().map(|&k| (k, 1.0)).collect();
            let f = migration_fraction(&old, &new, &sw);
            assert!((0.0..=1.0).contains(&f), "{o}->{n}: f={f}");
            let plan = migration_plan(&old, &new, keys.iter().cloned());
            assert!((plan.len() as f64 / 2000.0 - f).abs() < 1e-12, "{o}->{n}");
        }
    }
}
