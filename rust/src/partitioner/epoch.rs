//! Versioned partitioner epochs — the single mechanism every engine uses
//! to swap partitioning functions (see DESIGN.md "Epochs and the shared
//! ShuffleStage core").
//!
//! Prior work treats routing-table updates as *versioned* transitions
//! with explicit state-migration plans (Gedik's migration-aware
//! construction; Fang et al.'s mixed partitioner); our engines used to
//! hand-roll that per engine. Here the active partitioner is an
//! [`EpochedPartitioner`]: an `Arc`-swappable handle whose every install
//! bumps a monotone epoch number and yields an [`EpochSwap`] from which
//! the state-migration plan is *derived* (old routing vs new routing)
//! instead of being re-implemented at each call site.

use super::route::FlatRoutes;
use super::{migration_fraction, migration_plan, Partitioner};
use crate::workload::Key;
use std::fmt;
use std::sync::Arc;

/// An immutable, version-numbered snapshot of the active partitioning
/// function. Cheap to clone; engines route every record through one of
/// these, and reports surface its `epoch()` so repartitionings are
/// observable end-to-end.
///
/// Construction lowers the partitioner into a [`FlatRoutes`] fast path
/// once ([`Partitioner::flat_routes`]); the per-record `partition` then
/// runs over dense arrays with no virtual call. The lowering is exact, so
/// routing is bitwise-unchanged — partitioners without a flat form
/// (consistent-hash rings) fall through to the `dyn` call.
#[derive(Clone)]
pub struct PartitionerEpoch {
    epoch: u64,
    partitioner: Arc<dyn Partitioner>,
    flat: Option<Arc<FlatRoutes>>,
}

impl PartitionerEpoch {
    pub fn new(epoch: u64, partitioner: Arc<dyn Partitioner>) -> Self {
        let flat = partitioner.flat_routes().map(Arc::new);
        Self {
            epoch,
            partitioner,
            flat,
        }
    }

    /// The version number: 0 for the initial function, +1 per install.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    #[inline]
    pub fn partition(&self, key: Key) -> usize {
        match &self.flat {
            Some(f) => f.partition(key),
            None => self.partitioner.partition(key),
        }
    }

    /// The flat-array fast path this epoch routes through, if its
    /// partitioner has one (benches and tests assert the identity).
    pub fn flat(&self) -> Option<&FlatRoutes> {
        self.flat.as_deref()
    }

    pub fn n_partitions(&self) -> usize {
        self.partitioner.n_partitions()
    }

    pub fn explicit_routes(&self) -> usize {
        self.partitioner.explicit_routes()
    }

    pub fn as_dyn(&self) -> &dyn Partitioner {
        self.partitioner.as_ref()
    }
}

impl fmt::Debug for PartitionerEpoch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PartitionerEpoch(epoch={}, n={}, explicit={})",
            self.epoch,
            self.n_partitions(),
            self.explicit_routes()
        )
    }
}

/// The transition produced by one epoch bump: both routing snapshots,
/// from which migration plans and fractions are derived on demand.
#[derive(Debug, Clone)]
pub struct EpochSwap {
    /// Routing before the swap (epoch e).
    pub from: PartitionerEpoch,
    /// Routing after the swap (epoch e + 1).
    pub to: PartitionerEpoch,
}

impl EpochSwap {
    pub fn from_epoch(&self) -> u64 {
        self.from.epoch()
    }

    pub fn to_epoch(&self) -> u64 {
        self.to.epoch()
    }

    /// Does `key` route differently under the new epoch?
    pub fn moves(&self, key: Key) -> bool {
        self.from.partition(key) != self.to.partition(key)
    }

    /// The state-migration plan for `keys`: every key whose partition
    /// changed, with its source and destination. Derived from the epoch
    /// diff — engines no longer compute this ad hoc.
    pub fn plan(&self, keys: impl IntoIterator<Item = Key>) -> Vec<(Key, usize, usize)> {
        migration_plan(self.from.as_dyn(), self.to.as_dyn(), keys)
    }

    /// Fraction of state weight this swap moves (Fig 3 right).
    pub fn migration_fraction(&self, state_weights: &[(Key, f64)]) -> f64 {
        migration_fraction(self.from.as_dyn(), self.to.as_dyn(), state_weights)
    }
}

/// The `Arc`-swappable, version-numbered partitioner handle owned by the
/// DRM. `install` atomically (from the engines' perspective: between
/// records) replaces the function and bumps the epoch.
#[derive(Debug, Clone)]
pub struct EpochedPartitioner {
    current: PartitionerEpoch,
}

impl EpochedPartitioner {
    /// Wrap the initial partitioning function as epoch 0.
    pub fn new(initial: Arc<dyn Partitioner>) -> Self {
        Self {
            current: PartitionerEpoch::new(0, initial),
        }
    }

    /// A cheap snapshot of the current epoch for routing.
    pub fn current(&self) -> PartitionerEpoch {
        self.current.clone()
    }

    pub fn epoch(&self) -> u64 {
        self.current.epoch()
    }

    #[inline]
    pub fn partition(&self, key: Key) -> usize {
        self.current.partition(key)
    }

    pub fn n_partitions(&self) -> usize {
        self.current.n_partitions()
    }

    /// Install `next` as the new routing function, bumping the epoch.
    /// Returns the [`EpochSwap`] describing the transition; the caller
    /// derives the migration plan from it.
    pub fn install(&mut self, next: Arc<dyn Partitioner>) -> EpochSwap {
        assert_eq!(
            next.n_partitions(),
            self.current.n_partitions(),
            "epoch swap must preserve the partition count"
        );
        let from = self.current.clone();
        let to = PartitionerEpoch::new(from.epoch() + 1, next);
        self.current = to.clone();
        EpochSwap { from, to }
    }

    /// [`EpochedPartitioner::install`] for elasticity events: the new
    /// function may route over a *different* partition count. Kept as a
    /// separate entry point so ordinary repartitionings still catch
    /// accidental count changes via `install`'s assertion; the resulting
    /// [`EpochSwap`] derives cross-count migration plans exactly like the
    /// same-count case (see [`super::migration`]).
    pub fn install_resized(&mut self, next: Arc<dyn Partitioner>) -> EpochSwap {
        let from = self.current.clone();
        let to = PartitionerEpoch::new(from.epoch() + 1, next);
        self.current = to.clone();
        EpochSwap { from, to }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::Uhp;

    #[test]
    fn initial_epoch_is_zero_and_routes() {
        let ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(8, 1)));
        assert_eq!(ep.epoch(), 0);
        assert_eq!(ep.n_partitions(), 8);
        for k in 0..1000u64 {
            assert!(ep.partition(k) < 8);
        }
    }

    #[test]
    fn install_bumps_epoch_monotonically() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(4, 1)));
        for expect in 1..=5u64 {
            let swap = ep.install(Arc::new(Uhp::with_seed(4, expect)));
            assert_eq!(swap.from_epoch(), expect - 1);
            assert_eq!(swap.to_epoch(), expect);
            assert_eq!(ep.epoch(), expect);
        }
    }

    #[test]
    fn swap_plan_matches_routing_diff() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(6, 1)));
        let swap = ep.install(Arc::new(Uhp::with_seed(6, 2)));
        let keys: Vec<Key> = (0..2000).collect();
        let plan = swap.plan(keys.iter().cloned());
        assert!(!plan.is_empty(), "different seeds must move some keys");
        for &(k, from, to) in &plan {
            assert_eq!(from, swap.from.partition(k));
            assert_eq!(to, swap.to.partition(k));
            assert_ne!(from, to);
            assert!(swap.moves(k));
        }
        let planned: std::collections::HashSet<Key> = plan.iter().map(|e| e.0).collect();
        for &k in &keys {
            assert_eq!(planned.contains(&k), swap.moves(k));
        }
    }

    #[test]
    fn identity_swap_has_empty_plan() {
        let p: Arc<dyn Partitioner> = Arc::new(Uhp::with_seed(5, 9));
        let mut ep = EpochedPartitioner::new(p.clone());
        let swap = ep.install(p);
        assert!(swap.plan(0..500u64).is_empty());
        assert_eq!(swap.migration_fraction(&[(1, 2.0), (2, 3.0)]), 0.0);
        assert_eq!(swap.to_epoch(), 1, "epoch bumps even when routing is unchanged");
    }

    #[test]
    fn snapshots_survive_later_installs() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(8, 1)));
        let old = ep.current();
        ep.install(Arc::new(Uhp::with_seed(8, 2)));
        // the pre-swap snapshot still routes with the old function
        let fresh = Uhp::with_seed(8, 1);
        for k in 0..500u64 {
            assert_eq!(old.partition(k), fresh.partition(k));
        }
        assert_eq!(old.epoch(), 0);
    }

    #[test]
    fn epoch_routes_through_flat_fast_path() {
        use crate::partitioner::{Kip, KipConfig, WeightedHash};
        use crate::sketch::Histogram;
        let n = 8;
        let cfg = KipConfig::default();
        let hist = Histogram::from_freqs(&[(3, 0.3), (11, 0.2), (40, 0.1)], 1.0);
        let kip = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 5),
            &hist,
            cfg,
        );
        let ep = PartitionerEpoch::new(0, Arc::new(kip.clone()));
        let flat = ep.flat().expect("KIP epoch lowers to a flat table");
        assert_eq!(flat.explicit().len(), kip.explicit_routes());
        for k in 0..20_000u64 {
            // epoch fast path == flat snapshot == dyn partitioner
            assert_eq!(ep.partition(k), kip.partition(k));
            assert_eq!(flat.partition(k), ep.as_dyn().partition(k));
        }
    }

    #[test]
    #[should_panic]
    fn partition_count_change_rejected() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(4, 1)));
        ep.install(Arc::new(Uhp::with_seed(8, 1)));
    }

    #[test]
    fn install_resized_bumps_epoch_and_reroutes() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(4, 1)));
        let swap = ep.install_resized(Arc::new(Uhp::with_seed(8, 1)));
        assert_eq!(swap.from_epoch(), 0);
        assert_eq!(swap.to_epoch(), 1);
        assert_eq!(swap.from.n_partitions(), 4);
        assert_eq!(swap.to.n_partitions(), 8);
        assert_eq!(ep.n_partitions(), 8);
        let plan = swap.plan(0..2000u64);
        assert!(!plan.is_empty(), "scale-out must move keys");
        for &(k, from, to) in &plan {
            assert!(from < 4);
            assert!(to < 8);
            assert_eq!(from, swap.from.partition(k));
            assert_eq!(to, swap.to.partition(k));
        }
        // scale back in works the same way
        let swap2 = ep.install_resized(Arc::new(Uhp::with_seed(3, 1)));
        assert_eq!(swap2.to_epoch(), 2);
        assert_eq!(ep.n_partitions(), 3);
        for &(_, from, to) in &swap2.plan(0..2000u64) {
            assert!(from < 8);
            assert!(to < 3);
        }
    }

    #[test]
    fn install_resized_fraction_in_unit_interval() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(6, 2)));
        let swap = ep.install_resized(Arc::new(Uhp::with_seed(9, 2)));
        let sw: Vec<(Key, f64)> = (0..1000u64).map(|k| (k, 1.0 + (k % 5) as f64)).collect();
        let f = swap.migration_fraction(&sw);
        assert!((0.0..=1.0).contains(&f), "f={f}");
    }

    #[test]
    fn epoched_clone_is_independent() {
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(4, 1)));
        let snap = ep.clone();
        ep.install(Arc::new(Uhp::with_seed(4, 2)));
        assert_eq!(snap.epoch(), 0, "clone must not observe later installs");
        assert_eq!(ep.epoch(), 1);
        let fresh = Uhp::with_seed(4, 1);
        for k in 0..500u64 {
            assert_eq!(snap.partition(k), fresh.partition(k));
        }
    }
}
