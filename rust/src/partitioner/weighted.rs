//! The weighted hash partitioner HASH of §4.
//!
//! "the weighted hash partitioner HASH ... first maps the keys to one of
//! the H ≫ N hosts and then maps the hosts to partitions. Given no
//! histogram information, we assume that the hosts form a balanced
//! partition of the low frequency keys."
//!
//! The host→partition table is what Algorithm 1's lines 11–15 rebalance by
//! greedy bin packing: moving a *host* moves ~1/H of the tail mass, giving
//! KIP fine-grained control over tail load that plain (consistent) hashing
//! lacks — this is why KIP's imbalance stays flat in Fig 2 while the
//! baselines grow with N.

use super::route::{FlatRoutes, RouteTable};
use super::Partitioner;
use crate::hash::{bucket, hash_u64};
use crate::workload::Key;

pub const DEFAULT_HOSTS_PER_PARTITION: usize = 32;

#[derive(Debug, Clone, PartialEq)]
pub struct WeightedHash {
    /// host index -> partition
    host_to_partition: Vec<u32>,
    n_partitions: usize,
    seed: u64,
}

impl WeightedHash {
    /// Balanced initial mapping: host h -> h mod N.
    pub fn balanced(n_partitions: usize, n_hosts: usize, seed: u64) -> Self {
        assert!(n_partitions > 0);
        assert!(
            n_hosts >= n_partitions,
            "need H >= N (paper: H >> N), got H={n_hosts} N={n_partitions}"
        );
        Self {
            host_to_partition: (0..n_hosts).map(|h| (h % n_partitions) as u32).collect(),
            n_partitions,
            seed,
        }
    }

    /// Conventional sizing H = 32·N.
    pub fn with_default_hosts(n_partitions: usize, seed: u64) -> Self {
        Self::balanced(
            n_partitions,
            n_partitions * DEFAULT_HOSTS_PER_PARTITION,
            seed,
        )
    }

    pub fn n_hosts(&self) -> usize {
        self.host_to_partition.len()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    #[inline]
    pub fn host_of(&self, key: Key) -> usize {
        bucket(hash_u64(key, self.seed), self.host_to_partition.len())
    }

    pub fn partition_of_host(&self, host: usize) -> usize {
        self.host_to_partition[host] as usize
    }

    pub fn set_host(&mut self, host: usize, partition: usize) {
        assert!(partition < self.n_partitions);
        self.host_to_partition[host] = partition as u32;
    }

    /// Hosts currently mapped to each partition.
    pub fn hosts_per_partition(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_partitions];
        for &p in &self.host_to_partition {
            counts[p as usize] += 1;
        }
        counts
    }

    pub fn host_map(&self) -> &[u32] {
        &self.host_to_partition
    }

    /// Host *indices* currently mapped to each partition, each bucket in
    /// ascending host order — the bin-packing input of Algorithm 1's
    /// lines 11–15 ([`Kip::update`](super::Kip::update)). The sharded
    /// decision point computes this concurrently with its key-range
    /// location reads ([`crate::dr::parallel`]).
    pub fn hosts_by_partition(&self) -> Vec<Vec<usize>> {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.n_partitions];
        for (host, &p) in self.host_to_partition.iter().enumerate() {
            buckets[p as usize].push(host);
        }
        buckets
    }
}

impl Partitioner for WeightedHash {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        self.host_to_partition[self.host_of(key)] as usize
    }

    fn n_partitions(&self) -> usize {
        self.n_partitions
    }

    fn tail_shares(&self) -> Vec<f64> {
        let h = self.host_to_partition.len() as f64;
        self.hosts_per_partition()
            .into_iter()
            .map(|c| c as f64 / h)
            .collect()
    }

    fn flat_routes(&self) -> Option<FlatRoutes> {
        // already a flat host table — the lowering is a copy
        Some(FlatRoutes::new(
            RouteTable::default(),
            self.host_to_partition.clone(),
            self.seed,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::partition_loads;
    use crate::util::load_imbalance;
    use crate::workload::Key;

    #[test]
    fn balanced_mapping_covers_all_partitions() {
        let w = WeightedHash::balanced(5, 50, 0);
        let counts = w.hosts_per_partition();
        assert_eq!(counts, vec![10; 5]);
    }

    #[test]
    fn hosts_by_partition_lists_every_host_once_in_order() {
        let mut w = WeightedHash::balanced(4, 16, 2);
        w.set_host(0, 3);
        w.set_host(9, 3);
        let buckets = w.hosts_by_partition();
        assert_eq!(buckets.len(), 4);
        let mut seen = vec![false; 16];
        for (p, bucket) in buckets.iter().enumerate() {
            for win in bucket.windows(2) {
                assert!(win[0] < win[1], "bucket {p} not in ascending host order");
            }
            for &h in bucket {
                assert_eq!(w.partition_of_host(h), p);
                assert!(!seen[h], "host {h} listed twice");
                seen[h] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some host missing from buckets");
        assert!(buckets[3].contains(&0) && buckets[3].contains(&9));
    }

    #[test]
    fn partition_follows_host_map() {
        let mut w = WeightedHash::balanced(4, 16, 7);
        let key = 12345u64;
        let host = w.host_of(key);
        w.set_host(host, 3);
        assert_eq!(w.partition(key), 3);
    }

    #[test]
    fn tail_balance_better_than_plain_hash_variance() {
        // Moving hosts rebalances ~1/H tail mass per move; a balanced map
        // over uniform keys must be near-perfectly even.
        let w = WeightedHash::with_default_hosts(10, 3);
        let kw: Vec<(Key, f64)> = (0..200_000u64).map(|k| (k, 1.0)).collect();
        let imb = load_imbalance(&partition_loads(&w, &kw));
        assert!(imb < 1.05, "imb={imb}");
    }

    #[test]
    fn host_of_stable_under_map_changes() {
        let mut w = WeightedHash::balanced(4, 64, 1);
        let key = 99u64;
        let before = w.host_of(key);
        w.set_host(0, 2);
        w.set_host(63, 1);
        assert_eq!(w.host_of(key), before);
    }

    #[test]
    #[should_panic]
    fn too_few_hosts_panics() {
        WeightedHash::balanced(10, 5, 0);
    }

    #[test]
    #[should_panic]
    fn set_host_bad_partition_panics() {
        let mut w = WeightedHash::balanced(4, 16, 0);
        w.set_host(0, 4);
    }
}
