//! Partitioning functions — the paper's core contribution (§4) plus every
//! baseline it evaluates against (Fig 2/3).
//!
//! - [`Uhp`] — Uniform Hash Partitioning, the Spark/Flink default.
//! - [`WeightedHash`] — the two-level key→host→partition hash that KIP
//!   uses for the non-heavy tail (H ≫ N hosts, host→partition map
//!   adjusted by greedy bin packing).
//! - [`Kip`] — the Key Isolator Partitioner, updated by Algorithm 1.
//! - [`gedik`] — `Scan`, `Redist`, `Readj` from Gedik, VLDB J. 23(4)
//!   [12], over a consistent-hash base (reconstructions; see DESIGN.md).
//! - [`Mixed`] — the hash+explicit hybrid of Fang et al. [9].
//! - [`migration`] — state-migration cost between two partitioners.
//! - [`epoch`] — versioned partitioner epochs: the `Arc`-swappable handle
//!   every engine swaps through, with migration plans derived from the
//!   epoch diff.

pub mod epoch;
pub mod gedik;
pub mod kip;
pub mod migration;
pub mod mixed;
pub mod route;
pub mod weighted;

pub use epoch::{EpochSwap, EpochedPartitioner, PartitionerEpoch};
pub use gedik::{GedikConfig, GedikPartitioner, GedikStrategy};
pub use kip::{Kip, KipConfig};
pub use migration::{migration_fraction, migration_plan};
pub use mixed::Mixed;
pub use route::{FlatRoutes, RouteTable};
pub use weighted::WeightedHash;

use crate::hash::{bucket, hash_u64};
use crate::workload::Key;

/// A partitioning function: total, deterministic map key → partition.
pub trait Partitioner: Send + Sync {
    fn partition(&self, key: Key) -> usize;

    fn n_partitions(&self) -> usize;

    /// Number of explicitly-routed keys (routing-table footprint; 0 for
    /// pure hash partitioners). The naive explicit router the paper rejects
    /// would be O(#keys); KIP keeps this at O(λN).
    fn explicit_routes(&self) -> usize {
        0
    }

    /// Expected per-partition share of the *non-tracked tail* mass under
    /// this function's tail routing. Uniform for plain hashing; KIP's
    /// weighted hash and Gedik's ring override it. Used by the DRM to
    /// estimate load shares from a histogram.
    fn tail_shares(&self) -> Vec<f64> {
        vec![1.0 / self.n_partitions() as f64; self.n_partitions()]
    }

    /// Lower this function into an immutable [`FlatRoutes`] snapshot for
    /// the per-record fast path, or `None` when it has no exact flat form
    /// (consistent-hash rings). Implementations must be *exact*: the
    /// snapshot routes every key to the same partition as
    /// [`Partitioner::partition`]. Epoch construction calls this once per
    /// install ([`PartitionerEpoch::new`]).
    fn flat_routes(&self) -> Option<FlatRoutes> {
        None
    }
}

/// Uniform Hash Partitioning — murmur-finalized modulo-free bucketing,
/// the default partitioner of both Spark and Flink (§4).
#[derive(Debug, Clone)]
pub struct Uhp {
    n: usize,
    seed: u64,
}

impl Uhp {
    pub fn new(n: usize) -> Self {
        Self::with_seed(n, 0)
    }

    pub fn with_seed(n: usize, seed: u64) -> Self {
        assert!(n > 0);
        Self { n, seed }
    }
}

impl Partitioner for Uhp {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        bucket(hash_u64(key, self.seed), self.n)
    }

    fn n_partitions(&self) -> usize {
        self.n
    }

    fn flat_routes(&self) -> Option<FlatRoutes> {
        // one host per partition, identity-mapped: bucket(h, n) over the
        // identity table is exactly `partition` above
        Some(FlatRoutes::new(
            RouteTable::default(),
            (0..self.n as u32).collect(),
            self.seed,
        ))
    }
}

/// Compute per-partition loads of a weighted key set under a partitioner.
/// Used by every balance experiment.
pub fn partition_loads<P: Partitioner + ?Sized>(
    p: &P,
    key_weights: &[(Key, f64)],
) -> Vec<f64> {
    let mut loads = vec![0.0; p.n_partitions()];
    for &(k, w) in key_weights {
        loads[p.partition(k)] += w;
    }
    loads
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uhp_total_and_in_range() {
        let p = Uhp::new(7);
        for k in 0..10_000u64 {
            assert!(p.partition(k) < 7);
        }
    }

    #[test]
    fn uhp_deterministic() {
        let p = Uhp::new(16);
        let q = Uhp::new(16);
        for k in 0..1000u64 {
            assert_eq!(p.partition(k), q.partition(k));
        }
    }

    #[test]
    fn uhp_balanced_on_many_uniform_keys() {
        let p = Uhp::new(10);
        let kw: Vec<(Key, f64)> = (0..100_000u64).map(|k| (k, 1.0)).collect();
        let loads = partition_loads(&p, &kw);
        let imb = crate::util::load_imbalance(&loads);
        assert!(imb < 1.05, "imb={imb}");
    }

    #[test]
    fn uhp_flat_routes_match_dyn() {
        let p = Uhp::with_seed(9, 3);
        let f = p.flat_routes().expect("UHP has a flat form");
        assert!(f.explicit().is_empty());
        for k in 0..5000u64 {
            assert_eq!(f.partition(k), p.partition(k));
        }
    }

    #[test]
    fn uhp_seeds_differ() {
        let p = Uhp::with_seed(10, 1);
        let q = Uhp::with_seed(10, 2);
        let diff = (0..1000u64).filter(|&k| p.partition(k) != q.partition(k)).count();
        assert!(diff > 700);
    }

    #[test]
    fn loads_sum_preserved() {
        let p = Uhp::new(5);
        let kw: Vec<(Key, f64)> = (0..1000u64).map(|k| (k, 0.5)).collect();
        let loads = partition_loads(&p, &kw);
        assert!((loads.iter().sum::<f64>() - 500.0).abs() < 1e-9);
    }
}
