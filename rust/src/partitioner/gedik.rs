//! Baseline partitioning functions **Readj**, **Redist** and **Scan** from
//! Gedik, "Partitioning functions for stateful data parallelism in stream
//! processing", VLDB Journal 23(4), 2014 [12] — the closest prior work the
//! paper compares against (§2, §5).
//!
//! Gedik's functions are "a combination of consistent and explicit
//! hashing": a consistent-hash ring routes the tail while the tracked
//! heavy keys get explicit placements, re-computed at each update under a
//! balance constraint θ and a migration-aware utility (U = ρ + γ in the
//! paper's experimental setup). The three construction strategies differ
//! in how they trade migration against balance:
//!
//! - **Redist** — re-places every tracked key from scratch, greedily onto
//!   the least-loaded partition (best balance, most migration);
//! - **Readj** — keeps every tracked key where it was and only pulls keys
//!   out of partitions that exceed the balance bound (fewest moves);
//! - **Scan** — keeps a key in place when possible, otherwise scans for
//!   the nearest acceptable partition, *explicitly optimizing migration*
//!   ("Scan ... performs even better [on migration] at the cost of load
//!   balance", §5).
//!
//! These are reconstructions from the published descriptions (the original
//! code is not available); see DESIGN.md "Reconstructed components". The
//! consistent-hash tail is exactly why their imbalance grows with N in
//! Fig 2: ring-arc shares have relative spread ~1/√V per partition, which
//! KIP's host-rebalanced weighted hash avoids.

use super::Partitioner;
use crate::hash::hash_u64;
use crate::sketch::Histogram;
use crate::workload::Key;
use std::collections::HashMap;

/// A consistent-hash ring with `vnodes` virtual nodes per partition.
#[derive(Debug, Clone)]
pub struct ConsistentRing {
    /// (point, partition), sorted by point.
    points: Vec<(u64, u32)>,
    n: usize,
}

impl ConsistentRing {
    pub fn new(n_partitions: usize, vnodes: usize, seed: u64) -> Self {
        assert!(n_partitions > 0 && vnodes > 0);
        let mut points = Vec::with_capacity(n_partitions * vnodes);
        for p in 0..n_partitions {
            for v in 0..vnodes {
                let point = hash_u64((p as u64) << 20 | v as u64, seed ^ 0xF00D);
                points.push((point, p as u32));
            }
        }
        points.sort_unstable();
        points.dedup_by_key(|e| e.0);
        Self {
            points,
            n: n_partitions,
        }
    }

    #[inline]
    pub fn partition(&self, key: Key) -> usize {
        let h = hash_u64(key, 0xC0FFEE);
        let idx = self.points.partition_point(|&(pt, _)| pt < h);
        let idx = if idx == self.points.len() { 0 } else { idx };
        self.points[idx].1 as usize
    }

    /// Fraction of the ring owned by each partition — the expected tail
    /// load share.
    pub fn arc_shares(&self) -> Vec<f64> {
        let mut shares = vec![0.0f64; self.n];
        let ring = u64::MAX as f64;
        for i in 0..self.points.len() {
            let (pt, _) = self.points[i];
            let owner = self.points[i].1 as usize;
            // arc (prev_pt, pt] belongs to `owner`
            let prev = if i == 0 {
                // wrap-around arc
                let last = self.points[self.points.len() - 1].0;
                (u64::MAX - last) as f64 + pt as f64
            } else {
                (pt - self.points[i - 1].0) as f64
            };
            shares[owner] += prev / ring;
        }
        shares
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GedikStrategy {
    Readj,
    Redist,
    Scan,
}

impl GedikStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            GedikStrategy::Readj => "Readj",
            GedikStrategy::Redist => "Redist",
            GedikStrategy::Scan => "Scan",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct GedikConfig {
    /// Balance constraint θ (the paper runs θ_s = θ_c = θ_n = 0.2).
    pub theta: f64,
    /// Virtual nodes per partition on the ring.
    pub vnodes: usize,
}

impl Default for GedikConfig {
    fn default() -> Self {
        Self {
            theta: 0.2,
            vnodes: 50,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GedikPartitioner {
    strategy: GedikStrategy,
    cfg: GedikConfig,
    explicit: HashMap<Key, u32>,
    ring: ConsistentRing,
}

impl GedikPartitioner {
    pub fn initial(strategy: GedikStrategy, n: usize, cfg: GedikConfig, seed: u64) -> Self {
        Self {
            strategy,
            cfg,
            explicit: HashMap::new(),
            ring: ConsistentRing::new(n, cfg.vnodes, seed),
        }
    }

    pub fn strategy(&self) -> GedikStrategy {
        self.strategy
    }

    /// Construct the updated function from a histogram. `prev` supplies the
    /// current location of each tracked key (consistent/explicit combined).
    ///
    /// The per-key current-location reads (a ring binary search or an
    /// explicit-table hit per tracked key) are pure; this entry point
    /// computes them inline and hands them to
    /// [`GedikPartitioner::update_with_locations`], which the sharded
    /// decision point ([`crate::dr::parallel::gedik_candidate`]) also
    /// drives with the same table precomputed on pool workers split by
    /// key range — the greedy placement itself is identical either way.
    pub fn update(&self, hist: &Histogram) -> Self {
        let cur_locs: Vec<u32> = match self.strategy {
            // Redist re-places every tracked key from scratch and never
            // reads its current location.
            GedikStrategy::Redist => Vec::new(),
            _ => hist
                .entries()
                .iter()
                .map(|e| self.partition(e.key) as u32)
                .collect(),
        };
        self.update_with_locations(hist, &cur_locs)
    }

    /// The order-sensitive core of [`GedikPartitioner::update`]:
    /// `cur_locs[i]` is `self.partition(hist.entries()[i].key)` (unused —
    /// and allowed empty — for [`GedikStrategy::Redist`]). The greedy
    /// construction below is the unchanged sequential algorithm; only the
    /// production of `cur_locs` is parallelized by the sharded decision
    /// point.
    pub fn update_with_locations(&self, hist: &Histogram, cur_locs: &[u32]) -> Self {
        let n = self.ring.n;
        debug_assert!(
            matches!(self.strategy, GedikStrategy::Redist) || cur_locs.len() == hist.len(),
            "need one current location per tracked key"
        );
        // Tail load per partition = ring arc share × residual mass.
        let residual = (1.0 - hist.heavy_mass()).max(0.0);
        let mut load: Vec<f64> = self
            .ring
            .arc_shares()
            .iter()
            .map(|s| s * residual)
            .collect();

        // Balance bound: (1+θ)·ideal, relaxed to the heaviest key when a
        // single key exceeds it (no function can do better).
        let ideal = (1.0 / n as f64).max(hist.top_freq());
        let bound = ideal * (1.0 + self.cfg.theta);

        let mut explicit: HashMap<Key, u32> = HashMap::with_capacity(hist.len());
        match self.strategy {
            GedikStrategy::Redist => {
                // from-scratch greedy LPT placement
                for e in hist.entries() {
                    let p = argmin(&load);
                    load[p] += e.freq;
                    explicit.insert(e.key, p as u32);
                }
            }
            GedikStrategy::Readj => {
                // Keep everything in place, then *readjust*: evict keys out
                // of partitions that exceed the bound onto the currently
                // least-loaded partition, heaviest first (fixes the overload
                // in the fewest moves, the greedy described in [12]). Each
                // tracked key is considered once per update — no cascading.
                //
                // Note the migration profile this produces (Fig 3): under
                // drift the over-bound partitions recur, so heavy keys
                // shuttle between partitions epoch after epoch — Readj
                // migrates several times more state mass than KIP, whose
                // line-4 "keep in place" test gives placement hysteresis.
                let mut at: Vec<Vec<(Key, f64)>> = vec![Vec::new(); n];
                for (i, e) in hist.entries().iter().enumerate() {
                    let p = cur_locs[i] as usize;
                    at[p].push((e.key, e.freq));
                    load[p] += e.freq;
                }
                for p in 0..n {
                    at[p].sort_by(|a, b| b.1.total_cmp(&a.1)); // heaviest first
                    let i = 0;
                    while load[p] > bound && i < at[p].len() {
                        let (k, f) = at[p][i];
                        let q = argmin(&load);
                        if q == p {
                            break;
                        }
                        load[p] -= f;
                        load[q] += f;
                        explicit.insert(k, q as u32);
                        at[p].remove(i); // next candidate now at index i
                    }
                    for &(k, _) in &at[p] {
                        explicit.entry(k).or_insert(p as u32);
                    }
                }
            }
            GedikStrategy::Scan => {
                // migration-first: stay if under bound, else first fit by
                // scanning partitions in index order (cheap moves, coarse
                // balance — matches its Fig 3 profile)
                for (i, e) in hist.entries().iter().enumerate() {
                    let p0 = cur_locs[i] as usize;
                    let p = if load[p0] + e.freq <= bound {
                        p0
                    } else {
                        (0..n)
                            .find(|&q| load[q] + e.freq <= bound)
                            .unwrap_or_else(|| argmin(&load))
                    };
                    load[p] += e.freq;
                    explicit.insert(e.key, p as u32);
                }
            }
        }

        Self {
            strategy: self.strategy,
            cfg: self.cfg,
            explicit,
            ring: self.ring.clone(),
        }
    }
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .expect("non-empty")
}

impl Partitioner for GedikPartitioner {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        match self.explicit.get(&key) {
            Some(&p) => p as usize,
            None => self.ring.partition(key),
        }
    }

    fn n_partitions(&self) -> usize {
        self.ring.n
    }

    fn explicit_routes(&self) -> usize {
        self.explicit.len()
    }

    fn tail_shares(&self) -> Vec<f64> {
        self.ring.arc_shares()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{migration_fraction, partition_loads};
    use crate::util::load_imbalance;
    use crate::workload::{zipf::Zipf, Generator, Record};

    fn setup(strategy: GedikStrategy, n: usize) -> (GedikPartitioner, Vec<Record>, Histogram) {
        let mut z = Zipf::new(50_000, 1.0, 7);
        let recs = z.batch(300_000);
        let hist = Histogram::exact(&recs, 2 * n);
        let g = GedikPartitioner::initial(strategy, n, GedikConfig::default(), 1);
        (g, recs, hist)
    }

    fn key_weights(recs: &[Record]) -> Vec<(Key, f64)> {
        let mut m: HashMap<Key, f64> = HashMap::new();
        for r in recs {
            *m.entry(r.key).or_insert(0.0) += r.weight;
        }
        m.into_iter().collect()
    }

    #[test]
    fn ring_covers_all_partitions() {
        let ring = ConsistentRing::new(8, 50, 1);
        let mut seen = vec![false; 8];
        for k in 0..100_000u64 {
            seen[ring.partition(k)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn arc_shares_sum_to_one() {
        let ring = ConsistentRing::new(12, 40, 2);
        let s: f64 = ring.arc_shares().sum_check();
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    trait SumCheck {
        fn sum_check(&self) -> f64;
    }
    impl SumCheck for Vec<f64> {
        fn sum_check(&self) -> f64 {
            self.iter().sum()
        }
    }

    #[test]
    fn arc_shares_match_empirical_tail() {
        let ring = ConsistentRing::new(6, 50, 3);
        let shares = ring.arc_shares();
        let mut counts = vec![0.0f64; 6];
        let n = 200_000u64;
        for k in 0..n {
            counts[ring.partition(k)] += 1.0;
        }
        for p in 0..6 {
            let emp = counts[p] / n as f64;
            assert!(
                (emp - shares[p]).abs() < 0.01,
                "p={p} emp={emp} share={}",
                shares[p]
            );
        }
    }

    #[test]
    fn all_strategies_improve_over_no_update() {
        // n=8: the top key (~8.7%) is well under 1/n, so imbalance comes
        // from *stacked* medium keys, which every strategy can unstack.
        // (At large n the heaviest key pins the max load and no explicit
        // placement can improve on it — Fig 2's growth regime.)
        for strat in [GedikStrategy::Readj, GedikStrategy::Redist, GedikStrategy::Scan] {
            let (g, recs, hist) = setup(strat, 8);
            let kw = key_weights(&recs);
            let before = load_imbalance(&partition_loads(&g, &kw));
            let updated = g.update(&hist);
            let after = load_imbalance(&partition_loads(&updated, &kw));
            assert!(
                after < before,
                "{}: {after} not better than {before}",
                strat.name()
            );
        }
    }

    #[test]
    fn updates_never_hurt_at_scale() {
        // At n=16 the heaviest key dominates; strategies may be unable to
        // improve, but must never make balance worse.
        for strat in [GedikStrategy::Readj, GedikStrategy::Redist, GedikStrategy::Scan] {
            let (g, recs, hist) = setup(strat, 16);
            let kw = key_weights(&recs);
            let before = load_imbalance(&partition_loads(&g, &kw));
            let after = load_imbalance(&partition_loads(&g.update(&hist), &kw));
            assert!(
                after <= before + 0.15,
                "{}: {after} worse than {before}",
                strat.name()
            );
        }
    }

    #[test]
    fn redist_best_balance_scan_least_migration() {
        let n = 16;
        let (g0, recs, hist) = setup(GedikStrategy::Redist, n);
        let kw = key_weights(&recs);
        // first update from the ring-only function
        let redist1 = g0.update(&hist);
        let scan0 = GedikPartitioner::initial(GedikStrategy::Scan, n, GedikConfig::default(), 1);
        let scan1 = scan0.update(&hist);

        // drift: rebuild histogram from a different sample
        let mut z2 = Zipf::new(50_000, 1.0, 99);
        let recs2 = z2.batch(300_000);
        let hist2 = Histogram::exact(&recs2, 2 * n);

        let redist2 = redist1.update(&hist2);
        let scan2 = scan1.update(&hist2);
        let mig_redist = migration_fraction(&redist1, &redist2, &kw);
        let mig_scan = migration_fraction(&scan1, &scan2, &kw);
        assert!(
            mig_scan <= mig_redist + 1e-9,
            "scan migration {mig_scan} > redist {mig_redist}"
        );
    }

    #[test]
    fn readj_keeps_keys_when_balanced() {
        // Under a balanced histogram, Readj's second update moves nothing.
        let n = 8;
        let freqs: Vec<(Key, f64)> = (0..16u64).map(|k| (k, 0.01)).collect();
        let hist = Histogram::from_freqs(&freqs, 1.0);
        let g = GedikPartitioner::initial(GedikStrategy::Readj, n, GedikConfig::default(), 5);
        let g1 = g.update(&hist);
        let g2 = g1.update(&hist);
        let sw: Vec<(Key, f64)> = freqs.clone();
        assert!(migration_fraction(&g1, &g2, &sw) < 1e-9);
    }

    #[test]
    fn explicit_routes_bounded_by_histogram() {
        let (g, _, hist) = setup(GedikStrategy::Redist, 16);
        let updated = g.update(&hist);
        assert!(updated.explicit_routes() <= hist.len());
    }
}
