//! The mini-DDPS substrate: the distributed data processing engines DR
//! plugs into (the paper integrates with Spark and Flink; we build the
//! corresponding execution models from scratch — see DESIGN.md
//! "Substitutions" for the virtual-time rationale).
//!
//! - [`batch`] — one-shot batch jobs with mapper-buffer interception and
//!   **replay** (§3: "a batch job is repartitioned only in an early stage
//!   of the execution so that the cost of replay does not exceed the
//!   expected gains").
//! - [`microbatch`] — Spark-Streaming-like micro-batches: the partitioner
//!   swaps between batches, "Spark performs state migration automatically
//!   in the shuffle phase".
//! - [`streaming`] — Flink-like long-running tasks with checkpoint
//!   barriers; repartitioning rides the Asynchronous Distributed Snapshot
//!   and migrates state explicitly.
//!
//! All three engines drive the same [`exec::ShuffleStage`] core — one
//! implementation of the map-tap → shuffle → keyed-reduce → spill-cost
//! loop — and swap partitioners exclusively through versioned
//! [`PartitionerEpoch`](crate::partitioner::PartitionerEpoch)s whose
//! migration plans derive from the epoch diff. The core — and since PR 3
//! the DRM decision point steering it ([`crate::dr::parallel`]) — runs
//! either sequentially ([`EngineConfig::num_threads`] = 1) or sharded
//! over a persistent worker pool ([`exec::parallel`], [`exec::pool`],
//! `num_threads` > 1) with bitwise-identical reports.
//!
//! The engines themselves are driven by the unified loop in
//! [`pipeline`]: every `run_batch` / `run_interval` / `BatchJob::run`
//! call is one lockstep step of it, and the `run_stream` entry points
//! pull batches from a [`Source`](crate::workload::Source), overlapping
//! source materialization, the DRM decision point and the shuffle stage
//! on pool lanes (same `num_threads` knob, same bitwise-identical
//! reports — only the measured `wall_s` / `decision_wall_s` /
//! `source_wall_s` columns and the pipeline-occupancy ratio change).

pub mod batch;
pub mod cluster;
pub mod exec;
pub mod microbatch;
pub mod pipeline;
pub mod streaming;

pub use batch::{BatchJob, JobReport};
pub use cluster::{ClusterError, ClusterMaster, ClusterOptions, ClusterStats};
pub use exec::{
    adopt_decision, adopt_swap, apply_epoch_swap, decide_and_adopt, decision_point,
    decision_point_sharded, proposal_point_sharded, tap_records, tap_records_sharded,
    DecisionOutcome, MigrationReport, Scheduling, ShuffleStage, StageReport, TapAssignment,
    WorkerPool,
};
pub use microbatch::{BatchReport, MicroBatchEngine};
pub use pipeline::{Discipline, EngineCore, StepReport};
pub use streaming::{IntervalReport, RecoveryPoint, StreamingEngine};

use crate::sketch::SketchConfig;
use crate::util::VTime;

/// Cost model of one executor cluster. All costs are in virtual seconds;
/// the NER example calibrates `reduce_cost` from real PJRT timings.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Number of reduce partitions (tasks in the key-grouped stage).
    pub n_partitions: usize,
    /// Executor slots available to run tasks (nodes × cores).
    pub n_slots: usize,
    /// Map-side cost per record (parse + emit).
    pub map_cost: VTime,
    /// Reduce-side cost per unit of record *weight* (the key-grouped UDF —
    /// sorting, NLP model, state update).
    pub reduce_cost: VTime,
    /// Scheduling overhead per launched reduce task (what makes extreme
    /// over-partitioning costly in Fig 5).
    pub task_overhead: VTime,
    /// Shuffle cost per record (serialize + network).
    pub shuffle_cost: VTime,
    /// Cost per unit of state weight migrated at a repartitioning.
    pub migration_cost: VTime,
    /// Batch mode only: cost per record re-assigned during replay.
    pub replay_cost: VTime,
    /// Spill model: a reduce task whose load exceeds
    /// `spill_threshold_factor × (batch load / n_slots)` — i.e. more than
    /// its slot's memory-fair share — pays `spill_penalty ×` on the excess.
    /// This is the superlinear straggler behaviour of real executors
    /// (Spark spills to disk / GC-thrashes once a keygroup outgrows its
    /// slot): it is what makes skew expensive in wall-clock, what makes
    /// over-partitioning help plain hash (smaller tasks fit memory), and
    /// why DR's flattening pays more than linearly (Fig 4/5/7/8).
    pub spill_threshold_factor: f64,
    pub spill_penalty: f64,
    /// OS threads the [`exec::ShuffleStage`] executor shards its reduce
    /// partitions (and the DRW taps / histogram harvests) over, that the
    /// DRM decision point shards its histogram tree-merge and candidate
    /// construction over ([`crate::dr::parallel`]), and that gates the
    /// [`pipeline`] drive loop's lane overlap (source prefetch ∥ decision
    /// point ∥ stage). `1` — the default — is the sequential lockstep
    /// reference path; `> 1` runs all of them on a persistent
    /// [`exec::WorkerPool`] (parked threads reused across every interval,
    /// one pool per width for the process lifetime) and produces
    /// bitwise-identical reports (see [`exec::parallel`], [`exec::pool`]
    /// and DESIGN.md "Persistent worker pool and scratch arenas" /
    /// "Sharded DRM decision point" /
    /// "Pipelined engine loop"). Virtual-time results never depend on
    /// this knob — only the measured `wall_s` / `decision_wall_s` /
    /// `source_wall_s` columns and the pipeline-occupancy ratio do.
    pub num_threads: usize,
    /// Sketch-bounding knobs for the DR layer — DRW counter compaction,
    /// histogram size boundary, and the worker→master `take` cut
    /// ([`SketchConfig`]). The default is unbounded: every DR code path
    /// is bit-identical to the exact implementation. Env-overridable via
    /// `DYNREPART_SKETCH_COMPACTION` / `DYNREPART_SKETCH_BOUND` /
    /// `DYNREPART_SKETCH_TAKE` through [`EngineConfig::from_env`].
    pub sketch: SketchConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            n_partitions: 16,
            n_slots: 8,
            map_cost: 1e-6,
            reduce_cost: 10e-6,
            task_overhead: 20e-3,
            shuffle_cost: 0.5e-6,
            migration_cost: 2e-6,
            replay_cost: 0.2e-6,
            spill_threshold_factor: 1.5,
            spill_penalty: 2.5,
            num_threads: 1,
            sketch: SketchConfig::default(),
        }
    }
}

impl EngineConfig {
    pub fn validate(&self) {
        assert!(self.n_partitions > 0, "need partitions");
        assert!(self.n_slots > 0, "need slots");
        assert!(self.num_threads > 0, "need at least one executor thread");
        assert!(self.map_cost >= 0.0 && self.reduce_cost >= 0.0);
        assert!(self.spill_threshold_factor > 0.0 && self.spill_penalty >= 1.0);
    }

    /// Executor thread count requested via the `DYNREPART_THREADS`
    /// environment variable; 1 (the sequential path) when unset or empty.
    /// A malformed value (unparsable, or zero) **aborts with a clear
    /// error** instead of silently running sequentially — the strict
    /// parser lives in [`crate::util::env`]. The e2e tests and the figure
    /// drivers build their configs through [`EngineConfig::from_env`] so
    /// CI can run the whole tier-1 suite against the sharded executor.
    pub fn threads_from_env() -> usize {
        crate::util::env::knob_from_env("DYNREPART_THREADS", 1).unwrap_or(1)
    }

    /// [`Default`], with `num_threads` taken from `DYNREPART_THREADS` and
    /// the sketch knobs from `DYNREPART_SKETCH_*`
    /// ([`SketchConfig::from_env`]).
    pub fn from_env() -> Self {
        Self {
            num_threads: Self::threads_from_env(),
            sketch: SketchConfig::from_env(),
            ..Default::default()
        }
    }

    /// Reduce-task virtual time for a partition of `load` within a batch of
    /// `total_load`, applying the spill model.
    pub fn reduce_task_time(&self, load: f64, total_load: f64) -> VTime {
        let budget = self.spill_threshold_factor * total_load / self.n_slots as f64;
        let spilled = (load - budget).max(0.0);
        (load + spilled * (self.spill_penalty - 1.0)) * self.reduce_cost + self.task_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_task_time_linear_below_budget() {
        let cfg = EngineConfig::default(); // 16 partitions, 8 slots
        let total = 800.0; // budget = 1.5*800/8 = 150
        let t = cfg.reduce_task_time(100.0, total);
        assert!((t - (100.0 * cfg.reduce_cost + cfg.task_overhead)).abs() < 1e-12);
    }

    #[test]
    fn reduce_task_time_penalizes_spill() {
        let cfg = EngineConfig::default();
        let total = 800.0; // budget 150
        let t_fit = cfg.reduce_task_time(150.0, total);
        let t_spill = cfg.reduce_task_time(300.0, total);
        // excess 150 at 2.5x ⇒ 150 + 150*2.5 = 525 weight-equivalents
        let expect = (150.0 + 150.0 * 2.5) * cfg.reduce_cost + cfg.task_overhead;
        assert!((t_spill - expect).abs() < 1e-12);
        // marginal cost above the budget is spill_penalty× the linear one
        let lin = |w: f64| w * cfg.reduce_cost + cfg.task_overhead;
        assert!(t_spill - t_fit > 2.0 * (lin(300.0) - lin(150.0)));
    }

    #[test]
    fn more_slots_raise_budget() {
        let mut cfg = EngineConfig::default();
        let t8 = cfg.reduce_task_time(400.0, 800.0);
        cfg.n_slots = 32;
        let t32 = cfg.reduce_task_time(400.0, 800.0);
        assert!(t32 > t8, "smaller budget per slot spills more: {t32} vs {t8}");
    }

    #[test]
    fn default_is_sequential_and_env_threads_sane() {
        assert_eq!(EngineConfig::default().num_threads, 1);
        // the default sketch config is the exact, unbounded path
        assert!(EngineConfig::default().sketch.is_unbounded());
        // unset/empty env means the sequential default; malformed values
        // abort instead of silently degrading (the parse paths themselves
        // are unit-tested purely in util::env and sketch — mutating the
        // process env here would race the parallel test harness)
        assert!(EngineConfig::threads_from_env() >= 1);
        assert!(EngineConfig::from_env().num_threads >= 1);
    }

    #[test]
    fn threads_env_parse_paths_are_strict() {
        use crate::util::env::parse_knob;
        // the exact rules threads_from_env applies, as pure functions
        assert_eq!(parse_knob("DYNREPART_THREADS", None, 1), Ok(None));
        assert_eq!(parse_knob("DYNREPART_THREADS", Some(""), 1), Ok(None));
        assert_eq!(parse_knob("DYNREPART_THREADS", Some("4"), 1), Ok(Some(4)));
        assert!(parse_knob("DYNREPART_THREADS", Some("0"), 1).is_err());
        assert!(parse_knob("DYNREPART_THREADS", Some("four"), 1).is_err());
        assert!(parse_knob("DYNREPART_THREADS", Some("-2"), 1).is_err());
    }

    #[test]
    #[should_panic]
    fn zero_threads_rejected() {
        EngineConfig {
            num_threads: 0,
            ..Default::default()
        }
        .validate();
    }
}

/// Cumulative engine metrics across batches/intervals.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub records_processed: u64,
    pub total_vtime: VTime,
    pub map_vtime: VTime,
    pub reduce_vtime: VTime,
    pub migration_vtime: VTime,
    pub replay_vtime: VTime,
    /// Measured wall-clock seconds spent inside [`exec::ShuffleStage`]
    /// runs. Virtual times above are the scheduling *model*; this is where
    /// the real (possibly sharded, `num_threads > 1`) executor shows up.
    pub wall_s: f64,
    /// Measured wall-clock seconds spent inside DRM decision points
    /// (harvests + histogram merge + candidate construction,
    /// [`exec::decision_point_sharded`]). Comparing this against `wall_s`
    /// is the paper's "negligible overhead" claim as a measurable column:
    /// the decision point must stay small next to the stages it steers.
    pub decision_wall_s: f64,
    /// Measured wall-clock seconds spent materializing batches from the
    /// workload [`Source`](crate::workload::Source) — the [`pipeline`]
    /// loop's prefetch lane. 0.0 when records were handed in
    /// pre-materialized (`run_batch` / `run_interval` with a slice).
    pub source_wall_s: f64,
    /// Measured wall-clock seconds of the unified drive loop itself,
    /// barrier to barrier (covers the overlapped stage / decision /
    /// source lanes plus the serial barrier work). Denominator of
    /// [`EngineMetrics::pipeline_occupancy`].
    pub pipeline_wall_s: f64,
    pub state_weight_migrated: f64,
    pub repartition_count: u64,
}

impl EngineMetrics {
    pub fn throughput(&self) -> f64 {
        if self.total_vtime <= 0.0 {
            0.0
        } else {
            self.records_processed as f64 / self.total_vtime
        }
    }

    /// Measured work seconds (stage executors + decision points + source
    /// materialization) per wall second of the drive loop: ≲ 1 on the
    /// lockstep path (the three are serialized inside the span), > 1 when
    /// the pipelined loop overlaps its lanes ([`pipeline`]). 0.0 before
    /// any step ran.
    pub fn pipeline_occupancy(&self) -> f64 {
        if self.pipeline_wall_s <= 0.0 {
            return 0.0;
        }
        (self.wall_s + self.decision_wall_s + self.source_wall_s) / self.pipeline_wall_s
    }
}
