//! The sharded parallel executor behind [`ShuffleStage`](super::ShuffleStage)
//! (see DESIGN.md "The sharded parallel executor").
//!
//! The paper's 1.5–6× speedups come from DR flattening partition load so
//! that *parallel* reducers finish together. The sequential path only
//! models that with virtual time; this module runs one stage's reduce
//! partitions on the persistent [`WorkerPool`](super::pool::WorkerPool)
//! gang (parked threads, one pool per thread width — no per-call spawns)
//! so the spill/imbalance model can be validated against actual parallel
//! execution:
//!
//! - **Routing** ([`route`] / [`route_into`]): records are split into
//!   contiguous chunks, one per pool task, and each task routes its
//!   chunk through the shared [`PartitionerEpoch`] snapshot (epoch
//!   snapshots are `Arc`-cloneable and every `Partitioner` is
//!   `Send + Sync`, so the snapshot is shared by reference) while
//!   counting records per owning shard. A serial prefix sum over the
//!   per-(chunk, shard) counts then sizes one flat index table, and a
//!   second pass scatters each chunk's record indices to its
//!   pre-computed cursors — the flat [`RoutedBatch`] reuses its buffers
//!   across intervals via the pool's scratch arena, allocating nothing
//!   once warm.
//! - **Keyed reduce** ([`shuffle_sharded`]): partitions are split into
//!   contiguous *shards*, one per pool task ([`shard_ranges`]). Each
//!   shard task owns its partitions' loads, record counts and
//!   [`StateStore`]s outright — keyed reduce needs no locks — and visits
//!   only its own records ([`RoutedBatch`]'s per-shard index runs) in
//!   input order, so every per-partition f64 accumulation happens in
//!   exactly the sequential order and total work stays O(records).
//!   Shard tasks write disjoint partition ranges of the final output
//!   buffers directly. Reports are therefore **bitwise-identical** to
//!   the sequential path, independent of the thread count.
//! - **DRW taps and harvests** ([`tap_records_sharded`],
//!   [`harvest_sharded`]): the same sharding applied to the
//!   [`DrWorker`]s, preserving each DRW's observation/harvest sequence so
//!   sampling RNGs, counters and the DRM's histogram order advance
//!   exactly as they do sequentially — the taps stay consistent with
//!   where records actually ran. Downstream of the harvests, the DRM
//!   decision point itself is sharded too
//!   ([`dr::parallel`](crate::dr::parallel): parallel histogram
//!   tree-merge + key-range candidate preparation), so no serial region
//!   remains between the parallel shards.
//!
//! Engines opt in through
//! [`EngineConfig::num_threads`](super::EngineConfig::num_threads); the
//! default of 1 keeps today's sequential loop. Because results are
//! invariant, the only observable difference is the measured
//! [`StageReport::wall_s`](super::StageReport::wall_s) column:
//!
//! ```
//! use dynrepart::ddps::{EngineConfig, Scheduling, ShuffleStage};
//! use dynrepart::partitioner::{EpochedPartitioner, Uhp};
//! use dynrepart::workload::Record;
//! use std::sync::Arc;
//!
//! let par = EngineConfig { n_partitions: 8, n_slots: 4, num_threads: 4, ..Default::default() };
//! let seq = EngineConfig { num_threads: 1, ..par };
//! let epoch = EpochedPartitioner::new(Arc::new(Uhp::with_seed(8, 1))).current();
//! let records: Vec<Record> = (0u64..10_000).map(|k| Record::unit(k % 257, k)).collect();
//!
//! let p = ShuffleStage::new(&par, Scheduling::Wave).run(&records, &epoch, None);
//! let s = ShuffleStage::new(&seq, Scheduling::Wave).run(&records, &epoch, None);
//! assert_eq!(p.loads, s.loads); // bitwise-identical routing
//! assert_eq!(p.stage_time, s.stage_time); // identical virtual time
//! ```

use super::pool::{SharedSlice, WorkerPool};
use super::TapAssignment;
use crate::dr::DrWorker;
use crate::partitioner::PartitionerEpoch;
use crate::sketch::Histogram;
use crate::state::StateStore;
use crate::workload::Record;
use std::ops::Range;

/// The shard width [`shard_ranges`] cuts `0..n` into: every sharded step
/// of one stage derives its `chunks_mut` decomposition from this same
/// number, so all of them agree on who owns which index.
fn shard_chunk(n: usize, shards: usize) -> usize {
    n.div_ceil(shards.max(1)).max(1)
}

/// Split `0..n` into at most `shards` contiguous, equal-as-possible,
/// non-empty ranges (fewer when `n < shards`; **none** when `n == 0` —
/// callers treat the empty decomposition as a no-op). The ranges line up
/// exactly with `slice.chunks_mut(shard_chunk(n, shards))` over a slice
/// of length `n`.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<Range<usize>> {
    let chunk = shard_chunk(n, shards);
    (0..n)
        .step_by(chunk)
        .map(|start| start..(start + chunk).min(n))
        .collect()
}

/// One routed batch: the partition index per record (input order) plus,
/// for each partition shard, the indices of the records it owns — also in
/// input order, so shard tasks can replay exactly the sequential
/// accumulation order while touching only their own records.
///
/// The per-shard index lists live in one flat `Vec<u32>` addressed
/// through a per-shard offset table ([`RoutedBatch::shard_indices`]),
/// built by a counting pass + prefix sum in [`route_into`]; all four
/// buffers retain capacity across intervals when the batch is recycled
/// through the pool's scratch arena
/// ([`WorkerPool::take_routed`](super::pool::WorkerPool::take_routed)).
#[derive(Debug, Default)]
pub struct RoutedBatch {
    /// Partition index per record, in input order.
    pub routes: Vec<u32>,
    /// Record indices grouped by owning shard, each group in input order.
    flat: Vec<u32>,
    /// `flat[offsets[s]..offsets[s + 1]]` is shard `s`'s group.
    offsets: Vec<usize>,
    /// Per-(chunk, shard) counting matrix, then scatter cursors; kept
    /// only so its allocation is reused across intervals.
    counts: Vec<u32>,
}

impl RoutedBatch {
    /// Number of partition shards this batch was routed for (the length
    /// of `shard_ranges(n_partitions, num_threads)` at build time).
    pub fn n_shards(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// The record indices owned by partition shard `shard`, in input
    /// order.
    pub fn shard_indices(&self, shard: usize) -> &[u32] {
        &self.flat[self.offsets[shard]..self.offsets[shard + 1]]
    }
}

/// [`route`] into a recycled [`RoutedBatch`], reusing its buffers.
///
/// Records are routed in contiguous chunks, one per pool task. Pass A
/// routes each chunk through `epoch` while counting its records per
/// owning shard; a serial shard-major prefix sum turns the
/// per-(chunk, shard) counts into scatter cursors (and the per-shard
/// offset table); pass B scatters each chunk's record indices to its
/// cursors. Within a shard the groups land chunk-ascending with input
/// order inside each chunk — i.e. global input order, identical to the
/// sequential map (routing is pure).
///
/// Empty input (`records` empty, or an epoch with zero partitions) is a
/// no-op: the batch comes back with no routes and no shard groups.
pub fn route_into(
    out: &mut RoutedBatch,
    records: &[Record],
    epoch: &PartitionerEpoch,
    num_threads: usize,
) {
    debug_assert!(records.len() <= u32::MAX as usize);
    let n_partitions = epoch.n_partitions();
    let shard_count = shard_ranges(n_partitions, num_threads).len();
    let part_chunk = shard_chunk(n_partitions, num_threads);
    out.routes.clear();
    out.flat.clear();
    out.offsets.clear();
    out.offsets.resize(shard_count + 1, 0);
    if records.is_empty() || shard_count == 0 {
        return;
    }
    out.routes.resize(records.len(), 0);
    out.flat.resize(records.len(), 0);
    let rec_ranges = shard_ranges(records.len(), num_threads);
    let n_chunks = rec_ranges.len();
    out.counts.clear();
    out.counts.resize(n_chunks * shard_count, 0);
    let pool = WorkerPool::for_threads(num_threads);

    // Pass A: route each chunk, counting records per (chunk, shard).
    {
        let routes = SharedSlice::new(&mut out.routes);
        let counts = SharedSlice::new(&mut out.counts);
        let ranges = &rec_ranges;
        pool.run(n_chunks, &|c| {
            let range = ranges[c].clone();
            // Safety: chunk ranges are disjoint, and each task owns
            // exactly its own row of the counting matrix.
            let routes = unsafe { routes.slice(range.clone()) };
            let row = unsafe { counts.slice(c * shard_count..(c + 1) * shard_count) };
            for (o, r) in routes.iter_mut().zip(&records[range]) {
                let p = epoch.partition(r.key);
                *o = p as u32;
                row[p / part_chunk] += 1;
            }
        });
    }

    // Serial shard-major prefix sum: per-(chunk, shard) counts become
    // scatter cursors, and the running total becomes the offset table.
    let mut acc = 0usize;
    for s in 0..shard_count {
        out.offsets[s] = acc;
        for c in 0..n_chunks {
            let cell = &mut out.counts[c * shard_count + s];
            let v = *cell as usize;
            *cell = acc as u32;
            acc += v;
        }
    }
    out.offsets[shard_count] = acc;
    debug_assert_eq!(acc, records.len());

    // Pass B: scatter record indices at each chunk's private cursors.
    {
        let flat = SharedSlice::new(&mut out.flat);
        let counts = SharedSlice::new(&mut out.counts);
        let routes: &[u32] = &out.routes;
        let ranges = &rec_ranges;
        pool.run(n_chunks, &|c| {
            // Safety: the cursor row is task-private, and the prefix sum
            // hands every (chunk, shard) cell a disjoint run of `flat`.
            let row = unsafe { counts.slice(c * shard_count..(c + 1) * shard_count) };
            for i in ranges[c].clone() {
                let shard = routes[i] as usize / part_chunk;
                unsafe { flat.write(row[shard] as usize, i as u32) };
                row[shard] += 1;
            }
        });
    }
}

/// Route every record through `epoch` on the `num_threads`-wide worker
/// pool into a fresh [`RoutedBatch`]. Hot paths should prefer
/// [`route_into`] with a batch recycled from the pool's scratch arena.
pub fn route(records: &[Record], epoch: &PartitionerEpoch, num_threads: usize) -> RoutedBatch {
    let mut out = RoutedBatch::default();
    route_into(&mut out, records, epoch, num_threads);
    out
}

/// The sharded keyed reduce: accumulate a routed batch into per-partition
/// loads, record counts and (optionally) keyed state, with one pool task
/// per partition shard. Each task owns a disjoint partition range of the
/// output buffers and the stores (no locks, no per-shard staging copies)
/// and visits *only its own records* (the [`RoutedBatch`] index groups)
/// in input order, so per-partition accumulation order — and hence every
/// f64 sum and every `StateStore`'s insertion sequence — matches the
/// sequential loop exactly, while total work stays O(records).
///
/// Empty input (`records` empty or `n_partitions == 0`) is a no-op
/// returning the (possibly empty) zeroed buffers.
///
/// `num_threads` must equal the value `routed` was built with (the shard
/// decomposition is a pure function of `(n_partitions, num_threads)`).
pub fn shuffle_sharded(
    records: &[Record],
    routed: &RoutedBatch,
    n_partitions: usize,
    mut state: Option<&mut [StateStore]>,
    num_threads: usize,
) -> (Vec<f64>, Vec<u64>) {
    debug_assert_eq!(records.len(), routed.routes.len());
    let mut loads = vec![0.0f64; n_partitions];
    let mut record_counts = vec![0u64; n_partitions];
    if records.is_empty() || n_partitions == 0 {
        return (loads, record_counts);
    }
    let ranges = shard_ranges(n_partitions, num_threads);
    debug_assert_eq!(ranges.len(), routed.n_shards());
    let pool = WorkerPool::for_threads(num_threads);
    let loads_sh = SharedSlice::new(&mut loads);
    let counts_sh = SharedSlice::new(&mut record_counts);
    let stores_sh = state.as_deref_mut().map(|stores| {
        debug_assert_eq!(stores.len(), n_partitions);
        SharedSlice::new(stores)
    });
    let ranges_ref = &ranges;
    pool.run(ranges_ref.len(), &|s_idx| {
        let range = ranges_ref[s_idx].clone();
        let base = range.start;
        // Safety: partition shards are disjoint ranges of all three
        // output buffers, and each task touches only its own range.
        let loads = unsafe { loads_sh.slice(range.clone()) };
        let counts = unsafe { counts_sh.slice(range.clone()) };
        let mut stores = stores_sh.as_ref().map(|sh| unsafe { sh.slice(range.clone()) });
        for &i in routed.shard_indices(s_idx) {
            let r = &records[i as usize];
            let p = routed.routes[i as usize] as usize;
            loads[p - base] += r.weight;
            counts[p - base] += 1;
            if let Some(st) = &mut stores {
                st[p - base].fold_count(r.key, r.weight);
            }
        }
    });
    (loads, record_counts)
}

/// [`tap_records`](super::tap_records) with the DRWs sharded over the
/// worker pool (`num_threads <= 1` falls back to the sequential tap).
/// Each task owns a contiguous `&mut` slice of DRWs and replays exactly
/// the observation subsequence the sequential tap would feed them, so
/// sampling RNGs and counters advance identically.
pub fn tap_records_sharded(
    workers: &mut [DrWorker],
    records: &[Record],
    assign: TapAssignment,
    num_threads: usize,
) {
    if num_threads <= 1 || workers.len() <= 1 {
        super::tap_records(workers, records, assign);
        return;
    }
    let n_workers = workers.len();
    let per = records.len().div_ceil(n_workers).max(1);
    let ranges = shard_ranges(n_workers, num_threads);
    let pool = WorkerPool::for_threads(num_threads);
    let shared = SharedSlice::new(workers);
    let ranges_ref = &ranges;
    pool.run(ranges_ref.len(), &|s_idx| {
        let range = ranges_ref[s_idx].clone();
        // Safety: DRW shards are disjoint contiguous ranges.
        let shard = unsafe { shared.slice(range.clone()) };
        match assign {
            TapAssignment::Chunked => {
                for (local, w) in range.clone().enumerate() {
                    let start = (w * per).min(records.len());
                    let end = ((w + 1) * per).min(records.len());
                    for r in &records[start..end] {
                        shard[local].observe(r.key, r.weight);
                    }
                }
            }
            TapAssignment::RoundRobin => {
                // Worker w owns records w, w + n, w + 2n, … — walk each
                // owned DRW's stride directly (no full-batch scan). The
                // sequential tap interleaves workers per record, but
                // per-DRW the observation order is this same ascending
                // stride, and DRWs share no state across workers.
                for (local, w) in range.clone().enumerate() {
                    for i in (w..records.len()).step_by(n_workers) {
                        let r = &records[i];
                        shard[local].observe(r.key, r.weight);
                    }
                }
            }
        }
    });
}

/// Harvest every DRW's local histogram with the DRWs sharded over the
/// worker pool. Shards are contiguous and write disjoint ranges of the
/// output in place, so the DRM receives histograms in exactly the worker
/// order the sequential harvest produces.
pub fn harvest_sharded(
    workers: &mut [DrWorker],
    top_k: usize,
    num_threads: usize,
) -> Vec<Histogram> {
    if num_threads <= 1 || workers.len() <= 1 {
        return workers.iter_mut().map(|w| w.harvest(top_k)).collect();
    }
    let ranges = shard_ranges(workers.len(), num_threads);
    let mut out = vec![Histogram::empty(); workers.len()];
    let pool = WorkerPool::for_threads(num_threads);
    let w_sh = SharedSlice::new(workers);
    let o_sh = SharedSlice::new(&mut out);
    let ranges_ref = &ranges;
    pool.run(ranges_ref.len(), &|s_idx| {
        let range = ranges_ref[s_idx].clone();
        // Safety: worker and output shards are the same disjoint ranges.
        let shard = unsafe { w_sh.slice(range.clone()) };
        let outs = unsafe { o_sh.slice(range) };
        for (w, o) in shard.iter_mut().zip(outs) {
            *o = w.harvest(top_k);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{EpochedPartitioner, Uhp};
    use crate::workload::{zipf::Zipf, Generator};
    use std::sync::Arc;

    fn epoch(n: usize, seed: u64) -> PartitionerEpoch {
        EpochedPartitioner::new(Arc::new(Uhp::with_seed(n, seed))).current()
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for (n, shards) in [(1, 1), (7, 3), (8, 4), (16, 5), (3, 8), (64, 8), (0, 4)] {
            let ranges = shard_ranges(n, shards);
            assert!(ranges.len() <= shards.max(1), "n={n} shards={shards}");
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap at n={n} shards={shards}");
                assert!(r.end > r.start, "empty shard at n={n} shards={shards}");
                next = r.end;
            }
            assert_eq!(next, n, "n={n} shards={shards} not covered");
            // ranges line up with chunks_mut over a slice of length n
            let mut v = vec![0u8; n];
            let pieces: Vec<usize> =
                v.chunks_mut(shard_chunk(n, shards)).map(|c| c.len()).collect();
            assert_eq!(pieces.len(), ranges.len(), "n={n} shards={shards}");
            for (p, r) in pieces.iter().zip(&ranges) {
                assert_eq!(*p, r.len(), "n={n} shards={shards}");
            }
        }
        // the n == 0 edge is a documented empty decomposition
        for shards in [1, 4, 16] {
            assert!(shard_ranges(0, shards).is_empty(), "shards={shards}");
        }
    }

    #[test]
    fn route_matches_sequential_and_buckets_cover() {
        let ep = epoch(13, 7);
        let mut z = Zipf::new(5_000, 1.1, 7);
        let recs = z.batch(20_011); // odd count: uneven last chunk
        let seq = route(&recs, &ep, 1);
        assert_eq!(seq.routes.len(), recs.len());
        for threads in [2, 3, 8] {
            let par = route(&recs, &ep, threads);
            assert_eq!(par.routes, seq.routes, "{threads} threads");
            // buckets: every record exactly once, in its shard, in order
            let ranges = shard_ranges(13, threads);
            assert_eq!(par.n_shards(), ranges.len());
            let pc = shard_chunk(13, threads);
            // expected groups straight from the sequential routes
            let mut expect: Vec<Vec<u32>> = vec![Vec::new(); ranges.len()];
            for (i, &p) in seq.routes.iter().enumerate() {
                expect[p as usize / pc].push(i as u32);
            }
            let mut seen = 0usize;
            for (s, range) in ranges.iter().enumerate() {
                let indices = par.shard_indices(s);
                assert_eq!(indices, &expect[s][..], "{threads} threads: shard {s} group");
                for w in indices.windows(2) {
                    assert!(w[0] < w[1], "{threads} threads: bucket not in input order");
                }
                for &i in indices {
                    let p = par.routes[i as usize] as usize;
                    assert!(range.contains(&p), "{threads} threads: record in wrong shard");
                }
                seen += indices.len();
            }
            assert_eq!(seen, recs.len(), "{threads} threads: buckets must cover the batch");
        }
    }

    #[test]
    fn route_into_reuses_buffers_across_shapes() {
        let mut z = Zipf::new(3_000, 1.2, 11);
        let big = z.batch(10_007);
        let small = z.batch(257);
        let mut reused = RoutedBatch::default();
        // alternate shapes through one recycled batch; every fill must
        // equal a fresh route of the same input
        for (recs, n, threads) in
            [(&big, 13, 4), (&small, 7, 4), (&big, 7, 2), (&small, 13, 8)]
        {
            let ep = epoch(n, 5);
            route_into(&mut reused, recs, &ep, threads);
            let fresh = route(recs, &ep, threads);
            assert_eq!(reused.routes, fresh.routes, "n={n} threads={threads}");
            assert_eq!(reused.n_shards(), fresh.n_shards(), "n={n} threads={threads}");
            for s in 0..fresh.n_shards() {
                assert_eq!(
                    reused.shard_indices(s),
                    fresh.shard_indices(s),
                    "n={n} threads={threads} shard {s}"
                );
            }
        }
    }

    #[test]
    fn shuffle_sharded_matches_sequential_bitwise() {
        let n = 11;
        let ep = epoch(n, 3);
        let mut z = Zipf::new(2_000, 1.3, 3);
        let recs = z.batch(30_000);

        // sequential reference (the exact ShuffleStage loop)
        let mut loads_seq = vec![0.0f64; n];
        let mut counts_seq = vec![0u64; n];
        let mut stores_seq: Vec<StateStore> = (0..n).map(|_| StateStore::new()).collect();
        for r in &recs {
            let p = ep.partition(r.key);
            loads_seq[p] += r.weight;
            counts_seq[p] += 1;
            stores_seq[p].fold_count(r.key, r.weight);
        }

        for threads in [2, 4, 7] {
            let routed = route(&recs, &ep, threads);
            let mut stores: Vec<StateStore> = (0..n).map(|_| StateStore::new()).collect();
            let (loads, counts) =
                shuffle_sharded(&recs, &routed, n, Some(stores.as_mut_slice()), threads);
            assert_eq!(counts, counts_seq, "{threads} threads");
            for (a, b) in loads.iter().zip(&loads_seq) {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads: load bits differ");
            }
            for (s, r) in stores.iter().zip(&stores_seq) {
                assert_eq!(s.n_keys(), r.n_keys());
                assert_eq!(
                    s.total_weight().to_bits(),
                    r.total_weight().to_bits(),
                    "{threads} threads: state weight bits differ"
                );
                for k in r.keys() {
                    assert_eq!(s.get(k), r.get(k), "{threads} threads: key {k} state differs");
                }
            }
        }
    }

    #[test]
    fn sharded_tap_matches_sequential() {
        for assign in [TapAssignment::Chunked, TapAssignment::RoundRobin] {
            let mut z = Zipf::new(1_000, 1.0, 9);
            let recs = z.batch(10_007);
            let make = || -> Vec<DrWorker> {
                (0..5).map(|w| DrWorker::new(64, 0.5, w as u64)).collect()
            };
            for threads in [2, 3, 8] {
                let mut seq = make();
                super::super::tap_records(&mut seq, &recs, assign);
                let mut par = make();
                tap_records_sharded(&mut par, &recs, assign, threads);
                for (a, b) in par.iter().zip(&seq) {
                    assert_eq!(a.observed(), b.observed(), "{assign:?} {threads}");
                    assert_eq!(a.sampled(), b.sampled(), "{assign:?} {threads}");
                }
                // harvests (which drain the counters) must agree too
                let hp: Vec<Histogram> = harvest_sharded(&mut par, 8, threads);
                let hs: Vec<Histogram> = seq.iter_mut().map(|w| w.harvest(8)).collect();
                assert_eq!(hp.len(), hs.len());
                for (x, y) in hp.iter().zip(&hs) {
                    assert_eq!(x.entries(), y.entries(), "{assign:?} {threads}");
                }
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs_are_safe() {
        let ep = epoch(4, 1);
        // empty records: documented no-op end to end
        let empty = route(&[], &ep, 4);
        assert!(empty.routes.is_empty());
        assert_eq!(empty.n_shards(), 4);
        assert!((0..4).all(|s| empty.shard_indices(s).is_empty()));
        let (loads, counts) = shuffle_sharded(&[], &empty, 4, None, 4);
        assert_eq!(loads, vec![0.0; 4]);
        assert_eq!(counts, vec![0; 4]);
        // zero partitions: shard_ranges(0, t) is empty, so routing and
        // the reduce both degrade to no-ops instead of tripping the
        // shard-count assertion
        let ep0 = epoch(0, 1);
        let routed0 = route(&[], &ep0, 4);
        assert_eq!(routed0.n_shards(), 0);
        let (loads0, counts0) = shuffle_sharded(&[], &routed0, 0, None, 4);
        assert!(loads0.is_empty());
        assert!(counts0.is_empty());
        let recs = vec![Record::unit(1, 0), Record::unit(2, 1)];
        let routed0 = route(&recs, &ep0, 4);
        assert_eq!(routed0.n_shards(), 0);
        assert!(routed0.routes.is_empty());
        let (loads0, counts0) = shuffle_sharded(&[], &routed0, 0, None, 4);
        assert!(loads0.is_empty() && counts0.is_empty());
        // more threads than partitions/records
        let routed = route(&recs, &ep, 16);
        let (loads, counts) = shuffle_sharded(&recs, &routed, 4, None, 16);
        assert_eq!(counts.iter().sum::<u64>(), 2);
        assert!((loads.iter().sum::<f64>() - 2.0).abs() < 1e-12);
    }
}
