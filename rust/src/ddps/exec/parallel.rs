//! The sharded parallel executor behind [`ShuffleStage`](super::ShuffleStage)
//! (see DESIGN.md "The sharded parallel executor").
//!
//! The paper's 1.5–6× speedups come from DR flattening partition load so
//! that *parallel* reducers finish together. The sequential path only
//! models that with virtual time; this module runs one stage's reduce
//! partitions on real `std::thread::scope` workers so the spill/imbalance
//! model can be validated against actual parallel execution:
//!
//! - **Routing** ([`route`]): records are split into contiguous chunks,
//!   one per thread, and each thread routes its chunk through the shared
//!   [`PartitionerEpoch`] snapshot (epoch snapshots are `Arc`-cloneable
//!   and every `Partitioner` is `Send + Sync`, so the snapshot is shared
//!   by reference) while bucketing record indices by owning shard.
//! - **Keyed reduce** ([`shuffle_sharded`]): partitions are split into
//!   contiguous *shards*, one per thread ([`shard_ranges`]). Each shard
//!   worker owns its partitions' loads, record counts and
//!   [`StateStore`]s outright — keyed reduce needs no locks — and visits
//!   only its own records ([`RoutedBatch`]'s index buckets) in input
//!   order, so every per-partition f64 accumulation happens in exactly
//!   the sequential order and total work stays O(records). Per-shard
//!   results are merged in partition order. Reports are therefore
//!   **bitwise-identical** to the sequential path, independent of the
//!   thread count.
//! - **DRW taps and harvests** ([`tap_records_sharded`],
//!   [`harvest_sharded`]): the same sharding applied to the
//!   [`DrWorker`]s, preserving each DRW's observation/harvest sequence so
//!   sampling RNGs, counters and the DRM's histogram order advance
//!   exactly as they do sequentially — the taps stay consistent with
//!   where records actually ran. Downstream of the harvests, the DRM
//!   decision point itself is sharded too
//!   ([`dr::parallel`](crate::dr::parallel): parallel histogram
//!   tree-merge + key-range candidate preparation), so no serial region
//!   remains between the parallel shards.
//!
//! Engines opt in through
//! [`EngineConfig::num_threads`](super::EngineConfig::num_threads); the
//! default of 1 keeps today's sequential loop. Because results are
//! invariant, the only observable difference is the measured
//! [`StageReport::wall_s`](super::StageReport::wall_s) column:
//!
//! ```
//! use dynrepart::ddps::{EngineConfig, Scheduling, ShuffleStage};
//! use dynrepart::partitioner::{EpochedPartitioner, Uhp};
//! use dynrepart::workload::Record;
//! use std::sync::Arc;
//!
//! let par = EngineConfig { n_partitions: 8, n_slots: 4, num_threads: 4, ..Default::default() };
//! let seq = EngineConfig { num_threads: 1, ..par };
//! let epoch = EpochedPartitioner::new(Arc::new(Uhp::with_seed(8, 1))).current();
//! let records: Vec<Record> = (0u64..10_000).map(|k| Record::unit(k % 257, k)).collect();
//!
//! let p = ShuffleStage::new(&par, Scheduling::Wave).run(&records, &epoch, None);
//! let s = ShuffleStage::new(&seq, Scheduling::Wave).run(&records, &epoch, None);
//! assert_eq!(p.loads, s.loads); // bitwise-identical routing
//! assert_eq!(p.stage_time, s.stage_time); // identical virtual time
//! ```

use super::TapAssignment;
use crate::dr::DrWorker;
use crate::partitioner::PartitionerEpoch;
use crate::sketch::Histogram;
use crate::state::StateStore;
use crate::workload::Record;
use std::ops::Range;
use std::thread;

/// The shard width [`shard_ranges`] cuts `0..n` into: every sharded step
/// of one stage derives its `chunks_mut` decomposition from this same
/// number, so all of them agree on who owns which index.
fn shard_chunk(n: usize, shards: usize) -> usize {
    n.div_ceil(shards.max(1)).max(1)
}

/// Split `0..n` into at most `shards` contiguous, equal-as-possible,
/// non-empty ranges (fewer when `n < shards`). The ranges line up exactly
/// with `slice.chunks_mut(shard_chunk(n, shards))` over a slice of
/// length `n`.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<Range<usize>> {
    let chunk = shard_chunk(n, shards);
    (0..n)
        .step_by(chunk)
        .map(|start| start..(start + chunk).min(n))
        .collect()
}

/// One routed batch: the partition index per record (input order) plus,
/// for each partition shard, the indices of the records it owns — also in
/// input order, so shard workers can replay exactly the sequential
/// accumulation order while touching only their own records.
pub struct RoutedBatch {
    /// Partition index per record, in input order.
    pub routes: Vec<u32>,
    /// Record indices owned by each shard (shards as per [`shard_ranges`]
    /// over `(epoch.n_partitions(), num_threads)`), each in input order.
    pub shard_indices: Vec<Vec<u32>>,
}

/// Route every record through `epoch` on `num_threads` scoped workers.
/// One contiguous record chunk per thread; each thread also buckets its
/// chunk's record indices by owning shard, and the per-chunk buckets are
/// concatenated in chunk order — so every shard's index list is in input
/// order and the result is identical to the sequential map (routing is
/// pure).
pub fn route(records: &[Record], epoch: &PartitionerEpoch, num_threads: usize) -> RoutedBatch {
    debug_assert!(records.len() <= u32::MAX as usize);
    let n_partitions = epoch.n_partitions();
    let n_shards = shard_ranges(n_partitions, num_threads).len();
    let part_chunk = shard_chunk(n_partitions, num_threads);
    let mut routes = vec![0u32; records.len()];

    if num_threads <= 1 || records.len() <= 1 {
        let mut shard_indices: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
        for (i, r) in records.iter().enumerate() {
            let p = epoch.partition(r.key);
            routes[i] = p as u32;
            shard_indices[p / part_chunk].push(i as u32);
        }
        return RoutedBatch {
            routes,
            shard_indices,
        };
    }

    let chunk = shard_chunk(records.len(), num_threads);
    let mut chunk_buckets: Vec<Vec<Vec<u32>>> = Vec::new();
    thread::scope(|s| {
        let handles: Vec<_> = records
            .chunks(chunk)
            .zip(routes.chunks_mut(chunk))
            .enumerate()
            .map(|(ci, (rec, out))| {
                s.spawn(move || {
                    let base = ci * chunk;
                    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
                    for (j, (r, o)) in rec.iter().zip(out.iter_mut()).enumerate() {
                        let p = epoch.partition(r.key);
                        *o = p as u32;
                        buckets[p / part_chunk].push((base + j) as u32);
                    }
                    buckets
                })
            })
            .collect();
        chunk_buckets = handles
            .into_iter()
            .map(|h| h.join().expect("route worker panicked"))
            .collect();
    });

    // Concatenate per-chunk buckets in chunk order: input order per shard.
    let mut shard_indices: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
    for buckets in chunk_buckets {
        for (shard, mut bucket) in buckets.into_iter().enumerate() {
            shard_indices[shard].append(&mut bucket);
        }
    }
    RoutedBatch {
        routes,
        shard_indices,
    }
}

/// What one shard worker hands back: its partitions' loads and record
/// counts, indexed relative to the shard's range start.
struct ShardAccum {
    loads: Vec<f64>,
    record_counts: Vec<u64>,
}

/// The sharded keyed reduce: accumulate a routed batch into per-partition
/// loads, record counts and (optionally) keyed state, with one scoped
/// worker per partition shard. Each worker owns a disjoint `&mut` slice
/// of the stores (no locks) and visits *only its own records* (the
/// [`RoutedBatch`] index buckets) in input order, so per-partition
/// accumulation order — and hence every f64 sum and every `StateStore`'s
/// insertion sequence — matches the sequential loop exactly, while total
/// work stays O(records). Shard results are merged in partition order.
///
/// `num_threads` must equal the value `routed` was built with (the shard
/// decomposition is a pure function of `(n_partitions, num_threads)`).
pub fn shuffle_sharded(
    records: &[Record],
    routed: &RoutedBatch,
    n_partitions: usize,
    state: Option<&mut [StateStore]>,
    num_threads: usize,
) -> (Vec<f64>, Vec<u64>) {
    debug_assert_eq!(records.len(), routed.routes.len());
    let ranges = shard_ranges(n_partitions, num_threads);
    debug_assert_eq!(ranges.len(), routed.shard_indices.len());
    let chunk = shard_chunk(n_partitions, num_threads);
    let store_shards: Vec<Option<&mut [StateStore]>> = match state {
        Some(stores) => {
            debug_assert_eq!(stores.len(), n_partitions);
            stores.chunks_mut(chunk).map(Some).collect()
        }
        None => ranges.iter().map(|_| None).collect(),
    };

    let mut loads = vec![0.0f64; n_partitions];
    let mut record_counts = vec![0u64; n_partitions];
    thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .cloned()
            .zip(&routed.shard_indices)
            .zip(store_shards)
            .map(|((range, indices), stores)| {
                s.spawn(move || {
                    let mut stores = stores;
                    let base = range.start;
                    let mut acc = ShardAccum {
                        loads: vec![0.0; range.len()],
                        record_counts: vec![0; range.len()],
                    };
                    for &i in indices {
                        let r = &records[i as usize];
                        let p = routed.routes[i as usize] as usize;
                        acc.loads[p - base] += r.weight;
                        acc.record_counts[p - base] += 1;
                        if let Some(st) = stores.as_deref_mut() {
                            st[p - base].fold_count(r.key, r.weight);
                        }
                    }
                    acc
                })
            })
            .collect();
        // Deterministic merge: join shards in partition order.
        for (range, h) in ranges.iter().zip(handles) {
            let acc = h.join().expect("shard worker panicked");
            loads[range.clone()].copy_from_slice(&acc.loads);
            record_counts[range.clone()].copy_from_slice(&acc.record_counts);
        }
    });
    (loads, record_counts)
}

/// [`tap_records`](super::tap_records) with the DRWs sharded over
/// `num_threads` scoped workers (`<= 1` falls back to the sequential tap).
/// Each worker owns a contiguous `&mut` slice of DRWs and replays exactly
/// the observation subsequence the sequential tap would feed them, so
/// sampling RNGs and counters advance identically.
pub fn tap_records_sharded(
    workers: &mut [DrWorker],
    records: &[Record],
    assign: TapAssignment,
    num_threads: usize,
) {
    if num_threads <= 1 || workers.len() <= 1 {
        super::tap_records(workers, records, assign);
        return;
    }
    let n_workers = workers.len();
    let per = records.len().div_ceil(n_workers).max(1);
    let ranges = shard_ranges(n_workers, num_threads);
    let chunk = shard_chunk(n_workers, num_threads);
    thread::scope(|s| {
        for (range, shard) in ranges.iter().cloned().zip(workers.chunks_mut(chunk)) {
            s.spawn(move || match assign {
                TapAssignment::Chunked => {
                    for (local, w) in range.clone().enumerate() {
                        let start = (w * per).min(records.len());
                        let end = ((w + 1) * per).min(records.len());
                        for r in &records[start..end] {
                            shard[local].observe(r.key, r.weight);
                        }
                    }
                }
                TapAssignment::RoundRobin => {
                    // Worker w owns records w, w + n, w + 2n, … — walk each
                    // owned DRW's stride directly (no full-batch scan). The
                    // sequential tap interleaves workers per record, but
                    // per-DRW the observation order is this same ascending
                    // stride, and DRWs share no state across workers.
                    for (local, w) in range.clone().enumerate() {
                        for i in (w..records.len()).step_by(n_workers) {
                            let r = &records[i];
                            shard[local].observe(r.key, r.weight);
                        }
                    }
                }
            });
        }
    });
}

/// Harvest every DRW's local histogram with the DRWs sharded over
/// `num_threads` scoped workers. Shards are contiguous and joined in
/// order, so the DRM receives histograms in exactly the worker order the
/// sequential harvest produces.
pub fn harvest_sharded(
    workers: &mut [DrWorker],
    top_k: usize,
    num_threads: usize,
) -> Vec<Histogram> {
    if num_threads <= 1 || workers.len() <= 1 {
        return workers.iter_mut().map(|w| w.harvest(top_k)).collect();
    }
    let chunk = shard_chunk(workers.len(), num_threads);
    thread::scope(|s| {
        let handles: Vec<_> = workers
            .chunks_mut(chunk)
            .map(|shard| {
                s.spawn(move || shard.iter_mut().map(|w| w.harvest(top_k)).collect::<Vec<_>>())
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("harvest worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{EpochedPartitioner, Uhp};
    use crate::workload::{zipf::Zipf, Generator};
    use std::sync::Arc;

    fn epoch(n: usize, seed: u64) -> PartitionerEpoch {
        EpochedPartitioner::new(Arc::new(Uhp::with_seed(n, seed))).current()
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for (n, shards) in [(1, 1), (7, 3), (8, 4), (16, 5), (3, 8), (64, 8), (0, 4)] {
            let ranges = shard_ranges(n, shards);
            assert!(ranges.len() <= shards.max(1), "n={n} shards={shards}");
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap at n={n} shards={shards}");
                assert!(r.end > r.start, "empty shard at n={n} shards={shards}");
                next = r.end;
            }
            assert_eq!(next, n, "n={n} shards={shards} not covered");
            // ranges line up with chunks_mut over a slice of length n
            let mut v = vec![0u8; n];
            let pieces: Vec<usize> =
                v.chunks_mut(shard_chunk(n, shards)).map(|c| c.len()).collect();
            assert_eq!(pieces.len(), ranges.len(), "n={n} shards={shards}");
            for (p, r) in pieces.iter().zip(&ranges) {
                assert_eq!(*p, r.len(), "n={n} shards={shards}");
            }
        }
    }

    #[test]
    fn route_matches_sequential_and_buckets_cover() {
        let ep = epoch(13, 7);
        let mut z = Zipf::new(5_000, 1.1, 7);
        let recs = z.batch(20_011); // odd count: uneven last chunk
        let seq = route(&recs, &ep, 1);
        assert_eq!(seq.routes.len(), recs.len());
        for threads in [2, 3, 8] {
            let par = route(&recs, &ep, threads);
            assert_eq!(par.routes, seq.routes, "{threads} threads");
            // buckets: every record exactly once, in its shard, in order
            let ranges = shard_ranges(13, threads);
            assert_eq!(par.shard_indices.len(), ranges.len());
            let mut seen = 0usize;
            for (range, indices) in ranges.iter().zip(&par.shard_indices) {
                for w in indices.windows(2) {
                    assert!(w[0] < w[1], "{threads} threads: bucket not in input order");
                }
                for &i in indices {
                    let p = par.routes[i as usize] as usize;
                    assert!(range.contains(&p), "{threads} threads: record in wrong shard");
                }
                seen += indices.len();
            }
            assert_eq!(seen, recs.len(), "{threads} threads: buckets must cover the batch");
        }
    }

    #[test]
    fn shuffle_sharded_matches_sequential_bitwise() {
        let n = 11;
        let ep = epoch(n, 3);
        let mut z = Zipf::new(2_000, 1.3, 3);
        let recs = z.batch(30_000);

        // sequential reference (the exact ShuffleStage loop)
        let mut loads_seq = vec![0.0f64; n];
        let mut counts_seq = vec![0u64; n];
        let mut stores_seq: Vec<StateStore> = (0..n).map(|_| StateStore::new()).collect();
        for r in &recs {
            let p = ep.partition(r.key);
            loads_seq[p] += r.weight;
            counts_seq[p] += 1;
            stores_seq[p].fold_count(r.key, r.weight);
        }

        for threads in [2, 4, 7] {
            let routed = route(&recs, &ep, threads);
            let mut stores: Vec<StateStore> = (0..n).map(|_| StateStore::new()).collect();
            let (loads, counts) =
                shuffle_sharded(&recs, &routed, n, Some(stores.as_mut_slice()), threads);
            assert_eq!(counts, counts_seq, "{threads} threads");
            for (a, b) in loads.iter().zip(&loads_seq) {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads: load bits differ");
            }
            for (s, r) in stores.iter().zip(&stores_seq) {
                assert_eq!(s.n_keys(), r.n_keys());
                assert_eq!(
                    s.total_weight().to_bits(),
                    r.total_weight().to_bits(),
                    "{threads} threads: state weight bits differ"
                );
                for k in r.keys() {
                    assert_eq!(s.get(k), r.get(k), "{threads} threads: key {k} state differs");
                }
            }
        }
    }

    #[test]
    fn sharded_tap_matches_sequential() {
        for assign in [TapAssignment::Chunked, TapAssignment::RoundRobin] {
            let mut z = Zipf::new(1_000, 1.0, 9);
            let recs = z.batch(10_007);
            let make = || -> Vec<DrWorker> {
                (0..5).map(|w| DrWorker::new(64, 0.5, w as u64)).collect()
            };
            for threads in [2, 3, 8] {
                let mut seq = make();
                super::super::tap_records(&mut seq, &recs, assign);
                let mut par = make();
                tap_records_sharded(&mut par, &recs, assign, threads);
                for (a, b) in par.iter().zip(&seq) {
                    assert_eq!(a.observed(), b.observed(), "{assign:?} {threads}");
                    assert_eq!(a.sampled(), b.sampled(), "{assign:?} {threads}");
                }
                // harvests (which drain the counters) must agree too
                let hp: Vec<Histogram> = harvest_sharded(&mut par, 8, threads);
                let hs: Vec<Histogram> = seq.iter_mut().map(|w| w.harvest(8)).collect();
                assert_eq!(hp.len(), hs.len());
                for (x, y) in hp.iter().zip(&hs) {
                    assert_eq!(x.entries(), y.entries(), "{assign:?} {threads}");
                }
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs_are_safe() {
        let ep = epoch(4, 1);
        let empty = route(&[], &ep, 4);
        assert!(empty.routes.is_empty());
        assert!(empty.shard_indices.iter().all(|b| b.is_empty()));
        let (loads, counts) = shuffle_sharded(&[], &empty, 4, None, 4);
        assert_eq!(loads, vec![0.0; 4]);
        assert_eq!(counts, vec![0; 4]);
        // more threads than partitions/records
        let recs = vec![Record::unit(1, 0), Record::unit(2, 1)];
        let routed = route(&recs, &ep, 16);
        let (loads, counts) = shuffle_sharded(&recs, &routed, 4, None, 16);
        assert_eq!(counts.iter().sum::<u64>(), 2);
        assert!((loads.iter().sum::<f64>() - 2.0).abs() < 1e-12);
    }
}
