//! The persistent worker pool and scratch arenas behind every sharded
//! step (see DESIGN.md "Persistent worker pool and scratch arenas").
//!
//! Before this module every sharded step — routing, keyed reduce, DRW
//! taps and harvests, the DRM tree-merge and candidate preparation, and
//! the pipeline's three drive lanes — paid a fresh `std::thread::scope`
//! spawn per call: O(threads) thread creations and joins per interval,
//! repeated for every interval of every engine. The paper's DDPS hosts
//! (Spark/Flink) amortize executor startup away; this pool does the
//! same for the in-process executor so per-interval overhead is
//! O(records), not O(threads + partitions) in syscalls and allocations.
//!
//! One [`WorkerPool`] per thread width lives for the process lifetime in
//! a global registry ([`WorkerPool::for_threads`]), so every sharded
//! free function keeps its `num_threads: usize` signature and fetches
//! the pool internally; [`EngineCore`](crate::ddps::EngineCore) holds an
//! `Arc` handle to the same pool, which is how the pool trivially
//! survives `rescale` partition-count changes and checkpoint restores —
//! the threads belong to the width, not to any engine's state. A width-1
//! pool owns no threads at all: every dispatch runs inline on the
//! caller, which keeps the sequential reference path exactly what it
//! always was.
//!
//! Two kinds of parked threads, strictly layered so dispatch can never
//! deadlock:
//!
//! - **The gang** (`width - 1` workers): data-parallel shard rounds for
//!   [`WorkerPool::run`]. A round is broadcast under a seq/condvar
//!   handoff — the submitter bumps a round sequence number and parks
//!   until an `active` count drains to zero; worker `j` runs task
//!   `j + 1` while the submitter runs task 0 itself, so a round of
//!   `n_tasks` occupies exactly `n_tasks` threads, the same budget the
//!   scoped executor honoured. Rounds are serialized by a submit lock
//!   (concurrent lanes interleave whole rounds), and gang tasks are
//!   strict leaves: nothing inside a shard task ever submits.
//! - **The lanes** (2 threads): the pipeline's long overlap closures
//!   ([`WorkerPool::join2`] / [`WorkerPool::join3`] — stage ∥ decision ∥
//!   prefetch). Lanes submit gang rounds (the stage and the decision
//!   point are themselves sharded), which is why they are a separate
//!   thread set: re-entering the gang from a gang worker would hand a
//!   round's job pointer to a worker that might still be draining an
//!   older round. Lane acquisition is all-or-nothing, so two concurrent
//!   `join3` regions can never each hold one lane while waiting for the
//!   other's.
//!
//! Determinism is untouched by construction: the pool only changes
//! *which OS thread* runs a shard task, never the shard decomposition
//! (`shard_ranges`), the per-shard visit order, or any accumulation
//! order — the bitwise-identity property tests (`tests/prop_parallel.rs`)
//! pin pooled ≡ scoped-reference ≡ sequential across engines × thread
//! counts.
//!
//! The pool also owns the [`StageScratch`] arena: recycled
//! [`RoutedBatch`] routing buffers and double-buffered batch `Vec`s, so
//! the per-interval hot path re-uses its allocations instead of
//! rebuilding them (`cargo bench --bench micro_pool_reuse` measures the
//! spawn + realloc overhead against the preserved per-call baseline).

use super::parallel::RoutedBatch;
use crate::workload::Record;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::thread::{self, JoinHandle};

/// Lock a pool mutex, shrugging off poisoning: pool invariants are
/// restored *before* any panic propagates (rounds drain, jobs clear), so
/// a poisoned flag carries no information here and must not brick the
/// process-lifetime registry pools.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`lock`]'s counterpart for condvar waits.
fn wait_cv<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// A type-erased pointer to one gang round's task closure. The submitter
/// owns the closure on its stack and parks until every counted worker
/// has finished with it, which is what makes the `'static` erasure sound.
#[derive(Clone, Copy)]
struct GangJob(*const (dyn Fn(usize) + Sync + 'static));

unsafe impl Send for GangJob {}

/// Erase the borrow lifetime of a round closure. A plain `as` cast
/// cannot widen a trait object's lifetime bound, hence the transmute.
///
/// Safety: the caller must keep `f` alive (and its borrows valid) until
/// the round's `active` count has drained to zero.
unsafe fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> GangJob {
    GangJob(std::mem::transmute::<
        *const (dyn Fn(usize) + Sync + 'a),
        *const (dyn Fn(usize) + Sync + 'static),
    >(f as *const (dyn Fn(usize) + Sync + 'a)))
}

/// A type-erased pointer to one lane's overlap closure; same ownership
/// contract as [`GangJob`], scoped to the lane's `done` handshake.
struct LaneJob(*mut (dyn FnMut() + Send + 'static));

unsafe impl Send for LaneJob {}

/// [`erase`] for lane closures (`FnMut`, run exactly once per start).
unsafe fn erase_mut<'a>(f: &'a mut (dyn FnMut() + Send + 'a)) -> LaneJob {
    LaneJob(std::mem::transmute::<
        *mut (dyn FnMut() + Send + 'a),
        *mut (dyn FnMut() + Send + 'static),
    >(f as *mut (dyn FnMut() + Send + 'a)))
}

/// Broadcast state for gang rounds, guarded by one mutex.
#[derive(Default)]
struct GangState {
    /// Round sequence number; a worker runs a round when it observes a
    /// value it has not seen yet.
    seq: u64,
    /// The current round's task closure (set while a round is in flight).
    job: Option<GangJob>,
    /// Tasks in the current round; worker `j` participates iff
    /// `j + 1 < n_tasks` (the submitter runs task 0).
    n_tasks: usize,
    /// Counted workers still running the current round. The submitter
    /// parks on [`GangShared::done`] until this reaches zero, which is
    /// also what keeps the erased job pointer alive long enough.
    active: usize,
    /// A counted worker's task panicked this round.
    panicked: bool,
    shutdown: bool,
}

struct GangShared {
    state: Mutex<GangState>,
    /// Workers park here between rounds.
    work: Condvar,
    /// The submitter parks here while `active > 0`.
    done: Condvar,
}

struct Gang {
    shared: Arc<GangShared>,
    /// Serializes rounds: concurrent submitters (the pipeline lanes both
    /// shard their work) interleave whole rounds instead of racing the
    /// broadcast state.
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

/// The parked gang worker `j`: wait for an unseen round, run task
/// `j + 1` if this round needs it, decrement `active`, park again. A
/// worker the round does not need (its task index ≥ `n_tasks`) was never
/// counted in `active`, so it just records the sequence number and goes
/// back to sleep — it cannot stall the round and cannot miss a later
/// round it *is* needed for, because `seq` only advances once `active`
/// drains.
fn gang_worker(shared: Arc<GangShared>, j: usize) {
    let mut seen = 0u64;
    loop {
        let (job, n_tasks) = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.seq != seen {
                    seen = st.seq;
                    break (st.job, st.n_tasks);
                }
                st = wait_cv(&shared.work, st);
            }
        };
        if j + 1 >= n_tasks {
            continue;
        }
        let job = job.expect("gang round in flight without a job");
        // Safety: the submitter keeps the closure (and everything it
        // borrows) alive until this round's `active` count drains.
        let f = unsafe { &*job.0 };
        let ok = catch_unwind(AssertUnwindSafe(|| f(j + 1))).is_ok();
        let mut st = lock(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Per-lane handoff state, guarded by the lane's mutex.
#[derive(Default)]
struct LaneState {
    job: Option<LaneJob>,
    done: bool,
    panicked: bool,
    shutdown: bool,
}

#[derive(Default)]
struct Lane {
    state: Mutex<LaneState>,
    cv: Condvar,
}

/// The parked lane thread: wait for a job, run it once, flag `done`.
fn lane_worker(lane: Arc<Lane>) {
    loop {
        let job = {
            let mut st = lock(&lane.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job.take() {
                    break job;
                }
                st = wait_cv(&lane.cv, st);
            }
        };
        // Safety: the join region keeps the closure alive until it has
        // observed `done` under the lane mutex.
        let f = unsafe { &mut *job.0 };
        let ok = catch_unwind(AssertUnwindSafe(|| f())).is_ok();
        let mut st = lock(&lane.state);
        st.panicked = !ok;
        st.done = true;
        lane.cv.notify_all();
    }
}

struct LanePool {
    lanes: Vec<Arc<Lane>>,
    /// Indices of idle lanes. Acquisition is all-or-nothing
    /// ([`LanePool::acquire`]), which rules out the hold-and-wait
    /// deadlock between concurrent join regions.
    free: Mutex<Vec<usize>>,
    freed: Condvar,
    handles: Vec<JoinHandle<()>>,
}

impl LanePool {
    /// Take `n` lanes atomically: wait until `n` are free, then claim
    /// them all in one step.
    fn acquire(&self, n: usize) -> Vec<usize> {
        let mut free = lock(&self.free);
        loop {
            if free.len() >= n {
                let at = free.len() - n;
                return free.split_off(at);
            }
            free = wait_cv(&self.freed, free);
        }
    }

    fn release(&self, ids: Vec<usize>) {
        let mut free = lock(&self.free);
        free.extend(ids);
        self.freed.notify_all();
    }

    fn start(&self, id: usize, job: LaneJob) {
        let lane = &self.lanes[id];
        let mut st = lock(&lane.state);
        st.done = false;
        st.panicked = false;
        st.job = Some(job);
        lane.cv.notify_all();
    }

    /// Park until lane `id` finished its job; returns whether it
    /// panicked. Must be called before releasing the lane — it is what
    /// ends the erased closure's lifetime obligation.
    fn wait(&self, id: usize) -> bool {
        let lane = &self.lanes[id];
        let mut st = lock(&lane.state);
        while !st.done {
            st = wait_cv(&lane.cv, st);
        }
        st.panicked
    }
}

/// Recycled per-interval buffers, owned by the pool so every engine and
/// stage sharing a thread width also shares the warm allocations:
/// [`RoutedBatch`] routing buffers (flat index table + offsets + counting
/// matrix) and the drive loops' double-buffered batch `Vec`s.
#[derive(Default)]
pub struct StageScratch {
    routed: Vec<RoutedBatch>,
    batch_bufs: Vec<Vec<Record>>,
}

/// Free-list cap per buffer kind: enough for the handful of concurrent
/// stages a pool realistically serves, small enough that a burst of
/// engines cannot pin unbounded memory.
const SCRATCH_CAP: usize = 4;

/// A long-lived sharded worker pool plus its [`StageScratch`] arena —
/// one per thread width, process-lifetime, shared by every sharded step
/// (see the module docs for the handoff protocol and the determinism
/// argument).
pub struct WorkerPool {
    width: usize,
    gang: Option<Gang>,
    lanes: Option<LanePool>,
    scratch: Mutex<StageScratch>,
}

/// The process-wide width-keyed registry behind
/// [`WorkerPool::for_threads`].
static REGISTRY: OnceLock<Mutex<Vec<Arc<WorkerPool>>>> = OnceLock::new();

impl WorkerPool {
    /// Build a pool of `width` threads total (the caller counts as one:
    /// `width - 1` gang workers plus 2 overlap lanes are spawned; a
    /// width of 1 spawns nothing and runs everything inline). Prefer
    /// [`WorkerPool::for_threads`], which shares one pool per width for
    /// the process lifetime; direct construction exists for tests.
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        if width == 1 {
            return Self {
                width,
                gang: None,
                lanes: None,
                scratch: Mutex::new(StageScratch::default()),
            };
        }
        let shared = Arc::new(GangShared {
            state: Mutex::new(GangState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..width - 1)
            .map(|j| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("ddps-pool-{j}"))
                    .spawn(move || gang_worker(shared, j))
                    .expect("spawn pool worker")
            })
            .collect();
        let lanes: Vec<Arc<Lane>> = (0..2).map(|_| Arc::new(Lane::default())).collect();
        let lane_handles = lanes
            .iter()
            .enumerate()
            .map(|(i, lane)| {
                let lane = Arc::clone(lane);
                thread::Builder::new()
                    .name(format!("ddps-lane-{i}"))
                    .spawn(move || lane_worker(lane))
                    .expect("spawn pool lane")
            })
            .collect();
        Self {
            width,
            gang: Some(Gang {
                shared,
                submit: Mutex::new(()),
                handles,
            }),
            lanes: Some(LanePool {
                lanes,
                free: Mutex::new(vec![0, 1]),
                freed: Condvar::new(),
                handles: lane_handles,
            }),
            scratch: Mutex::new(StageScratch::default()),
        }
    }

    /// The shared pool for `num_threads` (clamped to at least 1),
    /// created on first use and kept for the process lifetime — the
    /// sharded free functions fetch their pool here from the same
    /// `num_threads` they always took, and [`EngineCore`] pins a handle
    /// at construction.
    ///
    /// [`EngineCore`]: crate::ddps::EngineCore
    pub fn for_threads(num_threads: usize) -> Arc<WorkerPool> {
        let width = num_threads.max(1);
        let reg = REGISTRY.get_or_init(|| Mutex::new(Vec::new()));
        let mut pools = lock(reg);
        if let Some(p) = pools.iter().find(|p| p.width == width) {
            return Arc::clone(p);
        }
        let p = Arc::new(WorkerPool::new(width));
        pools.push(Arc::clone(&p));
        p
    }

    /// Total threads this pool represents, the caller included.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Run one data-parallel round: `f(0)`, `f(1)`, …, `f(n_tasks - 1)`,
    /// each exactly once, on up to `n_tasks` threads (the caller runs
    /// task 0). Blocks until every task finished. On a width-1 pool —
    /// or for trivial rounds — the tasks run inline on the caller, in
    /// ascending order. Panics in any task propagate to the caller after
    /// the round has fully drained (borrows stay valid throughout).
    ///
    /// Tasks must be leaves: they may not submit rounds or join regions
    /// on any pool. `n_tasks` may not exceed the pool width.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let gang = match &self.gang {
            Some(g) if n_tasks > 1 => g,
            _ => {
                for t in 0..n_tasks {
                    f(t);
                }
                return;
            }
        };
        assert!(
            n_tasks <= self.width,
            "gang round of {n_tasks} tasks exceeds pool width {}",
            self.width
        );
        let round = lock(&gang.submit);
        {
            let mut st = lock(&gang.shared.state);
            st.seq = st.seq.wrapping_add(1);
            // Safety: this frame parks below until `active` drains, so
            // the borrow outlives every worker's use.
            st.job = Some(unsafe { erase(f) });
            st.n_tasks = n_tasks;
            st.active = n_tasks - 1;
            st.panicked = false;
            gang.shared.work.notify_all();
        }
        let res = catch_unwind(AssertUnwindSafe(|| f(0)));
        let worker_panicked = {
            let mut st = lock(&gang.shared.state);
            while st.active > 0 {
                st = wait_cv(&gang.shared.done, st);
            }
            st.job = None;
            st.panicked
        };
        drop(round);
        if let Err(p) = res {
            resume_unwind(p);
        }
        assert!(!worker_panicked, "worker pool gang task panicked");
    }

    /// Run `a` on a lane thread while `b` runs on the caller; both done
    /// before returning. Sequential pools run `a` then `b` inline. `b`
    /// deliberately carries no `Send` bound — the drive loops keep their
    /// (not necessarily `Send`) `Source` on the calling thread, exactly
    /// as the scoped regions did.
    pub fn join2<RA, RB>(&self, a: impl FnOnce() -> RA + Send, b: impl FnOnce() -> RB) -> (RA, RB)
    where
        RA: Send,
    {
        let Some(lanes) = &self.lanes else {
            let ra = a();
            let rb = b();
            return (ra, rb);
        };
        let mut ra = None;
        let mut a_opt = Some(a);
        let mut ta = || ra = Some((a_opt.take().expect("lane job runs once"))());
        let ids = lanes.acquire(1);
        // Safety: this frame parks in `wait` below before the closure
        // (and `ra`) can go out of scope.
        lanes.start(ids[0], unsafe { erase_mut(&mut ta) });
        let rb = catch_unwind(AssertUnwindSafe(b));
        let pa = lanes.wait(ids[0]);
        lanes.release(ids);
        match rb {
            Err(p) => resume_unwind(p),
            Ok(rb) => {
                assert!(!pa, "worker pool lane panicked");
                (ra.expect("lane ran"), rb)
            }
        }
    }

    /// [`WorkerPool::join2`] with two lane closures: `a` and `b` each on
    /// a lane thread, `c` on the caller. The two lanes are acquired
    /// atomically. Sequential pools run `a`, `b`, `c` inline in order.
    pub fn join3<RA, RB, RC>(
        &self,
        a: impl FnOnce() -> RA + Send,
        b: impl FnOnce() -> RB + Send,
        c: impl FnOnce() -> RC,
    ) -> (RA, RB, RC)
    where
        RA: Send,
        RB: Send,
    {
        let Some(lanes) = &self.lanes else {
            let ra = a();
            let rb = b();
            let rc = c();
            return (ra, rb, rc);
        };
        let mut ra = None;
        let mut a_opt = Some(a);
        let mut ta = || ra = Some((a_opt.take().expect("lane job runs once"))());
        let mut rb = None;
        let mut b_opt = Some(b);
        let mut tb = || rb = Some((b_opt.take().expect("lane job runs once"))());
        let ids = lanes.acquire(2);
        // Safety: as in `join2` — both lanes are waited on below.
        lanes.start(ids[0], unsafe { erase_mut(&mut ta) });
        lanes.start(ids[1], unsafe { erase_mut(&mut tb) });
        let rc = catch_unwind(AssertUnwindSafe(c));
        let pa = lanes.wait(ids[0]);
        let pb = lanes.wait(ids[1]);
        lanes.release(ids);
        match rc {
            Err(p) => resume_unwind(p),
            Ok(rc) => {
                assert!(!pa && !pb, "worker pool lane panicked");
                (ra.expect("lane ran"), rb.expect("lane ran"), rc)
            }
        }
    }

    /// Take a recycled routing buffer from the arena (or a fresh empty
    /// one). Return it with [`WorkerPool::put_routed`] after the stage.
    pub fn take_routed(&self) -> RoutedBatch {
        lock(&self.scratch).routed.pop().unwrap_or_default()
    }

    /// Return a routing buffer to the arena for the next interval;
    /// capacity is retained, contents are rewritten by the next
    /// [`route_into`](super::parallel::route_into).
    pub fn put_routed(&self, routed: RoutedBatch) {
        let mut s = lock(&self.scratch);
        if s.routed.len() < SCRATCH_CAP {
            s.routed.push(routed);
        }
    }

    /// Take a recycled batch buffer (the drive loops' double buffers).
    pub fn take_batch_buf(&self) -> Vec<Record> {
        lock(&self.scratch).batch_bufs.pop().unwrap_or_default()
    }

    /// Return a batch buffer to the arena; cleared here, capacity kept.
    pub fn put_batch_buf(&self, mut buf: Vec<Record>) {
        buf.clear();
        let mut s = lock(&self.scratch);
        if s.batch_bufs.len() < SCRATCH_CAP {
            s.batch_bufs.push(buf);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(gang) = self.gang.take() {
            {
                let mut st = lock(&gang.shared.state);
                st.shutdown = true;
                gang.shared.work.notify_all();
            }
            for h in gang.handles {
                let _ = h.join();
            }
        }
        if let Some(lanes) = self.lanes.take() {
            for lane in &lanes.lanes {
                let mut st = lock(&lane.state);
                st.shutdown = true;
                lane.cv.notify_all();
            }
            for h in lanes.handles {
                let _ = h.join();
            }
        }
    }
}

/// A `&mut [T]` sharable across one gang round, with the disjointness
/// obligation moved to the call sites: each task may only touch the
/// range (or single slots) it owns under the round's shard
/// decomposition. This is what lets shard workers write their partition
/// ranges of the *final* output buffers directly — no per-worker
/// accumulators, no merge copy — without changing any accumulation
/// order.
pub(crate) struct SharedSlice<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    pub(crate) fn new(s: &mut [T]) -> Self {
        Self {
            ptr: s.as_mut_ptr(),
            len: s.len(),
        }
    }

    /// Reborrow sub-range `r`.
    ///
    /// Safety: concurrent callers must hold disjoint ranges, and the
    /// underlying slice must outlive the round (guaranteed when the
    /// round is submitted from the frame that built `self`).
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }

    /// Write one element (no drop of the previous value — `T: Copy` at
    /// every call site).
    ///
    /// Safety: as [`SharedSlice::slice`], per index.
    pub(crate) unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        self.ptr.add(i).write(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_dispatches_every_task_exactly_once() {
        let pool = WorkerPool::for_threads(4);
        for n_tasks in 1..=4usize {
            let hits = Mutex::new(Vec::new());
            pool.run(n_tasks, &|t| hits.lock().unwrap().push(t));
            let mut got = hits.into_inner().unwrap();
            got.sort_unstable();
            assert_eq!(got, (0..n_tasks).collect::<Vec<_>>(), "{n_tasks} tasks");
        }
        // disjoint writes through a SharedSlice land where they should
        let mut out = vec![0usize; 11];
        {
            let sh = SharedSlice::new(&mut out);
            pool.run(4, &|t| {
                let start = t * 3;
                let end = (start + 3).min(11);
                let s = unsafe { sh.slice(start..end) };
                for (i, o) in s.iter_mut().enumerate() {
                    *o = start + i + 100;
                }
            });
        }
        assert_eq!(out, (100..111).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_pool_runs_inline_and_in_order() {
        let pool = WorkerPool::for_threads(1);
        assert_eq!(pool.width(), 1);
        let order = Mutex::new(Vec::new());
        pool.run(3, &|t| order.lock().unwrap().push(t));
        assert_eq!(order.into_inner().unwrap(), vec![0, 1, 2]);
        let (a, b, c) = pool.join3(|| 1, || 2, || 3);
        assert_eq!((a, b, c), (1, 2, 3));
    }

    #[test]
    fn registry_shares_one_pool_per_width() {
        let a = WorkerPool::for_threads(3);
        let b = WorkerPool::for_threads(3);
        assert!(Arc::ptr_eq(&a, &b));
        let c = WorkerPool::for_threads(2);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.width(), 2);
        // zero clamps to the sequential pool
        assert_eq!(WorkerPool::for_threads(0).width(), 1);
    }

    #[test]
    fn join_regions_return_results_and_can_nest_gang_rounds() {
        let pool = WorkerPool::for_threads(4);
        let xs: Vec<u64> = (0..1000).collect();
        let (sum, max, min) = pool.join3(
            || xs.iter().sum::<u64>(),
            || xs.iter().copied().max().unwrap(),
            || xs.iter().copied().min().unwrap(),
        );
        assert_eq!((sum, max, min), (499_500, 999, 0));
        // a lane closure submitting gang rounds (the pipeline shape)
        let mut out = vec![0u64; 8];
        let probe = {
            let sh = SharedSlice::new(&mut out);
            let p2 = Arc::clone(&pool);
            let (_, probe) = pool.join2(
                move || {
                    p2.run(4, &|t| {
                        let s = unsafe { sh.slice(t * 2..t * 2 + 2) };
                        s[0] = t as u64;
                        s[1] = t as u64 + 10;
                    });
                },
                || 7u32,
            );
            probe
        };
        assert_eq!(probe, 7);
        assert_eq!(out, vec![0, 10, 1, 11, 2, 12, 3, 13]);
    }

    #[test]
    fn panics_propagate_and_the_pool_survives() {
        // a directly-built pool so Drop (shutdown + join) is exercised
        let pool = WorkerPool::new(3);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(3, &|t| {
                if t == 1 {
                    panic!("boom");
                }
            })
        }));
        assert!(res.is_err(), "worker panic must propagate");
        // the pool keeps working after a panicked round
        let hits = Mutex::new(0usize);
        pool.run(3, &|_| *hits.lock().unwrap() += 1);
        assert_eq!(hits.into_inner().unwrap(), 3);
        // lane panics propagate too, and lanes are released
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.join2(|| panic!("lane boom"), || 0)
        }));
        assert!(res.is_err());
        let (a, b) = pool.join2(|| 5, || 6);
        assert_eq!((a, b), (5, 6));
    }

    #[test]
    fn scratch_arena_recycles_buffers() {
        let pool = WorkerPool::new(1);
        let mut buf = pool.take_batch_buf();
        buf.reserve(1024);
        let cap = buf.capacity();
        pool.put_batch_buf(buf);
        let again = pool.take_batch_buf();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap, "capacity must be retained");
        // routed buffers round-trip as well
        let routed = pool.take_routed();
        pool.put_routed(routed);
        // the free list is bounded
        for _ in 0..16 {
            pool.put_batch_buf(Vec::new());
        }
        assert!(lock(&pool.scratch).batch_bufs.len() <= SCRATCH_CAP);
    }
}
