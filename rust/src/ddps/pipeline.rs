//! The unified, pipelined engine drive loop (see DESIGN.md "Pipelined
//! engine loop").
//!
//! Before this module the three engines ran source generation → DRM
//! decision point → [`ShuffleStage`] in strict lockstep: the sharded
//! executor and the sharded decision point idled while the source
//! materialized the next batch. The paper's DR module wins precisely by
//! keeping the decision point *off* the critical path, so the loop here
//! overlaps three lanes on the persistent worker pool's dedicated lane
//! threads ([`exec::pool`](super::exec::pool) — parked, reused across
//! every interval), gated by the same [`EngineConfig::num_threads`] knob
//! that shards the executor:
//!
//! | lane      | interval *k* runs…                 | state it touches        |
//! |-----------|------------------------------------|-------------------------|
//! | stage     | the [`ShuffleStage`] of batch *k*  | epoch snapshot, stores  |
//! | source    | materializing batch *k+1*          | the [`Source`] only     |
//! | decision  | the DRM decision point (harvest → merge → candidate) | DRM + DRWs |
//!
//! The lanes touch disjoint engine state, so they commute with the
//! lockstep order; the only synchronization is the **epoch-swap barrier**
//! between intervals. The decision lane computes a *proposal* only
//! ([`exec::proposal_point_sharded`] — candidate constructed, epoch
//! untouched); at the barrier the engine's decider rules on it
//! ([`resolve_and_adopt`]: commit or decline on the DRM, then
//! [`exec::adopt_decision`] migrates keyed state and switches the routing
//! snapshot) — stores, partitioner and epoch are only ever mutated there.
//! Decisions, verdicts, epochs, migration plans and every virtual-time
//! report column are therefore bitwise-identical to the lockstep path at
//! any thread count (pinned by `tests/prop_parallel.rs` and
//! `tests/prop_decider.rs`); the overlap shows up only in the measured
//! `wall_s` / `decision_wall_s` / `source_wall_s` columns and the
//! per-step pipeline-occupancy ratio.
//!
//! Discipline differences (who decides when) are preserved exactly:
//!
//! - **micro-batch** (`D_k A_k T_k S_k` per batch): batch *k*'s decision
//!   uses taps from batches `1..k-1`, so the loop computes batch *k+1*'s
//!   decision concurrently with stage *k* — it only needs taps `1..k`,
//!   which exist once tap *k* ran — and adopts it at the next barrier.
//!   The decision lane starts only after the prefetch lane confirms a
//!   batch *k+1* exists: no speculative harvests, so a pipelined engine
//!   left mid-stream is in exactly the state a lockstep engine would be.
//! - **streaming** (`T_k S_k C_k D_k A_k` per interval): the barrier
//!   decision needs only interval *k*'s taps (taken before the stage), so
//!   it overlaps its *own* stage; the checkpoint still snapshots
//!   post-stage, pre-migration state at the barrier.
//! - **batch jobs** ([`job_step`] / [`drive_jobs`]): one mid-map decision
//!   inside each independent job; across a round sequence the next
//!   round's records materialize while the current job's stage runs.
//!
//! The engines' `run_batch` / `run_interval` single-batch entry points
//! call [`lockstep_step`] — the same phases in lockstep order — so *all*
//! engine traffic flows through this one loop implementation.

use super::exec::pool::WorkerPool;
use super::exec::{self, Scheduling, ShuffleStage, StageReport, TapAssignment};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{
    Decider, DeciderState, DecisionProposal, DrConfig, DrMaster, DrWorker, PartitionerChoice,
    ProposalStats, Verdict,
};
use crate::partitioner::{Partitioner, PartitionerEpoch};
use crate::state::StateStore;
use crate::util::VTime;
use crate::workload::{Record, Source};
use std::sync::Arc;
use std::time::Instant;

/// The engine state the unified loop drives: the DRM and its DRWs, the
/// routing-epoch snapshot, per-partition keyed state and cumulative
/// metrics. The three engines are thin wrappers holding one of these plus
/// their discipline-specific extras (checkpoint store, counters); the
/// loop splits its fields across the pipeline lanes, which is why it is a
/// struct of independently borrowable parts rather than trait methods.
/// `Clone` snapshots the whole thing — recovery points
/// ([`crate::ddps::streaming::RecoveryPoint`]) are clones taken at the
/// epoch-swap barrier.
#[derive(Clone)]
pub struct EngineCore {
    pub(crate) cfg: EngineConfig,
    pub(crate) drm: DrMaster,
    pub(crate) workers: Vec<DrWorker>,
    pub(crate) partitioner: PartitionerEpoch,
    pub(crate) stores: Vec<StateStore>,
    pub(crate) metrics: EngineMetrics,
    /// The construction seed, retained so elasticity events can mint new
    /// DRWs deterministically ([`EngineCore::rescale`]).
    pub(crate) seed: u64,
    /// Per-partition service-time multipliers fed to every stage (scenario
    /// harness worker-slowdown events; all `1.0` ≡ no slowdown, bitwise).
    pub(crate) service_rates: Vec<f64>,
    /// The repartitioning gate ruling at every epoch-swap barrier
    /// ([`DrConfig::decider`]). Engine-resident because its state (EWMA
    /// drift history, backoff cooldown, adopt/defer tallies) must ride
    /// recovery points with the rest of the core.
    pub(crate) decider: DeciderState,
    /// Reduce-side weight of the most recent completed stage — the
    /// CostModel decider's load estimate. Always one *completed* stage
    /// behind the barrier in both lockstep and pipelined drives, so
    /// verdicts are thread-count-invariant.
    pub(crate) recent_load: f64,
    /// The persistent worker pool this engine dispatches to — pinned at
    /// construction from [`EngineConfig::num_threads`] and shared with
    /// every other engine of the same width
    /// ([`WorkerPool::for_threads`]). The threads belong to the width,
    /// not to this core's state, which is why the pool trivially
    /// survives [`EngineCore::rescale`], checkpoint clones and
    /// fail-restore.
    pub(crate) pool: Arc<WorkerPool>,
}

impl EngineCore {
    /// Build the shared core: DRM, `n_workers` DRWs (slots for chunked
    /// map taps, partitions for pinned source taps), the epoch-0 routing
    /// snapshot and one empty state store per partition.
    pub fn new(
        cfg: EngineConfig,
        dr: DrConfig,
        choice: PartitionerChoice,
        n_workers: usize,
        seed: u64,
    ) -> Self {
        cfg.validate();
        let drm = DrMaster::with_sketch(dr, choice, cfg.n_partitions, seed, cfg.sketch);
        let workers = (0..n_workers)
            .map(|w| {
                DrWorker::with_sketch(
                    drm.worker_capacity(),
                    dr.sample_rate,
                    seed ^ (w as u64) << 8,
                    cfg.sketch,
                )
            })
            .collect();
        let partitioner = drm.handle();
        let stores = (0..cfg.n_partitions).map(|_| StateStore::new()).collect();
        Self {
            service_rates: vec![1.0; cfg.n_partitions],
            decider: DeciderState::new(dr.decider),
            recent_load: 0.0,
            pool: WorkerPool::for_threads(cfg.num_threads),
            cfg,
            drm,
            workers,
            partitioner,
            stores,
            metrics: EngineMetrics::default(),
            seed,
        }
    }

    /// Scale the engine to a new partition count — the core half of an
    /// elasticity event. The DRM rebuilds its family over `n_partitions`
    /// and installs it as a cross-count epoch ([`DrMaster::rescale`]);
    /// keyed state then migrates along the derived plan exactly like an
    /// ordinary repartitioning (new partitions start empty on scale-out,
    /// departing partitions drain fully on scale-in), the DRW set resizes
    /// to `n_workers` (new workers minted from the stored seed), and
    /// service rates reset to `1.0` for new partitions. Deterministic:
    /// nothing here depends on the thread count.
    pub fn rescale(
        &mut self,
        n_partitions: usize,
        n_slots: usize,
        n_workers: usize,
    ) -> exec::MigrationReport {
        assert!(n_partitions > 0, "rescale requires at least one partition");
        let old_n = self.cfg.n_partitions;
        let swap = self.drm.rescale(n_partitions);
        // The stores slice must cover both routings while the plan runs.
        let cover = n_partitions.max(old_n);
        if self.stores.len() < cover {
            self.stores.resize_with(cover, StateStore::new);
        }
        let mig = exec::apply_epoch_swap(&self.cfg, &mut self.stores, &swap);
        // Scale-in: every key above the new count routes below it under
        // the new function, so the dropped stores are already drained.
        for s in &self.stores[n_partitions..] {
            debug_assert_eq!(s.n_keys(), 0, "scale-in left state behind");
        }
        self.stores.truncate(n_partitions);
        self.cfg.n_partitions = n_partitions;
        self.cfg.n_slots = n_slots;
        self.cfg.validate();
        if n_workers < self.workers.len() {
            self.workers.truncate(n_workers);
        } else {
            for w in self.workers.len()..n_workers {
                self.workers.push(DrWorker::with_sketch(
                    self.drm.worker_capacity(),
                    self.drm.config().sample_rate,
                    self.seed ^ (w as u64) << 8,
                    self.cfg.sketch,
                ));
            }
        }
        self.service_rates.resize(n_partitions, 1.0);
        self.partitioner = swap.to.clone();
        self.metrics.state_weight_migrated += mig.moved_weight;
        self.metrics.repartition_count += 1;
        self.metrics.migration_vtime += mig.pause;
        self.metrics.total_vtime += mig.pause;
        mig
    }

    /// Model partition `p`'s worker as `factor×` slower (`1.0` restores
    /// full speed). Feeds only virtual time; see
    /// [`ShuffleStage::with_service_rates`].
    pub fn set_service_rate(&mut self, p: usize, factor: f64) {
        assert!(p < self.cfg.n_partitions, "partition out of range");
        assert!(factor > 0.0, "service-rate factor must be positive");
        self.service_rates[p] = factor;
    }
}

/// What distinguishes the micro-batch and streaming engines inside the
/// shared loop: tap assignment, scheduling model, and on which side of
/// the stage the DRM decision point fires. (One-shot batch jobs have
/// their own single-stage step, [`job_step`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Spark-Streaming-like: the decision point fires at the batch
    /// boundary *before* the batch (histograms from earlier batches),
    /// chunked map taps, wave-scheduled stage, keyed state folded and
    /// migrated at adoption.
    MicroBatch,
    /// Flink-like: round-robin source taps, pinned backpressure stage,
    /// checkpoint and decision point at the barrier *after* the interval.
    Streaming,
}

/// Everything one step (batch / interval) of the unified loop produced;
/// the engines wrap this into their public report types.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// The shuffle-stage outcome (loads, virtual times, measured
    /// `wall_s`).
    pub stage: StageReport,
    /// Virtual makespan of the step: `migration + stage_time` for the
    /// engines, `map + replay + reduce` for batch jobs.
    pub makespan: VTime,
    /// Records in this step's batch.
    pub n_records: usize,
    /// Measured wall seconds of this step's DRM decision point.
    pub decision_wall_s: f64,
    pub repartitioned: bool,
    pub migration_pause: VTime,
    pub migrated_fraction: f64,
    /// Batch jobs only: prefix records whose assignments were recomputed.
    pub replayed_records: u64,
    pub replay_time: VTime,
    /// Measured wall seconds materializing this step's batch from its
    /// [`Source`] (0.0 when the caller handed in records directly).
    pub source_wall_s: f64,
    /// Measured wall seconds of this step's barrier-to-barrier drive
    /// span — the denominator of `pipeline_occupancy`, accumulated into
    /// [`EngineMetrics::pipeline_wall_s`].
    pub pipeline_wall_s: f64,
    /// Measured work seconds attributed to this step (stage executor +
    /// decision point + source materialization) per wall second of the
    /// step's barrier-to-barrier span: ≲ 1 on the lockstep path, > 1
    /// when the pipelined lanes overlap. Steady-state attribution — the
    /// overlapped work of step *k* partly ran inside step *k−1*'s span,
    /// so read the cumulative [`EngineMetrics::pipeline_occupancy`] for
    /// the run-level number.
    pub pipeline_occupancy: f64,
    /// Partitioner epoch in force after this step's barrier.
    pub epoch: u64,
    /// Cumulative swaps the engine's decider has adopted, after this
    /// step's barrier. (Batch jobs have no persistent decider: 0.)
    pub decisions_adopted: u64,
    /// Cumulative worthwhile proposals the decider restrained, after
    /// this step's barrier. Always 0 under the default `Naive` policy.
    pub decisions_deferred: u64,
}

/// Exactly predict what adopting `candidate` would migrate, mirroring
/// [`exec::apply_epoch_swap`]'s accumulation — stores in partition order,
/// keys in insertion order, weights summed where the candidate routes a
/// key off its current partition — so an adopted plan's measured
/// `migrated_fraction` equals this prediction bitwise (pinned in
/// `tests/prop_decider.rs`). Runs only for policies that price migration.
fn predicted_migration(stores: &[StateStore], candidate: &dyn Partitioner) -> (f64, f64) {
    let total_weight: f64 = stores.iter().map(|s| s.total_weight()).sum();
    let mut moved = 0.0;
    for (p, store) in stores.iter().enumerate() {
        for (key, st) in store.iter() {
            if candidate.partition(key) != p {
                moved += st.weight;
            }
        }
    }
    let fraction = if total_weight > 0.0 { moved / total_weight } else { 0.0 };
    (moved, fraction)
}

/// The decider gate at the epoch-swap barrier: assemble the proposal's
/// virtual statistics, let the engine's [`DeciderState`] rule, then
/// commit or decline on the DRM and adopt the resulting decision (state
/// migration + routing switch). Deferred and rejected proposals never
/// touch the epoch — the engine keeps routing through the installed
/// snapshot, which is why restraint cannot perturb determinism. Runs
/// barrier-side on every path (lockstep and both pipelined drives), with
/// the stage joined and the stores quiescent.
fn resolve_and_adopt(core: &mut EngineCore, proposal: DecisionProposal) -> exec::DecisionOutcome {
    let wall_start = Instant::now();
    // The store walk is priced work too — only the policies that weigh
    // migration pay for it.
    let (moved, fraction) = if proposal.worth_it && core.decider.policy().prices_migration() {
        let candidate = proposal
            .candidate()
            .expect("worthwhile proposals carry a candidate");
        predicted_migration(&core.stores, candidate)
    } else {
        (0.0, 0.0)
    };
    let stats = ProposalStats {
        worth_it: proposal.worth_it,
        current_max_share: proposal.current_max_share,
        planned_max_share: proposal.planned_max_share,
        heavy_mass: proposal.histogram.heavy_mass(),
        predicted_moved_weight: moved,
        predicted_migration_fraction: fraction,
        recent_load: core.recent_load,
        reduce_cost: core.cfg.reduce_cost,
        migration_cost: core.cfg.migration_cost,
    };
    let verdict = core.decider.judge(&stats);
    let mut decision = match verdict {
        Verdict::Adopt => core.drm.commit(proposal),
        Verdict::Defer | Verdict::Reject => core.drm.decline(proposal),
    };
    decision.decision_wall_s += wall_start.elapsed().as_secs_f64();
    exec::adopt_decision(
        &core.cfg,
        decision,
        &mut core.partitioner,
        Some(core.stores.as_mut_slice()),
        &mut core.metrics,
    )
}

/// Metrics accounting + report assembly shared by every path through the
/// loop — one place, so lockstep and pipelined accumulate identically.
fn assemble(
    core: &mut EngineCore,
    disc: Discipline,
    n_records: usize,
    mut stage: StageReport,
    outcome: exec::DecisionOutcome,
    source_wall_s: f64,
    span: Instant,
) -> StepReport {
    // A bare stage reports decision_wall_s = 0.0; attribute the decision
    // point the engine actually ran around it, so the stage-level column
    // and the step's agree.
    stage.decision_wall_s = outcome.decision_wall_s;
    // The next barrier's cost model sees this completed stage's load.
    core.recent_load = stage.loads.iter().sum();
    let pipeline_wall_s = span.elapsed().as_secs_f64();
    let busy = stage.wall_s + outcome.decision_wall_s + source_wall_s;
    let makespan = outcome.migration.pause + stage.stage_time;
    let m = &mut core.metrics;
    m.records_processed += n_records as u64;
    m.total_vtime += makespan;
    if disc == Discipline::MicroBatch {
        // The wave model runs map before reduce; the pinned model folds
        // source time into the stage's max() and reports no map phase.
        m.map_vtime += stage.map_time;
    }
    m.reduce_vtime += stage.reduce_time;
    m.migration_vtime += outcome.migration.pause;
    m.wall_s += stage.wall_s;
    m.decision_wall_s += outcome.decision_wall_s;
    m.source_wall_s += source_wall_s;
    m.pipeline_wall_s += pipeline_wall_s;
    StepReport {
        makespan,
        n_records,
        decision_wall_s: outcome.decision_wall_s,
        repartitioned: outcome.repartitioned,
        migration_pause: outcome.migration.pause,
        migrated_fraction: outcome.migration.migrated_fraction,
        replayed_records: 0,
        replay_time: 0.0,
        source_wall_s,
        pipeline_wall_s,
        pipeline_occupancy: if pipeline_wall_s > 0.0 {
            busy / pipeline_wall_s
        } else {
            1.0
        },
        epoch: core.partitioner.epoch(),
        decisions_adopted: core.decider.adopted(),
        decisions_deferred: core.decider.deferred(),
        stage,
    }
}

/// One batch/interval in lockstep order — the engines' single-batch
/// `run_batch` / `run_interval` entry points, and the `num_threads = 1`
/// path of [`drive`]. `after_stage` runs post-stage, pre-adoption (the
/// streaming engine checkpoints there); pass a no-op otherwise.
pub fn lockstep_step(
    core: &mut EngineCore,
    records: &[Record],
    disc: Discipline,
    source_wall_s: f64,
    span: Instant,
    after_stage: &mut dyn FnMut(&[Record], &[StateStore]),
) -> StepReport {
    let threads = core.cfg.num_threads;
    match disc {
        Discipline::MicroBatch => {
            let proposal =
                exec::proposal_point_sharded(&mut core.drm, &mut core.workers, threads);
            let outcome = resolve_and_adopt(core, proposal);
            exec::tap_records_sharded(&mut core.workers, records, TapAssignment::Chunked, threads);
            let stage = ShuffleStage::new(&core.cfg, Scheduling::Wave)
                .with_service_rates(&core.service_rates)
                .run(records, &core.partitioner, Some(core.stores.as_mut_slice()));
            after_stage(records, &core.stores);
            assemble(core, disc, records.len(), stage, outcome, source_wall_s, span)
        }
        Discipline::Streaming => {
            exec::tap_records_sharded(
                &mut core.workers,
                records,
                TapAssignment::RoundRobin,
                threads,
            );
            let stage = ShuffleStage::new(&core.cfg, Scheduling::Pinned)
                .with_service_rates(&core.service_rates)
                .run(records, &core.partitioner, Some(core.stores.as_mut_slice()));
            after_stage(records, &core.stores);
            let proposal =
                exec::proposal_point_sharded(&mut core.drm, &mut core.workers, threads);
            let outcome = resolve_and_adopt(core, proposal);
            assemble(core, disc, records.len(), stage, outcome, source_wall_s, span)
        }
    }
}

/// Drive `core` over `source` for up to `max_batches` batches of
/// `batch_size` records. With `cfg.num_threads > 1` the loop pipelines —
/// stage, prefetch and decision lanes run on the pool's parked lane
/// threads as described in the module docs; otherwise it degenerates to
/// fetch + lockstep steps. Batch buffers are recycled through the pool's
/// scratch arena on both paths. Reports are bitwise-identical either way
/// except the measured wall-clock columns. Stops early when the source
/// exhausts; the source is never pulled past `max_batches`, so a bounded
/// source can be resumed afterwards exactly where a lockstep driver
/// would have left it.
pub fn drive(
    core: &mut EngineCore,
    source: &mut dyn Source,
    batch_size: usize,
    max_batches: usize,
    disc: Discipline,
    after_stage: &mut dyn FnMut(&[Record], &[StateStore]),
) -> Vec<StepReport> {
    if max_batches == 0 {
        return Vec::new();
    }
    if core.cfg.num_threads <= 1 {
        let mut reports = Vec::new();
        let mut buf = core.pool.take_batch_buf();
        for _ in 0..max_batches {
            let span = Instant::now();
            if !source.next_batch_into(batch_size, &mut buf) {
                break;
            }
            let source_wall_s = span.elapsed().as_secs_f64();
            reports.push(lockstep_step(core, &buf, disc, source_wall_s, span, after_stage));
        }
        core.pool.put_batch_buf(buf);
        return reports;
    }
    match disc {
        Discipline::MicroBatch => {
            drive_microbatch(core, source, batch_size, max_batches, after_stage)
        }
        Discipline::Streaming => {
            drive_streaming(core, source, batch_size, max_batches, after_stage)
        }
    }
}

/// Pipelined micro-batch drive: per iteration *k*, resolve the proposal
/// precomputed for batch *k* (decider verdict + adoption), tap, then
/// overlap stage *k* with the prefetch of batch *k+1* and — once the
/// prefetch confirms it exists — batch *k+1*'s proposal point.
fn drive_microbatch(
    core: &mut EngineCore,
    source: &mut dyn Source,
    batch_size: usize,
    max_batches: usize,
    after_stage: &mut dyn FnMut(&[Record], &[StateStore]),
) -> Vec<StepReport> {
    let mut reports = Vec::new();
    let pool = Arc::clone(&core.pool);
    let mut cur: Vec<Record> = pool.take_batch_buf();
    let mut next: Vec<Record> = pool.take_batch_buf();

    // Prime the pipeline: materialize batch 1 and run its proposal point
    // (there is no previous stage to hide either behind).
    let mut span = Instant::now();
    if !source.next_batch_into(batch_size, &mut cur) {
        pool.put_batch_buf(cur);
        pool.put_batch_buf(next);
        return reports;
    }
    let mut source_wall_s = span.elapsed().as_secs_f64();
    let mut pending = Some(exec::proposal_point_sharded(
        &mut core.drm,
        &mut core.workers,
        core.cfg.num_threads,
    ));

    for k in 1..=max_batches {
        // Epoch-swap barrier: let the decider rule on batch k's proposal
        // and adopt the verdict (state migration + routing switch), then
        // tap batch k — both before the stage, as in lockstep. The lane
        // only *proposed*; commit/decline happens here, serially, so
        // verdicts see exactly the lockstep engine state.
        let proposal = pending.take().expect("pipeline invariant: proposal precomputed");
        let outcome = resolve_and_adopt(core, proposal);
        exec::tap_records_sharded(
            &mut core.workers,
            &cur,
            TapAssignment::Chunked,
            core.cfg.num_threads,
        );

        // Overlap region: stage(k) ∥ prefetch(k+1) ∥ decision(k+1).
        let want_next = k < max_batches;
        let mut have_next = false;
        let mut next_wall = 0.0;
        let stage = {
            let EngineCore {
                cfg,
                drm,
                workers,
                partitioner,
                stores,
                service_rates,
                ..
            } = &mut *core;
            let num_threads = cfg.num_threads;
            let stage_cfg: &EngineConfig = cfg;
            let epoch_snapshot: &PartitionerEpoch = partitioner;
            let rates: &[f64] = service_rates;
            let records: &[Record] = &cur;
            let stores: &mut [StateStore] = stores;
            let (stage, dec) = pool.join2(
                move || {
                    ShuffleStage::new(stage_cfg, Scheduling::Wave)
                        .with_service_rates(rates)
                        .run(records, epoch_snapshot, Some(stores))
                },
                || {
                    // Prefetch lane (this thread): materialize batch k+1.
                    if want_next {
                        let t0 = Instant::now();
                        have_next = source.next_batch_into(batch_size, &mut next);
                        next_wall = t0.elapsed().as_secs_f64();
                    }
                    // Decision lane — only once batch k+1 is known to
                    // exist, so the DRM/DRW state never runs ahead of
                    // lockstep. It computes the *proposal* only: no
                    // epoch moves off the barrier.
                    if want_next && have_next {
                        Some(exec::proposal_point_sharded(drm, workers, num_threads))
                    } else {
                        None
                    }
                },
            );
            pending = dec;
            stage
        };
        after_stage(&cur, &core.stores);
        reports.push(assemble(
            core,
            Discipline::MicroBatch,
            cur.len(),
            stage,
            outcome,
            source_wall_s,
            span,
        ));
        if !want_next || !have_next {
            break;
        }
        std::mem::swap(&mut cur, &mut next);
        source_wall_s = next_wall;
        span = Instant::now();
    }
    pool.put_batch_buf(cur);
    pool.put_batch_buf(next);
    reports
}

/// Pipelined streaming drive: per interval *k*, tap, then overlap stage
/// *k* with its *own* barrier proposal point (which needs only interval
/// *k*'s taps) and the prefetch of interval *k+1*; checkpoint, decider
/// verdict and adoption all happen at the barrier.
fn drive_streaming(
    core: &mut EngineCore,
    source: &mut dyn Source,
    batch_size: usize,
    max_batches: usize,
    after_stage: &mut dyn FnMut(&[Record], &[StateStore]),
) -> Vec<StepReport> {
    let mut reports = Vec::new();
    let pool = Arc::clone(&core.pool);
    let mut cur: Vec<Record> = pool.take_batch_buf();
    let mut next: Vec<Record> = pool.take_batch_buf();

    let mut span = Instant::now();
    if !source.next_batch_into(batch_size, &mut cur) {
        pool.put_batch_buf(cur);
        pool.put_batch_buf(next);
        return reports;
    }
    let mut source_wall_s = span.elapsed().as_secs_f64();

    for k in 1..=max_batches {
        exec::tap_records_sharded(
            &mut core.workers,
            &cur,
            TapAssignment::RoundRobin,
            core.cfg.num_threads,
        );

        // Overlap region: stage(k) ∥ decision(k) ∥ prefetch(k+1).
        let want_next = k < max_batches;
        let mut have_next = false;
        let mut next_wall = 0.0;
        let (stage, dec_res) = {
            let EngineCore {
                cfg,
                drm,
                workers,
                partitioner,
                stores,
                service_rates,
                ..
            } = &mut *core;
            let num_threads = cfg.num_threads;
            let stage_cfg: &EngineConfig = cfg;
            let epoch_snapshot: &PartitionerEpoch = partitioner;
            let rates: &[f64] = service_rates;
            let records: &[Record] = &cur;
            let stores: &mut [StateStore] = stores;
            let (stage, dec, ()) = pool.join3(
                move || {
                    ShuffleStage::new(stage_cfg, Scheduling::Pinned)
                        .with_service_rates(rates)
                        .run(records, epoch_snapshot, Some(stores))
                },
                move || exec::proposal_point_sharded(drm, workers, num_threads),
                || {
                    // Prefetch lane (this thread): materialize batch k+1.
                    if want_next {
                        let t0 = Instant::now();
                        have_next = source.next_batch_into(batch_size, &mut next);
                        next_wall = t0.elapsed().as_secs_f64();
                    }
                },
            );
            (stage, dec)
        };
        // Checkpoint sees post-stage, pre-migration state, as in lockstep
        // (the lane only proposed — it touches no stores and no epoch, so
        // computing it concurrently cannot change what the snapshot
        // contains).
        after_stage(&cur, &core.stores);
        let outcome = resolve_and_adopt(core, dec_res);
        reports.push(assemble(
            core,
            Discipline::Streaming,
            cur.len(),
            stage,
            outcome,
            source_wall_s,
            span,
        ));
        if !want_next || !have_next {
            break;
        }
        std::mem::swap(&mut cur, &mut next);
        source_wall_s = next_wall;
        span = Instant::now();
    }
    pool.put_batch_buf(cur);
    pool.put_batch_buf(next);
    reports
}

/// One one-shot batch job through the shared loop: prefix tap → mid-map
/// decision ([`exec::decide_and_adopt`], stateless — the already-evicted
/// prefix is priced as *replay*) → full-input wave stage. `overlap` runs
/// on the calling thread while the stage executes on a pool lane
/// (`num_threads > 1`); [`drive_jobs`] materializes the next round's
/// records there, standalone jobs pass a no-op.
pub fn job_step(
    cfg: &EngineConfig,
    dr: DrConfig,
    choice: PartitionerChoice,
    seed: u64,
    decision_at: f64,
    records: &[Record],
    source_wall_s: f64,
    span: Instant,
    overlap: &mut dyn FnMut(),
) -> StepReport {
    let mut drm = DrMaster::with_sketch(dr, choice, cfg.n_partitions, seed, cfg.sketch);
    let mut workers: Vec<DrWorker> = (0..cfg.n_slots)
        .map(|w| {
            DrWorker::with_sketch(
                drm.worker_capacity(),
                dr.sample_rate,
                seed ^ (w as u64) << 8,
                cfg.sketch,
            )
        })
        .collect();
    let mut partitioner = drm.handle();

    // Map phase part 1: the prefix, observed by the DRWs and already
    // evicted with the epoch-0 partitioner.
    let cut = ((records.len() as f64 * decision_at) as usize).min(records.len());
    exec::tap_records_sharded(
        &mut workers,
        &records[..cut],
        TapAssignment::Chunked,
        cfg.num_threads,
    );

    // The single mid-map decision point; adoption is stateless (batch
    // jobs have no operator state) — the prefix replays instead.
    let mut scratch = EngineMetrics::default();
    let outcome =
        exec::decide_and_adopt(cfg, &mut drm, &mut workers, &mut partitioner, None, &mut scratch);
    let (replayed_records, replay_time) = if outcome.repartitioned {
        (cut as u64, cut as f64 * cfg.replay_cost)
    } else {
        (0, 0.0)
    };

    // Map phase part 2 + shuffle + wave reduce with the (possibly new)
    // epoch; the caller's overlap lane runs alongside. A width-1 pool
    // runs stage-then-overlap inline — the old sequential order.
    let mut stage = {
        let pool = WorkerPool::for_threads(cfg.num_threads);
        let epoch_snapshot = &partitioner;
        let (stage, ()) = pool.join2(
            move || ShuffleStage::new(cfg, Scheduling::Wave).run(records, epoch_snapshot, None),
            || overlap(),
        );
        stage
    };
    stage.decision_wall_s = outcome.decision_wall_s;

    let pipeline_wall_s = span.elapsed().as_secs_f64();
    let busy = stage.wall_s + outcome.decision_wall_s + source_wall_s;
    StepReport {
        makespan: stage.map_time + replay_time + stage.reduce_time,
        n_records: records.len(),
        decision_wall_s: outcome.decision_wall_s,
        repartitioned: outcome.repartitioned,
        migration_pause: 0.0,
        migrated_fraction: 0.0,
        replayed_records,
        replay_time,
        source_wall_s,
        pipeline_wall_s,
        pipeline_occupancy: if pipeline_wall_s > 0.0 {
            busy / pipeline_wall_s
        } else {
            1.0
        },
        epoch: partitioner.epoch(),
        // One-shot jobs mint a fresh DRM per job and keep the legacy
        // eager path ([`exec::decide_and_adopt`] ≡ Naive): there is no
        // persistent decider to tally.
        decisions_adopted: 0,
        decisions_deferred: 0,
        stage,
    }
}

/// Drive a sequence of independent one-shot batch jobs over `source` —
/// one job per pulled batch, each with a fresh DRM/DRW set (§3: a batch
/// job decides once, mid-map). While job *k*'s shuffle stage runs, the
/// calling thread materializes round *k+1*'s records — the crawl-rounds
/// overlap. Like [`drive`], the source is never pulled past `max_jobs`.
pub fn drive_jobs(
    cfg: &EngineConfig,
    dr: DrConfig,
    choice: PartitionerChoice,
    seed: u64,
    decision_at: f64,
    source: &mut dyn Source,
    batch_size: usize,
    max_jobs: usize,
) -> Vec<StepReport> {
    let mut reports = Vec::new();
    if max_jobs == 0 {
        return reports;
    }
    let pool = WorkerPool::for_threads(cfg.num_threads);
    let mut cur: Vec<Record> = pool.take_batch_buf();
    let mut next: Vec<Record> = pool.take_batch_buf();
    let mut span = Instant::now();
    if !source.next_batch_into(batch_size, &mut cur) {
        pool.put_batch_buf(cur);
        pool.put_batch_buf(next);
        return reports;
    }
    let mut source_wall_s = span.elapsed().as_secs_f64();
    for k in 1..=max_jobs {
        let want_next = k < max_jobs;
        let mut have_next = false;
        let mut next_wall = 0.0;
        let step = {
            let mut overlap = || {
                if want_next {
                    let t0 = Instant::now();
                    have_next = source.next_batch_into(batch_size, &mut next);
                    next_wall = t0.elapsed().as_secs_f64();
                }
            };
            job_step(
                cfg,
                dr,
                choice,
                seed,
                decision_at,
                &cur,
                source_wall_s,
                span,
                &mut overlap,
            )
        };
        reports.push(step);
        if !want_next || !have_next {
            break;
        }
        std::mem::swap(&mut cur, &mut next);
        source_wall_s = next_wall;
        span = Instant::now();
    }
    pool.put_batch_buf(cur);
    pool.put_batch_buf(next);
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator, ReplaySource};

    fn core(n_partitions: usize, n_slots: usize, num_threads: usize, seed: u64) -> EngineCore {
        let cfg = EngineConfig {
            n_partitions,
            n_slots,
            num_threads,
            ..Default::default()
        };
        EngineCore::new(cfg, DrConfig::forced(), PartitionerChoice::Kip, n_slots, seed)
    }

    fn batches(n: usize, per: usize, seed: u64) -> Vec<Vec<Record>> {
        let mut z = Zipf::new(3_000, 1.2, seed);
        (0..n).map(|_| z.batch(per)).collect()
    }

    #[test]
    fn pipelined_drive_matches_lockstep_steps_bitwise() {
        for disc in [Discipline::MicroBatch, Discipline::Streaming] {
            let bs = batches(4, 10_000, 9);
            let mut seq = core(8, 8, 1, 9);
            let mut seq_steps = Vec::new();
            for b in &bs {
                seq_steps.push(lockstep_step(
                    &mut seq,
                    b,
                    disc,
                    0.0,
                    Instant::now(),
                    &mut |_, _| {},
                ));
            }
            for threads in [2, 4] {
                let mut par = core(8, 8, threads, 9);
                let mut src = ReplaySource::new(bs.clone());
                let par_steps =
                    drive(&mut par, &mut src, 0, bs.len(), disc, &mut |_, _| {});
                assert_eq!(par_steps.len(), seq_steps.len(), "{disc:?} {threads}");
                for (a, b) in seq_steps.iter().zip(&par_steps) {
                    let tag = format!("{disc:?} {threads} threads");
                    assert_eq!(a.n_records, b.n_records, "{tag}");
                    assert_eq!(a.repartitioned, b.repartitioned, "{tag}");
                    assert_eq!(a.epoch, b.epoch, "{tag}");
                    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{tag}");
                    assert_eq!(
                        a.migration_pause.to_bits(),
                        b.migration_pause.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(
                        a.migrated_fraction.to_bits(),
                        b.migrated_fraction.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(a.stage.record_counts, b.stage.record_counts, "{tag}");
                    for (x, y) in a.stage.loads.iter().zip(&b.stage.loads) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: loads");
                    }
                }
                assert_eq!(seq.partitioner.epoch(), par.partitioner.epoch());
                let (ws, wp) = (
                    seq.stores.iter().map(|s| s.total_weight()).sum::<f64>(),
                    par.stores.iter().map(|s| s.total_weight()).sum::<f64>(),
                );
                assert_eq!(ws.to_bits(), wp.to_bits(), "{disc:?} {threads}: state weight");
            }
        }
    }

    #[test]
    fn drive_stops_at_source_exhaustion_without_overrunning_drm_state() {
        // 3 stored batches, max 10 requested: the pipelined loop must
        // leave the engine exactly where a 3-batch lockstep loop does
        // (same decision count — no speculative harvest for a batch that
        // never arrives).
        let bs = batches(3, 5_000, 11);
        let mut seq = core(6, 6, 1, 11);
        for b in &bs {
            lockstep_step(
                &mut seq,
                b,
                Discipline::MicroBatch,
                0.0,
                Instant::now(),
                &mut |_, _| {},
            );
        }
        let mut par = core(6, 6, 3, 11);
        let mut src = ReplaySource::new(bs.clone());
        let steps = drive(
            &mut par,
            &mut src,
            0,
            10,
            Discipline::MicroBatch,
            &mut |_, _| {},
        );
        assert_eq!(steps.len(), 3);
        assert_eq!(seq.drm.decisions_made(), par.drm.decisions_made());
        assert_eq!(seq.drm.epoch(), par.drm.epoch());
        assert_eq!(seq.partitioner.epoch(), par.partitioner.epoch());
    }

    #[test]
    fn rescale_migrates_state_across_counts_and_continues() {
        let bs = batches(2, 8_000, 15);
        let mut c = core(4, 4, 1, 15);
        for b in &bs {
            lockstep_step(
                &mut c,
                b,
                Discipline::MicroBatch,
                0.0,
                Instant::now(),
                &mut |_, _| {},
            );
        }
        let weight_before: f64 = c.stores.iter().map(|s| s.total_weight()).sum();
        let epoch_before = c.partitioner.epoch();
        let mig = c.rescale(7, 7, 7);
        assert_eq!(c.cfg.n_partitions, 7);
        assert_eq!(c.stores.len(), 7);
        assert_eq!(c.workers.len(), 7);
        assert_eq!(c.service_rates, vec![1.0; 7]);
        assert_eq!(c.partitioner.epoch(), epoch_before + 1);
        assert_eq!(c.partitioner.n_partitions(), 7);
        assert!(mig.moved_weight > 0.0, "scale-out must move state");
        let weight_after: f64 = c.stores.iter().map(|s| s.total_weight()).sum();
        assert!((weight_before - weight_after).abs() < 1e-9, "state weight not conserved");
        for (p, s) in c.stores.iter().enumerate() {
            for k in s.keys() {
                assert_eq!(c.partitioner.partition(k), p, "key parked off-route");
            }
        }
        // the engine keeps running at the new count
        let step = lockstep_step(
            &mut c,
            &bs[0],
            Discipline::MicroBatch,
            0.0,
            Instant::now(),
            &mut |_, _| {},
        );
        assert_eq!(step.stage.loads.len(), 7);

        // ...and scales back in, draining the departing stores
        c.rescale(3, 3, 3);
        assert_eq!(c.stores.len(), 3);
        let weight_in: f64 = c.stores.iter().map(|s| s.total_weight()).sum();
        assert!((weight_before - weight_in).abs() < 1e-9);
    }

    #[test]
    fn cloned_core_replays_identically() {
        let bs = batches(3, 6_000, 17);
        let mut a = core(6, 6, 1, 17);
        lockstep_step(
            &mut a,
            &bs[0],
            Discipline::Streaming,
            0.0,
            Instant::now(),
            &mut |_, _| {},
        );
        let mut b = a.clone();
        for batch in &bs[1..] {
            let sa = lockstep_step(
                &mut a,
                batch,
                Discipline::Streaming,
                0.0,
                Instant::now(),
                &mut |_, _| {},
            );
            let sb = lockstep_step(
                &mut b,
                batch,
                Discipline::Streaming,
                0.0,
                Instant::now(),
                &mut |_, _| {},
            );
            assert_eq!(sa.epoch, sb.epoch);
            assert_eq!(sa.makespan.to_bits(), sb.makespan.to_bits());
            assert_eq!(sa.migrated_fraction.to_bits(), sb.migrated_fraction.to_bits());
            assert_eq!(sa.stage.record_counts, sb.stage.record_counts);
        }
        let (wa, wb) = (
            a.stores.iter().map(|s| s.total_weight()).sum::<f64>(),
            b.stores.iter().map(|s| s.total_weight()).sum::<f64>(),
        );
        assert_eq!(wa.to_bits(), wb.to_bits());
    }

    #[test]
    fn occupancy_and_source_wall_are_measured() {
        let bs = batches(3, 8_000, 13);
        let mut c = core(6, 6, 4, 13);
        let mut src = ReplaySource::new(bs);
        let steps = drive(&mut c, &mut src, 0, 3, Discipline::Streaming, &mut |_, _| {});
        for s in &steps {
            assert!(s.source_wall_s >= 0.0);
            assert!(s.pipeline_occupancy >= 0.0);
        }
        assert!(c.metrics.pipeline_wall_s > 0.0);
        assert!(c.metrics.pipeline_occupancy() >= 0.0);
    }
}
