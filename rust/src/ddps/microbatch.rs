//! Micro-batch engine — the Spark Streaming execution model (§3, §5).
//!
//! "Due to the micro-batch nature of Spark Streaming, it uses the new
//! partitioner when it generates micro-batches from the streaming DAG.
//! Spark performs state migration automatically in the shuffle phase."
//!
//! Per micro-batch:
//! 1. the DRM decision point — harvest DRW histograms from *previous*
//!    batches, possibly install a new partitioner, migrate state;
//! 2. map phase over the executor slots (DRW tap runs here);
//! 3. shuffle by the current partitioner;
//! 4. key-grouped reduce tasks, wave-scheduled over the slots (this is
//!    where skew turns into stragglers);
//! 5. fold into per-partition keyed state.

use super::{EngineConfig, EngineMetrics};
use crate::dr::{DrConfig, DrMaster, DrWorker, PartitionerChoice};
use crate::partitioner::migration_plan;
use crate::state::StateStore;
use crate::util::{load_imbalance, wave_makespan, VTime};
use crate::workload::Record;

#[derive(Debug, Clone)]
pub struct BatchReport {
    pub batch_no: u64,
    /// Wall time of this micro-batch on the virtual cluster.
    pub makespan: VTime,
    pub map_time: VTime,
    pub reduce_time: VTime,
    pub migration_time: VTime,
    /// Reduce-side weight per partition.
    pub loads: Vec<f64>,
    pub imbalance: f64,
    /// Fraction of state weight migrated at the batch boundary.
    pub migrated_fraction: f64,
    pub repartitioned: bool,
}

pub struct MicroBatchEngine {
    cfg: EngineConfig,
    drm: DrMaster,
    workers: Vec<DrWorker>,
    partitioner: crate::dr::master::PartitionerHandle,
    stores: Vec<StateStore>,
    metrics: EngineMetrics,
    batch_no: u64,
}

impl MicroBatchEngine {
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        cfg.validate();
        let drm = DrMaster::new(dr, choice, cfg.n_partitions, seed);
        let workers = (0..cfg.n_slots)
            .map(|w| DrWorker::new(drm.worker_capacity(), dr.sample_rate, seed ^ (w as u64) << 8))
            .collect();
        let partitioner = drm.handle();
        let stores = (0..cfg.n_partitions).map(|_| StateStore::new()).collect();
        Self {
            cfg,
            drm,
            workers,
            partitioner,
            stores,
            metrics: EngineMetrics::default(),
            batch_no: 0,
        }
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    pub fn stores(&self) -> &[StateStore] {
        &self.stores
    }

    pub fn drm(&self) -> &DrMaster {
        &self.drm
    }

    pub fn partitioner(&self) -> &crate::dr::master::PartitionerHandle {
        &self.partitioner
    }

    /// The DRM decision point at a micro-batch boundary. Returns the
    /// migration pause time and migrated state fraction.
    fn decision_point(&mut self) -> (VTime, f64, bool) {
        let k = self.drm.histogram_size();
        let hists: Vec<_> = self.workers.iter_mut().map(|w| w.harvest(k)).collect();
        let old = self.partitioner.clone();
        let decision = self.drm.decide(hists);
        let Some(new) = decision.new_partitioner else {
            return (0.0, 0.0, false);
        };

        // Spark migrates state "automatically in the shuffle phase": keys
        // whose partition changed drag their state. We account the cost
        // explicitly against the batch makespan.
        let mut moved_weight = 0.0;
        let mut total_weight = 0.0;
        for p in 0..self.cfg.n_partitions {
            total_weight += self.stores[p].total_weight();
        }
        let keys: Vec<Vec<crate::workload::Key>> = self
            .stores
            .iter()
            .map(|s| s.keys().collect())
            .collect();
        for (p, part_keys) in keys.into_iter().enumerate() {
            let plan = migration_plan(old.as_dyn(), new.as_dyn(), part_keys.into_iter());
            for (key, from, to) in plan {
                debug_assert_eq!(from, p);
                if let Some(st) = self.stores[from].extract(key) {
                    moved_weight += st.weight;
                    self.stores[to].install(key, st);
                }
            }
        }
        self.partitioner = new;
        let pause = moved_weight * self.cfg.migration_cost;
        let frac = if total_weight > 0.0 {
            moved_weight / total_weight
        } else {
            0.0
        };
        self.metrics.state_weight_migrated += moved_weight;
        self.metrics.repartition_count += 1;
        (pause, frac, true)
    }

    /// Run one micro-batch through map → shuffle → reduce → state.
    pub fn run_batch(&mut self, records: &[Record]) -> BatchReport {
        self.batch_no += 1;

        // 1. decision point (uses histograms gathered in earlier batches)
        let (migration_time, migrated_fraction, repartitioned) = self.decision_point();

        // 2. map phase: records split evenly over slots; the DRW tap runs
        //    on the map path.
        let per_slot = records.len().div_ceil(self.cfg.n_slots);
        for (i, r) in records.iter().enumerate() {
            self.workers[i / per_slot.max(1)].observe(r.key, r.weight);
        }
        let map_time = per_slot as f64 * (self.cfg.map_cost + self.cfg.shuffle_cost);

        // 3. shuffle: route by the current partitioner; gather loads.
        let mut loads = vec![0.0f64; self.cfg.n_partitions];
        for r in records {
            let p = self.partitioner.partition(r.key);
            loads[p] += r.weight;
            // 5. fold state as the reducer would
            self.stores[p].fold_count(r.key, r.weight);
        }

        // 4. reduce phase: one task per partition (spill model applies),
        //    wave-scheduled.
        let total_load: f64 = loads.iter().sum();
        let task_costs: Vec<VTime> = loads
            .iter()
            .map(|l| self.cfg.reduce_task_time(*l, total_load))
            .collect();
        let reduce_time = wave_makespan(&task_costs, self.cfg.n_slots);

        let makespan = migration_time + map_time + reduce_time;
        self.metrics.records_processed += records.len() as u64;
        self.metrics.total_vtime += makespan;
        self.metrics.map_vtime += map_time;
        self.metrics.reduce_vtime += reduce_time;
        self.metrics.migration_vtime += migration_time;

        BatchReport {
            batch_no: self.batch_no,
            makespan,
            map_time,
            reduce_time,
            migration_time,
            imbalance: load_imbalance(&loads),
            loads,
            migrated_fraction,
            repartitioned,
        }
    }

    /// Total state weight currently held (all partitions).
    pub fn total_state_weight(&self) -> f64 {
        self.stores.iter().map(|s| s.total_weight()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator};

    fn cfg(n_partitions: usize, n_slots: usize) -> EngineConfig {
        EngineConfig {
            n_partitions,
            n_slots,
            ..Default::default()
        }
    }

    #[test]
    fn first_batch_never_repartitions() {
        let mut e = MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 1);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let r = e.run_batch(&z.batch(50_000));
        assert!(!r.repartitioned, "no histogram exists before batch 1");
        assert_eq!(r.batch_no, 1);
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn skewed_stream_repartitions_and_improves() {
        let mut e = MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 2);
        let mut z = Zipf::new(50_000, 1.4, 2);
        let r1 = e.run_batch(&z.batch(100_000));
        let r2 = e.run_batch(&z.batch(100_000));
        assert!(r2.repartitioned, "skew must trigger DR at batch 2");
        assert!(r2.imbalance < r1.imbalance, "{} vs {}", r2.imbalance, r1.imbalance);
        assert!(r2.migrated_fraction > 0.0, "stateful keys must migrate");
        assert_eq!(e.metrics().repartition_count, 1);
    }

    #[test]
    fn dr_off_is_stable_hash() {
        let mut e = MicroBatchEngine::new(cfg(8, 4), DrConfig::disabled(), PartitionerChoice::Uhp, 3);
        let mut z = Zipf::new(50_000, 1.4, 3);
        let r1 = e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(!r1.repartitioned && !r2.repartitioned);
        assert_eq!(e.metrics().repartition_count, 0);
        assert!((r1.imbalance - r2.imbalance).abs() < 0.2, "hash is stationary");
    }

    #[test]
    fn state_is_conserved_across_migration() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 4);
        let mut z = Zipf::new(1_000, 1.3, 4);
        let mut expected = 0.0;
        for _ in 0..5 {
            let batch = z.batch(10_000);
            expected += batch.iter().map(|r| r.weight).sum::<f64>();
            e.run_batch(&batch);
        }
        assert!(
            (e.total_state_weight() - expected).abs() < 1e-6,
            "state lost or duplicated: {} vs {expected}",
            e.total_state_weight()
        );
    }

    #[test]
    fn loads_sum_to_batch_weight() {
        let mut e = MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 5);
        let mut z = Zipf::new(10_000, 1.0, 5);
        let batch = z.batch(20_000);
        let w: f64 = batch.iter().map(|r| r.weight).sum();
        let r = e.run_batch(&batch);
        assert!((r.loads.iter().sum::<f64>() - w).abs() < 1e-6);
    }

    #[test]
    fn migration_pause_accounted() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 6);
        let mut z = Zipf::new(5_000, 1.5, 6);
        e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(r2.repartitioned);
        assert!(r2.migration_time > 0.0);
        assert!(e.metrics().migration_vtime > 0.0);
    }

    #[test]
    fn more_slots_shorter_batches() {
        let mut slow = MicroBatchEngine::new(cfg(16, 2), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut fast = MicroBatchEngine::new(cfg(16, 16), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut z = Zipf::new(10_000, 1.0, 7);
        let batch = z.batch(100_000);
        let t_slow = slow.run_batch(&batch).makespan;
        let t_fast = fast.run_batch(&batch).makespan;
        assert!(t_fast < t_slow, "{t_fast} vs {t_slow}");
    }
}
