//! Micro-batch engine — the Spark Streaming execution model (§3, §5).
//!
//! "Due to the micro-batch nature of Spark Streaming, it uses the new
//! partitioner when it generates micro-batches from the streaming DAG.
//! Spark performs state migration automatically in the shuffle phase."
//!
//! Thin wrapper over the unified drive loop ([`pipeline`],
//! [`Discipline::MicroBatch`]). Per micro-batch:
//! 1. the DRM decision point — harvest DRW histograms from *previous*
//!    batches; an accepted decision bumps the partitioner epoch, and the
//!    migration plan derived from the epoch swap moves keyed state;
//! 2. map-tap over the executor slots (chunked assignment);
//! 3. one wave-scheduled [`ShuffleStage`](super::ShuffleStage) (shuffle →
//!    keyed reduce → state fold; this is where skew turns into
//!    stragglers).
//!
//! [`MicroBatchEngine::run_batch`] performs exactly that lockstep step on
//! a caller-supplied batch; [`MicroBatchEngine::run_stream`] pulls the
//! batches from a [`Source`] and — with `num_threads > 1` — overlaps the
//! source prefetch and the next batch's decision point with the running
//! stage, with bitwise-identical reports.

use super::pipeline::{self, Discipline, EngineCore, StepReport};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{DeciderState, DrConfig, DrMaster, PartitionerChoice};
use crate::partitioner::PartitionerEpoch;
use crate::state::StateStore;
use crate::util::VTime;
use crate::workload::{Record, Source};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BatchReport {
    pub batch_no: u64,
    /// Wall time of this micro-batch on the virtual cluster.
    pub makespan: VTime,
    pub map_time: VTime,
    pub reduce_time: VTime,
    pub migration_time: VTime,
    /// Measured wall-clock seconds of the stage executor (sequential or
    /// sharded per `num_threads`); `makespan` above is the virtual model.
    pub wall_s: f64,
    /// Measured wall-clock seconds of this batch boundary's DRM decision
    /// point (sharded DRW harvests + histogram tree-merge + candidate
    /// construction). Compare against `wall_s` for the decision-latency
    /// budget (EXPERIMENTS.md "Decision latency").
    pub decision_wall_s: f64,
    /// Measured wall-clock seconds materializing this batch from its
    /// [`Source`] — the pipelined loop's prefetch lane. 0.0 when the
    /// batch was handed to [`MicroBatchEngine::run_batch`] directly.
    pub source_wall_s: f64,
    /// Measured work seconds attributed to this batch (stage + decision
    /// point + source) per wall second of its drive-loop span: ≲ 1 in
    /// lockstep, > 1 when the pipelined lanes overlap (EXPERIMENTS.md
    /// "Pipeline overlap").
    pub pipeline_occupancy: f64,
    /// Reduce-side weight per partition.
    pub loads: Vec<f64>,
    pub imbalance: f64,
    /// Fraction of state weight migrated at the batch boundary.
    pub migrated_fraction: f64,
    pub repartitioned: bool,
    /// Partitioner epoch this batch was routed under.
    pub epoch: u64,
    /// Cumulative swaps the decider adopted, after this boundary.
    pub decisions_adopted: u64,
    /// Cumulative worthwhile proposals the decider restrained, after
    /// this boundary.
    pub decisions_deferred: u64,
}

pub struct MicroBatchEngine {
    core: EngineCore,
    batch_no: u64,
}

impl MicroBatchEngine {
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        let n_workers = cfg.n_slots;
        Self {
            core: EngineCore::new(cfg, dr, choice, n_workers, seed),
            batch_no: 0,
        }
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.core.metrics
    }

    pub fn stores(&self) -> &[StateStore] {
        &self.core.stores
    }

    pub fn drm(&self) -> &DrMaster {
        &self.core.drm
    }

    /// The engine-resident decider (policy + adopt/defer tallies).
    pub fn decider(&self) -> &DeciderState {
        &self.core.decider
    }

    /// The routing epoch currently in force.
    pub fn partitioner(&self) -> &PartitionerEpoch {
        &self.core.partitioner
    }

    /// The current epoch number (observable in every [`BatchReport`]).
    pub fn epoch(&self) -> u64 {
        self.core.partitioner.epoch()
    }

    fn report(&self, step: StepReport) -> BatchReport {
        BatchReport {
            batch_no: self.batch_no,
            makespan: step.makespan,
            map_time: step.stage.map_time,
            reduce_time: step.stage.reduce_time,
            migration_time: step.migration_pause,
            wall_s: step.stage.wall_s,
            decision_wall_s: step.decision_wall_s,
            source_wall_s: step.source_wall_s,
            pipeline_occupancy: step.pipeline_occupancy,
            imbalance: step.stage.imbalance,
            loads: step.stage.loads,
            migrated_fraction: step.migrated_fraction,
            repartitioned: step.repartitioned,
            epoch: step.epoch,
            decisions_adopted: step.decisions_adopted,
            decisions_deferred: step.decisions_deferred,
        }
    }

    /// Run one micro-batch through decision point → map-tap → shuffle →
    /// reduce → state: one lockstep step of the unified loop.
    pub fn run_batch(&mut self, records: &[Record]) -> BatchReport {
        self.batch_no += 1;
        let step = pipeline::lockstep_step(
            &mut self.core,
            records,
            Discipline::MicroBatch,
            0.0,
            Instant::now(),
            &mut |_, _| {},
        );
        self.report(step)
    }

    /// Drive the engine over `source` for up to `max_batches` batches of
    /// `batch_size` records (stopping early if the source exhausts).
    /// With `num_threads > 1` the loop pipelines: while batch *k*'s
    /// stage runs, the source materializes batch *k+1* and the DRM
    /// computes batch *k+1*'s decision ([`pipeline::drive`]) — reports
    /// stay bitwise-identical to a `run_batch` loop over the same
    /// batches; only the measured wall-clock columns change.
    pub fn run_stream(
        &mut self,
        source: &mut dyn Source,
        batch_size: usize,
        max_batches: usize,
    ) -> Vec<BatchReport> {
        let steps = pipeline::drive(
            &mut self.core,
            source,
            batch_size,
            max_batches,
            Discipline::MicroBatch,
            &mut |_, _| {},
        );
        steps
            .into_iter()
            .map(|step| {
                self.batch_no += 1;
                self.report(step)
            })
            .collect()
    }

    /// Total state weight currently held (all partitions).
    pub fn total_state_weight(&self) -> f64 {
        self.core.stores.iter().map(|s| s.total_weight()).sum()
    }

    /// Elasticity event: scale to `n_partitions` reduce partitions over
    /// `n_slots` executor slots (DRWs track the slot count, as at
    /// construction). Keyed state migrates along the cross-count epoch
    /// diff ([`EngineCore::rescale`]); the pause lands in the metrics'
    /// migration accounting.
    pub fn scale_to(
        &mut self,
        n_partitions: usize,
        n_slots: usize,
    ) -> super::exec::MigrationReport {
        self.core.rescale(n_partitions, n_slots, n_slots)
    }

    /// Failure-model event: partition `p`'s reducers run `factor×` slower;
    /// `1.0` restores full speed. Virtual-time only.
    pub fn set_service_rate(&mut self, p: usize, factor: f64) {
        self.core.set_service_rate(p, factor);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator};

    fn cfg(n_partitions: usize, n_slots: usize) -> EngineConfig {
        EngineConfig {
            n_partitions,
            n_slots,
            ..Default::default()
        }
    }

    #[test]
    fn first_batch_never_repartitions() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 1);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let r = e.run_batch(&z.batch(50_000));
        assert!(!r.repartitioned, "no histogram exists before batch 1");
        assert_eq!(r.batch_no, 1);
        assert_eq!(r.epoch, 0);
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn skewed_stream_repartitions_and_improves() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 2);
        let mut z = Zipf::new(50_000, 1.4, 2);
        let r1 = e.run_batch(&z.batch(100_000));
        let r2 = e.run_batch(&z.batch(100_000));
        assert!(r2.repartitioned, "skew must trigger DR at batch 2");
        assert!(r2.imbalance < r1.imbalance, "{} vs {}", r2.imbalance, r1.imbalance);
        assert!(r2.migrated_fraction > 0.0, "stateful keys must migrate");
        assert_eq!(e.metrics().repartition_count, 1);
        assert_eq!(r1.epoch, 0);
        assert_eq!(r2.epoch, 1, "repartitioning must bump the epoch");
        assert_eq!(e.epoch(), 1);
    }

    #[test]
    fn dr_off_is_stable_hash() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::disabled(), PartitionerChoice::Uhp, 3);
        let mut z = Zipf::new(50_000, 1.4, 3);
        let r1 = e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(!r1.repartitioned && !r2.repartitioned);
        assert_eq!(e.metrics().repartition_count, 0);
        assert_eq!(r2.epoch, 0, "no epoch bumps without DR");
        assert!((r1.imbalance - r2.imbalance).abs() < 0.2, "hash is stationary");
    }

    #[test]
    fn state_is_conserved_across_migration() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 4);
        let mut z = Zipf::new(1_000, 1.3, 4);
        let mut expected = 0.0;
        for _ in 0..5 {
            let batch = z.batch(10_000);
            expected += batch.iter().map(|r| r.weight).sum::<f64>();
            e.run_batch(&batch);
        }
        assert!(
            (e.total_state_weight() - expected).abs() < 1e-6,
            "state lost or duplicated: {} vs {expected}",
            e.total_state_weight()
        );
    }

    #[test]
    fn loads_sum_to_batch_weight() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 5);
        let mut z = Zipf::new(10_000, 1.0, 5);
        let batch = z.batch(20_000);
        let w: f64 = batch.iter().map(|r| r.weight).sum();
        let r = e.run_batch(&batch);
        assert!((r.loads.iter().sum::<f64>() - w).abs() < 1e-6);
    }

    #[test]
    fn migration_pause_accounted() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 6);
        let mut z = Zipf::new(5_000, 1.5, 6);
        e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(r2.repartitioned);
        assert!(r2.migration_time > 0.0);
        assert!(e.metrics().migration_vtime > 0.0);
    }

    #[test]
    fn more_slots_shorter_batches() {
        let mut slow =
            MicroBatchEngine::new(cfg(16, 2), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut fast =
            MicroBatchEngine::new(cfg(16, 16), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut z = Zipf::new(10_000, 1.0, 7);
        let batch = z.batch(100_000);
        let t_slow = slow.run_batch(&batch).makespan;
        let t_fast = fast.run_batch(&batch).makespan;
        assert!(t_fast < t_slow, "{t_fast} vs {t_slow}");
    }

    #[test]
    fn forced_updates_bump_epoch_every_batch() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 8);
        let mut z = Zipf::new(5_000, 1.2, 8);
        for expect in 1..=4u64 {
            let r = e.run_batch(&z.batch(10_000));
            assert_eq!(r.epoch, expect, "forced update must bump the epoch each batch");
        }
        assert_eq!(e.drm().epoch(), 4);
    }

    #[test]
    fn run_stream_equals_run_batch_loop() {
        // run_stream over a generator must reproduce a manual
        // z.batch → run_batch loop exactly (records, reports, state).
        let mut a = MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 9);
        let mut za = Zipf::new(20_000, 1.2, 9);
        let manual: Vec<BatchReport> = (0..4).map(|_| a.run_batch(&za.batch(30_000))).collect();

        let mut b = MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 9);
        let mut zb = Zipf::new(20_000, 1.2, 9);
        let streamed = b.run_stream(&mut zb, 30_000, 4);

        assert_eq!(streamed.len(), manual.len());
        for (x, y) in manual.iter().zip(&streamed) {
            assert_eq!(x.batch_no, y.batch_no);
            assert_eq!(x.repartitioned, y.repartitioned);
            assert_eq!(x.epoch, y.epoch);
            assert_eq!(x.makespan.to_bits(), y.makespan.to_bits());
            assert_eq!(x.imbalance.to_bits(), y.imbalance.to_bits());
        }
        assert_eq!(
            a.total_state_weight().to_bits(),
            b.total_state_weight().to_bits()
        );
        assert_eq!(
            a.metrics().total_vtime.to_bits(),
            b.metrics().total_vtime.to_bits()
        );
        assert!(b.metrics().source_wall_s >= 0.0);
        assert!(b.metrics().pipeline_occupancy() > 0.0);
    }

    #[test]
    fn scale_to_conserves_state_and_continues() {
        let mut e =
            MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 11);
        let mut z = Zipf::new(5_000, 1.2, 11);
        let mut expected = 0.0;
        for _ in 0..2 {
            let b = z.batch(20_000);
            expected += b.iter().map(|r| r.weight).sum::<f64>();
            e.run_batch(&b);
        }
        let epoch = e.epoch();
        e.scale_to(9, 12);
        assert_eq!(e.partitioner().n_partitions(), 9);
        assert_eq!(e.epoch(), epoch + 1);
        assert!((e.total_state_weight() - expected).abs() < 1e-6);
        let b = z.batch(20_000);
        expected += b.iter().map(|r| r.weight).sum::<f64>();
        let r = e.run_batch(&b);
        assert_eq!(r.loads.len(), 9);
        e.scale_to(4, 4);
        assert!((e.total_state_weight() - expected).abs() < 1e-6);
        e.run_batch(&z.batch(20_000));
    }

    #[test]
    fn run_stream_stops_on_bounded_source() {
        use crate::workload::Bounded;
        let mut e = MicroBatchEngine::new(cfg(4, 4), DrConfig::default(), PartitionerChoice::Kip, 10);
        let src = Zipf::new(1_000, 1.0, 10);
        let mut bounded = Bounded::new(src, 25_000);
        let reports = e.run_stream(&mut bounded, 10_000, 100);
        assert_eq!(reports.len(), 3, "10k + 10k + 5k then exhaustion");
        assert_eq!(e.metrics().records_processed, 25_000);
    }
}
