//! Micro-batch engine — the Spark Streaming execution model (§3, §5).
//!
//! "Due to the micro-batch nature of Spark Streaming, it uses the new
//! partitioner when it generates micro-batches from the streaming DAG.
//! Spark performs state migration automatically in the shuffle phase."
//!
//! Thin driver over the shared [`ShuffleStage`] core. Per micro-batch:
//! 1. the DRM decision point — harvest DRW histograms from *previous*
//!    batches; an accepted decision bumps the partitioner epoch, and the
//!    migration plan derived from the epoch swap moves keyed state;
//! 2. map-tap over the executor slots (chunked assignment);
//! 3. one wave-scheduled [`ShuffleStage`] (shuffle → keyed reduce → state
//!    fold; this is where skew turns into stragglers).

use super::exec::{self, Scheduling, ShuffleStage, TapAssignment};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{DrConfig, DrMaster, DrWorker, PartitionerChoice};
use crate::partitioner::PartitionerEpoch;
use crate::state::StateStore;
use crate::util::VTime;
use crate::workload::Record;

#[derive(Debug, Clone)]
pub struct BatchReport {
    pub batch_no: u64,
    /// Wall time of this micro-batch on the virtual cluster.
    pub makespan: VTime,
    pub map_time: VTime,
    pub reduce_time: VTime,
    pub migration_time: VTime,
    /// Measured wall-clock seconds of the stage executor (sequential or
    /// sharded per `num_threads`); `makespan` above is the virtual model.
    pub wall_s: f64,
    /// Measured wall-clock seconds of this batch boundary's DRM decision
    /// point (sharded DRW harvests + histogram tree-merge + candidate
    /// construction). Compare against `wall_s` for the decision-latency
    /// budget (EXPERIMENTS.md "Decision latency").
    pub decision_wall_s: f64,
    /// Reduce-side weight per partition.
    pub loads: Vec<f64>,
    pub imbalance: f64,
    /// Fraction of state weight migrated at the batch boundary.
    pub migrated_fraction: f64,
    pub repartitioned: bool,
    /// Partitioner epoch this batch was routed under.
    pub epoch: u64,
}

pub struct MicroBatchEngine {
    cfg: EngineConfig,
    drm: DrMaster,
    workers: Vec<DrWorker>,
    partitioner: PartitionerEpoch,
    stores: Vec<StateStore>,
    metrics: EngineMetrics,
    batch_no: u64,
}

impl MicroBatchEngine {
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        cfg.validate();
        let drm = DrMaster::new(dr, choice, cfg.n_partitions, seed);
        let workers = (0..cfg.n_slots)
            .map(|w| DrWorker::new(drm.worker_capacity(), dr.sample_rate, seed ^ (w as u64) << 8))
            .collect();
        let partitioner = drm.handle();
        let stores = (0..cfg.n_partitions).map(|_| StateStore::new()).collect();
        Self {
            cfg,
            drm,
            workers,
            partitioner,
            stores,
            metrics: EngineMetrics::default(),
            batch_no: 0,
        }
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    pub fn stores(&self) -> &[StateStore] {
        &self.stores
    }

    pub fn drm(&self) -> &DrMaster {
        &self.drm
    }

    /// The routing epoch currently in force.
    pub fn partitioner(&self) -> &PartitionerEpoch {
        &self.partitioner
    }

    /// The current epoch number (observable in every [`BatchReport`]).
    pub fn epoch(&self) -> u64 {
        self.partitioner.epoch()
    }

    /// The DRM decision point at a micro-batch boundary. Returns the
    /// migration pause time, migrated state fraction, whether a swap was
    /// adopted, and the measured decision wall clock.
    fn decision_point(&mut self) -> (VTime, f64, bool, f64) {
        let decision =
            exec::decision_point_sharded(&mut self.drm, &mut self.workers, self.cfg.num_threads);
        let decision_wall_s = decision.decision_wall_s;
        let Some(swap) = decision.swap else {
            return (0.0, 0.0, false, decision_wall_s);
        };

        // Spark migrates state "automatically in the shuffle phase": keys
        // whose partition changed drag their state. The plan derives from
        // the epoch swap; the cost is charged against the batch makespan.
        let mig = exec::adopt_swap(
            &self.cfg,
            &mut self.stores,
            &mut self.partitioner,
            &mut self.metrics,
            &swap,
        );
        (mig.pause, mig.migrated_fraction, true, decision_wall_s)
    }

    /// Run one micro-batch through map → shuffle → reduce → state.
    pub fn run_batch(&mut self, records: &[Record]) -> BatchReport {
        self.batch_no += 1;

        // 1. decision point (uses histograms gathered in earlier batches)
        let (migration_time, migrated_fraction, repartitioned, decision_wall_s) =
            self.decision_point();

        // 2. map-tap: records split evenly over slots; the DRW tap runs on
        //    the map path and rides the executor's sharding.
        exec::tap_records_sharded(
            &mut self.workers,
            records,
            TapAssignment::Chunked,
            self.cfg.num_threads,
        );

        // 3. the shared stage: shuffle by the current epoch, wave-scheduled
        //    keyed reduce (spill model applies), state folded per partition.
        let stage = ShuffleStage::new(&self.cfg, Scheduling::Wave).run(
            records,
            &self.partitioner,
            Some(self.stores.as_mut_slice()),
        );

        let makespan = migration_time + stage.stage_time;
        self.metrics.records_processed += records.len() as u64;
        self.metrics.total_vtime += makespan;
        self.metrics.map_vtime += stage.map_time;
        self.metrics.reduce_vtime += stage.reduce_time;
        self.metrics.migration_vtime += migration_time;
        self.metrics.wall_s += stage.wall_s;
        self.metrics.decision_wall_s += decision_wall_s;

        BatchReport {
            batch_no: self.batch_no,
            makespan,
            map_time: stage.map_time,
            reduce_time: stage.reduce_time,
            migration_time,
            wall_s: stage.wall_s,
            decision_wall_s,
            imbalance: stage.imbalance,
            loads: stage.loads,
            migrated_fraction,
            repartitioned,
            epoch: self.partitioner.epoch(),
        }
    }

    /// Total state weight currently held (all partitions).
    pub fn total_state_weight(&self) -> f64 {
        self.stores.iter().map(|s| s.total_weight()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator};

    fn cfg(n_partitions: usize, n_slots: usize) -> EngineConfig {
        EngineConfig {
            n_partitions,
            n_slots,
            ..Default::default()
        }
    }

    #[test]
    fn first_batch_never_repartitions() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 1);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let r = e.run_batch(&z.batch(50_000));
        assert!(!r.repartitioned, "no histogram exists before batch 1");
        assert_eq!(r.batch_no, 1);
        assert_eq!(r.epoch, 0);
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn skewed_stream_repartitions_and_improves() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 8), DrConfig::default(), PartitionerChoice::Kip, 2);
        let mut z = Zipf::new(50_000, 1.4, 2);
        let r1 = e.run_batch(&z.batch(100_000));
        let r2 = e.run_batch(&z.batch(100_000));
        assert!(r2.repartitioned, "skew must trigger DR at batch 2");
        assert!(r2.imbalance < r1.imbalance, "{} vs {}", r2.imbalance, r1.imbalance);
        assert!(r2.migrated_fraction > 0.0, "stateful keys must migrate");
        assert_eq!(e.metrics().repartition_count, 1);
        assert_eq!(r1.epoch, 0);
        assert_eq!(r2.epoch, 1, "repartitioning must bump the epoch");
        assert_eq!(e.epoch(), 1);
    }

    #[test]
    fn dr_off_is_stable_hash() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::disabled(), PartitionerChoice::Uhp, 3);
        let mut z = Zipf::new(50_000, 1.4, 3);
        let r1 = e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(!r1.repartitioned && !r2.repartitioned);
        assert_eq!(e.metrics().repartition_count, 0);
        assert_eq!(r2.epoch, 0, "no epoch bumps without DR");
        assert!((r1.imbalance - r2.imbalance).abs() < 0.2, "hash is stationary");
    }

    #[test]
    fn state_is_conserved_across_migration() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 4);
        let mut z = Zipf::new(1_000, 1.3, 4);
        let mut expected = 0.0;
        for _ in 0..5 {
            let batch = z.batch(10_000);
            expected += batch.iter().map(|r| r.weight).sum::<f64>();
            e.run_batch(&batch);
        }
        assert!(
            (e.total_state_weight() - expected).abs() < 1e-6,
            "state lost or duplicated: {} vs {expected}",
            e.total_state_weight()
        );
    }

    #[test]
    fn loads_sum_to_batch_weight() {
        let mut e =
            MicroBatchEngine::new(cfg(8, 4), DrConfig::default(), PartitionerChoice::Kip, 5);
        let mut z = Zipf::new(10_000, 1.0, 5);
        let batch = z.batch(20_000);
        let w: f64 = batch.iter().map(|r| r.weight).sum();
        let r = e.run_batch(&batch);
        assert!((r.loads.iter().sum::<f64>() - w).abs() < 1e-6);
    }

    #[test]
    fn migration_pause_accounted() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 6);
        let mut z = Zipf::new(5_000, 1.5, 6);
        e.run_batch(&z.batch(50_000));
        let r2 = e.run_batch(&z.batch(50_000));
        assert!(r2.repartitioned);
        assert!(r2.migration_time > 0.0);
        assert!(e.metrics().migration_vtime > 0.0);
    }

    #[test]
    fn more_slots_shorter_batches() {
        let mut slow =
            MicroBatchEngine::new(cfg(16, 2), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut fast =
            MicroBatchEngine::new(cfg(16, 16), DrConfig::disabled(), PartitionerChoice::Uhp, 7);
        let mut z = Zipf::new(10_000, 1.0, 7);
        let batch = z.batch(100_000);
        let t_slow = slow.run_batch(&batch).makespan;
        let t_fast = fast.run_batch(&batch).makespan;
        assert!(t_fast < t_slow, "{t_fast} vs {t_slow}");
    }

    #[test]
    fn forced_updates_bump_epoch_every_batch() {
        let mut e = MicroBatchEngine::new(cfg(6, 6), DrConfig::forced(), PartitionerChoice::Kip, 8);
        let mut z = Zipf::new(5_000, 1.2, 8);
        for expect in 1..=4u64 {
            let r = e.run_batch(&z.batch(10_000));
            assert_eq!(r.epoch, expect, "forced update must bump the epoch each batch");
        }
        assert_eq!(e.drm().epoch(), 4);
    }
}
