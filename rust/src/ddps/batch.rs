//! One-shot batch jobs with mapper-buffer interception and **replay** —
//! the Spark batch execution model of §3 and the web-crawl rounds of §6.
//!
//! "When we repartition a batch job, we may have to buffer the Mapper
//! output after processing and use the new partitioning function as soon
//! as it becomes ready. Ideally, we intervene while the data is still in
//! the buffers and before it is evicted to the disk at the Mappers. Since
//! during eviction, the system distributes data by using the actual hash
//! partitioner, changing the partitioning function after data has been
//! written to disk requires recomputing partition assignments (replay)
//! using the new partitioner. Hence a batch job is repartitioned only in
//! an early stage of the execution so that the cost of replay does not
//! exceed the expected gains of better partitioning."
//!
//! Thin wrapper over the unified loop's one-shot job step
//! ([`pipeline::job_step`]): one stage per job, with a single mid-map
//! decision point whose epoch swap prices the replay of already-evicted
//! prefix records. [`BatchJob::run_stream`] drives a *sequence* of jobs
//! (crawl rounds) over a [`Source`], materializing round *k+1*'s records
//! while round *k*'s stage runs ([`pipeline::drive_jobs`]).

use super::pipeline::{self, StepReport};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::VTime;
use crate::workload::{Record, Source};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct JobReport {
    /// Total job time on the virtual cluster (map + replay + reduce).
    pub makespan: VTime,
    pub map_time: VTime,
    pub reduce_time: VTime,
    /// Replay pause: records already evicted with the old partitioner that
    /// had their assignments recomputed.
    pub replay_time: VTime,
    /// Measured wall-clock seconds of the stage executor (sequential or
    /// sharded per `num_threads`); `makespan` above is the virtual model.
    pub wall_s: f64,
    /// Measured wall-clock seconds of the single mid-map DRM decision
    /// point (sharded DRW harvests + histogram tree-merge + candidate
    /// construction). Compare against `wall_s` for the decision-latency
    /// budget (EXPERIMENTS.md "Decision latency").
    pub decision_wall_s: f64,
    /// Measured wall-clock seconds materializing this job's records from
    /// its [`Source`] — the round-pipeline's prefetch lane. 0.0 when the
    /// records were handed to [`BatchJob::run`] directly.
    pub source_wall_s: f64,
    /// Measured wall-clock seconds of this job's drive span (the
    /// occupancy denominator); [`BatchJob::aggregate`] sums it so the
    /// aggregated [`EngineMetrics::pipeline_occupancy`] works for round
    /// sequences, which have no persistent engine to accumulate it.
    pub pipeline_wall_s: f64,
    /// Measured work seconds attributed to this job (stage + decision
    /// point + source) per wall second of its drive span: ≲ 1 for a
    /// standalone job, > 1 when [`BatchJob::run_stream`] overlaps the
    /// next round's materialization (EXPERIMENTS.md "Pipeline overlap").
    pub pipeline_occupancy: f64,
    pub replayed_records: u64,
    pub repartitioned: bool,
    pub loads: Vec<f64>,
    /// Records (not weight) per partition — Fig 7's "record balance".
    pub record_counts: Vec<u64>,
    pub imbalance: f64,
    /// Partitioner epoch the job finished under (0 = initial, 1 = the
    /// mid-map repartitioning fired).
    pub epoch: u64,
}

/// A one-shot key-grouped batch job (map → shuffle → reduce).
pub struct BatchJob {
    cfg: EngineConfig,
    dr: DrConfig,
    choice: PartitionerChoice,
    /// Fraction of the input after which the DRM makes its (single)
    /// repartitioning decision — "an early stage of the execution".
    pub decision_at: f64,
    seed: u64,
}

impl BatchJob {
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        cfg.validate();
        Self {
            cfg,
            dr,
            choice,
            decision_at: 0.1,
            seed,
        }
    }

    fn report(step: StepReport) -> JobReport {
        JobReport {
            makespan: step.makespan,
            map_time: step.stage.map_time,
            reduce_time: step.stage.reduce_time,
            replay_time: step.replay_time,
            wall_s: step.stage.wall_s,
            decision_wall_s: step.decision_wall_s,
            source_wall_s: step.source_wall_s,
            pipeline_wall_s: step.pipeline_wall_s,
            pipeline_occupancy: step.pipeline_occupancy,
            replayed_records: step.replayed_records,
            repartitioned: step.repartitioned,
            imbalance: step.stage.imbalance,
            loads: step.stage.loads,
            record_counts: step.stage.record_counts,
            epoch: step.epoch,
        }
    }

    /// Execute the job. The DRM decision fires once, after `decision_at`
    /// of the input has been mapped; earlier output is replayed. One
    /// one-shot step of the unified loop ([`pipeline::job_step`]).
    pub fn run(&self, records: &[Record]) -> JobReport {
        Self::report(pipeline::job_step(
            &self.cfg,
            self.dr,
            self.choice,
            self.seed,
            self.decision_at,
            records,
            0.0,
            Instant::now(),
            &mut || {},
        ))
    }

    /// Run a sequence of independent jobs — one per batch pulled from
    /// `source` (e.g. a [`CrawlSource`]'s rounds), up to `max_jobs`. With
    /// `num_threads > 1`, round *k+1*'s records materialize on the
    /// prefetch lane while round *k*'s shuffle stage runs; each job's
    /// report is bitwise-identical to a standalone [`BatchJob::run`] on
    /// the same records.
    ///
    /// [`CrawlSource`]: crate::workload::webcrawl::CrawlSource
    pub fn run_stream(
        &self,
        source: &mut dyn Source,
        batch_size: usize,
        max_jobs: usize,
    ) -> Vec<JobReport> {
        pipeline::drive_jobs(
            &self.cfg,
            self.dr,
            self.choice,
            self.seed,
            self.decision_at,
            source,
            batch_size,
            max_jobs,
        )
        .into_iter()
        .map(Self::report)
        .collect()
    }

    /// Convenience: run with DR on and off, returning (with, without).
    pub fn compare(&self, records: &[Record]) -> (JobReport, JobReport) {
        let with = self.run(records);
        let without = BatchJob {
            dr: DrConfig::disabled(),
            choice: PartitionerChoice::Uhp,
            ..*self
        }
        .run(records);
        (with, without)
    }

    /// Aggregate a sequence of job reports (e.g. crawl rounds).
    pub fn aggregate(reports: &[JobReport]) -> EngineMetrics {
        let mut m = EngineMetrics::default();
        for r in reports {
            m.total_vtime += r.makespan;
            m.map_vtime += r.map_time;
            m.reduce_vtime += r.reduce_time;
            m.replay_vtime += r.replay_time;
            m.wall_s += r.wall_s;
            m.decision_wall_s += r.decision_wall_s;
            m.source_wall_s += r.source_wall_s;
            m.pipeline_wall_s += r.pipeline_wall_s;
            m.repartition_count += r.repartitioned as u64;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{zipf::Zipf, Generator, ReplaySource};

    fn cfg() -> EngineConfig {
        EngineConfig {
            n_partitions: 16,
            n_slots: 8,
            ..Default::default()
        }
    }

    #[test]
    fn dr_improves_skewed_batch_job() {
        // exp 1.0: many medium-weight keys — the regime where DR shines
        // (Fig 4: "DR is beneficial for the moderate values of the Zipf
        // exponent"). partitions <= slots, like the paper's 35-over-40
        // setup: a single reduce wave, the straggler gates the stage.
        let mut z = Zipf::new(100_000, 1.0, 1);
        let recs = z.batch(200_000);
        let cfg = EngineConfig {
            n_partitions: 16,
            n_slots: 16,
            ..Default::default()
        };
        let job = BatchJob::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 1);
        let (with, without) = job.compare(&recs);
        assert!(with.repartitioned);
        assert!(!without.repartitioned);
        assert_eq!(with.epoch, 1, "repartitioning must be visible as epoch 1");
        assert_eq!(without.epoch, 0);
        assert!(
            with.imbalance < without.imbalance,
            "{} vs {}",
            with.imbalance,
            without.imbalance
        );
        assert!(
            with.makespan < without.makespan,
            "{} vs {}",
            with.makespan,
            without.makespan
        );
    }

    #[test]
    fn replay_cost_charged_only_on_repartition() {
        let mut z = Zipf::new(50_000, 1.4, 2);
        let recs = z.batch(100_000);
        let job = BatchJob::new(cfg(), DrConfig::default(), PartitionerChoice::Kip, 2);
        let r = job.run(&recs);
        assert!(r.repartitioned);
        assert_eq!(r.replayed_records, 10_000); // decision_at = 0.1
        assert!(r.replay_time > 0.0);
        assert_eq!(r.epoch, 1);

        let mut z0 = Zipf::new(50_000, 0.0, 3); // uniform: no repartition
        let recs0 = z0.batch(100_000);
        let r0 = job.run(&recs0);
        assert!(!r0.repartitioned);
        assert_eq!(r0.replayed_records, 0);
        assert_eq!(r0.replay_time, 0.0);
        assert_eq!(r0.epoch, 0);
    }

    #[test]
    fn record_counts_match_total() {
        let mut z = Zipf::new(10_000, 1.0, 4);
        let recs = z.batch(50_000);
        let job = BatchJob::new(cfg(), DrConfig::default(), PartitionerChoice::Kip, 4);
        let r = job.run(&recs);
        assert_eq!(r.record_counts.iter().sum::<u64>(), 50_000);
    }

    #[test]
    fn later_decision_point_replays_more() {
        let mut z = Zipf::new(50_000, 1.4, 5);
        let recs = z.batch(100_000);
        // forced updates: this test is about replay accounting, not the
        // decision threshold
        let mut early = BatchJob::new(cfg(), DrConfig::forced(), PartitionerChoice::Kip, 5);
        early.decision_at = 0.05;
        let mut late = BatchJob::new(cfg(), DrConfig::forced(), PartitionerChoice::Kip, 5);
        late.decision_at = 0.5;
        let re = early.run(&recs);
        let rl = late.run(&recs);
        assert!(re.repartitioned && rl.repartitioned);
        assert!(rl.replayed_records > re.replayed_records);
        assert!(rl.replay_time > re.replay_time);
    }

    #[test]
    fn aggregate_sums_rounds() {
        let mut z = Zipf::new(10_000, 1.3, 6);
        let job = BatchJob::new(cfg(), DrConfig::default(), PartitionerChoice::Kip, 6);
        let reports: Vec<JobReport> = (0..3).map(|_| job.run(&z.batch(50_000))).collect();
        let m = BatchJob::aggregate(&reports);
        let sum: f64 = reports.iter().map(|r| r.makespan).sum();
        assert!((m.total_vtime - sum).abs() < 1e-9);
    }

    #[test]
    fn run_stream_jobs_match_standalone_runs() {
        // each job in a pipelined round sequence must be bitwise-identical
        // to a standalone run on the same records, at any thread count.
        let mut z = Zipf::new(20_000, 1.2, 7);
        let rounds: Vec<Vec<crate::workload::Record>> =
            (0..3).map(|_| z.batch(40_000)).collect();
        let job = BatchJob::new(cfg(), DrConfig::default(), PartitionerChoice::Kip, 7);
        let standalone: Vec<JobReport> = rounds.iter().map(|r| job.run(r)).collect();
        for threads in [1usize, 4] {
            let par_job = BatchJob::new(
                EngineConfig {
                    num_threads: threads,
                    ..cfg()
                },
                DrConfig::default(),
                PartitionerChoice::Kip,
                7,
            );
            let mut src = ReplaySource::new(rounds.clone());
            let streamed = par_job.run_stream(&mut src, 0, 10);
            assert_eq!(streamed.len(), standalone.len(), "{threads} threads");
            for (a, b) in standalone.iter().zip(&streamed) {
                assert_eq!(a.repartitioned, b.repartitioned, "{threads} threads");
                assert_eq!(a.epoch, b.epoch, "{threads} threads");
                assert_eq!(a.replayed_records, b.replayed_records, "{threads} threads");
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{threads} threads");
                assert_eq!(a.record_counts, b.record_counts, "{threads} threads");
            }
        }
    }
}
