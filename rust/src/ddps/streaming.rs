//! Continuous streaming engine — the Flink execution model (§3, §5).
//!
//! Long-running source and reducer tasks connected by keyed channels.
//! Unlike the micro-batch engine there is no wave scheduling: each
//! partition is pinned to a long-running task ("Flink deploys long-running
//! tasks that cannot be scheduled one after another", which is why
//! over-partitioning does not help in Flink — §5). Throughput is gated by
//! the *bottleneck* reducer through backpressure; repartitioning happens
//! at checkpoint barriers, riding the Asynchronous Distributed Snapshot
//! mechanism, with explicit operator-state migration.
//!
//! Thin wrapper over the unified drive loop ([`pipeline`],
//! [`Discipline::Streaming`]) in the
//! [`Scheduling::Pinned`](super::Scheduling::Pinned) discipline; epoch
//! swaps are aligned with the checkpoint barrier, and the state-migration
//! plan derives from the epoch diff. [`StreamingEngine::run_interval`]
//! processes one caller-supplied interval in lockstep;
//! [`StreamingEngine::run_stream`] pulls intervals from a [`Source`] and
//! — with `num_threads > 1` — overlaps the source prefetch and the
//! barrier's decision point with the running stage, with
//! bitwise-identical reports.

use super::pipeline::{self, Discipline, EngineCore, StepReport};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{DeciderState, DrConfig, DrMaster, PartitionerChoice};
use crate::partitioner::PartitionerEpoch;
use crate::state::{Checkpoint, CheckpointStore, StateStore};
use crate::util::VTime;
use crate::workload::{Record, Source};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct IntervalReport {
    pub interval_no: u64,
    /// Virtual time this checkpoint interval took to process.
    pub elapsed: VTime,
    /// Measured wall-clock seconds of the stage executor (sequential or
    /// sharded per `num_threads`); `elapsed` above is the virtual model.
    pub wall_s: f64,
    /// Measured wall-clock seconds of this barrier's DRM decision point
    /// (sharded DRW harvests + histogram tree-merge + candidate
    /// construction). Compare against `wall_s` for the decision-latency
    /// budget (EXPERIMENTS.md "Decision latency").
    pub decision_wall_s: f64,
    /// Measured wall-clock seconds materializing this interval from its
    /// [`Source`] — the pipelined loop's prefetch lane. 0.0 when the
    /// interval was handed to [`StreamingEngine::run_interval`] directly.
    pub source_wall_s: f64,
    /// Measured work seconds attributed to this interval (stage +
    /// decision point + source) per wall second of its drive-loop span:
    /// ≲ 1 in lockstep, > 1 when the pipelined lanes overlap
    /// (EXPERIMENTS.md "Pipeline overlap").
    pub pipeline_occupancy: f64,
    /// Records per virtual second in this interval.
    pub throughput: f64,
    pub imbalance: f64,
    pub migrated_fraction: f64,
    pub migration_pause: VTime,
    pub repartitioned: bool,
    /// Utilisation of the bottleneck reducer relative to the mean — how
    /// hard backpressure bites.
    pub bottleneck_ratio: f64,
    /// Partitioner epoch in force after this interval's barrier.
    pub epoch: u64,
    /// Reduce-side weight per partition in this interval — what the
    /// scenario harness's backlog model consumes (per-partition arrivals
    /// vs the service capacity of each pinned reducer).
    pub loads: Vec<f64>,
    /// Cumulative swaps the decider adopted, after this barrier.
    pub decisions_adopted: u64,
    /// Cumulative worthwhile proposals the decider restrained, after
    /// this barrier.
    pub decisions_deferred: u64,
}

pub struct StreamingEngine {
    core: EngineCore,
    checkpoints: CheckpointStore,
    interval_no: u64,
    vtime: VTime,
}

/// A full post-barrier snapshot of a [`StreamingEngine`] — everything the
/// engine will read on any later interval (core engine state, checkpoint
/// history, counters). Taken between intervals, where no stage, decision
/// or migration is in flight, so an engine restored from one and fed the
/// same intervals produces bitwise-identical reports, epochs and state as
/// the engine that never failed: every computation downstream of this
/// state is deterministic (see DESIGN.md "Scenario harness"). The
/// per-barrier [`Checkpoint`]s remain the *pre-migration* task snapshots
/// the paper's snapshot mechanism takes; a recovery point is the
/// orthogonal whole-engine image used by crash-restore.
#[derive(Clone)]
pub struct RecoveryPoint {
    core: EngineCore,
    checkpoints: CheckpointStore,
    interval_no: u64,
    vtime: VTime,
}

impl RecoveryPoint {
    /// Interval count at the barrier this point was taken.
    pub fn interval_no(&self) -> u64 {
        self.interval_no
    }

    /// Virtual time accumulated up to the barrier.
    pub fn vtime(&self) -> VTime {
        self.vtime
    }

    /// Total state weight captured in the snapshot.
    pub fn total_state_weight(&self) -> f64 {
        self.core.stores.iter().map(|s| s.total_weight()).sum()
    }
}

impl StreamingEngine {
    /// In the streaming engine every partition is a pinned long-running
    /// task, so `cfg.n_slots` must be ≥ `cfg.n_partitions` (the paper runs
    /// them equal: parallelism 14 / 28). One DRW per source task.
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        assert!(
            cfg.n_slots >= cfg.n_partitions,
            "streaming tasks are pinned: need slots >= partitions"
        );
        let n_workers = cfg.n_partitions;
        Self {
            core: EngineCore::new(cfg, dr, choice, n_workers, seed),
            checkpoints: CheckpointStore::new(3),
            interval_no: 0,
            vtime: 0.0,
        }
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.core.metrics
    }

    pub fn vtime(&self) -> VTime {
        self.vtime
    }

    /// Checkpoint intervals processed so far.
    pub fn interval_no(&self) -> u64 {
        self.interval_no
    }

    pub fn stores(&self) -> &[StateStore] {
        &self.core.stores
    }

    pub fn checkpoints(&self) -> &CheckpointStore {
        &self.checkpoints
    }

    pub fn drm(&self) -> &DrMaster {
        &self.core.drm
    }

    /// The engine-resident decider (policy, EWMA drift history, backoff
    /// counter, adopt/defer tallies) — observable so recovery tests can
    /// pin that restores bring it back bitwise.
    pub fn decider(&self) -> &DeciderState {
        &self.core.decider
    }

    /// The routing epoch currently in force.
    pub fn partitioner(&self) -> &PartitionerEpoch {
        &self.core.partitioner
    }

    /// The current epoch number (observable in every [`IntervalReport`]).
    pub fn epoch(&self) -> u64 {
        self.core.partitioner.epoch()
    }

    pub fn total_state_weight(&self) -> f64 {
        self.core.stores.iter().map(|s| s.total_weight()).sum()
    }

    fn report(&self, step: StepReport) -> IntervalReport {
        IntervalReport {
            interval_no: self.interval_no,
            elapsed: step.makespan,
            wall_s: step.stage.wall_s,
            decision_wall_s: step.decision_wall_s,
            source_wall_s: step.source_wall_s,
            pipeline_occupancy: step.pipeline_occupancy,
            throughput: if step.makespan > 0.0 {
                step.n_records as f64 / step.makespan
            } else {
                0.0
            },
            imbalance: step.stage.imbalance,
            migrated_fraction: step.migrated_fraction,
            migration_pause: step.migration_pause,
            repartitioned: step.repartitioned,
            bottleneck_ratio: step.stage.bottleneck_ratio,
            epoch: step.epoch,
            loads: step.stage.loads,
            decisions_adopted: step.decisions_adopted,
            decisions_deferred: step.decisions_deferred,
        }
    }

    /// Process one checkpoint interval of records, then take the barrier:
    /// snapshot, DRM decision, possible epoch swap + state migration —
    /// one lockstep step of the unified loop.
    pub fn run_interval(&mut self, records: &[Record]) -> IntervalReport {
        self.interval_no += 1;
        let id = self.interval_no;
        let checkpoints = &mut self.checkpoints;
        let step = pipeline::lockstep_step(
            &mut self.core,
            records,
            Discipline::Streaming,
            0.0,
            Instant::now(),
            &mut |recs, stores| {
                checkpoints.save(Checkpoint {
                    id,
                    records_at: vec![recs.len() as u64; stores.len()],
                    stores: stores.to_vec(),
                });
            },
        );
        self.vtime += step.makespan;
        self.report(step)
    }

    /// Drive the engine over `source` for up to `max_intervals`
    /// checkpoint intervals of `batch_size` records (stopping early if
    /// the source exhausts). With `num_threads > 1` the loop pipelines:
    /// while interval *k*'s stage drains, the source materializes
    /// interval *k+1* and the barrier's decision point harvests and
    /// merges concurrently ([`pipeline::drive`]) — reports stay
    /// bitwise-identical to a `run_interval` loop over the same
    /// intervals; only the measured wall-clock columns change.
    pub fn run_stream(
        &mut self,
        source: &mut dyn Source,
        batch_size: usize,
        max_intervals: usize,
    ) -> Vec<IntervalReport> {
        let mut id = self.interval_no;
        let checkpoints = &mut self.checkpoints;
        let steps = pipeline::drive(
            &mut self.core,
            source,
            batch_size,
            max_intervals,
            Discipline::Streaming,
            &mut |recs, stores| {
                id += 1;
                checkpoints.save(Checkpoint {
                    id,
                    records_at: vec![recs.len() as u64; stores.len()],
                    stores: stores.to_vec(),
                });
            },
        );
        steps
            .into_iter()
            .map(|step| {
                self.interval_no += 1;
                self.vtime += step.makespan;
                self.report(step)
            })
            .collect()
    }

    /// Snapshot the whole engine at the current barrier (between
    /// intervals). Restoring from it ([`StreamingEngine::restore`]) and
    /// replaying the same intervals reproduces an uninterrupted run
    /// bitwise — the crash-recovery contract the scenario harness
    /// verifies.
    pub fn recovery_point(&self) -> RecoveryPoint {
        RecoveryPoint {
            core: self.core.clone(),
            checkpoints: self.checkpoints.clone(),
            interval_no: self.interval_no,
            vtime: self.vtime,
        }
    }

    /// Rebuild an engine from a [`RecoveryPoint`] — the crash-restore
    /// path. The restored engine continues from the snapshot's barrier:
    /// interval numbering, checkpoint ids, epochs and virtual time all
    /// resume exactly where the snapshot left them; the lost gap is
    /// replayed from a retained source (e.g.
    /// [`ReplaySource`](crate::workload::ReplaySource)).
    pub fn restore(point: &RecoveryPoint) -> Self {
        Self {
            core: point.core.clone(),
            checkpoints: point.checkpoints.clone(),
            interval_no: point.interval_no,
            vtime: point.vtime,
        }
    }

    /// Elasticity event: scale to `n_partitions` pinned tasks (slots and
    /// DRWs track the partition count, preserving the slots ≥ partitions
    /// invariant). Keyed state migrates along the cross-count epoch diff
    /// at a barrier, and the migration pause is charged against the
    /// engine's virtual timeline like any repartitioning pause. Returns
    /// the interval-report-shaped migration numbers so scenario tables can
    /// surface the event.
    pub fn scale_to(&mut self, n_partitions: usize) -> super::exec::MigrationReport {
        let mig = self.core.rescale(n_partitions, n_partitions, n_partitions);
        self.vtime += mig.pause;
        mig
    }

    /// Failure-model event: partition `p`'s long-running task runs
    /// `factor×` slower (backpressure tightens around it); `1.0` restores
    /// full speed. Virtual-time only — see
    /// [`ShuffleStage`](super::ShuffleStage)`::with_service_rates`.
    pub fn set_service_rate(&mut self, p: usize, factor: f64) {
        self.core.set_service_rate(p, factor);
    }

    /// The service-rate multipliers currently in force, one per partition.
    pub fn service_rates(&self) -> &[f64] {
        &self.core.service_rates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{lfm::Lfm, zipf::Zipf, Generator};

    fn cfg(n: usize) -> EngineConfig {
        EngineConfig {
            n_partitions: n,
            n_slots: n,
            task_overhead: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_improves_after_repartition() {
        let mut e = StreamingEngine::new(cfg(8), DrConfig::default(), PartitionerChoice::Kip, 1);
        let mut z = Zipf::new(50_000, 1.3, 1);
        let r1 = e.run_interval(&z.batch(100_000));
        let r2 = e.run_interval(&z.batch(100_000));
        assert!(r2.repartitioned || r1.repartitioned);
        let r3 = e.run_interval(&z.batch(100_000));
        assert!(
            r3.throughput > r1.throughput,
            "{} vs {}",
            r3.throughput,
            r1.throughput
        );
        assert!(r3.imbalance < r1.imbalance);
        assert!(r3.epoch >= 1, "repartitioning must be visible as an epoch bump");
    }

    #[test]
    fn state_conserved_across_barriers() {
        let mut e = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 2);
        let mut l = Lfm::with_defaults(2);
        let mut expected = 0.0;
        for _ in 0..6 {
            let batch = l.next_batch(20_000);
            expected += batch.iter().map(|r| r.weight).sum::<f64>();
            e.run_interval(&batch);
        }
        assert!((e.total_state_weight() - expected).abs() < 1e-6);
        assert!(e.metrics().repartition_count >= 4);
    }

    #[test]
    fn checkpoints_snapshot_pre_migration_state() {
        let mut e = StreamingEngine::new(cfg(4), DrConfig::forced(), PartitionerChoice::Kip, 3);
        let mut z = Zipf::new(1_000, 1.2, 3);
        e.run_interval(&z.batch(10_000));
        let w_after_1 = e.total_state_weight();
        let cp = e.checkpoints().latest().unwrap();
        assert_eq!(cp.id, 1);
        assert!((cp.total_state_weight() - w_after_1).abs() < 1e-9);
    }

    #[test]
    fn backpressure_ratio_tracks_skew() {
        let mut skewed =
            StreamingEngine::new(cfg(8), DrConfig::disabled(), PartitionerChoice::Uhp, 4);
        let mut uniform =
            StreamingEngine::new(cfg(8), DrConfig::disabled(), PartitionerChoice::Uhp, 4);
        let mut zs = Zipf::new(50_000, 1.8, 4);
        let mut zu = Zipf::new(50_000, 0.0, 5);
        let rs = skewed.run_interval(&zs.batch(50_000));
        let ru = uniform.run_interval(&zu.batch(50_000));
        assert!(rs.bottleneck_ratio > ru.bottleneck_ratio + 0.5);
    }

    #[test]
    #[should_panic]
    fn overpartitioning_streaming_rejected() {
        let bad = EngineConfig {
            n_partitions: 16,
            n_slots: 8,
            ..Default::default()
        };
        StreamingEngine::new(bad, DrConfig::default(), PartitionerChoice::Kip, 5);
    }

    #[test]
    fn vtime_accumulates() {
        let mut e = StreamingEngine::new(cfg(4), DrConfig::default(), PartitionerChoice::Kip, 6);
        let mut z = Zipf::new(10_000, 1.0, 6);
        let a = e.run_interval(&z.batch(10_000));
        let b = e.run_interval(&z.batch(10_000));
        assert!((e.vtime() - (a.elapsed + b.elapsed)).abs() < 1e-12);
    }

    #[test]
    fn barrier_aligned_epochs_are_monotone() {
        let mut e = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 7);
        let mut z = Zipf::new(5_000, 1.3, 7);
        let mut last = 0;
        for i in 1..=4u64 {
            let r = e.run_interval(&z.batch(10_000));
            assert_eq!(r.interval_no, i);
            assert!(r.epoch > last, "forced barrier update must bump the epoch");
            last = r.epoch;
        }
        assert_eq!(e.epoch(), last);
    }

    #[test]
    fn restored_engine_reproduces_uninterrupted_run() {
        let mk = || StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 9);
        let mut z = Zipf::new(5_000, 1.2, 9);
        let batches: Vec<Vec<Record>> = (0..6).map(|_| z.batch(12_000)).collect();

        // uninterrupted reference run
        let mut gold = mk();
        let gold_reports: Vec<IntervalReport> =
            batches.iter().map(|b| gold.run_interval(b)).collect();

        // crashed run: snapshot after interval 3, "lose" intervals 4..,
        // restore and replay them
        let mut live = mk();
        for b in &batches[..3] {
            live.run_interval(b);
        }
        let point = live.recovery_point();
        assert_eq!(point.interval_no(), 3);
        live.run_interval(&batches[3]); // work lost in the crash
        drop(live);
        let mut resumed = StreamingEngine::restore(&point);
        let resumed_reports: Vec<IntervalReport> =
            batches[3..].iter().map(|b| resumed.run_interval(b)).collect();

        for (g, r) in gold_reports[3..].iter().zip(&resumed_reports) {
            assert_eq!(g.interval_no, r.interval_no);
            assert_eq!(g.epoch, r.epoch);
            assert_eq!(g.repartitioned, r.repartitioned);
            assert_eq!(g.elapsed.to_bits(), r.elapsed.to_bits());
            assert_eq!(g.throughput.to_bits(), r.throughput.to_bits());
            assert_eq!(g.imbalance.to_bits(), r.imbalance.to_bits());
            assert_eq!(g.migrated_fraction.to_bits(), r.migrated_fraction.to_bits());
        }
        assert_eq!(gold.vtime().to_bits(), resumed.vtime().to_bits());
        assert_eq!(gold.epoch(), resumed.epoch());
        assert_eq!(
            gold.total_state_weight().to_bits(),
            resumed.total_state_weight().to_bits()
        );
        assert_eq!(
            gold.checkpoints().latest().unwrap().id,
            resumed.checkpoints().latest().unwrap().id
        );
    }

    #[test]
    fn scale_to_migrates_and_keeps_running() {
        let mut e = StreamingEngine::new(cfg(4), DrConfig::forced(), PartitionerChoice::Kip, 10);
        let mut z = Zipf::new(8_000, 1.2, 10);
        e.run_interval(&z.batch(20_000));
        let w = e.total_state_weight();
        let epoch = e.epoch();
        let vt = e.vtime();
        let mig = e.scale_to(7);
        assert!(mig.moved_weight > 0.0);
        assert_eq!(e.partitioner().n_partitions(), 7);
        assert_eq!(e.stores().len(), 7);
        assert_eq!(e.epoch(), epoch + 1, "scale event is an epoch bump");
        assert!((e.total_state_weight() - w).abs() < 1e-9);
        assert!(e.vtime() > vt, "migration pause charged to the timeline");
        let r = e.run_interval(&z.batch(20_000));
        assert_eq!(r.interval_no, 2);
        // scale back in below the original count
        e.scale_to(2);
        assert_eq!(e.stores().len(), 2);
        let recomputed: f64 = e.stores().iter().map(|s| s.total_weight()).sum();
        assert!((e.total_state_weight() - recomputed).abs() < 1e-12);
        e.run_interval(&z.batch(20_000));
    }

    #[test]
    fn slowdown_stretches_interval_and_restore_speed_recovers() {
        let mk = || StreamingEngine::new(cfg(4), DrConfig::disabled(), PartitionerChoice::Uhp, 11);
        let mut z = Zipf::new(10_000, 0.2, 11);
        let batch = z.batch(30_000);
        let mut plain = mk();
        let rp = plain.run_interval(&batch);
        let mut slowed = mk();
        slowed.set_service_rate(1, 4.0);
        assert_eq!(slowed.service_rates()[1], 4.0);
        let rs = slowed.run_interval(&batch);
        assert!(rs.elapsed > rp.elapsed, "slowdown must stretch the interval");
        assert!(rs.bottleneck_ratio > rp.bottleneck_ratio);
        slowed.set_service_rate(1, 1.0);
        let rr = slowed.run_interval(&batch);
        assert_eq!(rr.elapsed.to_bits(), rp.elapsed.to_bits(), "restored speed must match");
    }

    #[test]
    fn run_stream_equals_run_interval_loop_with_drift() {
        // run_stream over a drifting LFM source must reproduce a manual
        // next_batch → run_interval loop exactly, checkpoints included.
        let mut a = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 8);
        let mut la = Lfm::with_defaults(8);
        let manual: Vec<IntervalReport> =
            (0..4).map(|_| a.run_interval(&la.next_batch(15_000))).collect();

        let mut b = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 8);
        let mut src = Lfm::with_defaults(8).drifting();
        let streamed = b.run_stream(&mut src, 15_000, 4);

        assert_eq!(streamed.len(), manual.len());
        for (x, y) in manual.iter().zip(&streamed) {
            assert_eq!(x.interval_no, y.interval_no);
            assert_eq!(x.repartitioned, y.repartitioned);
            assert_eq!(x.epoch, y.epoch);
            assert_eq!(x.elapsed.to_bits(), y.elapsed.to_bits());
            assert_eq!(x.migrated_fraction.to_bits(), y.migrated_fraction.to_bits());
        }
        assert_eq!(a.vtime().to_bits(), b.vtime().to_bits());
        assert_eq!(a.checkpoints().len(), b.checkpoints().len());
        let (ca, cb) = (
            a.checkpoints().latest().unwrap(),
            b.checkpoints().latest().unwrap(),
        );
        assert_eq!(ca.id, cb.id);
        assert_eq!(
            ca.total_state_weight().to_bits(),
            cb.total_state_weight().to_bits()
        );
    }
}
