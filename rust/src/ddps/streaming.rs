//! Continuous streaming engine — the Flink execution model (§3, §5).
//!
//! Long-running source and reducer tasks connected by keyed channels.
//! Unlike the micro-batch engine there is no wave scheduling: each
//! partition is pinned to a long-running task ("Flink deploys long-running
//! tasks that cannot be scheduled one after another", which is why
//! over-partitioning does not help in Flink — §5). Throughput is gated by
//! the *bottleneck* reducer through backpressure; repartitioning happens
//! at checkpoint barriers, riding the Asynchronous Distributed Snapshot
//! mechanism, with explicit operator-state migration.
//!
//! Thin driver over the shared [`ShuffleStage`] core in its
//! [`Scheduling::Pinned`] discipline; epoch swaps are aligned with the
//! checkpoint barrier, and the state-migration plan derives from the
//! epoch diff.

use super::exec::{self, Scheduling, ShuffleStage, TapAssignment};
use super::{EngineConfig, EngineMetrics};
use crate::dr::{DrConfig, DrMaster, DrWorker, PartitionerChoice};
use crate::partitioner::PartitionerEpoch;
use crate::state::{Checkpoint, CheckpointStore, StateStore};
use crate::util::VTime;
use crate::workload::Record;

#[derive(Debug, Clone)]
pub struct IntervalReport {
    pub interval_no: u64,
    /// Virtual time this checkpoint interval took to process.
    pub elapsed: VTime,
    /// Measured wall-clock seconds of the stage executor (sequential or
    /// sharded per `num_threads`); `elapsed` above is the virtual model.
    pub wall_s: f64,
    /// Measured wall-clock seconds of this barrier's DRM decision point
    /// (sharded DRW harvests + histogram tree-merge + candidate
    /// construction). Compare against `wall_s` for the decision-latency
    /// budget (EXPERIMENTS.md "Decision latency").
    pub decision_wall_s: f64,
    /// Records per virtual second in this interval.
    pub throughput: f64,
    pub imbalance: f64,
    pub migrated_fraction: f64,
    pub migration_pause: VTime,
    pub repartitioned: bool,
    /// Utilisation of the bottleneck reducer relative to the mean — how
    /// hard backpressure bites.
    pub bottleneck_ratio: f64,
    /// Partitioner epoch in force after this interval's barrier.
    pub epoch: u64,
}

pub struct StreamingEngine {
    cfg: EngineConfig,
    drm: DrMaster,
    /// One DRW per source task (sources tap keys before the key-grouping).
    workers: Vec<DrWorker>,
    partitioner: PartitionerEpoch,
    stores: Vec<StateStore>,
    checkpoints: CheckpointStore,
    metrics: EngineMetrics,
    interval_no: u64,
    vtime: VTime,
}

impl StreamingEngine {
    /// In the streaming engine every partition is a pinned long-running
    /// task, so `cfg.n_slots` must be ≥ `cfg.n_partitions` (the paper runs
    /// them equal: parallelism 14 / 28).
    pub fn new(cfg: EngineConfig, dr: DrConfig, choice: PartitionerChoice, seed: u64) -> Self {
        cfg.validate();
        assert!(
            cfg.n_slots >= cfg.n_partitions,
            "streaming tasks are pinned: need slots >= partitions"
        );
        let drm = DrMaster::new(dr, choice, cfg.n_partitions, seed);
        let workers = (0..cfg.n_partitions)
            .map(|w| DrWorker::new(drm.worker_capacity(), dr.sample_rate, seed ^ (w as u64) << 8))
            .collect();
        let partitioner = drm.handle();
        let stores = (0..cfg.n_partitions).map(|_| StateStore::new()).collect();
        Self {
            cfg,
            drm,
            workers,
            partitioner,
            stores,
            checkpoints: CheckpointStore::new(3),
            metrics: EngineMetrics::default(),
            interval_no: 0,
            vtime: 0.0,
        }
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    pub fn vtime(&self) -> VTime {
        self.vtime
    }

    pub fn stores(&self) -> &[StateStore] {
        &self.stores
    }

    pub fn checkpoints(&self) -> &CheckpointStore {
        &self.checkpoints
    }

    pub fn drm(&self) -> &DrMaster {
        &self.drm
    }

    /// The routing epoch currently in force.
    pub fn partitioner(&self) -> &PartitionerEpoch {
        &self.partitioner
    }

    /// The current epoch number (observable in every [`IntervalReport`]).
    pub fn epoch(&self) -> u64 {
        self.partitioner.epoch()
    }

    pub fn total_state_weight(&self) -> f64 {
        self.stores.iter().map(|s| s.total_weight()).sum()
    }

    /// Process one checkpoint interval of records, then take the barrier:
    /// snapshot, DRM decision, possible epoch swap + state migration.
    pub fn run_interval(&mut self, records: &[Record]) -> IntervalReport {
        self.interval_no += 1;
        let n = self.cfg.n_partitions;

        // Sources tap the stream (round-robin source assignment), sharded
        // with the executor.
        exec::tap_records_sharded(
            &mut self.workers,
            records,
            TapAssignment::RoundRobin,
            self.cfg.num_threads,
        );

        // Key-grouped routing to the pinned reducers through the shared
        // stage: backpressure model — all channels drain at the pace of
        // the bottleneck reducer.
        let stage = ShuffleStage::new(&self.cfg, Scheduling::Pinned).run(
            records,
            &self.partitioner,
            Some(self.stores.as_mut_slice()),
        );

        // Barrier: snapshot.
        self.checkpoints.save(Checkpoint {
            id: self.interval_no,
            records_at: vec![records.len() as u64; n],
            stores: self.stores.clone(),
        });

        // Barrier: DRM decision; an accepted decision bumps the epoch and
        // the swap's derived plan migrates operator state explicitly.
        let decision =
            exec::decision_point_sharded(&mut self.drm, &mut self.workers, self.cfg.num_threads);
        let decision_wall_s = decision.decision_wall_s;
        let (mut migration_pause, mut migrated_fraction, mut repartitioned) = (0.0, 0.0, false);
        if let Some(swap) = decision.swap {
            let mig = exec::adopt_swap(
                &self.cfg,
                &mut self.stores,
                &mut self.partitioner,
                &mut self.metrics,
                &swap,
            );
            migration_pause = mig.pause;
            migrated_fraction = mig.migrated_fraction;
            repartitioned = true;
        }

        let elapsed = stage.stage_time + migration_pause;
        self.vtime += elapsed;
        self.metrics.records_processed += records.len() as u64;
        self.metrics.total_vtime += elapsed;
        self.metrics.reduce_vtime += stage.reduce_time;
        self.metrics.migration_vtime += migration_pause;
        self.metrics.wall_s += stage.wall_s;
        self.metrics.decision_wall_s += decision_wall_s;

        IntervalReport {
            interval_no: self.interval_no,
            elapsed,
            wall_s: stage.wall_s,
            decision_wall_s,
            throughput: if elapsed > 0.0 {
                records.len() as f64 / elapsed
            } else {
                0.0
            },
            imbalance: stage.imbalance,
            migrated_fraction,
            migration_pause,
            repartitioned,
            bottleneck_ratio: stage.bottleneck_ratio,
            epoch: self.partitioner.epoch(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{lfm::Lfm, zipf::Zipf, Generator};

    fn cfg(n: usize) -> EngineConfig {
        EngineConfig {
            n_partitions: n,
            n_slots: n,
            task_overhead: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_improves_after_repartition() {
        let mut e = StreamingEngine::new(cfg(8), DrConfig::default(), PartitionerChoice::Kip, 1);
        let mut z = Zipf::new(50_000, 1.3, 1);
        let r1 = e.run_interval(&z.batch(100_000));
        let r2 = e.run_interval(&z.batch(100_000));
        assert!(r2.repartitioned || r1.repartitioned);
        let r3 = e.run_interval(&z.batch(100_000));
        assert!(
            r3.throughput > r1.throughput,
            "{} vs {}",
            r3.throughput,
            r1.throughput
        );
        assert!(r3.imbalance < r1.imbalance);
        assert!(r3.epoch >= 1, "repartitioning must be visible as an epoch bump");
    }

    #[test]
    fn state_conserved_across_barriers() {
        let mut e = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 2);
        let mut l = Lfm::with_defaults(2);
        let mut expected = 0.0;
        for _ in 0..6 {
            let batch = l.next_batch(20_000);
            expected += batch.iter().map(|r| r.weight).sum::<f64>();
            e.run_interval(&batch);
        }
        assert!((e.total_state_weight() - expected).abs() < 1e-6);
        assert!(e.metrics().repartition_count >= 4);
    }

    #[test]
    fn checkpoints_snapshot_pre_migration_state() {
        let mut e = StreamingEngine::new(cfg(4), DrConfig::forced(), PartitionerChoice::Kip, 3);
        let mut z = Zipf::new(1_000, 1.2, 3);
        e.run_interval(&z.batch(10_000));
        let w_after_1 = e.total_state_weight();
        let cp = e.checkpoints().latest().unwrap();
        assert_eq!(cp.id, 1);
        assert!((cp.total_state_weight() - w_after_1).abs() < 1e-9);
    }

    #[test]
    fn backpressure_ratio_tracks_skew() {
        let mut skewed =
            StreamingEngine::new(cfg(8), DrConfig::disabled(), PartitionerChoice::Uhp, 4);
        let mut uniform =
            StreamingEngine::new(cfg(8), DrConfig::disabled(), PartitionerChoice::Uhp, 4);
        let mut zs = Zipf::new(50_000, 1.8, 4);
        let mut zu = Zipf::new(50_000, 0.0, 5);
        let rs = skewed.run_interval(&zs.batch(50_000));
        let ru = uniform.run_interval(&zu.batch(50_000));
        assert!(rs.bottleneck_ratio > ru.bottleneck_ratio + 0.5);
    }

    #[test]
    #[should_panic]
    fn overpartitioning_streaming_rejected() {
        let bad = EngineConfig {
            n_partitions: 16,
            n_slots: 8,
            ..Default::default()
        };
        StreamingEngine::new(bad, DrConfig::default(), PartitionerChoice::Kip, 5);
    }

    #[test]
    fn vtime_accumulates() {
        let mut e = StreamingEngine::new(cfg(4), DrConfig::default(), PartitionerChoice::Kip, 6);
        let mut z = Zipf::new(10_000, 1.0, 6);
        let a = e.run_interval(&z.batch(10_000));
        let b = e.run_interval(&z.batch(10_000));
        assert!((e.vtime() - (a.elapsed + b.elapsed)).abs() < 1e-12);
    }

    #[test]
    fn barrier_aligned_epochs_are_monotone() {
        let mut e = StreamingEngine::new(cfg(6), DrConfig::forced(), PartitionerChoice::Kip, 7);
        let mut z = Zipf::new(5_000, 1.3, 7);
        let mut last = 0;
        for i in 1..=4u64 {
            let r = e.run_interval(&z.batch(10_000));
            assert_eq!(r.interval_no, i);
            assert!(r.epoch > last, "forced barrier update must bump the epoch");
            last = r.epoch;
        }
        assert_eq!(e.epoch(), last);
    }
}
