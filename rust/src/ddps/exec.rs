//! The shared shuffle-stage execution core (see DESIGN.md "Epochs and the
//! shared ShuffleStage core").
//!
//! All three DDPS engines run the same logical loop — map-tap → shuffle by
//! the current [`PartitionerEpoch`] → keyed reduce with spill-cost
//! accounting — and differ only in *scheduling discipline* and in when the
//! DRM decision point fires:
//!
//! | engine     | tap                   | scheduling           | decision point        |
//! |------------|-----------------------|----------------------|-----------------------|
//! | batch      | chunked, prefix only  | [`Scheduling::Wave`]   | mid-map, once         |
//! | microbatch | chunked, every batch  | [`Scheduling::Wave`]   | between batches       |
//! | streaming  | round-robin sources   | [`Scheduling::Pinned`] | checkpoint barrier    |
//!
//! [`ShuffleStage`] implements the loop once; the sequencing of decision
//! points, stages and epoch swaps lives in the unified drive loop
//! ([`pipeline`](super::pipeline)), with the engines as thin wrappers
//! over it. The full decision step (harvest → decide → adopt) has a
//! single entry point here, [`decide_and_adopt`], split into its
//! [`decision_point_sharded`] and [`adopt_decision`] halves so the
//! pipelined loop can compute a decision concurrently with a stage and
//! adopt it at the epoch-swap barrier. The stage executes in one of two
//! modes, selected by [`EngineConfig::num_threads`]:
//!
//! | mode       | `num_threads` | execution                                             |
//! |------------|---------------|-------------------------------------------------------|
//! | sequential | `= 1`         | the single-threaded reference loop (default)          |
//! | parallel   | `> 1`         | [`parallel`]: persistent pool workers ([`pool`]), one contiguous partition shard each, lock-free per-shard state stores, disjoint writes in partition order |
//!
//! Both modes produce bitwise-identical reports; virtual time is the
//! scheduling *model* and never depends on the thread count, while the
//! measured [`StageReport::wall_s`] and
//! [`StageReport::decision_wall_s`] columns are where real parallelism
//! shows up. The same knob shards the DRM side: DRW taps and harvests
//! ride the executor's sharding ([`tap_records_sharded`],
//! [`decision_point_sharded`]), and the decision point itself — histogram
//! tree-merge and candidate construction — runs on the same persistent
//! worker pool through [`dr::parallel`](crate::dr::parallel) (DESIGN.md
//! "Sharded DRM decision point"), so no serial region is left between the
//! parallel shards. All of it dispatches onto one long-lived
//! [`pool::WorkerPool`] per thread width (parked threads, recycled
//! scratch buffers — no per-interval spawns or reallocations; DESIGN.md
//! "Persistent worker pool and scratch arenas").

pub mod parallel;
pub mod pool;

pub use parallel::{harvest_sharded, tap_records_sharded};
pub use pool::WorkerPool;

use super::{EngineConfig, EngineMetrics};
use crate::dr::{DecisionProposal, DrDecision, DrMaster, DrWorker};
use crate::partitioner::{EpochSwap, PartitionerEpoch};
use crate::sketch::Histogram;
use crate::state::StateStore;
use crate::util::{load_imbalance, wave_makespan, VTime};
use crate::workload::{Key, Record};
use std::time::Instant;

/// How map/source work is spread over the DRW taps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapAssignment {
    /// Contiguous chunks of the batch per worker — Spark map tasks.
    Chunked,
    /// Round-robin over workers — long-running streaming source tasks.
    RoundRobin,
}

/// Feed `records` through the DRW sampling taps under `assign`.
pub fn tap_records(workers: &mut [DrWorker], records: &[Record], assign: TapAssignment) {
    if workers.is_empty() {
        return;
    }
    match assign {
        TapAssignment::Chunked => {
            let per = records.len().div_ceil(workers.len()).max(1);
            for (i, r) in records.iter().enumerate() {
                workers[i / per].observe(r.key, r.weight);
            }
        }
        TapAssignment::RoundRobin => {
            let n = workers.len();
            for (i, r) in records.iter().enumerate() {
                workers[i % n].observe(r.key, r.weight);
            }
        }
    }
}

/// The DRM decision point shared by every engine: harvest each DRW's local
/// histogram (decaying its counters for the next interval) and let the
/// master decide. Returns the decision; on a repartitioning the caller
/// applies the epoch swap with [`apply_epoch_swap`].
pub fn decision_point(drm: &mut DrMaster, workers: &mut [DrWorker]) -> DrDecision {
    decision_point_sharded(drm, workers, 1)
}

/// [`decision_point`] with the whole decision point sharded over
/// `num_threads` persistent pool workers ([`pool`]): the DRW harvests ride
/// [`parallel::harvest_sharded`] (contiguous shards joined in worker
/// order, so the DRM receives exactly the sequential histogram sequence),
/// and the DRM itself merges and constructs sharded
/// ([`DrMaster::decide_sharded`], backed by
/// [`dr::parallel`](crate::dr::parallel)). Decisions, epochs and
/// migration plans are bitwise-identical at any thread count; the
/// returned [`DrDecision::decision_wall_s`] is re-measured here to cover
/// the full span — harvests, merge, blend, candidate construction — and
/// is what the engines surface in their reports' `decision_wall_s`
/// columns.
pub fn decision_point_sharded(
    drm: &mut DrMaster,
    workers: &mut [DrWorker],
    num_threads: usize,
) -> DrDecision {
    let wall_start = Instant::now();
    // The worker→master `take` cut: each harvest ships only
    // `drm.ship_size()` entries (== histogram_size unless take_top_k set).
    let k = drm.ship_size();
    let hists: Vec<Histogram> = parallel::harvest_sharded(workers, k, num_threads);
    let mut decision = drm.decide_sharded(hists, num_threads);
    decision.decision_wall_s = wall_start.elapsed().as_secs_f64();
    decision
}

/// The proposal half of [`decision_point_sharded`]: harvest the DRWs and
/// let the master construct a candidate *without installing it* — the
/// epoch does not move. The engines run this on the pipelined decision
/// lane (or inline, sequentially) and hand the proposal to the decider
/// at the epoch-swap barrier, which commits or declines it there. The
/// returned [`DecisionProposal::decision_wall_s`] is re-measured to span
/// harvests, merge, blend and candidate construction.
pub fn proposal_point_sharded(
    drm: &mut DrMaster,
    workers: &mut [DrWorker],
    num_threads: usize,
) -> DecisionProposal {
    let wall_start = Instant::now();
    let k = drm.ship_size();
    let hists: Vec<Histogram> = parallel::harvest_sharded(workers, k, num_threads);
    let mut proposal = drm.propose_sharded(hists, num_threads);
    proposal.decision_wall_s = wall_start.elapsed().as_secs_f64();
    proposal
}

/// How reduce work turns into virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduling {
    /// Spark-style short tasks, wave-scheduled over `n_slots`, with the
    /// spill model and per-task overhead; map and reduce phases are
    /// sequential (stage time = map + reduce).
    Wave,
    /// Flink-style pinned long-running tasks, one per partition; the
    /// interval drains at the pace of the bottleneck reducer through
    /// backpressure (stage time = max(source, reduce), no task overhead).
    Pinned,
}

/// Outcome of one shuffle stage: per-partition routing result plus the
/// virtual-time accounting under the stage's scheduling discipline.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Reduce-side weight per partition.
    pub loads: Vec<f64>,
    /// Records (not weight) per partition — Fig 7's "record balance".
    pub record_counts: Vec<u64>,
    /// Map/source-side virtual time (parse + emit + shuffle write).
    pub map_time: VTime,
    /// Reduce-side virtual time under the scheduling discipline.
    pub reduce_time: VTime,
    /// Combined stage time: `map + reduce` for [`Scheduling::Wave`],
    /// `max(source, reduce)` for [`Scheduling::Pinned`].
    pub stage_time: VTime,
    /// Measured wall-clock seconds this stage's executor actually took
    /// (routing + keyed reduce). Unlike the virtual times above this is a
    /// *measurement*, varies run to run, and (with `decision_wall_s`) is
    /// the only report field that depends on
    /// [`EngineConfig::num_threads`].
    pub wall_s: f64,
    /// Measured wall-clock seconds of the DRM decision point attributed to
    /// this stage. Every report type carries the `wall_s` /
    /// `decision_wall_s` pair of measured columns; a bare stage contains
    /// no decision point, so [`ShuffleStage::run`] reports `0.0` here and
    /// the engines' report assembly overwrites it with the decision
    /// point they actually ran around the stage — the stage-level column
    /// and the engine reports' column always agree.
    pub decision_wall_s: f64,
    pub imbalance: f64,
    /// Load of the most loaded partition relative to the mean — how hard
    /// backpressure bites in the pinned model.
    pub bottleneck_ratio: f64,
}

/// The shared map → shuffle → keyed-reduce loop, parameterized by
/// [`EngineConfig`] and driven through a [`PartitionerEpoch`].
pub struct ShuffleStage<'a> {
    cfg: &'a EngineConfig,
    sched: Scheduling,
    /// Per-partition service-time multipliers (scenario harness: a slowed
    /// worker has rate > 1). `None` ≡ all-ones.
    rates: Option<&'a [f64]>,
}

impl<'a> ShuffleStage<'a> {
    pub fn new(cfg: &'a EngineConfig, sched: Scheduling) -> Self {
        Self {
            cfg,
            sched,
            rates: None,
        }
    }

    /// Model partition `p`'s reducer as taking `rates[p]×` its nominal
    /// service time — the scenario harness's worker-slowdown event. The
    /// multipliers feed only the *virtual-time* accounting below (reduce
    /// task costs, the pinned bottleneck and `bottleneck_ratio`); routing,
    /// loads, record counts and keyed state are untouched, so a run with
    /// all rates at `1.0` is bitwise-identical to one without rates.
    pub fn with_service_rates(mut self, rates: &'a [f64]) -> Self {
        self.rates = Some(rates);
        self
    }

    /// Route `records` through `epoch`, optionally folding reducer state,
    /// and account virtual time. The spill model (`reduce_task_time`)
    /// applies under [`Scheduling::Wave`]; the pinned model is gated by
    /// the bottleneck reducer. With `cfg.num_threads > 1` the routing and
    /// the keyed reduce run sharded on the persistent worker pool
    /// ([`parallel`], [`pool`]), with the routing buffers recycled
    /// through the pool's scratch arena; both paths produce
    /// bitwise-identical loads, counts and state.
    pub fn run(
        &self,
        records: &[Record],
        epoch: &PartitionerEpoch,
        mut state: Option<&mut [StateStore]>,
    ) -> StageReport {
        let wall_start = Instant::now();
        let n = self.cfg.n_partitions;
        debug_assert_eq!(epoch.n_partitions(), n, "epoch/config partition mismatch");

        // Shuffle: route by the epoch's function; gather loads and fold
        // keyed state exactly as the reducers would.
        let (loads, record_counts) = if self.cfg.num_threads > 1 {
            let pool = pool::WorkerPool::for_threads(self.cfg.num_threads);
            let mut routed = pool.take_routed();
            parallel::route_into(&mut routed, records, epoch, self.cfg.num_threads);
            let out = parallel::shuffle_sharded(
                records,
                &routed,
                n,
                state.as_deref_mut(),
                self.cfg.num_threads,
            );
            pool.put_routed(routed);
            out
        } else {
            let mut loads = vec![0.0f64; n];
            let mut record_counts = vec![0u64; n];
            for r in records {
                let p = epoch.partition(r.key);
                loads[p] += r.weight;
                record_counts[p] += 1;
                if let Some(stores) = state.as_deref_mut() {
                    stores[p].fold_count(r.key, r.weight);
                }
            }
            (loads, record_counts)
        };
        // The executor span ends here: the virtual-time modeling below is
        // O(n_partitions) bookkeeping, not sharded work.
        let wall_s = wall_start.elapsed().as_secs_f64();

        finish_stage_report(
            self.cfg,
            self.sched,
            records.len(),
            loads,
            record_counts,
            self.rates,
            wall_s,
        )
    }
}

/// The virtual-time accounting half of [`ShuffleStage::run`]: turn routed
/// per-partition loads/counts into a [`StageReport`] under the scheduling
/// discipline. Extracted so the distributed master
/// ([`cluster`](super::cluster)) accounts the workers' wire-shipped loads
/// through exactly the code path the in-process stage uses — same fold
/// orders, same f64 bits.
pub(crate) fn finish_stage_report(
    cfg: &EngineConfig,
    sched: Scheduling,
    n_records: usize,
    loads: Vec<f64>,
    record_counts: Vec<u64>,
    rates: Option<&[f64]>,
    wall_s: f64,
) -> StageReport {
    let n = cfg.n_partitions;
    if let Some(rates) = rates {
        debug_assert_eq!(rates.len(), n, "service rates/partition mismatch");
    }
    let rate = |p: usize| rates.map_or(1.0, |r| r[p]);
    let total_load: f64 = loads.iter().sum();
    // Effective (service-rate-weighted) bottleneck: what backpressure
    // actually gates on when a worker is slowed. Identical to the raw
    // bottleneck when no rates are set.
    let bottleneck = loads
        .iter()
        .enumerate()
        .map(|(p, l)| l * rate(p))
        .fold(0.0, f64::max);
    let (map_time, reduce_time, stage_time) = match sched {
        Scheduling::Wave => {
            let per_slot = n_records.div_ceil(cfg.n_slots);
            let map_time = per_slot as f64 * (cfg.map_cost + cfg.shuffle_cost);
            let task_costs: Vec<VTime> = loads
                .iter()
                .enumerate()
                .map(|(p, l)| cfg.reduce_task_time(*l, total_load) * rate(p))
                .collect();
            let reduce_time = wave_makespan(&task_costs, cfg.n_slots);
            (map_time, reduce_time, map_time + reduce_time)
        }
        Scheduling::Pinned => {
            let source_time = n_records as f64 / n as f64 * (cfg.map_cost + cfg.shuffle_cost);
            let reduce_time = bottleneck * cfg.reduce_cost;
            (source_time, reduce_time, source_time.max(reduce_time))
        }
    };

    let mean_load = total_load / n as f64;
    StageReport {
        imbalance: load_imbalance(&loads),
        bottleneck_ratio: if mean_load > 0.0 { bottleneck / mean_load } else { 1.0 },
        loads,
        record_counts,
        map_time,
        reduce_time,
        stage_time,
        wall_s,
        decision_wall_s: 0.0,
    }
}

/// Outcome of applying an epoch swap to the keyed state.
#[derive(Debug, Clone, Copy)]
pub struct MigrationReport {
    /// Pause charged against the engine timeline (`moved × migration_cost`).
    pub pause: VTime,
    /// Absolute state weight that moved.
    pub moved_weight: f64,
    /// Fraction of total state weight that moved (Fig 3 right).
    pub migrated_fraction: f64,
}

impl MigrationReport {
    /// The no-migration report (kept decision, or stateless adoption).
    pub fn none() -> Self {
        Self {
            pause: 0.0,
            moved_weight: 0.0,
            migrated_fraction: 0.0,
        }
    }
}

/// Execute `swap`'s migration plan over the per-partition stores: every
/// key whose partition changed drags its operator state, paying
/// `migration_cost` per unit of weight. The plan is derived from the
/// epoch diff — no engine re-implements the key walk.
pub fn apply_epoch_swap(
    cfg: &EngineConfig,
    stores: &mut [StateStore],
    swap: &EpochSwap,
) -> MigrationReport {
    let total_weight: f64 = stores.iter().map(|s| s.total_weight()).sum();
    let mut moved = 0.0;
    let keys: Vec<Vec<Key>> = stores.iter().map(|s| s.keys().collect()).collect();
    for (p, part_keys) in keys.into_iter().enumerate() {
        for (key, from, to) in swap.plan(part_keys) {
            // Precondition (debug-asserted): the stores are laid out per
            // `swap.from` routing — swaps must be adopted in epoch order.
            // Extraction uses the store the key was actually found in, so
            // a violated precondition in release builds cannot corrupt
            // state weights (it can only leave a key un-migrated).
            debug_assert_eq!(from, p, "store layout diverged from swap.from routing");
            if let Some(st) = stores[p].extract(key) {
                moved += st.weight;
                stores[to].install(key, st);
            }
        }
    }
    MigrationReport {
        pause: moved * cfg.migration_cost,
        moved_weight: moved,
        migrated_fraction: if total_weight > 0.0 { moved / total_weight } else { 0.0 },
    }
}

/// Outcome of one full DRM decision step ([`decide_and_adopt`] /
/// [`adopt_decision`]): the measured decision-point cost, whether a new
/// partitioner was installed, and the resulting state migration (zeroed
/// when the decision kept the current function or no stores were given).
#[derive(Debug, Clone, Copy)]
pub struct DecisionOutcome {
    /// Measured wall-clock seconds of the decision point (harvests +
    /// merge + candidate construction), copied from
    /// [`DrDecision::decision_wall_s`].
    pub decision_wall_s: f64,
    /// Did this step install a new partitioner (epoch bump)?
    pub repartitioned: bool,
    /// State migration performed by the adoption;
    /// [`MigrationReport::none`] when nothing moved.
    pub migration: MigrationReport,
    /// The epoch in force after adoption.
    pub epoch: u64,
}

/// Adopt a [`DrDecision`]: on an accepted swap, migrate keyed state along
/// the derived plan (when `stores` are given — stateless batch jobs pass
/// `None` and price a *replay* instead) and switch the engine's routing
/// snapshot to the new epoch. This is the adoption half of the decision
/// step; [`decide_and_adopt`] fuses it with the harvest half. The
/// split exists for the pipelined loop
/// ([`pipeline`](crate::ddps::pipeline)), which computes the decision
/// concurrently with the previous stage and adopts it at the epoch-swap
/// barrier.
pub fn adopt_decision(
    cfg: &EngineConfig,
    decision: DrDecision,
    partitioner: &mut PartitionerEpoch,
    stores: Option<&mut [StateStore]>,
    metrics: &mut EngineMetrics,
) -> DecisionOutcome {
    let decision_wall_s = decision.decision_wall_s;
    let Some(swap) = decision.swap else {
        return DecisionOutcome {
            decision_wall_s,
            repartitioned: false,
            migration: MigrationReport::none(),
            epoch: partitioner.epoch(),
        };
    };
    let migration = match stores {
        Some(stores) => adopt_swap(cfg, stores, partitioner, metrics, &swap),
        None => {
            // Stateless adoption (batch jobs): only the routing snapshot
            // switches; the caller prices the mapper-output replay.
            *partitioner = swap.to.clone();
            metrics.repartition_count += 1;
            MigrationReport::none()
        }
    };
    DecisionOutcome {
        decision_wall_s,
        repartitioned: true,
        migration,
        epoch: partitioner.epoch(),
    }
}

/// The full DRM decision step every engine performs the same way —
/// sharded DRW harvest → merge/decide ([`decision_point_sharded`]) →
/// adoption ([`adopt_decision`]). One entry point instead of three
/// per-engine copies of the harvest → swap → adopt boilerplate; the
/// unified loop in [`pipeline`](crate::ddps::pipeline) is its only
/// caller besides tests.
pub fn decide_and_adopt(
    cfg: &EngineConfig,
    drm: &mut DrMaster,
    workers: &mut [DrWorker],
    partitioner: &mut PartitionerEpoch,
    stores: Option<&mut [StateStore]>,
    metrics: &mut EngineMetrics,
) -> DecisionOutcome {
    let decision = decision_point_sharded(drm, workers, cfg.num_threads);
    adopt_decision(cfg, decision, partitioner, stores, metrics)
}

/// Adopt an accepted decision — the step every engine performs the same
/// way: migrate keyed state along the swap's derived plan, switch the
/// engine's routing snapshot to the new epoch, and record the migration
/// in the engine metrics.
pub fn adopt_swap(
    cfg: &EngineConfig,
    stores: &mut [StateStore],
    partitioner: &mut PartitionerEpoch,
    metrics: &mut EngineMetrics,
    swap: &EpochSwap,
) -> MigrationReport {
    let mig = apply_epoch_swap(cfg, stores, swap);
    *partitioner = swap.to.clone();
    metrics.state_weight_migrated += mig.moved_weight;
    metrics.repartition_count += 1;
    mig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{EpochedPartitioner, Uhp};
    use crate::workload::{zipf::Zipf, Generator};
    use std::sync::Arc;

    fn cfg(n_partitions: usize, n_slots: usize) -> EngineConfig {
        EngineConfig {
            n_partitions,
            n_slots,
            ..Default::default()
        }
    }

    fn epoch(n: usize, seed: u64) -> PartitionerEpoch {
        EpochedPartitioner::new(Arc::new(Uhp::with_seed(n, seed))).current()
    }

    #[test]
    fn stage_conserves_weight_and_counts() {
        let cfg = cfg(8, 4);
        let mut z = Zipf::new(10_000, 1.1, 1);
        let recs = z.batch(30_000);
        let w: f64 = recs.iter().map(|r| r.weight).sum();
        let r = ShuffleStage::new(&cfg, Scheduling::Wave).run(&recs, &epoch(8, 1), None);
        assert!((r.loads.iter().sum::<f64>() - w).abs() < 1e-6);
        assert_eq!(r.record_counts.iter().sum::<u64>(), 30_000);
        assert!(r.stage_time > 0.0);
        assert!((r.stage_time - (r.map_time + r.reduce_time)).abs() < 1e-12);
    }

    #[test]
    fn pinned_stage_is_bottleneck_gated() {
        let cfg = cfg(4, 4);
        let mut z = Zipf::new(5_000, 1.5, 2);
        let recs = z.batch(20_000);
        let r = ShuffleStage::new(&cfg, Scheduling::Pinned).run(&recs, &epoch(4, 2), None);
        let bottleneck = r.loads.iter().cloned().fold(0.0, f64::max);
        assert!((r.reduce_time - bottleneck * cfg.reduce_cost).abs() < 1e-12);
        assert!((r.stage_time - r.map_time.max(r.reduce_time)).abs() < 1e-12);
        assert!(r.bottleneck_ratio >= 1.0);
    }

    #[test]
    fn stage_folds_state_when_given_stores() {
        let cfg = cfg(4, 4);
        let mut z = Zipf::new(1_000, 1.0, 3);
        let recs = z.batch(5_000);
        let mut stores: Vec<StateStore> = (0..4).map(|_| StateStore::new()).collect();
        let ep = epoch(4, 3);
        ShuffleStage::new(&cfg, Scheduling::Wave).run(&recs, &ep, Some(&mut stores));
        let total: f64 = stores.iter().map(|s| s.total_weight()).sum();
        let w: f64 = recs.iter().map(|r| r.weight).sum();
        assert!((total - w).abs() < 1e-6);
        // every key's state sits where the epoch routes it
        for (p, s) in stores.iter().enumerate() {
            for k in s.keys() {
                assert_eq!(ep.partition(k), p);
            }
        }
    }

    #[test]
    fn empty_batch_costs_only_overheadless_zero() {
        let cfg = cfg(4, 2);
        let r = ShuffleStage::new(&cfg, Scheduling::Pinned).run(&[], &epoch(4, 4), None);
        assert_eq!(r.record_counts.iter().sum::<u64>(), 0);
        assert!((r.stage_time - 0.0).abs() < 1e-12);
        assert_eq!(r.bottleneck_ratio, 1.0);
    }

    #[test]
    fn tap_chunked_and_round_robin_observe_everything() {
        for assign in [TapAssignment::Chunked, TapAssignment::RoundRobin] {
            let mut workers: Vec<DrWorker> =
                (0..4).map(|w| DrWorker::new(64, 1.0, w as u64)).collect();
            let mut z = Zipf::new(1_000, 1.0, 5);
            let recs = z.batch(10_000);
            tap_records(&mut workers, &recs, assign);
            let seen: u64 = workers.iter().map(|w| w.observed()).sum();
            assert_eq!(seen, 10_000, "{assign:?} dropped records");
        }
    }

    #[test]
    fn parallel_stage_matches_sequential_bitwise() {
        for sched in [Scheduling::Wave, Scheduling::Pinned] {
            let seq_cfg = cfg(9, 4);
            let par_cfg = EngineConfig {
                num_threads: 4,
                ..seq_cfg
            };
            let ep = epoch(9, 6);
            let mut z = Zipf::new(3_000, 1.2, 6);
            let recs = z.batch(40_000);
            let mut stores_seq: Vec<StateStore> = (0..9).map(|_| StateStore::new()).collect();
            let mut stores_par: Vec<StateStore> = (0..9).map(|_| StateStore::new()).collect();
            let rs = ShuffleStage::new(&seq_cfg, sched).run(&recs, &ep, Some(&mut stores_seq));
            let rp = ShuffleStage::new(&par_cfg, sched).run(&recs, &ep, Some(&mut stores_par));
            assert_eq!(rs.record_counts, rp.record_counts, "{sched:?}");
            for (a, b) in rs.loads.iter().zip(&rp.loads) {
                assert_eq!(a.to_bits(), b.to_bits(), "{sched:?}: loads not bitwise-equal");
            }
            assert_eq!(rs.map_time.to_bits(), rp.map_time.to_bits(), "{sched:?}");
            assert_eq!(rs.reduce_time.to_bits(), rp.reduce_time.to_bits(), "{sched:?}");
            assert_eq!(rs.stage_time.to_bits(), rp.stage_time.to_bits(), "{sched:?}");
            assert_eq!(rs.imbalance.to_bits(), rp.imbalance.to_bits(), "{sched:?}");
            for (s, p) in stores_seq.iter().zip(&stores_par) {
                assert_eq!(s.n_keys(), p.n_keys(), "{sched:?}");
                assert_eq!(
                    s.total_weight().to_bits(),
                    p.total_weight().to_bits(),
                    "{sched:?}: state weight bits differ"
                );
            }
            assert!(rs.wall_s >= 0.0 && rp.wall_s >= 0.0);
        }
    }

    #[test]
    fn sharded_decision_point_matches_sequential() {
        use crate::dr::{DrConfig, PartitionerChoice};
        let make = |seed: u64| {
            let drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, seed);
            let workers: Vec<DrWorker> = (0..6)
                .map(|w| DrWorker::new(drm.worker_capacity(), 1.0, seed ^ (w as u64) << 8))
                .collect();
            (drm, workers)
        };
        let mut z = Zipf::new(5_000, 1.3, 11);
        let recs = z.batch(60_000);

        let (mut drm_seq, mut w_seq) = make(11);
        tap_records(&mut w_seq, &recs, TapAssignment::Chunked);
        let d_seq = decision_point(&mut drm_seq, &mut w_seq);

        let (mut drm_par, mut w_par) = make(11);
        tap_records_sharded(&mut w_par, &recs, TapAssignment::Chunked, 3);
        let d_par = decision_point_sharded(&mut drm_par, &mut w_par, 3);

        assert_eq!(d_seq.repartitioned(), d_par.repartitioned());
        assert_eq!(d_seq.epoch, d_par.epoch);
        assert_eq!(d_seq.histogram.entries(), d_par.histogram.entries());
        assert!(d_seq.decision_wall_s >= 0.0 && d_par.decision_wall_s >= 0.0);
        let (sp, pp) = (
            d_seq.new_partitioner().expect("forced"),
            d_par.new_partitioner().expect("forced"),
        );
        for k in 0..5_000u64 {
            assert_eq!(sp.partition(k), pp.partition(k), "routing diverged at key {k}");
        }
    }

    #[test]
    fn decide_and_adopt_equals_manual_decision_then_adoption() {
        use crate::dr::{DrConfig, PartitionerChoice};
        let cfg = cfg(6, 6);
        let make = || {
            let drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 6, 21);
            let workers: Vec<DrWorker> = (0..4)
                .map(|w| DrWorker::new(drm.worker_capacity(), 1.0, 21 ^ (w as u64) << 8))
                .collect();
            let partitioner = drm.handle();
            let stores: Vec<StateStore> = (0..6).map(|_| StateStore::new()).collect();
            (drm, workers, partitioner, stores)
        };
        let mut z = Zipf::new(2_000, 1.3, 21);
        let recs = z.batch(30_000);

        // fused path, with stores (micro-batch / streaming shape)
        let (mut drm_a, mut w_a, mut p_a, mut s_a) = make();
        for r in &recs {
            s_a[p_a.partition(r.key)].fold_count(r.key, r.weight);
        }
        tap_records(&mut w_a, &recs, TapAssignment::Chunked);
        let mut m_a = EngineMetrics::default();
        let out_a = decide_and_adopt(
            &cfg,
            &mut drm_a,
            &mut w_a,
            &mut p_a,
            Some(s_a.as_mut_slice()),
            &mut m_a,
        );
        assert!(out_a.repartitioned, "forced update must fire");
        assert_eq!(out_a.epoch, 1);
        assert_eq!(p_a.epoch(), 1);
        assert!(out_a.migration.moved_weight > 0.0);
        assert_eq!(m_a.repartition_count, 1);
        assert!(
            (m_a.state_weight_migrated - out_a.migration.moved_weight).abs() < 1e-12
        );
        // stores follow the new routing
        for (p, s) in s_a.iter().enumerate() {
            for k in s.keys() {
                assert_eq!(p_a.partition(k), p);
            }
        }

        // split path (decision then adoption), stateless (batch-job shape)
        let (mut drm_b, mut w_b, mut p_b, _) = make();
        tap_records(&mut w_b, &recs, TapAssignment::Chunked);
        let decision = decision_point_sharded(&mut drm_b, &mut w_b, 1);
        let mut m_b = EngineMetrics::default();
        let out_b = adopt_decision(&cfg, decision, &mut p_b, None, &mut m_b);
        assert!(out_b.repartitioned);
        assert_eq!(out_b.epoch, 1);
        assert_eq!(out_b.migration.moved_weight, 0.0, "stateless adoption");
        assert_eq!(m_b.repartition_count, 1);
        assert_eq!(m_b.state_weight_migrated, 0.0);
        // both paths install the same routing
        for k in 0..2_000u64 {
            assert_eq!(p_a.partition(k), p_b.partition(k), "routing diverged at {k}");
        }
    }

    #[test]
    fn unit_service_rates_are_bitwise_invisible() {
        let cfg = cfg(6, 4);
        let ones = vec![1.0f64; 6];
        let mut z = Zipf::new(2_000, 1.2, 8);
        let recs = z.batch(20_000);
        for sched in [Scheduling::Wave, Scheduling::Pinned] {
            let ep = epoch(6, 8);
            let plain = ShuffleStage::new(&cfg, sched).run(&recs, &ep, None);
            let rated = ShuffleStage::new(&cfg, sched)
                .with_service_rates(&ones)
                .run(&recs, &ep, None);
            assert_eq!(plain.map_time.to_bits(), rated.map_time.to_bits(), "{sched:?}");
            assert_eq!(plain.reduce_time.to_bits(), rated.reduce_time.to_bits(), "{sched:?}");
            assert_eq!(plain.stage_time.to_bits(), rated.stage_time.to_bits(), "{sched:?}");
            assert_eq!(
                plain.bottleneck_ratio.to_bits(),
                rated.bottleneck_ratio.to_bits(),
                "{sched:?}"
            );
        }
    }

    #[test]
    fn slowed_partition_stretches_virtual_time_only() {
        let cfg = cfg(4, 4);
        let mut z = Zipf::new(2_000, 0.3, 9);
        let recs = z.batch(20_000);
        let ep = epoch(4, 9);
        let mut rates = vec![1.0f64; 4];
        rates[2] = 3.0;
        for sched in [Scheduling::Wave, Scheduling::Pinned] {
            let plain = ShuffleStage::new(&cfg, sched).run(&recs, &ep, None);
            let slowed = ShuffleStage::new(&cfg, sched)
                .with_service_rates(&rates)
                .run(&recs, &ep, None);
            assert!(
                slowed.reduce_time > plain.reduce_time,
                "{sched:?}: a slowed worker must stretch the reduce phase"
            );
            // routing is untouched: same loads, counts, imbalance
            assert_eq!(plain.record_counts, slowed.record_counts, "{sched:?}");
            for (a, b) in plain.loads.iter().zip(&slowed.loads) {
                assert_eq!(a.to_bits(), b.to_bits(), "{sched:?}");
            }
            assert_eq!(plain.imbalance.to_bits(), slowed.imbalance.to_bits(), "{sched:?}");
            assert!(slowed.bottleneck_ratio > plain.bottleneck_ratio, "{sched:?}");
        }
    }

    #[test]
    fn apply_epoch_swap_moves_exactly_the_replanned_keys() {
        let cfg = cfg(6, 6);
        let mut ep = EpochedPartitioner::new(Arc::new(Uhp::with_seed(6, 1)));
        let mut stores: Vec<StateStore> = (0..6).map(|_| StateStore::new()).collect();
        for k in 0..500u64 {
            stores[ep.partition(k)].fold_count(k, 1.0 + k as f64 % 3.0);
        }
        let before: f64 = stores.iter().map(|s| s.total_weight()).sum();
        let swap = ep.install(Arc::new(Uhp::with_seed(6, 2)));
        let mig = apply_epoch_swap(&cfg, &mut stores, &swap);
        let after: f64 = stores.iter().map(|s| s.total_weight()).sum();
        assert!((before - after).abs() < 1e-9, "state weight not conserved");
        assert!(mig.moved_weight > 0.0);
        assert!((0.0..=1.0).contains(&mig.migrated_fraction));
        assert!((mig.pause - mig.moved_weight * cfg.migration_cost).abs() < 1e-12);
        // every key now lives where the new epoch routes it
        for (p, s) in stores.iter().enumerate() {
            for k in s.keys() {
                assert_eq!(swap.to.partition(k), p);
            }
        }
    }
}
