//! The worker process run loop: own a contiguous partition shard (its
//! [`StateStore`]s and [`DrWorker`]s), fold batches from the feed
//! connection, ship harvests/movers/snapshots to the master on the
//! control connection, and apply migration ops at each barrier.
//!
//! Every per-record loop here replays the exact sequential subsequence
//! the in-process engines produce for this shard — the round-robin tap,
//! the record-order shuffle fold, the slab-order mover walk and the
//! plan-order op application — so the worker's state and histograms are
//! bitwise those of the oracle's partitions `[part_lo, part_hi)`.

use super::transport::{self, Endpoint, RealClock};
use super::wire::{
    self, AssignWire, DrwSnapWire, FinalPartWire, HarvestWire, HistogramWire, KeyStateWire,
    Message, MoverWire, OpWire, SnapshotWire, StoreSnapWire,
};
use super::ClusterError;
use crate::ddps::exec::parallel::harvest_sharded;
use crate::ddps::EngineConfig;
use crate::dr::DrWorker;
use crate::sketch::{FreqCounter, SketchConfig};
use crate::state::StateStore;
use crate::workload::{Record, SocketSource};
use std::time::Duration;

const CONNECT_ATTEMPTS: u32 = 50;
const CONNECT_BASE: Duration = Duration::from_millis(5);
const CONNECT_CAP: Duration = Duration::from_millis(100);
const IO_TIMEOUT: Duration = Duration::from_secs(120);

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerOptions {
    pub endpoint: Endpoint,
    pub worker_id: u32,
    /// Test hook: exit right after *receiving* this interval's batch,
    /// before processing any of it — a crash at the worst moment for
    /// the master's restore path.
    pub fail_at: Option<u64>,
}

/// How the run loop ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerOutcome {
    /// Clean shutdown after `Eof` + `Finish`.
    Finished,
    /// The `fail_at` crash hook fired (the CLI maps this to exit 3).
    FailInjected,
}

/// This worker's shard: stores and DRWs for partitions `[lo, hi)`.
struct Shard {
    lo: usize,
    hi: usize,
    n_partitions: usize,
    ship_k: usize,
    stores: Vec<StateStore>,
    drws: Vec<DrWorker>,
}

fn sketch_of(a: &AssignWire) -> SketchConfig {
    SketchConfig {
        compaction_interval: a.sketch_compaction as usize,
        size_boundary: a.sketch_bound as usize,
        take_top_k: a.sketch_take as usize,
    }
}

impl Shard {
    /// A fresh shard — the exact DRW construction of the in-process
    /// engine core, restricted to this worker's global DRW indices.
    fn fresh(a: &AssignWire) -> Self {
        let (lo, hi) = (a.part_lo as usize, a.part_hi as usize);
        let sketch = sketch_of(a);
        let stores = (lo..hi).map(|_| StateStore::new()).collect();
        let drws = (lo..hi)
            .map(|d| {
                DrWorker::with_sketch(
                    a.counter_capacity as usize,
                    f64::from_bits(a.sample_rate_bits),
                    a.base_seed ^ ((d as u64) << 8),
                    sketch,
                )
            })
            .collect();
        Self {
            lo,
            hi,
            n_partitions: a.n_partitions as usize,
            ship_k: a.ship_k as usize,
            stores,
            drws,
        }
    }

    /// Rebuild from a barrier snapshot: stores by in-order install (the
    /// slab order and cached-total bits carry over verbatim), DRWs from
    /// their counter/RNG/compaction state.
    fn restore(a: &AssignWire, snap: &SnapshotWire) -> Result<Self, ClusterError> {
        let (lo, hi) = (a.part_lo as usize, a.part_hi as usize);
        if snap.stores.len() != hi - lo || snap.drws.len() != hi - lo {
            return Err(ClusterError::Protocol(format!(
                "snapshot has {} stores / {} drws for a shard of {}",
                snap.stores.len(),
                snap.drws.len(),
                hi - lo
            )));
        }
        let sketch = sketch_of(a);
        let sample_rate = f64::from_bits(a.sample_rate_bits);
        let stores = snap.stores.iter().map(restore_store).collect();
        let drws = snap
            .drws
            .iter()
            .map(|d| restore_drw(d, sample_rate, sketch))
            .collect();
        Ok(Self {
            lo,
            hi,
            n_partitions: a.n_partitions as usize,
            ship_k: a.ship_k as usize,
            stores,
            drws,
        })
    }

    fn snapshot(&self) -> SnapshotWire {
        SnapshotWire {
            stores: self
                .stores
                .iter()
                .map(|s| StoreSnapWire {
                    entries: s
                        .iter()
                        .map(|(k, st)| (k, KeyStateWire::from_state(st)))
                        .collect(),
                    total_bits: s.total_weight().to_bits(),
                })
                .collect(),
            drws: self
                .drws
                .iter()
                .map(|w| DrwSnapWire {
                    capacity: w.counter().capacity() as u64,
                    decay_bits: w.counter().decay().to_bits(),
                    total_bits: w.counter().total().to_bits(),
                    entries: w
                        .counter()
                        .entries_sorted()
                        .iter()
                        .map(|&(k, c)| (k, c.to_bits()))
                        .collect(),
                    rng: w.rng_state(),
                    observed: w.observed(),
                    sampled: w.sampled(),
                    since_compaction: w.since_compaction() as u64,
                })
                .collect(),
        }
    }

    fn final_parts(&self) -> Vec<FinalPartWire> {
        self.stores
            .iter()
            .enumerate()
            .map(|(idx, s)| FinalPartWire {
                part: (self.lo + idx) as u32,
                n_keys: s.n_keys() as u64,
                fingerprint: s.fingerprint(),
                total_bits: s.total_weight().to_bits(),
            })
            .collect()
    }
}

fn restore_store(s: &StoreSnapWire) -> StateStore {
    let mut store = StateStore::new();
    for (key, st) in &s.entries {
        store.install(*key, st.to_state());
    }
    store.set_cached_total_weight(f64::from_bits(s.total_bits));
    store
}

fn restore_drw(d: &DrwSnapWire, sample_rate: f64, sketch: SketchConfig) -> DrWorker {
    let entries: Vec<(u64, f64)> = d
        .entries
        .iter()
        .map(|&(k, b)| (k, f64::from_bits(b)))
        .collect();
    let counter = FreqCounter::from_parts(
        d.capacity as usize,
        f64::from_bits(d.decay_bits),
        f64::from_bits(d.total_bits),
        &entries,
    );
    DrWorker::from_parts(
        counter,
        sample_rate,
        d.rng,
        d.observed,
        d.sampled,
        sketch,
        d.since_compaction as usize,
    )
}

fn unexpected(expected: &str, got: &Message) -> ClusterError {
    ClusterError::Protocol(format!("expected {expected}, got {}", got.name()))
}

/// Connect to the master, process batches until `Eof`, answer `Finish`
/// with the final per-partition state rows.
pub fn run_worker(opts: &WorkerOptions) -> Result<WorkerOutcome, ClusterError> {
    let mut clock = RealClock;
    let mut control = transport::connect_retry(
        &opts.endpoint,
        CONNECT_ATTEMPTS,
        CONNECT_BASE,
        CONNECT_CAP,
        &mut clock,
    )?;
    control.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT))?;
    wire::write_frame(
        &mut control,
        &Message::HelloControl {
            worker_id: opts.worker_id,
        },
    )?;
    let mut feed = transport::connect_retry(
        &opts.endpoint,
        CONNECT_ATTEMPTS,
        CONNECT_BASE,
        CONNECT_CAP,
        &mut clock,
    )?;
    feed.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT))?;
    wire::write_frame(
        &mut feed,
        &Message::HelloFeed {
            worker_id: opts.worker_id,
        },
    )?;

    let assign = match wire::read_frame(&mut control)?.0 {
        Message::Assign(a) => a,
        other => return Err(unexpected("Assign", &other)),
    };
    let mut shard = if assign.restore {
        match wire::read_frame(&mut control)?.0 {
            Message::Restore(snap) => Shard::restore(&assign, &snap)?,
            other => return Err(unexpected("Restore", &other)),
        }
    } else {
        Shard::fresh(&assign)
    };
    let mut routes = assign.routes.to_flat()?;
    let mut source = SocketSource::from_env(feed);
    let num_threads = EngineConfig::from_env().num_threads;

    let n = shard.n_partitions;
    let (lo, hi) = (shard.lo, shard.hi);
    let mut interval = assign.next_interval;
    let mut buf: Vec<Record> = Vec::new();
    let mut loads = vec![0.0f64; hi - lo];
    let mut counts = vec![0u64; hi - lo];

    while source.try_next(&mut buf)? {
        if source.last_interval() != interval {
            return Err(ClusterError::Protocol(format!(
                "expected the batch for interval {interval}, got {}",
                source.last_interval()
            )));
        }
        if opts.fail_at == Some(interval) {
            return Ok(WorkerOutcome::FailInjected);
        }

        // DRW tap: the engines' round-robin record→DRW assignment,
        // restricted to this shard's global DRW indices
        for (i, r) in buf.iter().enumerate() {
            let d = i % n;
            if d >= lo && d < hi {
                shard.drws[d - lo].observe(r.key, r.weight);
            }
        }

        // shuffle fold in record order — the per-partition load sums and
        // keyed folds accumulate exactly as in the sequential oracle
        for l in loads.iter_mut() {
            *l = 0.0;
        }
        for c in counts.iter_mut() {
            *c = 0;
        }
        for r in &buf {
            let p = routes.partition(r.key);
            if p >= lo && p < hi {
                loads[p - lo] += r.weight;
                counts[p - lo] += 1;
                shard.stores[p - lo].fold_count(r.key, r.weight);
            }
        }

        let ship_k = shard.ship_k;
        let hists = harvest_sharded(&mut shard.drws, ship_k, num_threads);
        wire::write_frame(
            &mut control,
            &Message::Harvest(HarvestWire {
                interval,
                hists: hists.iter().map(HistogramWire::from_histogram).collect(),
                loads: loads.iter().map(|l| l.to_bits()).collect(),
                counts: counts.clone(),
                totals: shard
                    .stores
                    .iter()
                    .map(|s| s.total_weight().to_bits())
                    .collect(),
            }),
        )?;

        // control phase: optional plan/movers exchange, then the barrier
        loop {
            match wire::read_frame(&mut control)?.0 {
                Message::PlanRequest { routes: rw } => {
                    let candidate = rw.to_flat()?;
                    let mut movers = Vec::new();
                    for (idx, store) in shard.stores.iter().enumerate() {
                        let p = lo + idx;
                        for (key, st) in store.iter() {
                            if candidate.partition(key) != p {
                                movers.push(MoverWire {
                                    part: p as u32,
                                    key,
                                    state: KeyStateWire::from_state(st),
                                });
                            }
                        }
                    }
                    wire::write_frame(&mut control, &Message::Movers { interval, movers })?;
                }
                Message::BarrierEnd(be) => {
                    if be.interval != interval {
                        return Err(ClusterError::Protocol(format!(
                            "barrier for interval {}, expected {interval}",
                            be.interval
                        )));
                    }
                    // this worker's subsequence of the global plan, in
                    // plan order — the same per-store op sequences the
                    // oracle's apply_epoch_swap produces
                    for op in &be.ops {
                        match op {
                            OpWire::Extract { part, key } => {
                                let _ = shard.stores[*part as usize - lo].extract(*key);
                            }
                            OpWire::Install { part, key, state } => {
                                shard.stores[*part as usize - lo].install(*key, state.to_state());
                            }
                        }
                    }
                    if let Some((_epoch, rw)) = &be.swap {
                        routes = rw.to_flat()?;
                    }
                    let snapshot = shard.snapshot();
                    wire::write_frame(&mut control, &Message::BarrierDone { interval, snapshot })?;
                    break;
                }
                other => return Err(unexpected("PlanRequest or BarrierEnd", &other)),
            }
        }
        interval += 1;
    }

    // Eof on the feed: report final state and exit
    match wire::read_frame(&mut control)?.0 {
        Message::Finish => {}
        other => return Err(unexpected("Finish", &other)),
    }
    wire::write_frame(
        &mut control,
        &Message::FinalState {
            parts: shard.final_parts(),
        },
    )?;
    Ok(WorkerOutcome::Finished)
}
