//! The master process: spawn N worker processes, drive the streaming
//! decision pipeline over their sockets, and keep every virtual-time
//! column bitwise-identical to the in-process
//! [`StreamingEngine`](crate::ddps::StreamingEngine) oracle.
//!
//! The master owns exactly the pieces the single-process engine keeps on
//! the decision side — the [`DrMaster`], the [`DeciderState`], the
//! routing epoch — and mirrors its interval verbatim: shuffle-fold
//! accounting through [`exec::finish_stage_report`], proposal via
//! [`DrMaster::propose_sharded`] over the workers' wire-shipped
//! histograms, [`ProposalStats`] assembled from wire-summed mover
//! weights in the same accumulation order `predicted_migration` uses,
//! commit/decline on the decider's verdict, and an epoch swap whose op
//! list is the global `apply_epoch_swap` plan order restricted per
//! worker. Only the measured wall-clock columns may differ.
//!
//! Crash-restore: workers snapshot their shard into every `BarrierDone`;
//! when a worker's connection drops mid-interval the master respawns it,
//! replays the last barrier snapshot plus the retained in-flight batch,
//! and re-reads the harvest — the run's reports and final state are
//! bitwise those of a run that never lost the worker.

use super::transport::{self, Endpoint, Listener, Stream};
use super::wire::{
    self, AssignWire, BarrierEndWire, FinalPartWire, HarvestWire, Message, MoverWire, OpWire,
    RoutesWire, SnapshotWire,
};
use super::ClusterError;
use crate::ddps::exec::{self, MigrationReport, Scheduling};
use crate::ddps::{EngineConfig, EngineMetrics, IntervalReport};
use crate::dr::{DeciderState, DrConfig, DrMaster, PartitionerChoice, ProposalStats, Verdict};
use crate::partitioner::PartitionerEpoch;
use crate::sketch::Histogram;
use crate::state::StateStore;
use crate::workload::Record;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const ACCEPT_TIMEOUT: Duration = Duration::from_secs(20);
const IO_TIMEOUT: Duration = Duration::from_secs(120);
/// Revives allowed per harvest read before the error is surfaced.
const REVIVE_ATTEMPTS: u32 = 3;

/// How to stand the cluster up.
#[derive(Debug, Clone, Default)]
pub struct ClusterOptions {
    pub n_workers: usize,
    /// Binary to spawn workers from; defaults to the current executable
    /// (the `dynrepart worker` subcommand). Tests pass
    /// `env!("CARGO_BIN_EXE_dynrepart")` — the test harness binary has no
    /// `worker` subcommand.
    pub worker_bin: Option<PathBuf>,
    /// Directory for the master's Unix socket (defaults to the system
    /// temp dir).
    pub socket_dir: Option<PathBuf>,
    /// Test hook: worker `id` exits right after receiving the batch of
    /// `interval`, exercising the crash-restore path.
    pub fail_at: Option<(u32, u64)>,
}

impl ClusterOptions {
    pub fn new(n_workers: usize) -> Self {
        Self {
            n_workers,
            ..Self::default()
        }
    }
}

/// Wire-level accounting plus the run's determinism digests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ClusterStats {
    /// Batch-frame bytes broadcast over the feed connections.
    pub shuffle_bytes: u64,
    /// Plan/mover/barrier-op bytes exchanged for state migration.
    pub migration_bytes: u64,
    /// Barrier-snapshot and restore bytes.
    pub snapshot_bytes: u64,
    /// FNV digest over every adopted migration plan (interval, epoch,
    /// ops) — worker-count-invariant by construction.
    pub plan_digest: u64,
    /// [`final_digest`] of the run's final per-partition state rows (set
    /// by [`ClusterMaster::finish`]).
    pub state_digest: u64,
    /// Workers respawned after a dropped connection.
    pub worker_restores: u64,
}

/// What [`ClusterMaster::finish`] collects from the workers: one row per
/// partition, in partition order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinalStateSummary {
    pub parts: Vec<FinalPartWire>,
    /// Sum of the per-partition cached totals in partition order — the
    /// bit pattern of the oracle's `total_state_weight()`.
    pub total_state_weight: f64,
    pub state_digest: u64,
}

struct WorkerLink {
    child: Child,
    control: Stream,
    feed: Stream,
    lo: usize,
    hi: usize,
}

/// Kill-on-drop guard for the spawn window between `Command::spawn` and
/// the links taking ownership.
struct Pending(Vec<Child>);

impl Drop for Pending {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

pub struct ClusterMaster {
    cfg: EngineConfig,
    dr: DrConfig,
    seed: u64,
    drm: DrMaster,
    decider: DeciderState,
    partitioner: PartitionerEpoch,
    routes_wire: RoutesWire,
    metrics: EngineMetrics,
    /// Service rates are all `1.0` — the cluster models no slowdown
    /// events — kept as a vector so the stage accounting takes the same
    /// `Some(rates)` path the in-process engine takes.
    rates: Vec<f64>,
    links: Vec<WorkerLink>,
    listener: Listener,
    endpoint: Endpoint,
    worker_bin: PathBuf,
    /// Latest barrier snapshot per worker, for crash-restore.
    snapshots: Vec<Option<SnapshotWire>>,
    /// The in-flight batch frame, replayed to a revived worker.
    retained_batch: Option<Vec<u8>>,
    pending_barrier: bool,
    interval_no: u64,
    vtime: f64,
    recent_load: f64,
    stats: ClusterStats,
}

fn unexpected(expected: &str, got: &Message) -> ClusterError {
    ClusterError::Protocol(format!("expected {expected}, got {}", got.name()))
}

fn spawn_worker(
    bin: &Path,
    ep: &Endpoint,
    id: u32,
    fail_at: Option<u64>,
) -> Result<Child, ClusterError> {
    let mut cmd = Command::new(bin);
    cmd.arg("worker")
        .arg("--connect")
        .arg(ep.to_arg())
        .arg("--id")
        .arg(id.to_string());
    if let Some(at) = fail_at {
        cmd.arg("--fail-at").arg(at.to_string());
    }
    cmd.stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit());
    cmd.spawn().map_err(ClusterError::from)
}

impl ClusterMaster {
    /// Bind a fresh socket, spawn `opts.n_workers` worker processes,
    /// collect their hello handshakes and assign each its contiguous
    /// partition shard. Streaming-only: slots must cover the pinned
    /// partitions, exactly as [`StreamingEngine::new`] asserts.
    ///
    /// [`StreamingEngine::new`]: crate::ddps::StreamingEngine::new
    pub fn launch(
        cfg: EngineConfig,
        dr: DrConfig,
        choice: PartitionerChoice,
        seed: u64,
        opts: &ClusterOptions,
    ) -> Result<Self, ClusterError> {
        cfg.validate();
        let w = opts.n_workers;
        assert!(w >= 1, "need at least one worker");
        assert!(
            w <= cfg.n_partitions,
            "more workers than partitions: every worker needs a shard"
        );
        assert!(
            cfg.n_slots >= cfg.n_partitions,
            "streaming tasks are pinned: need slots >= partitions"
        );
        let drm = DrMaster::with_sketch(dr, choice, cfg.n_partitions, seed, cfg.sketch);
        let decider = DeciderState::new(dr.decider);
        let partitioner = drm.handle();
        let routes_wire =
            RoutesWire::from_flat(partitioner.flat().ok_or(ClusterError::NotLowerable)?);
        let endpoint = Endpoint::Unix(transport::fresh_socket_path(opts.socket_dir.as_deref()));
        let listener = Listener::bind(&endpoint)?;
        let worker_bin = match &opts.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe()?,
        };

        let mut pending = Pending(Vec::with_capacity(w));
        for id in 0..w as u32 {
            let fail_at = match opts.fail_at {
                Some((fid, at)) if fid == id => Some(at),
                _ => None,
            };
            pending.0.push(spawn_worker(&worker_bin, &endpoint, id, fail_at)?);
        }

        // Collect 2N hello-identified connections into their slots.
        let mut controls: Vec<Option<Stream>> = (0..w).map(|_| None).collect();
        let mut feeds: Vec<Option<Stream>> = (0..w).map(|_| None).collect();
        let place = |slots: &mut Vec<Option<Stream>>, id: u32, s: Stream| {
            let slot = slots
                .get_mut(id as usize)
                .ok_or_else(|| ClusterError::Protocol(format!("hello from unknown worker {id}")))?;
            if slot.is_some() {
                return Err(ClusterError::Protocol(format!(
                    "duplicate hello from worker {id}"
                )));
            }
            *slot = Some(s);
            Ok(())
        };
        for _ in 0..2 * w {
            let mut s = listener.accept_timeout(ACCEPT_TIMEOUT)?;
            s.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT))?;
            match wire::read_frame(&mut s)?.0 {
                Message::HelloControl { worker_id } => place(&mut controls, worker_id, s)?,
                Message::HelloFeed { worker_id } => place(&mut feeds, worker_id, s)?,
                other => return Err(unexpected("a hello", &other)),
            }
        }

        let children = std::mem::take(&mut pending.0);
        std::mem::forget(pending);
        let n = cfg.n_partitions;
        let links = children
            .into_iter()
            .enumerate()
            .map(|(id, child)| WorkerLink {
                child,
                control: controls[id].take().expect("all hellos collected"),
                feed: feeds[id].take().expect("all hellos collected"),
                lo: id * n / w,
                hi: (id + 1) * n / w,
            })
            .collect();

        let mut master = Self {
            rates: vec![1.0; cfg.n_partitions],
            snapshots: (0..w).map(|_| None).collect(),
            cfg,
            dr,
            seed,
            drm,
            decider,
            partitioner,
            routes_wire,
            metrics: EngineMetrics::default(),
            links,
            listener,
            endpoint,
            worker_bin,
            retained_batch: None,
            pending_barrier: false,
            interval_no: 0,
            vtime: 0.0,
            recent_load: 0.0,
            stats: ClusterStats::default(),
        };
        for id in 0..w {
            let assign = master.make_assign(id, 1, false);
            wire::write_frame(&mut master.links[id].control, &Message::Assign(assign))?;
        }
        Ok(master)
    }

    fn make_assign(&self, id: usize, next_interval: u64, restore: bool) -> AssignWire {
        let link = &self.links[id];
        AssignWire {
            worker_id: id as u32,
            n_workers: self.links.len() as u32,
            n_partitions: self.cfg.n_partitions as u32,
            part_lo: link.lo as u32,
            part_hi: link.hi as u32,
            base_seed: self.seed,
            sample_rate_bits: self.dr.sample_rate.to_bits(),
            counter_capacity: self.drm.worker_capacity() as u64,
            sketch_compaction: self.cfg.sketch.compaction_interval as u64,
            sketch_bound: self.cfg.sketch.size_boundary as u64,
            sketch_take: self.cfg.sketch.take_top_k as u64,
            ship_k: self.drm.ship_size() as u64,
            next_interval,
            epoch: self.partitioner.epoch(),
            restore,
            routes: self.routes_wire.clone(),
        }
    }

    /// One decision interval over the wire — the distributed
    /// `run_interval`. Broadcast the batch, close the previous barrier,
    /// collect harvests (reviving any worker whose connection dropped),
    /// run the proposal → decider → commit path on the master, derive the
    /// migration op list, and close the interval with a `BarrierEnd`.
    pub fn run_interval(&mut self, records: &[Record]) -> Result<IntervalReport, ClusterError> {
        let span = Instant::now();
        self.interval_no += 1;
        let interval = self.interval_no;

        // (1) Broadcast the batch. Workers prefetch on a dedicated
        // thread, so these writes drain even while workers sit in the
        // previous barrier.
        let frame = wire::encode_frame(&Message::Batch {
            interval,
            records: records.to_vec(),
        })?;
        for link in &mut self.links {
            link.feed.write_all(&frame)?;
            link.feed.flush()?;
            self.stats.shuffle_bytes += frame.len() as u64;
        }
        self.retained_batch = Some(frame);

        // (2) Close the previous interval's barrier — overlapped behind
        // the batch broadcast, like the pipelined in-process loop.
        if self.pending_barrier {
            self.await_barrier(interval - 1)?;
            self.pending_barrier = false;
        }

        // (3) Harvests, worker by worker in shard order; a dropped
        // connection here is the crash-restore path.
        let stage_start = Instant::now();
        let n = self.cfg.n_partitions;
        let mut loads = vec![0.0f64; n];
        let mut counts = vec![0u64; n];
        let mut totals = vec![0.0f64; n];
        let mut hists: Vec<Histogram> = Vec::with_capacity(n);
        for id in 0..self.links.len() {
            let h = self.read_harvest(id, interval)?;
            let (lo, hi) = (self.links[id].lo, self.links[id].hi);
            let shard = hi - lo;
            if h.hists.len() != shard
                || h.loads.len() != shard
                || h.counts.len() != shard
                || h.totals.len() != shard
            {
                return Err(ClusterError::Protocol(format!(
                    "worker {id} harvested {} partitions, owns {shard}",
                    h.hists.len()
                )));
            }
            hists.extend(h.hists.iter().map(|hw| hw.to_histogram()));
            for off in 0..shard {
                loads[lo + off] = f64::from_bits(h.loads[off]);
                counts[lo + off] = h.counts[off];
                totals[lo + off] = f64::from_bits(h.totals[off]);
            }
        }
        let stage_wall = stage_start.elapsed().as_secs_f64();

        // (4) Stage accounting through the exact in-process code path —
        // the workers' fold-order load sums feed the same arithmetic.
        let mut stage = exec::finish_stage_report(
            &self.cfg,
            Scheduling::Pinned,
            records.len(),
            loads,
            counts,
            Some(&self.rates),
            stage_wall,
        );

        // (5) Proposal + decider verdict, mirroring `resolve_and_adopt`:
        // histograms concatenate in worker order == the sequential DRW
        // harvest order, and the predicted migration sums mover weights
        // in the global store-walk order.
        let dwall_start = Instant::now();
        let proposal = self.drm.propose_sharded(hists, self.cfg.num_threads);
        let mut dwall = dwall_start.elapsed().as_secs_f64();

        let resolve_start = Instant::now();
        let total_state: f64 = totals.iter().sum();
        let mut gathered: Option<Vec<MoverWire>> = None;
        let (moved_pred, fraction_pred) =
            if proposal.worth_it && self.decider.policy().prices_migration() {
                let candidate = proposal
                    .candidate()
                    .expect("worthwhile proposals carry a candidate");
                let flat = candidate.flat_routes().ok_or(ClusterError::NotLowerable)?;
                let rw = RoutesWire::from_flat(&flat);
                let movers = self.gather_movers(interval, &rw)?;
                let moved: f64 = movers
                    .iter()
                    .map(|m| f64::from_bits(m.state.weight_bits))
                    .sum();
                let fraction = if total_state > 0.0 { moved / total_state } else { 0.0 };
                gathered = Some(movers);
                (moved, fraction)
            } else {
                (0.0, 0.0)
            };
        let pstats = ProposalStats {
            worth_it: proposal.worth_it,
            current_max_share: proposal.current_max_share,
            planned_max_share: proposal.planned_max_share,
            heavy_mass: proposal.histogram.heavy_mass(),
            predicted_moved_weight: moved_pred,
            predicted_migration_fraction: fraction_pred,
            recent_load: self.recent_load,
            reduce_cost: self.cfg.reduce_cost,
            migration_cost: self.cfg.migration_cost,
        };
        let verdict = self.decider.judge(&pstats);
        let decision = match verdict {
            Verdict::Adopt => self.drm.commit(proposal),
            Verdict::Defer | Verdict::Reject => self.drm.decline(proposal),
        };
        dwall += resolve_start.elapsed().as_secs_f64();

        // (6) Adoption: derive the global op list in `apply_epoch_swap`
        // plan order (workers in shard order, keys in slab order, Extract
        // then Install per key) and switch the master's routing epoch.
        let mut ops: Vec<OpWire> = Vec::new();
        let mut barrier_swap: Option<(u64, RoutesWire)> = None;
        let (migration, repartitioned) = if let Some(swap) = decision.swap {
            let flat = swap
                .to
                .flat()
                .cloned()
                .ok_or(ClusterError::NotLowerable)?;
            let rw = RoutesWire::from_flat(&flat);
            // the priced path already gathered against the identical
            // candidate routing; Naive/Threshold gather now
            let movers = match gathered.take() {
                Some(m) => m,
                None => self.gather_movers(interval, &rw)?,
            };
            let mut moved = 0.0;
            ops.reserve(movers.len() * 2);
            for m in &movers {
                moved += f64::from_bits(m.state.weight_bits);
                ops.push(OpWire::Extract {
                    part: m.part,
                    key: m.key,
                });
                ops.push(OpWire::Install {
                    part: flat.partition(m.key) as u32,
                    key: m.key,
                    state: m.state.clone(),
                });
            }
            let fraction = if total_state > 0.0 { moved / total_state } else { 0.0 };
            self.stats.plan_digest =
                plan_digest_step(self.stats.plan_digest, interval, swap.to.epoch(), &ops);
            self.partitioner = swap.to.clone();
            self.routes_wire = rw.clone();
            self.metrics.state_weight_migrated += moved;
            self.metrics.repartition_count += 1;
            barrier_swap = Some((swap.to.epoch(), rw));
            (
                MigrationReport {
                    pause: moved * self.cfg.migration_cost,
                    moved_weight: moved,
                    migrated_fraction: fraction,
                },
                true,
            )
        } else {
            (MigrationReport::none(), false)
        };

        // (7) Close the interval: each worker gets its shard's op
        // subsequence (global order preserved) plus the swap, applies it,
        // snapshots, and answers BarrierDone — which we collect at the
        // start of the next interval.
        for id in 0..self.links.len() {
            let (lo, hi) = (self.links[id].lo, self.links[id].hi);
            let be = BarrierEndWire {
                interval,
                swap: barrier_swap.clone(),
                ops: ops
                    .iter()
                    .filter(|op| (op.part() as usize) >= lo && (op.part() as usize) < hi)
                    .cloned()
                    .collect(),
            };
            let nbytes = wire::write_frame(&mut self.links[id].control, &Message::BarrierEnd(be))?;
            if barrier_swap.is_some() {
                self.stats.migration_bytes += nbytes as u64;
            }
        }
        self.pending_barrier = true;

        // (8) Assemble, mirroring the in-process `assemble` verbatim —
        // recent_load updates only after the stats consumed the previous
        // interval's value.
        stage.decision_wall_s = dwall;
        self.recent_load = stage.loads.iter().sum();
        let pipeline_wall_s = span.elapsed().as_secs_f64();
        let busy = stage.wall_s + dwall;
        let makespan = migration.pause + stage.stage_time;
        let m = &mut self.metrics;
        m.records_processed += records.len() as u64;
        m.total_vtime += makespan;
        m.reduce_vtime += stage.reduce_time;
        m.migration_vtime += migration.pause;
        m.wall_s += stage.wall_s;
        m.decision_wall_s += dwall;
        m.pipeline_wall_s += pipeline_wall_s;
        self.vtime += makespan;
        Ok(IntervalReport {
            interval_no: interval,
            elapsed: makespan,
            wall_s: stage.wall_s,
            decision_wall_s: dwall,
            source_wall_s: 0.0,
            pipeline_occupancy: if pipeline_wall_s > 0.0 {
                busy / pipeline_wall_s
            } else {
                1.0
            },
            throughput: if makespan > 0.0 {
                records.len() as f64 / makespan
            } else {
                0.0
            },
            imbalance: stage.imbalance,
            migrated_fraction: migration.migrated_fraction,
            migration_pause: migration.pause,
            repartitioned,
            bottleneck_ratio: stage.bottleneck_ratio,
            epoch: self.partitioner.epoch(),
            loads: stage.loads,
            decisions_adopted: self.decider.adopted(),
            decisions_deferred: self.decider.deferred(),
        })
    }

    fn await_barrier(&mut self, interval: u64) -> Result<(), ClusterError> {
        for id in 0..self.links.len() {
            let (msg, nbytes) = wire::read_frame(&mut self.links[id].control)?;
            match msg {
                Message::BarrierDone {
                    interval: i,
                    snapshot,
                } if i == interval => {
                    self.stats.snapshot_bytes += nbytes as u64;
                    self.snapshots[id] = Some(snapshot);
                }
                other => return Err(unexpected("BarrierDone", &other)),
            }
        }
        Ok(())
    }

    fn read_harvest(&mut self, id: usize, interval: u64) -> Result<HarvestWire, ClusterError> {
        let mut revives = 0;
        loop {
            match wire::read_frame(&mut self.links[id].control) {
                Ok((Message::Harvest(h), _)) if h.interval == interval => return Ok(h),
                Ok((Message::Harvest(h), _)) => {
                    return Err(ClusterError::Protocol(format!(
                        "worker {id} harvested interval {}, expected {interval}",
                        h.interval
                    )));
                }
                Ok((other, _)) => return Err(unexpected("Harvest", &other)),
                Err(e @ (ClusterError::Disconnected(_) | ClusterError::Truncated(_))) => {
                    revives += 1;
                    if revives > REVIVE_ATTEMPTS {
                        return Err(e);
                    }
                    self.revive_worker(id, interval)?;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Respawn worker `id` after a dropped connection: fresh process,
    /// fresh handshake, the last barrier snapshot (if any — a crash in
    /// interval 1 restores fresh empty state, which is exactly what the
    /// worker had), and the retained in-flight batch replayed.
    fn revive_worker(&mut self, id: usize, interval: u64) -> Result<(), ClusterError> {
        let _ = self.links[id].child.kill();
        let _ = self.links[id].child.wait();
        let child = spawn_worker(&self.worker_bin, &self.endpoint, id as u32, None)?;
        self.links[id].child = child;
        let (control, feed) = self.accept_pair(id as u32)?;
        self.links[id].control = control;
        self.links[id].feed = feed;
        let restore = self.snapshots[id].is_some();
        let assign = self.make_assign(id, interval, restore);
        wire::write_frame(&mut self.links[id].control, &Message::Assign(assign))?;
        if let Some(snap) = self.snapshots[id].clone() {
            let nbytes =
                wire::write_frame(&mut self.links[id].control, &Message::Restore(snap))?;
            self.stats.snapshot_bytes += nbytes as u64;
        }
        let frame = self
            .retained_batch
            .clone()
            .expect("a batch is in flight whenever a harvest is awaited");
        self.links[id].feed.write_all(&frame)?;
        self.links[id].feed.flush()?;
        self.stats.shuffle_bytes += frame.len() as u64;
        self.stats.worker_restores += 1;
        Ok(())
    }

    /// Accept the two hello-identified connections of one respawned
    /// worker.
    fn accept_pair(&mut self, expect: u32) -> Result<(Stream, Stream), ClusterError> {
        let mut control = None;
        let mut feed = None;
        while control.is_none() || feed.is_none() {
            let mut s = self.listener.accept_timeout(ACCEPT_TIMEOUT)?;
            s.set_timeouts(Some(IO_TIMEOUT), Some(IO_TIMEOUT))?;
            match wire::read_frame(&mut s)?.0 {
                Message::HelloControl { worker_id } if worker_id == expect && control.is_none() => {
                    control = Some(s);
                }
                Message::HelloFeed { worker_id } if worker_id == expect && feed.is_none() => {
                    feed = Some(s);
                }
                other => {
                    return Err(ClusterError::Protocol(format!(
                        "unexpected {} while re-accepting worker {expect}",
                        other.name()
                    )));
                }
            }
        }
        Ok((control.expect("looped until set"), feed.expect("looped until set")))
    }

    fn gather_movers(
        &mut self,
        interval: u64,
        rw: &RoutesWire,
    ) -> Result<Vec<MoverWire>, ClusterError> {
        let msg = Message::PlanRequest { routes: rw.clone() };
        for id in 0..self.links.len() {
            let nbytes = wire::write_frame(&mut self.links[id].control, &msg)?;
            self.stats.migration_bytes += nbytes as u64;
        }
        let mut all = Vec::new();
        for id in 0..self.links.len() {
            match wire::read_frame(&mut self.links[id].control)? {
                (Message::Movers { interval: i, movers }, nbytes) if i == interval => {
                    self.stats.migration_bytes += nbytes as u64;
                    all.extend(movers);
                }
                (other, _) => return Err(unexpected("Movers", &other)),
            }
        }
        Ok(all)
    }

    /// Close the last barrier, signal end-of-feed, and collect every
    /// worker's final per-partition state rows (partition order).
    pub fn finish(&mut self) -> Result<FinalStateSummary, ClusterError> {
        if self.pending_barrier {
            self.await_barrier(self.interval_no)?;
            self.pending_barrier = false;
        }
        let eof = wire::encode_frame(&Message::Eof)?;
        for link in &mut self.links {
            link.feed.write_all(&eof)?;
            link.feed.flush()?;
        }
        for id in 0..self.links.len() {
            wire::write_frame(&mut self.links[id].control, &Message::Finish)?;
        }
        let mut parts: Vec<FinalPartWire> = Vec::with_capacity(self.cfg.n_partitions);
        for id in 0..self.links.len() {
            match wire::read_frame(&mut self.links[id].control)?.0 {
                Message::FinalState { parts: p } => {
                    let shard = self.links[id].hi - self.links[id].lo;
                    if p.len() != shard {
                        return Err(ClusterError::Protocol(format!(
                            "worker {id} reported {} final partitions, owns {shard}",
                            p.len()
                        )));
                    }
                    parts.extend(p);
                }
                other => return Err(unexpected("FinalState", &other)),
            }
        }
        for link in &mut self.links {
            let _ = link.child.wait();
        }
        let total_state_weight = parts.iter().map(|p| f64::from_bits(p.total_bits)).sum();
        let state_digest = final_digest(&parts);
        self.stats.state_digest = state_digest;
        Ok(FinalStateSummary {
            parts,
            total_state_weight,
            state_digest,
        })
    }

    pub fn epoch(&self) -> u64 {
        self.partitioner.epoch()
    }

    pub fn vtime(&self) -> f64 {
        self.vtime
    }

    pub fn interval_no(&self) -> u64 {
        self.interval_no
    }

    pub fn n_workers(&self) -> usize {
        self.links.len()
    }

    pub fn stats(&self) -> &ClusterStats {
        &self.stats
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }
}

impl Drop for ClusterMaster {
    fn drop(&mut self) {
        for link in &mut self.links {
            let _ = link.child.kill();
            let _ = link.child.wait();
        }
        if let Endpoint::Unix(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

fn fnv(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold one adopted migration plan into the running plan digest. Covers
/// the interval, the new epoch, and every op (tag, partition, key, and
/// the moved weight's bits for installs) — identical op streams at any
/// worker count produce identical digests.
fn plan_digest_step(h: u64, interval: u64, epoch: u64, ops: &[OpWire]) -> u64 {
    let mut h = if h == 0 { FNV_OFFSET } else { h };
    h = fnv(h, interval);
    h = fnv(h, epoch);
    for op in ops {
        match op {
            OpWire::Extract { part, key } => {
                h = fnv(h, 0);
                h = fnv(h, *part as u64);
                h = fnv(h, *key);
            }
            OpWire::Install { part, key, state } => {
                h = fnv(h, 1);
                h = fnv(h, *part as u64);
                h = fnv(h, *key);
                h = fnv(h, state.weight_bits);
            }
        }
    }
    h
}

/// FNV digest over final per-partition state rows — what the cluster's
/// `state_digest` pins against the in-process oracle.
pub fn final_digest(parts: &[FinalPartWire]) -> u64 {
    let mut h = FNV_OFFSET;
    for p in parts {
        h = fnv(h, p.part as u64);
        h = fnv(h, p.n_keys);
        h = fnv(h, p.fingerprint);
        h = fnv(h, p.total_bits);
    }
    h
}

/// [`final_digest`] of an in-process engine's stores — the oracle side of
/// the cluster's final-state pin.
pub fn store_digest(stores: &[StateStore]) -> u64 {
    let parts: Vec<FinalPartWire> = stores
        .iter()
        .enumerate()
        .map(|(p, s)| FinalPartWire {
            part: p as u32,
            n_keys: s.n_keys() as u64,
            fingerprint: s.fingerprint(),
            total_bits: s.total_weight().to_bits(),
        })
        .collect();
    final_digest(&parts)
}
