//! Socket plumbing under the wire codec: endpoints (Unix or TCP),
//! listeners with accept deadlines, streams with read/write timeouts,
//! and bounded retry with exponential backoff.
//!
//! Failure surfaces by name, never by panic: a refused connect is
//! [`ClusterError::ConnectRefused`], an elapsed deadline is
//! [`ClusterError::Timeout`], a peer closing mid-frame reaches the codec
//! as [`ClusterError::Truncated`]. Retry pacing goes through the
//! [`Clock`] trait so tests pin the exact backoff schedule with a fake
//! clock — no real sleeps in CI.

use super::ClusterError;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Where a cluster socket lives: a Unix socket path or a TCP address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    Unix(PathBuf),
    Tcp(String),
}

impl Endpoint {
    pub fn unix(path: impl Into<PathBuf>) -> Self {
        Self::Unix(path.into())
    }

    pub fn tcp(addr: impl Into<String>) -> Self {
        Self::Tcp(addr.into())
    }

    /// Parse the CLI form: `tcp:<addr>`, `unix:<path>`, or a bare path
    /// (treated as a Unix socket).
    pub fn parse(s: &str) -> Self {
        if let Some(addr) = s.strip_prefix("tcp:") {
            Self::Tcp(addr.to_string())
        } else if let Some(path) = s.strip_prefix("unix:") {
            Self::Unix(path.into())
        } else {
            Self::Unix(s.into())
        }
    }

    /// The prefixed CLI form [`Endpoint::parse`] reads back.
    pub fn to_arg(&self) -> String {
        match self {
            Self::Unix(p) => format!("unix:{}", p.display()),
            Self::Tcp(a) => format!("tcp:{a}"),
        }
    }
}

/// A bound listening socket.
pub enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    pub fn bind(ep: &Endpoint) -> Result<Self, ClusterError> {
        match ep {
            Endpoint::Unix(p) => {
                // a stale socket file from a dead process would refuse the bind
                let _ = std::fs::remove_file(p);
                Ok(Self::Unix(UnixListener::bind(p)?))
            }
            Endpoint::Tcp(a) => Ok(Self::Tcp(TcpListener::bind(a.as_str())?)),
        }
    }

    /// Accept one connection within `timeout`, by polling a non-blocking
    /// accept. The returned stream is left in blocking mode.
    pub fn accept_timeout(&self, timeout: Duration) -> Result<Stream, ClusterError> {
        let start = Instant::now();
        self.set_nonblocking(true)?;
        let result = loop {
            let attempt = match self {
                Self::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
                Self::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            };
            match attempt {
                Ok(s) => break Ok(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if start.elapsed() >= timeout {
                        break Err(ClusterError::Timeout(format!(
                            "no connection within {timeout:?}"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => break Err(e.into()),
            }
        };
        self.set_nonblocking(false)?;
        let s = result?;
        s.set_nonblocking(false)?;
        Ok(s)
    }

    fn set_nonblocking(&self, nb: bool) -> Result<(), ClusterError> {
        match self {
            Self::Unix(l) => l.set_nonblocking(nb)?,
            Self::Tcp(l) => l.set_nonblocking(nb)?,
        }
        Ok(())
    }
}

/// A connected stream; [`Read`]/[`Write`] delegate to the inner socket.
pub enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub fn set_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<(), ClusterError> {
        match self {
            Self::Unix(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)?;
            }
            Self::Tcp(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)?;
            }
        }
        Ok(())
    }

    fn set_nonblocking(&self, nb: bool) -> Result<(), ClusterError> {
        match self {
            Self::Unix(s) => s.set_nonblocking(nb)?,
            Self::Tcp(s) => s.set_nonblocking(nb)?,
        }
        Ok(())
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Unix(s) => s.read(buf),
            Self::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Unix(s) => s.write(buf),
            Self::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Unix(s) => s.flush(),
            Self::Tcp(s) => s.flush(),
        }
    }
}

/// One connection attempt.
pub fn connect(ep: &Endpoint) -> Result<Stream, ClusterError> {
    match ep {
        Endpoint::Unix(p) => Ok(Stream::Unix(UnixStream::connect(p)?)),
        Endpoint::Tcp(a) => Ok(Stream::Tcp(TcpStream::connect(a.as_str())?)),
    }
}

/// Injectable time source for retry pacing; production uses
/// [`RealClock`], tests substitute a recording fake.
pub trait Clock {
    fn sleep(&mut self, d: Duration);
}

pub struct RealClock;

impl Clock for RealClock {
    fn sleep(&mut self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Is this error worth another attempt? Corruption and protocol errors
/// are not — retrying a bad frame yields the same bad frame.
pub fn is_retryable(e: &ClusterError) -> bool {
    matches!(
        e,
        ClusterError::ConnectRefused(_) | ClusterError::Timeout(_) | ClusterError::Io(_)
    )
}

/// Run `op` up to `attempts` times. After each failed retryable attempt
/// except the last, sleep exactly once on `clock`, doubling from `base`
/// and capping at `cap`. A non-retryable error aborts immediately.
pub fn retry<T>(
    attempts: u32,
    base: Duration,
    cap: Duration,
    clock: &mut dyn Clock,
    mut op: impl FnMut() -> Result<T, ClusterError>,
) -> Result<T, ClusterError> {
    assert!(attempts >= 1, "need at least one attempt");
    let mut backoff = base.min(cap);
    let mut last = None;
    for attempt in 0..attempts {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if is_retryable(&e) => last = Some(e),
            Err(e) => return Err(e),
        }
        if attempt + 1 < attempts {
            clock.sleep(backoff);
            backoff = (backoff * 2).min(cap);
        }
    }
    Err(last.expect("at least one attempt ran"))
}

/// [`connect`] under [`retry`] — how workers reach a master that may
/// still be binding its socket.
pub fn connect_retry(
    ep: &Endpoint,
    attempts: u32,
    base: Duration,
    cap: Duration,
    clock: &mut dyn Clock,
) -> Result<Stream, ClusterError> {
    retry(attempts, base, cap, clock, || connect(ep))
}

static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// A socket path under `dir` (or the system temp dir) that is unique per
/// process and call — masters bind here, workers get the path as an arg.
pub fn fresh_socket_path(dir: Option<&Path>) -> PathBuf {
    let seq = SOCK_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = dir.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
    dir.join(format!("dynrepart-{}-{seq}.sock", std::process::id()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddps::cluster::wire::{self, Message};

    /// Records requested sleeps instead of performing them.
    struct FakeClock {
        slept: Vec<Duration>,
    }

    impl FakeClock {
        fn new() -> Self {
            Self { slept: Vec::new() }
        }
    }

    impl Clock for FakeClock {
        fn sleep(&mut self, d: Duration) {
            self.slept.push(d);
        }
    }

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn connect_to_missing_socket_is_connect_refused() {
        let ep = Endpoint::unix(fresh_socket_path(None));
        assert!(matches!(
            connect(&ep),
            Err(ClusterError::ConnectRefused(_))
        ));
    }

    #[test]
    fn retry_sleeps_exactly_once_per_failed_attempt_with_backoff() {
        let mut clock = FakeClock::new();
        let mut calls = 0;
        let out = retry(5, ms(10), ms(1000), &mut clock, || {
            calls += 1;
            if calls < 4 {
                Err(ClusterError::ConnectRefused("not yet".into()))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), 4);
        assert_eq!(calls, 4);
        assert_eq!(clock.slept, vec![ms(10), ms(20), ms(40)]);
    }

    #[test]
    fn retry_backoff_caps_and_total_failure_returns_last_error() {
        let mut clock = FakeClock::new();
        let mut calls = 0;
        let out: Result<(), _> = retry(4, ms(10), ms(25), &mut clock, || {
            calls += 1;
            Err(ClusterError::Timeout(format!("attempt {calls}")))
        });
        assert_eq!(out.unwrap_err(), ClusterError::Timeout("attempt 4".into()));
        assert_eq!(calls, 4);
        // one sleep per failed attempt except the last, capped at 25ms
        assert_eq!(clock.slept, vec![ms(10), ms(20), ms(25)]);
    }

    #[test]
    fn non_retryable_error_aborts_without_sleeping() {
        let mut clock = FakeClock::new();
        let mut calls = 0;
        let out: Result<(), _> = retry(5, ms(10), ms(1000), &mut clock, || {
            calls += 1;
            Err(ClusterError::BadMagic(7))
        });
        assert_eq!(out.unwrap_err(), ClusterError::BadMagic(7));
        assert_eq!(calls, 1);
        assert!(clock.slept.is_empty());
    }

    #[test]
    fn connect_retry_paces_through_the_clock() {
        let ep = Endpoint::unix(fresh_socket_path(None));
        let mut clock = FakeClock::new();
        let out = connect_retry(&ep, 3, ms(5), ms(100), &mut clock);
        assert!(matches!(out, Err(ClusterError::ConnectRefused(_))));
        assert_eq!(clock.slept, vec![ms(5), ms(10)]);
    }

    #[test]
    fn mid_frame_disconnect_is_truncated() {
        // a peer that writes half a frame and drops the connection
        let (mut a, b) = UnixStream::pair().unwrap();
        let frame = wire::encode_frame(&Message::Batch {
            interval: 1,
            records: vec![],
        })
        .unwrap();
        a.write_all(&frame[..frame.len() - 4]).unwrap();
        drop(a);
        let mut s = Stream::Unix(b);
        assert!(matches!(
            wire::read_frame(&mut s),
            Err(ClusterError::Truncated(_))
        ));
    }

    #[test]
    fn clean_close_at_frame_boundary_is_disconnected() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let mut s = Stream::Unix(b);
        assert!(matches!(
            wire::read_frame(&mut s),
            Err(ClusterError::Disconnected(_))
        ));
    }

    #[test]
    fn read_deadline_surfaces_as_timeout() {
        let (_a, b) = UnixStream::pair().unwrap();
        let s = Stream::Unix(b);
        s.set_timeouts(Some(ms(30)), None).unwrap();
        let mut s = s;
        assert!(matches!(
            wire::read_frame(&mut s),
            Err(ClusterError::Timeout(_))
        ));
    }

    #[test]
    fn accept_deadline_surfaces_as_timeout() {
        let ep = Endpoint::unix(fresh_socket_path(None));
        let listener = Listener::bind(&ep).unwrap();
        assert!(matches!(
            listener.accept_timeout(ms(30)),
            Err(ClusterError::Timeout(_))
        ));
        if let Endpoint::Unix(p) = &ep {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn accept_returns_the_connecting_stream() {
        let ep = Endpoint::unix(fresh_socket_path(None));
        let listener = Listener::bind(&ep).unwrap();
        let ep2 = ep.clone();
        let client = std::thread::spawn(move || {
            let mut clock = RealClock;
            let mut s = connect_retry(&ep2, 20, ms(2), ms(20), &mut clock).unwrap();
            wire::write_frame(&mut s, &Message::HelloControl { worker_id: 9 }).unwrap();
        });
        let mut s = listener.accept_timeout(Duration::from_secs(5)).unwrap();
        let (msg, _) = wire::read_frame(&mut s).unwrap();
        assert_eq!(msg, Message::HelloControl { worker_id: 9 });
        client.join().unwrap();
        if let Endpoint::Unix(p) = &ep {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn endpoint_arg_forms_round_trip() {
        for ep in [
            Endpoint::unix("/tmp/x.sock"),
            Endpoint::tcp("127.0.0.1:9999"),
        ] {
            assert_eq!(Endpoint::parse(&ep.to_arg()), ep);
        }
        assert_eq!(
            Endpoint::parse("/tmp/bare.sock"),
            Endpoint::unix("/tmp/bare.sock")
        );
    }
}
