//! Length-prefixed binary frames with a versioned header — the entire
//! cluster protocol in one codec.
//!
//! Frame layout (all little-endian):
//!
//! ```text
//!   magic   u32   0xD14E50A7
//!   version u16   1
//!   type    u16   message code (1..=13)
//!   len     u32   payload bytes (≤ MAX_PAYLOAD)
//!   payload [u8; len]
//! ```
//!
//! Decoding is strict and total: oversized frames are rejected *before*
//! allocation, truncated and garbage frames surface as named
//! [`ClusterError`] variants, a decoded payload must consume every byte,
//! and no input makes the decoder panic. Every `f64` crosses the wire as
//! its `to_bits()` image, so weights, loads, totals and counter values
//! arrive bit-for-bit — the invariant the cluster's bitwise oracle tests
//! lean on.

use super::ClusterError;
use crate::partitioner::{FlatRoutes, RouteTable};
use crate::sketch::{Histogram, HistogramEntry};
use crate::state::KeyState;
use crate::workload::Record;
use std::io::{Read, Write};

pub const MAGIC: u32 = 0xD14E_50A7;
pub const VERSION: u16 = 1;
pub const HEADER_LEN: usize = 12;
/// Upper bound on one frame's payload; a header declaring more is
/// rejected before any allocation happens.
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;

// ---------------------------------------------------------------- encoder

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn boolean(&mut self, v: bool) {
        self.u8(v as u8);
    }

    fn seq_len(&mut self, n: usize) {
        debug_assert!(n <= u32::MAX as usize, "sequence too long for the wire");
        self.u32(n as u32);
    }
}

// ---------------------------------------------------------------- decoder

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ClusterError> {
        if self.remaining() < n {
            return Err(ClusterError::Truncated(format!(
                "need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ClusterError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ClusterError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ClusterError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ClusterError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn boolean(&mut self) -> Result<bool, ClusterError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(ClusterError::BadMessage(format!("bool byte {b}"))),
        }
    }

    /// A sequence-length prefix, sanity-checked against the bytes left:
    /// `n` elements of at least `min_elem` bytes each must fit, so a
    /// corrupted length can never trigger an oversized allocation.
    fn seq_len(&mut self, min_elem: usize) -> Result<usize, ClusterError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem) > self.remaining() {
            return Err(ClusterError::Truncated(format!(
                "sequence of {n} elements (≥ {min_elem} B each) exceeds {} remaining bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn finish(self) -> Result<(), ClusterError> {
        if self.pos != self.buf.len() {
            return Err(ClusterError::BadMessage(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ------------------------------------------------------------ wire structs

/// A [`FlatRoutes`] snapshot on the wire: explicit pairs in ascending key
/// order, the dense host→partition table, and the tail-hash seed. The
/// lowering is exact, so shipping routes never changes a single routing
/// decision.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutesWire {
    pub explicit: Vec<(u64, u32)>,
    pub hosts: Vec<u32>,
    pub seed: u64,
}

impl RoutesWire {
    pub fn from_flat(f: &FlatRoutes) -> Self {
        Self {
            explicit: f.explicit().iter().collect(),
            hosts: f.hosts().to_vec(),
            seed: f.seed(),
        }
    }

    pub fn to_flat(&self) -> Result<FlatRoutes, ClusterError> {
        if self.hosts.is_empty() {
            return Err(ClusterError::BadMessage("routes with no hosts".into()));
        }
        Ok(FlatRoutes::new(
            RouteTable::from_pairs(self.explicit.clone()),
            self.hosts.clone(),
            self.seed,
        ))
    }

    fn enc(&self, e: &mut Enc) {
        e.seq_len(self.explicit.len());
        for &(k, p) in &self.explicit {
            e.u64(k);
            e.u32(p);
        }
        e.seq_len(self.hosts.len());
        for &h in &self.hosts {
            e.u32(h);
        }
        e.u64(self.seed);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let n = d.seq_len(12)?;
        let mut explicit = Vec::with_capacity(n);
        for _ in 0..n {
            explicit.push((d.u64()?, d.u32()?));
        }
        let n = d.seq_len(4)?;
        let mut hosts = Vec::with_capacity(n);
        for _ in 0..n {
            hosts.push(d.u32()?);
        }
        let seed = d.u64()?;
        Ok(Self {
            explicit,
            hosts,
            seed,
        })
    }
}

/// One keyed [`KeyState`] on the wire, weight and values as raw bits.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyStateWire {
    pub records: u64,
    pub weight_bits: u64,
    pub values_bits: Vec<u64>,
}

impl KeyStateWire {
    pub fn from_state(st: &KeyState) -> Self {
        Self {
            records: st.records,
            weight_bits: st.weight.to_bits(),
            values_bits: st.values.iter().map(|v| v.to_bits()).collect(),
        }
    }

    pub fn to_state(&self) -> KeyState {
        let mut st = KeyState::new();
        st.records = self.records;
        st.weight = f64::from_bits(self.weight_bits);
        st.values = self.values_bits.iter().map(|&b| f64::from_bits(b)).collect();
        st
    }

    fn enc(&self, e: &mut Enc) {
        e.u64(self.records);
        e.u64(self.weight_bits);
        e.seq_len(self.values_bits.len());
        for &v in &self.values_bits {
            e.u64(v);
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let records = d.u64()?;
        let weight_bits = d.u64()?;
        let n = d.seq_len(8)?;
        let mut values_bits = Vec::with_capacity(n);
        for _ in 0..n {
            values_bits.push(d.u64()?);
        }
        Ok(Self {
            records,
            weight_bits,
            values_bits,
        })
    }
}

/// A harvested [`Histogram`] entry-for-entry: already in histogram order
/// (descending frequency, ties by ascending key), so reconstruction is
/// order-preserving and re-sorts nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramWire {
    /// `(key, freq.to_bits())` in harvest order.
    pub entries: Vec<(u64, u64)>,
    pub total_bits: u64,
}

impl HistogramWire {
    pub fn from_histogram(h: &Histogram) -> Self {
        Self {
            entries: h.entries().iter().map(|e| (e.key, e.freq.to_bits())).collect(),
            total_bits: h.total_weight().to_bits(),
        }
    }

    pub fn to_histogram(&self) -> Histogram {
        Histogram::from_sorted_entries(
            self.entries
                .iter()
                .map(|&(key, bits)| HistogramEntry {
                    key,
                    freq: f64::from_bits(bits),
                })
                .collect(),
            f64::from_bits(self.total_bits),
        )
    }

    fn enc(&self, e: &mut Enc) {
        e.seq_len(self.entries.len());
        for &(k, f) in &self.entries {
            e.u64(k);
            e.u64(f);
        }
        e.u64(self.total_bits);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let n = d.seq_len(16)?;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push((d.u64()?, d.u64()?));
        }
        let total_bits = d.u64()?;
        Ok(Self {
            entries,
            total_bits,
        })
    }
}

/// The master's one-time worker configuration: shard bounds, DRW
/// construction parameters (mirroring the in-process
/// [`EngineCore`](crate::ddps) construction exactly), and the epoch in
/// force. When `restore` is set a [`Message::Restore`] snapshot follows
/// on the control connection.
#[derive(Debug, Clone, PartialEq)]
pub struct AssignWire {
    pub worker_id: u32,
    pub n_workers: u32,
    pub n_partitions: u32,
    /// Owned contiguous partition (and DRW) range `[part_lo, part_hi)`.
    pub part_lo: u32,
    pub part_hi: u32,
    pub base_seed: u64,
    pub sample_rate_bits: u64,
    pub counter_capacity: u64,
    pub sketch_compaction: u64,
    pub sketch_bound: u64,
    pub sketch_take: u64,
    /// Per-DRW harvest size ([`DrMaster::ship_size`](crate::dr::DrMaster)).
    pub ship_k: u64,
    pub next_interval: u64,
    pub epoch: u64,
    pub restore: bool,
    pub routes: RoutesWire,
}

impl AssignWire {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.worker_id);
        e.u32(self.n_workers);
        e.u32(self.n_partitions);
        e.u32(self.part_lo);
        e.u32(self.part_hi);
        e.u64(self.base_seed);
        e.u64(self.sample_rate_bits);
        e.u64(self.counter_capacity);
        e.u64(self.sketch_compaction);
        e.u64(self.sketch_bound);
        e.u64(self.sketch_take);
        e.u64(self.ship_k);
        e.u64(self.next_interval);
        e.u64(self.epoch);
        e.boolean(self.restore);
        self.routes.enc(e);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        Ok(Self {
            worker_id: d.u32()?,
            n_workers: d.u32()?,
            n_partitions: d.u32()?,
            part_lo: d.u32()?,
            part_hi: d.u32()?,
            base_seed: d.u64()?,
            sample_rate_bits: d.u64()?,
            counter_capacity: d.u64()?,
            sketch_compaction: d.u64()?,
            sketch_bound: d.u64()?,
            sketch_take: d.u64()?,
            ship_k: d.u64()?,
            next_interval: d.u64()?,
            epoch: d.u64()?,
            restore: d.boolean()?,
            routes: RoutesWire::dec(d)?,
        })
    }
}

/// A worker's barrier contribution: per-owned-partition loads, record
/// counts and cached state totals (as bits, in partition order) plus one
/// harvested histogram per owned DRW.
#[derive(Debug, Clone, PartialEq)]
pub struct HarvestWire {
    pub interval: u64,
    pub hists: Vec<HistogramWire>,
    pub loads: Vec<u64>,
    pub counts: Vec<u64>,
    pub totals: Vec<u64>,
}

impl HarvestWire {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.interval);
        e.seq_len(self.hists.len());
        for h in &self.hists {
            h.enc(e);
        }
        e.seq_len(self.loads.len());
        for &v in &self.loads {
            e.u64(v);
        }
        e.seq_len(self.counts.len());
        for &v in &self.counts {
            e.u64(v);
        }
        e.seq_len(self.totals.len());
        for &v in &self.totals {
            e.u64(v);
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let interval = d.u64()?;
        let n = d.seq_len(12)?;
        let mut hists = Vec::with_capacity(n);
        for _ in 0..n {
            hists.push(HistogramWire::dec(d)?);
        }
        let mut u64_seq = |d: &mut Dec| -> Result<Vec<u64>, ClusterError> {
            let n = d.seq_len(8)?;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(d.u64()?);
            }
            Ok(v)
        };
        let loads = u64_seq(d)?;
        let counts = u64_seq(d)?;
        let totals = u64_seq(d)?;
        Ok(Self {
            interval,
            hists,
            loads,
            counts,
            totals,
        })
    }
}

/// One key leaving its partition under a candidate routing, with its
/// full keyed state.
#[derive(Debug, Clone, PartialEq)]
pub struct MoverWire {
    /// The partition currently holding the key.
    pub part: u32,
    pub key: u64,
    pub state: KeyStateWire,
}

impl MoverWire {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.part);
        e.u64(self.key);
        self.state.enc(e);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        Ok(Self {
            part: d.u32()?,
            key: d.u64()?,
            state: KeyStateWire::dec(d)?,
        })
    }
}

/// One migration-plan operation, in the global plan order the in-process
/// [`apply_epoch_swap`](crate::ddps) uses; each worker receives the
/// subsequence touching its partitions, preserving that order.
#[derive(Debug, Clone, PartialEq)]
pub enum OpWire {
    Extract { part: u32, key: u64 },
    Install { part: u32, key: u64, state: KeyStateWire },
}

impl OpWire {
    pub fn part(&self) -> u32 {
        match self {
            Self::Extract { part, .. } | Self::Install { part, .. } => *part,
        }
    }

    fn enc(&self, e: &mut Enc) {
        match self {
            Self::Extract { part, key } => {
                e.u8(0);
                e.u32(*part);
                e.u64(*key);
            }
            Self::Install { part, key, state } => {
                e.u8(1);
                e.u32(*part);
                e.u64(*key);
                state.enc(e);
            }
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        match d.u8()? {
            0 => Ok(Self::Extract {
                part: d.u32()?,
                key: d.u64()?,
            }),
            1 => Ok(Self::Install {
                part: d.u32()?,
                key: d.u64()?,
                state: KeyStateWire::dec(d)?,
            }),
            t => Err(ClusterError::BadMessage(format!("op tag {t}"))),
        }
    }
}

/// Close of one decision barrier: the epoch swap (if adopted) and this
/// worker's migration-op subsequence.
#[derive(Debug, Clone, PartialEq)]
pub struct BarrierEndWire {
    pub interval: u64,
    /// `(new_epoch, new_routes)` when the decider adopted a swap.
    pub swap: Option<(u64, RoutesWire)>,
    pub ops: Vec<OpWire>,
}

impl BarrierEndWire {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.interval);
        match &self.swap {
            Some((epoch, routes)) => {
                e.boolean(true);
                e.u64(*epoch);
                routes.enc(e);
            }
            None => e.boolean(false),
        }
        e.seq_len(self.ops.len());
        for op in &self.ops {
            op.enc(e);
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let interval = d.u64()?;
        let swap = if d.boolean()? {
            Some((d.u64()?, RoutesWire::dec(d)?))
        } else {
            None
        };
        let n = d.seq_len(13)?;
        let mut ops = Vec::with_capacity(n);
        for _ in 0..n {
            ops.push(OpWire::dec(d)?);
        }
        Ok(Self {
            interval,
            swap,
            ops,
        })
    }
}

/// One [`StateStore`](crate::state::StateStore) in slab order, with the
/// cached running total's exact bits. Rebuilding by installing entries in
/// order and then restoring the cached total reproduces the store —
/// including its insertion order and total-weight bit history — exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreSnapWire {
    pub entries: Vec<(u64, KeyStateWire)>,
    pub total_bits: u64,
}

impl StoreSnapWire {
    fn enc(&self, e: &mut Enc) {
        e.seq_len(self.entries.len());
        for (k, st) in &self.entries {
            e.u64(*k);
            st.enc(e);
        }
        e.u64(self.total_bits);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let n = d.seq_len(28)?;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push((d.u64()?, KeyStateWire::dec(d)?));
        }
        let total_bits = d.u64()?;
        Ok(Self {
            entries,
            total_bits,
        })
    }
}

/// One [`DrWorker`](crate::dr::DrWorker) snapshot: counter entries in
/// ascending key order plus the sampling-RNG state and compaction phase,
/// so a restored DRW observes and harvests bitwise like the lost one.
#[derive(Debug, Clone, PartialEq)]
pub struct DrwSnapWire {
    pub capacity: u64,
    pub decay_bits: u64,
    pub total_bits: u64,
    /// `(key, count.to_bits())` in ascending key order.
    pub entries: Vec<(u64, u64)>,
    pub rng: [u64; 4],
    pub observed: u64,
    pub sampled: u64,
    pub since_compaction: u64,
}

impl DrwSnapWire {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.capacity);
        e.u64(self.decay_bits);
        e.u64(self.total_bits);
        e.seq_len(self.entries.len());
        for &(k, c) in &self.entries {
            e.u64(k);
            e.u64(c);
        }
        for &r in &self.rng {
            e.u64(r);
        }
        e.u64(self.observed);
        e.u64(self.sampled);
        e.u64(self.since_compaction);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let capacity = d.u64()?;
        let decay_bits = d.u64()?;
        let total_bits = d.u64()?;
        let n = d.seq_len(16)?;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push((d.u64()?, d.u64()?));
        }
        let rng = [d.u64()?, d.u64()?, d.u64()?, d.u64()?];
        Ok(Self {
            capacity,
            decay_bits,
            total_bits,
            entries,
            rng,
            observed: d.u64()?,
            sampled: d.u64()?,
            since_compaction: d.u64()?,
        })
    }
}

/// A worker's full recovery point: its stores and DRWs at a barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotWire {
    pub stores: Vec<StoreSnapWire>,
    pub drws: Vec<DrwSnapWire>,
}

impl SnapshotWire {
    fn enc(&self, e: &mut Enc) {
        e.seq_len(self.stores.len());
        for s in &self.stores {
            s.enc(e);
        }
        e.seq_len(self.drws.len());
        for w in &self.drws {
            w.enc(e);
        }
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        let n = d.seq_len(12)?;
        let mut stores = Vec::with_capacity(n);
        for _ in 0..n {
            stores.push(StoreSnapWire::dec(d)?);
        }
        let n = d.seq_len(60)?;
        let mut drws = Vec::with_capacity(n);
        for _ in 0..n {
            drws.push(DrwSnapWire::dec(d)?);
        }
        Ok(Self { stores, drws })
    }
}

/// Per-partition final-state row: key count, FNV fingerprint over the
/// full keyed state, and the cached total's bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinalPartWire {
    pub part: u32,
    pub n_keys: u64,
    pub fingerprint: u64,
    pub total_bits: u64,
}

impl FinalPartWire {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.part);
        e.u64(self.n_keys);
        e.u64(self.fingerprint);
        e.u64(self.total_bits);
    }

    fn dec(d: &mut Dec) -> Result<Self, ClusterError> {
        Ok(Self {
            part: d.u32()?,
            n_keys: d.u64()?,
            fingerprint: d.u64()?,
            total_bits: d.u64()?,
        })
    }
}

// ---------------------------------------------------------------- messages

/// The complete message set. Control-connection traffic: everything
/// except [`Message::Batch`] / [`Message::Eof`], which ride the feed.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    HelloControl { worker_id: u32 },
    HelloFeed { worker_id: u32 },
    Assign(AssignWire),
    Restore(SnapshotWire),
    Batch { interval: u64, records: Vec<Record> },
    Eof,
    Harvest(HarvestWire),
    PlanRequest { routes: RoutesWire },
    Movers { interval: u64, movers: Vec<MoverWire> },
    BarrierEnd(BarrierEndWire),
    BarrierDone { interval: u64, snapshot: SnapshotWire },
    Finish,
    FinalState { parts: Vec<FinalPartWire> },
}

impl Message {
    fn code(&self) -> u16 {
        match self {
            Self::HelloControl { .. } => 1,
            Self::HelloFeed { .. } => 2,
            Self::Assign(_) => 3,
            Self::Restore(_) => 4,
            Self::Batch { .. } => 5,
            Self::Eof => 6,
            Self::Harvest(_) => 7,
            Self::PlanRequest { .. } => 8,
            Self::Movers { .. } => 9,
            Self::BarrierEnd(_) => 10,
            Self::BarrierDone { .. } => 11,
            Self::Finish => 12,
            Self::FinalState { .. } => 13,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::HelloControl { .. } => "HelloControl",
            Self::HelloFeed { .. } => "HelloFeed",
            Self::Assign(_) => "Assign",
            Self::Restore(_) => "Restore",
            Self::Batch { .. } => "Batch",
            Self::Eof => "Eof",
            Self::Harvest(_) => "Harvest",
            Self::PlanRequest { .. } => "PlanRequest",
            Self::Movers { .. } => "Movers",
            Self::BarrierEnd(_) => "BarrierEnd",
            Self::BarrierDone { .. } => "BarrierDone",
            Self::Finish => "Finish",
            Self::FinalState { .. } => "FinalState",
        }
    }

    fn encode_payload(&self, e: &mut Enc) {
        match self {
            Self::HelloControl { worker_id } | Self::HelloFeed { worker_id } => {
                e.u32(*worker_id);
            }
            Self::Assign(a) => a.enc(e),
            Self::Restore(s) => s.enc(e),
            Self::Batch { interval, records } => {
                e.u64(*interval);
                e.seq_len(records.len());
                for r in records {
                    e.u64(r.key);
                    e.u64(r.ts);
                    e.u64(r.weight.to_bits());
                }
            }
            Self::Eof | Self::Finish => {}
            Self::Harvest(h) => h.enc(e),
            Self::PlanRequest { routes } => routes.enc(e),
            Self::Movers { interval, movers } => {
                e.u64(*interval);
                e.seq_len(movers.len());
                for m in movers {
                    m.enc(e);
                }
            }
            Self::BarrierEnd(b) => b.enc(e),
            Self::BarrierDone { interval, snapshot } => {
                e.u64(*interval);
                snapshot.enc(e);
            }
            Self::FinalState { parts } => {
                e.seq_len(parts.len());
                for p in parts {
                    p.enc(e);
                }
            }
        }
    }

    fn decode_payload(code: u16, payload: &[u8]) -> Result<Self, ClusterError> {
        let mut d = Dec::new(payload);
        let msg = match code {
            1 => Self::HelloControl { worker_id: d.u32()? },
            2 => Self::HelloFeed { worker_id: d.u32()? },
            3 => Self::Assign(AssignWire::dec(&mut d)?),
            4 => Self::Restore(SnapshotWire::dec(&mut d)?),
            5 => {
                let interval = d.u64()?;
                let n = d.seq_len(24)?;
                let mut records = Vec::with_capacity(n);
                for _ in 0..n {
                    records.push(Record {
                        key: d.u64()?,
                        ts: d.u64()?,
                        weight: f64::from_bits(d.u64()?),
                    });
                }
                Self::Batch { interval, records }
            }
            6 => Self::Eof,
            7 => Self::Harvest(HarvestWire::dec(&mut d)?),
            8 => Self::PlanRequest {
                routes: RoutesWire::dec(&mut d)?,
            },
            9 => {
                let interval = d.u64()?;
                let n = d.seq_len(32)?;
                let mut movers = Vec::with_capacity(n);
                for _ in 0..n {
                    movers.push(MoverWire::dec(&mut d)?);
                }
                Self::Movers { interval, movers }
            }
            10 => Self::BarrierEnd(BarrierEndWire::dec(&mut d)?),
            11 => Self::BarrierDone {
                interval: d.u64()?,
                snapshot: SnapshotWire::dec(&mut d)?,
            },
            12 => Self::Finish,
            13 => {
                let n = d.seq_len(28)?;
                let mut parts = Vec::with_capacity(n);
                for _ in 0..n {
                    parts.push(FinalPartWire::dec(&mut d)?);
                }
                Self::FinalState { parts }
            }
            c => return Err(ClusterError::BadMessage(format!("unknown message type {c}"))),
        };
        d.finish()?;
        Ok(msg)
    }
}

// ------------------------------------------------------------------ frames

/// Encode one full frame (header + payload) into a byte vector — the
/// form the master retains to replay a batch to a restored worker.
pub fn encode_frame(msg: &Message) -> Result<Vec<u8>, ClusterError> {
    let mut e = Enc::default();
    e.u32(MAGIC);
    e.u16(VERSION);
    e.u16(msg.code());
    e.u32(0); // payload length backpatched below
    msg.encode_payload(&mut e);
    let len = e.buf.len() - HEADER_LEN;
    if len > MAX_PAYLOAD as usize {
        return Err(ClusterError::FrameTooLarge {
            len: len.min(u32::MAX as usize) as u32,
        });
    }
    let len_bytes = (len as u32).to_le_bytes();
    e.buf[8..12].copy_from_slice(&len_bytes);
    Ok(e.buf)
}

/// Write one frame; returns the bytes put on the wire (for the byte
/// accounting in EXPERIMENTS.md).
pub fn write_frame(w: &mut dyn Write, msg: &Message) -> Result<usize, ClusterError> {
    let frame = encode_frame(msg)?;
    w.write_all(&frame)?;
    w.flush()?;
    Ok(frame.len())
}

/// Read one frame; returns the message and the bytes consumed. A clean
/// close at a frame boundary is [`ClusterError::Disconnected`]; a close
/// mid-frame is [`ClusterError::Truncated`].
pub fn read_frame(r: &mut dyn Read) -> Result<(Message, usize), ClusterError> {
    let mut header = [0u8; HEADER_LEN];
    read_full(r, &mut header, true)?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(ClusterError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(ClusterError::BadVersion(version));
    }
    let code = u16::from_le_bytes(header[6..8].try_into().unwrap());
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(ClusterError::FrameTooLarge { len });
    }
    let mut payload = vec![0u8; len as usize];
    read_full(r, &mut payload, false)?;
    let msg = Message::decode_payload(code, &payload)?;
    Ok((msg, HEADER_LEN + len as usize))
}

fn read_full(r: &mut dyn Read, buf: &mut [u8], at_boundary: bool) -> Result<(), ClusterError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if at_boundary && got == 0 {
                    ClusterError::Disconnected("peer closed at frame boundary".into())
                } else {
                    ClusterError::Truncated(format!(
                        "stream ended after {got} of {} bytes",
                        buf.len()
                    ))
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Awkward f64 bit patterns the codec must carry verbatim: a NaN with
    /// payload bits, negative zero, a subnormal, and a sum with
    /// non-associative history.
    fn tricky_bits() -> [u64; 4] {
        [
            0x7FF8_DEAD_BEEF_0001,
            (-0.0f64).to_bits(),
            1u64, // smallest subnormal
            (0.1f64 + 0.2f64).to_bits(),
        ]
    }

    fn sample_state() -> KeyStateWire {
        KeyStateWire {
            records: 3,
            weight_bits: tricky_bits()[3],
            values_bits: tricky_bits().to_vec(),
        }
    }

    fn sample_routes() -> RoutesWire {
        RoutesWire {
            explicit: vec![(2, 1), (9, 0), (40, 3)],
            hosts: vec![0, 1, 2, 3, 2, 1],
            seed: 0x1234_5678_9ABC_DEF0,
        }
    }

    fn sample_snapshot() -> SnapshotWire {
        SnapshotWire {
            stores: vec![StoreSnapWire {
                entries: vec![(7, sample_state()), (11, sample_state())],
                total_bits: tricky_bits()[3],
            }],
            drws: vec![DrwSnapWire {
                capacity: 64,
                decay_bits: 0.5f64.to_bits(),
                total_bits: tricky_bits()[0],
                entries: vec![(1, 2.0f64.to_bits()), (5, 1.0f64.to_bits())],
                rng: [1, 2, 3, 4],
                observed: 100,
                sampled: 40,
                since_compaction: 17,
            }],
        }
    }

    /// One of every message type, with tricky payloads.
    fn sample_messages() -> Vec<Message> {
        let bits = tricky_bits();
        vec![
            Message::HelloControl { worker_id: 3 },
            Message::HelloFeed { worker_id: 0 },
            Message::Assign(AssignWire {
                worker_id: 1,
                n_workers: 4,
                n_partitions: 16,
                part_lo: 4,
                part_hi: 8,
                base_seed: 99,
                sample_rate_bits: 0.25f64.to_bits(),
                counter_capacity: 128,
                sketch_compaction: 1000,
                sketch_bound: 64,
                sketch_take: 8,
                ship_k: 32,
                next_interval: 5,
                epoch: 2,
                restore: true,
                routes: sample_routes(),
            }),
            Message::Restore(sample_snapshot()),
            Message::Batch {
                interval: 7,
                records: vec![
                    Record {
                        key: 42,
                        ts: 1,
                        weight: f64::from_bits(bits[0]),
                    },
                    Record {
                        key: 0,
                        ts: u64::MAX,
                        weight: f64::from_bits(bits[1]),
                    },
                ],
            },
            Message::Eof,
            Message::Harvest(HarvestWire {
                interval: 7,
                hists: vec![HistogramWire {
                    entries: vec![(9, 0.6f64.to_bits()), (4, 0.4f64.to_bits())],
                    total_bits: 1000.0f64.to_bits(),
                }],
                loads: bits.to_vec(),
                counts: vec![10, 0, 3, 9],
                totals: bits.to_vec(),
            }),
            Message::PlanRequest {
                routes: sample_routes(),
            },
            Message::Movers {
                interval: 7,
                movers: vec![MoverWire {
                    part: 2,
                    key: 9,
                    state: sample_state(),
                }],
            },
            Message::BarrierEnd(BarrierEndWire {
                interval: 7,
                swap: Some((3, sample_routes())),
                ops: vec![
                    OpWire::Extract { part: 2, key: 9 },
                    OpWire::Install {
                        part: 5,
                        key: 9,
                        state: sample_state(),
                    },
                ],
            }),
            Message::BarrierDone {
                interval: 7,
                snapshot: sample_snapshot(),
            },
            Message::Finish,
            Message::FinalState {
                parts: vec![FinalPartWire {
                    part: 6,
                    n_keys: 12,
                    fingerprint: 0xDEAD_BEEF,
                    total_bits: bits[3],
                }],
            },
        ]
    }

    fn decode(buf: &[u8]) -> Result<(Message, usize), ClusterError> {
        read_frame(&mut &buf[..])
    }

    #[test]
    fn round_trip_every_message_type() {
        let msgs = sample_messages();
        assert_eq!(msgs.len(), 13, "one sample per message type");
        let mut seen = std::collections::HashSet::new();
        for msg in &msgs {
            assert!(seen.insert(msg.code()), "duplicate code {}", msg.code());
            let frame = encode_frame(msg).unwrap();
            let (back, used) = decode(&frame).unwrap();
            assert_eq!(&back, msg, "{} did not round-trip", msg.name());
            assert_eq!(used, frame.len());
        }
    }

    #[test]
    fn f64_bits_survive_the_wire_exactly() {
        for &bits in &tricky_bits() {
            let msg = Message::Batch {
                interval: 1,
                records: vec![Record {
                    key: 1,
                    ts: 0,
                    weight: f64::from_bits(bits),
                }],
            };
            let frame = encode_frame(&msg).unwrap();
            match decode(&frame).unwrap().0 {
                Message::Batch { records, .. } => {
                    assert_eq!(records[0].weight.to_bits(), bits);
                }
                other => panic!("decoded {}", other.name()),
            }
        }
    }

    #[test]
    fn key_state_round_trips_bitwise() {
        let w = sample_state();
        let st = w.to_state();
        assert_eq!(KeyStateWire::from_state(&st), w);
    }

    #[test]
    fn empty_input_is_disconnected() {
        assert!(matches!(decode(&[]), Err(ClusterError::Disconnected(_))));
    }

    #[test]
    fn partial_header_is_truncated() {
        let frame = encode_frame(&Message::Eof).unwrap();
        for cut in 1..HEADER_LEN {
            assert!(
                matches!(decode(&frame[..cut]), Err(ClusterError::Truncated(_))),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn bad_magic_is_named() {
        let mut frame = encode_frame(&Message::Finish).unwrap();
        frame[0] ^= 0xFF;
        let bad = u32::from_le_bytes(frame[0..4].try_into().unwrap());
        assert_eq!(decode(&frame).unwrap_err(), ClusterError::BadMagic(bad));
    }

    #[test]
    fn bad_version_is_named() {
        let mut frame = encode_frame(&Message::Finish).unwrap();
        frame[4] = 9;
        assert_eq!(decode(&frame).unwrap_err(), ClusterError::BadVersion(9));
    }

    #[test]
    fn unknown_message_type_is_bad_message() {
        let mut frame = encode_frame(&Message::Finish).unwrap();
        frame[6] = 200;
        assert!(matches!(decode(&frame), Err(ClusterError::BadMessage(_))));
    }

    #[test]
    fn oversized_declared_payload_is_rejected_before_allocation() {
        let mut frame = encode_frame(&Message::Finish).unwrap();
        frame[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(
            decode(&frame).unwrap_err(),
            ClusterError::FrameTooLarge {
                len: MAX_PAYLOAD + 1
            }
        );
    }

    #[test]
    fn truncated_payload_at_every_cut_is_an_error() {
        for msg in sample_messages() {
            let frame = encode_frame(&msg).unwrap();
            for cut in HEADER_LEN..frame.len() {
                let err = decode(&frame[..cut]).unwrap_err();
                assert!(
                    matches!(err, ClusterError::Truncated(_)),
                    "{} cut at {cut}: {err}",
                    msg.name()
                );
            }
        }
    }

    #[test]
    fn trailing_payload_bytes_are_bad_message() {
        let msg = Message::HelloControl { worker_id: 7 };
        let mut frame = encode_frame(&msg).unwrap();
        frame.push(0xAB);
        let len = (frame.len() - HEADER_LEN) as u32;
        frame[8..12].copy_from_slice(&len.to_le_bytes());
        assert!(matches!(decode(&frame), Err(ClusterError::BadMessage(_))));
    }

    #[test]
    fn corrupt_length_prefix_cannot_force_a_huge_allocation() {
        // claim 2^32-ish movers inside a tiny payload: the length guard
        // must reject it from the bytes remaining, not try to allocate
        let msg = Message::Movers {
            interval: 1,
            movers: vec![],
        };
        let mut frame = encode_frame(&msg).unwrap();
        let off = HEADER_LEN + 8; // the movers length prefix
        frame[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode(&frame), Err(ClusterError::Truncated(_))));
    }

    #[test]
    fn single_byte_corruption_never_panics() {
        // flip every byte of every sample frame; decode must return
        // *something* (Ok or a named error) without panicking
        for msg in sample_messages() {
            let frame = encode_frame(&msg).unwrap();
            for pos in 0..frame.len() {
                let mut bad = frame.clone();
                bad[pos] ^= 0xFF;
                let _ = decode(&bad);
            }
        }
    }

    #[test]
    fn bad_bool_byte_is_bad_message() {
        let msg = Message::BarrierEnd(BarrierEndWire {
            interval: 1,
            swap: None,
            ops: vec![],
        });
        let mut frame = encode_frame(&msg).unwrap();
        frame[HEADER_LEN + 8] = 2; // the swap presence flag
        assert!(matches!(decode(&frame), Err(ClusterError::BadMessage(_))));
    }

    #[test]
    fn routes_wire_lowers_back_to_identical_flat_routes() {
        use crate::partitioner::{FlatRoutes, RouteTable};
        let flat = FlatRoutes::new(
            RouteTable::from_pairs(vec![(9, 2), (40, 0)]),
            vec![0, 1, 2, 3],
            77,
        );
        let wire = RoutesWire::from_flat(&flat);
        let back = wire.to_flat().unwrap();
        for k in 0..10_000u64 {
            assert_eq!(back.partition(k), flat.partition(k));
        }
    }

    #[test]
    fn routes_with_no_hosts_are_rejected() {
        let w = RoutesWire {
            explicit: vec![],
            hosts: vec![],
            seed: 0,
        };
        assert!(matches!(w.to_flat(), Err(ClusterError::BadMessage(_))));
    }
}
