//! Distributed multi-process engine: one master process plus N worker
//! processes speaking a small length-prefixed binary protocol over Unix
//! or TCP sockets.
//!
//! The in-process [`StreamingEngine`](crate::ddps::StreamingEngine) is
//! the oracle: the cluster runs the *same* decision pipeline — tap →
//! shuffle fold → DRW harvest → [`DrMaster`](crate::dr::DrMaster)
//! proposal → [`Decider`](crate::dr::Decider) verdict → epoch swap +
//! keyed-state migration — except that the workers own contiguous
//! partition shards in separate processes and every cross-process edge
//! crosses a socket. Determinism survives the wire because nothing on
//! the wire is re-derived: per-partition load sums keep their fold
//! order, histograms ship entry-for-entry in harvest order, and every
//! `f64` travels as its raw bits ([`wire`]).
//!
//! Layout of one decision interval (master's view):
//!
//! ```text
//!   Batch ──────────▶ feed(w)          broadcast, overlaps prev barrier
//!   BarrierDone ◀──── ctrl(w)          close interval-1, keep snapshot
//!   Harvest ◀──────── ctrl(w)          loads/counts/totals + histograms
//!   PlanRequest ────▶ ctrl(w)          candidate routes (flat lowering)
//!   Movers ◀───────── ctrl(w)          keys leaving their partitions
//!   BarrierEnd ─────▶ ctrl(w)          epoch swap + per-worker op list
//! ```
//!
//! Submodules: [`wire`] (versioned frame codec), [`transport`]
//! (connect/accept/timeouts/retry), [`worker`] (the worker run loop),
//! [`master`] (the master engine, spawn + crash-restore).

pub mod master;
pub mod transport;
pub mod wire;
pub mod worker;

pub use master::{
    final_digest, store_digest, ClusterMaster, ClusterOptions, ClusterStats, FinalStateSummary,
};
pub use transport::Endpoint;
pub use worker::{run_worker, WorkerOptions, WorkerOutcome};

use std::fmt;

/// Every way the cluster layer can fail, by name — wire corruption,
/// transport trouble and protocol violations all surface as a variant
/// here, never as a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    /// The peer endpoint refused (or does not exist yet).
    ConnectRefused(String),
    /// A read/write/accept deadline elapsed.
    Timeout(String),
    /// The peer closed the connection at a frame boundary.
    Disconnected(String),
    /// A frame header declared a payload beyond [`wire::MAX_PAYLOAD`].
    FrameTooLarge { len: u32 },
    /// The stream ended (or a length prefix overran) mid-frame.
    Truncated(String),
    /// The frame did not start with [`wire::MAGIC`].
    BadMagic(u32),
    /// The frame's protocol version is not [`wire::VERSION`].
    BadVersion(u16),
    /// The payload failed to decode as its declared message type.
    BadMessage(String),
    /// A partitioner had no exact flat lowering to ship as routes.
    NotLowerable,
    /// A well-formed message arrived where the protocol forbids it.
    Protocol(String),
    /// Any other I/O error.
    Io(String),
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::ConnectRefused(s) => write!(f, "connection refused: {s}"),
            Self::Timeout(s) => write!(f, "timed out: {s}"),
            Self::Disconnected(s) => write!(f, "peer disconnected: {s}"),
            Self::FrameTooLarge { len } => write!(f, "frame payload of {len} bytes exceeds cap"),
            Self::Truncated(s) => write!(f, "truncated frame: {s}"),
            Self::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            Self::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            Self::BadMessage(s) => write!(f, "malformed message: {s}"),
            Self::NotLowerable => write!(f, "partitioner has no flat routing table to ship"),
            Self::Protocol(s) => write!(f, "protocol violation: {s}"),
            Self::Io(s) => write!(f, "i/o error: {s}"),
        }
    }
}

impl std::error::Error for ClusterError {}

impl From<std::io::Error> for ClusterError {
    fn from(e: std::io::Error) -> Self {
        use std::io::ErrorKind;
        match e.kind() {
            ErrorKind::ConnectionRefused | ErrorKind::NotFound => {
                Self::ConnectRefused(e.to_string())
            }
            ErrorKind::WouldBlock | ErrorKind::TimedOut => Self::Timeout(e.to_string()),
            ErrorKind::UnexpectedEof => Self::Truncated(e.to_string()),
            ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted | ErrorKind::BrokenPipe => {
                Self::Disconnected(e.to_string())
            }
            _ => Self::Io(e.to_string()),
        }
    }
}
