//! Strict parsing for `DYNREPART_*` environment knobs.
//!
//! The env readers used to swallow malformed values and silently fall
//! back to their defaults, so a typo like `DYNREPART_THREADS=fuor`
//! quietly ran the sequential path. Every knob now goes through
//! [`parse_knob`]: *unset or empty* still means "use the default" (CI
//! legs intentionally pass empty strings to disable knobs), but anything
//! else must parse, or the process aborts with an error naming the
//! variable and the offending value.
//!
//! The parsers are pure functions over `Option<&str>` so they can be
//! unit-tested without touching the process environment (env mutation is
//! racy under the parallel test harness).

/// Parse one unsigned-integer env knob strictly. `None`, `""` or
/// whitespace ⇒ `Ok(None)` (unset — caller applies its default); a value
/// that parses and is `>= min` ⇒ `Ok(Some(v))`; anything else ⇒ `Err`
/// with a message naming the variable.
pub fn parse_knob(name: &str, value: Option<&str>, min: usize) -> Result<Option<usize>, String> {
    let Some(raw) = value else {
        return Ok(None);
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Ok(None);
    }
    match trimmed.parse::<usize>() {
        Ok(v) if v >= min => Ok(Some(v)),
        Ok(v) => Err(format!(
            "{name}={v} is out of range: must be an integer >= {min}"
        )),
        Err(_) => Err(format!(
            "{name}={trimmed:?} is not a valid non-negative integer"
        )),
    }
}

/// [`parse_knob`] against the live environment, panicking with the parse
/// error on a malformed value — the shared entry point of
/// `EngineConfig::threads_from_env` and `SketchConfig::from_env`.
pub fn knob_from_env(name: &str, min: usize) -> Option<usize> {
    let value = std::env::var(name).ok();
    match parse_knob(name, value.as_deref(), min) {
        Ok(v) => v,
        Err(e) => panic!("{e}"),
    }
}

/// Parse one *choice* env knob strictly, same discipline as
/// [`parse_knob`]: `None`/empty/whitespace ⇒ `Ok(None)`; a (trimmed)
/// value appearing in `allowed` ⇒ `Ok(Some(choice))`; anything else ⇒
/// `Err` naming the variable and listing the valid spellings.
pub fn parse_choice_knob<'a>(
    name: &str,
    value: Option<&str>,
    allowed: &[&'a str],
) -> Result<Option<&'a str>, String> {
    let Some(raw) = value else {
        return Ok(None);
    };
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Ok(None);
    }
    match allowed.iter().find(|a| **a == trimmed) {
        Some(choice) => Ok(Some(choice)),
        None => Err(format!(
            "{name}={trimmed:?} is not a valid choice (expected one of: {})",
            allowed.join(", ")
        )),
    }
}

/// [`parse_choice_knob`] against the live environment, panicking with
/// the parse error on a malformed value — the entry point of
/// `DeciderConfig::with_env` (`DYNREPART_DECIDER`).
pub fn choice_from_env<'a>(name: &str, allowed: &[&'a str]) -> Option<&'a str> {
    let value = std::env::var(name).ok();
    match parse_choice_knob(name, value.as_deref(), allowed) {
        Ok(v) => v,
        Err(e) => panic!("{e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_and_empty_mean_default() {
        assert_eq!(parse_knob("X", None, 1), Ok(None));
        assert_eq!(parse_knob("X", Some(""), 1), Ok(None));
        assert_eq!(parse_knob("X", Some("   "), 1), Ok(None));
    }

    #[test]
    fn valid_values_parse() {
        assert_eq!(parse_knob("X", Some("1"), 1), Ok(Some(1)));
        assert_eq!(parse_knob("X", Some("8"), 1), Ok(Some(8)));
        assert_eq!(parse_knob("X", Some(" 4 "), 1), Ok(Some(4)), "whitespace is trimmed");
        assert_eq!(parse_knob("X", Some("0"), 0), Ok(Some(0)), "min 0 admits 0");
    }

    #[test]
    fn garbage_is_rejected_with_the_variable_name() {
        for bad in ["fuor", "4x", "1.5", "-1", "0x10"] {
            let err = parse_knob("DYNREPART_THREADS", Some(bad), 1).unwrap_err();
            assert!(
                err.contains("DYNREPART_THREADS"),
                "error must name the variable: {err}"
            );
            assert!(err.contains(bad.trim()), "error must show the value: {err}");
        }
    }

    #[test]
    fn below_minimum_is_rejected_not_defaulted() {
        let err = parse_knob("DYNREPART_THREADS", Some("0"), 1).unwrap_err();
        assert!(err.contains("DYNREPART_THREADS=0"), "{err}");
        assert!(err.contains(">= 1"), "{err}");
    }

    #[test]
    fn choice_knobs_follow_the_same_discipline() {
        let allowed = ["naive", "threshold", "retentive", "cost-model"];
        assert_eq!(parse_choice_knob("X", None, &allowed), Ok(None));
        assert_eq!(parse_choice_knob("X", Some("  "), &allowed), Ok(None));
        assert_eq!(
            parse_choice_knob("X", Some(" cost-model "), &allowed),
            Ok(Some("cost-model")),
            "whitespace is trimmed"
        );
        let err = parse_choice_knob("DYNREPART_DECIDER", Some("eager"), &allowed).unwrap_err();
        assert!(err.contains("DYNREPART_DECIDER"), "{err}");
        assert!(err.contains("eager"), "{err}");
        assert!(err.contains("naive"), "error must list the choices: {err}");
    }
}
