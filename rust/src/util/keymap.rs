//! Fast hash maps for u64 keys on the request path.
//!
//! std's default SipHash is DoS-resistant but ~5× slower than needed for
//! the per-record `partition()` lookup and the DRW counter bump. Keys here
//! are already murmur-finalized 64-bit ids (not attacker-controlled
//! strings), so a single fmix64 round is both sufficient and fast.
//! §Perf in EXPERIMENTS.md records the before/after.

use crate::hash::fmix64;
use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// One-round fmix64 hasher for u64 keys.
#[derive(Default)]
pub struct KeyHasher {
    state: u64,
}

impl Hasher for KeyHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // generic fallback (not on the hot path)
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.state = fmix64(self.state ^ u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.state = fmix64(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

pub type KeyBuild = BuildHasherDefault<KeyHasher>;

/// HashMap keyed by u64 record keys, fmix64-hashed.
pub type KeyMap<V> = HashMap<u64, V, KeyBuild>;
pub type KeySet = HashSet<u64, KeyBuild>;

pub fn key_map<V>() -> KeyMap<V> {
    KeyMap::default()
}

pub fn key_map_with_capacity<V>(cap: usize) -> KeyMap<V> {
    KeyMap::with_capacity_and_hasher(cap, KeyBuild::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: KeyMap<u32> = key_map();
        for k in 0..10_000u64 {
            m.insert(k, (k * 3) as u32);
        }
        for k in 0..10_000u64 {
            assert_eq!(m[&k], (k * 3) as u32);
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn capacity_constructor() {
        let mut m: KeyMap<u8> = key_map_with_capacity(64);
        m.insert(1, 2);
        assert_eq!(m.get(&1), Some(&2));
    }

    #[test]
    fn hasher_spreads_sequential_keys() {
        // sequential u64 keys must not collide in low bits
        let mut low_bits = std::collections::HashSet::new();
        for k in 0..1000u64 {
            let mut h = KeyHasher::default();
            h.write_u64(k);
            low_bits.insert(h.finish() & 0xFFF);
        }
        assert!(low_bits.len() > 700, "low-bit collisions: {}", low_bits.len());
    }
}
