//! Virtual time for the DDPS executor model.
//!
//! The paper's processing-time figures were taken on 4–15-node clusters
//! we cannot reproduce here, so the engines account *virtual* time per
//! executor slot, discrete-event style (see DESIGN.md "Substitutions").
//! Per-record costs are calibrated from real PJRT kernel timings, so the
//! virtual timeline is anchored to measured compute. Virtual time is the
//! scheduling *model* and is bitwise-identical at any
//! `EngineConfig::num_threads`; the sharded executor
//! (`ddps::exec::parallel`) additionally reports measured wall clock in
//! the `wall_s` report fields — that is where real parallelism shows up.

/// Virtual seconds.
pub type VTime = f64;

/// A pool of executor slots with independent virtual clocks, used by the
/// wave scheduler: a task is assigned to the earliest-free slot and advances
/// that slot's clock by the task's cost.
#[derive(Debug, Clone)]
pub struct SlotClock {
    slots: Vec<VTime>,
}

impl SlotClock {
    pub fn new(n_slots: usize) -> Self {
        assert!(n_slots > 0, "need at least one executor slot");
        Self {
            slots: vec![0.0; n_slots],
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Assign a task of `cost` virtual seconds to the earliest-free slot.
    /// Returns (slot index, completion time).
    pub fn assign(&mut self, cost: VTime) -> (usize, VTime) {
        let (idx, _) = self
            .slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .expect("non-empty");
        self.slots[idx] += cost;
        (idx, self.slots[idx])
    }

    /// Assign a task that cannot start before `ready` (e.g. shuffle barrier).
    pub fn assign_after(&mut self, ready: VTime, cost: VTime) -> (usize, VTime) {
        let (idx, _) = self
            .slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .expect("non-empty");
        self.slots[idx] = self.slots[idx].max(ready) + cost;
        (idx, self.slots[idx])
    }

    /// Time at which every slot is idle — the stage completion time.
    pub fn makespan(&self) -> VTime {
        self.slots.iter().cloned().fold(0.0, f64::max)
    }

    /// Advance all slots to at least `t` (barrier).
    pub fn barrier(&mut self, t: VTime) {
        for s in &mut self.slots {
            *s = s.max(t);
        }
    }

    pub fn reset(&mut self) {
        for s in &mut self.slots {
            *s = 0.0;
        }
    }

    pub fn slot_times(&self) -> &[VTime] {
        &self.slots
    }
}

/// Schedule a set of task costs onto `n_slots` with LPT-free arrival order
/// (the order tasks become ready, like Spark's wave scheduling) and return
/// the makespan. Convenience used widely by figure drivers.
pub fn wave_makespan(task_costs: &[VTime], n_slots: usize) -> VTime {
    let mut clock = SlotClock::new(n_slots);
    for &c in task_costs {
        clock.assign(c);
    }
    clock.makespan()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_slot_sums() {
        assert!((wave_makespan(&[1.0, 2.0, 3.0], 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn many_slots_is_max() {
        assert!((wave_makespan(&[1.0, 2.0, 3.0], 3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn straggler_dominates() {
        // 4 slots, one huge task: makespan is the straggler.
        let costs = [10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        assert!((wave_makespan(&costs, 4) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn wave_scheduling_two_waves() {
        // 2 slots, tasks [3,3,3,3] -> two waves of 3 -> makespan 6.
        assert!((wave_makespan(&[3.0, 3.0, 3.0, 3.0], 2) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn assign_after_respects_ready_time() {
        let mut c = SlotClock::new(2);
        let (_, done) = c.assign_after(5.0, 1.0);
        assert!((done - 6.0).abs() < 1e-12);
    }

    #[test]
    fn barrier_advances_all() {
        let mut c = SlotClock::new(3);
        c.assign(1.0);
        c.barrier(4.0);
        assert!(c.slot_times().iter().all(|&t| t >= 4.0));
    }

    #[test]
    #[should_panic]
    fn zero_slots_panics() {
        SlotClock::new(0);
    }
}
