//! Small statistics helpers shared by metrics, benches, and figure drivers.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample (nearest-rank); sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Load imbalance as defined in the paper (Fig 2/3/4/5): max load / mean load.
pub fn load_imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let m = mean(loads);
    if m <= 0.0 {
        return 1.0;
    }
    loads.iter().cloned().fold(f64::NEG_INFINITY, f64::max) / m
}

/// Relative standard deviation of loads — a secondary balance metric.
pub fn load_rsd(loads: &[f64]) -> f64 {
    let m = mean(loads);
    if m <= 0.0 {
        return 0.0;
    }
    std(loads) / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 10.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn online_empty_is_safe() {
        let o = Online::new();
        assert_eq!(o.mean(), 0.0);
        assert_eq!(o.std(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn imbalance_uniform_is_one() {
        assert!((load_imbalance(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_skewed() {
        // loads 3,1,1,1 -> mean 1.5, max 3 -> imbalance 2
        assert!((load_imbalance(&[3.0, 1.0, 1.0, 1.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_degenerate() {
        assert_eq!(load_imbalance(&[]), 1.0);
        assert_eq!(load_imbalance(&[0.0, 0.0]), 1.0);
    }
}
