//! Shared utilities: PRNGs, statistics, virtual time, table output.

pub mod env;
pub mod keymap;
pub mod rng;
pub mod stats;
pub mod table;
pub mod vtime;

pub use keymap::{key_map, key_map_with_capacity, KeyMap, KeySet};
pub use rng::{Rng, SplitMix64};
pub use stats::{load_imbalance, load_rsd, mean, percentile, std, Online};
pub use table::Table;
pub use vtime::{wave_makespan, SlotClock, VTime};
