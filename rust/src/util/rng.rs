//! Deterministic PRNGs for workload generation and property tests.
//!
//! crates.io is unreachable in this build image, so instead of `rand` we
//! implement two standard, well-tested generators: SplitMix64 (for seeding
//! and cheap streams) and xoshiro256** (for bulk sampling). Both are
//! reproducible across runs given the same seed, which every experiment
//! driver and property test relies on.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branch-light).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate 1.
    pub fn next_exp(&mut self) -> f64 {
        -self.next_f64().max(f64::MIN_POSITIVE).ln()
    }

    /// Pareto with shape `alpha`, scale 1: heavy-tailed per-item costs.
    pub fn next_pareto(&mut self, alpha: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        u.powf(-1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw xoshiro256** state, for wire-level snapshots: a generator
    /// rebuilt via [`Rng::from_state`] continues the exact draw sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 0 from the canonical C implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_hits_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniformity_chi2_rough() {
        // 10 bins, 100k draws: chi2 with 9 dof should be < 30 w.h.p.
        let mut r = Rng::new(1234);
        let mut counts = [0f64; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[(r.next_f64() * 10.0) as usize] += 1.0;
        }
        let exp = n as f64 / 10.0;
        let chi2: f64 = counts.iter().map(|c| (c - exp) * (c - exp) / exp).sum();
        assert!(chi2 < 40.0, "chi2={chi2}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn pareto_is_heavy_tailed_and_geq_one() {
        let mut r = Rng::new(11);
        let mut max = 0.0f64;
        for _ in 0..100_000 {
            let x = r.next_pareto(1.2);
            assert!(x >= 1.0);
            max = max.max(x);
        }
        assert!(max > 100.0, "expected a heavy tail, max={max}");
    }

    #[test]
    fn state_snapshot_resumes_the_exact_sequence() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
