//! Aligned-text and TSV table output for benches and figure drivers.
//!
//! Every figure driver emits its series through this writer so the bench
//! output both reads well on a terminal and can be fed to plotting scripts.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format f64 cells with 4 significant decimals.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        let _ = writeln!(out, "{}", "-".repeat(hdr.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        out
    }

    /// Render as TSV (headers prefixed with `#`).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        let _ = writeln!(out, "{}", self.headers.join("\t"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join("\t"));
        }
        out
    }

    /// Print to stdout and, if `DYNREPART_OUT` is set, also write
    /// `<DYNREPART_OUT>/<slug>.tsv` for plotting.
    pub fn emit(&self, slug: &str) {
        print!("{}", self.render());
        println!();
        if let Ok(dir) = std::env::var("DYNREPART_OUT") {
            let path = Path::new(&dir).join(format!("{slug}.tsv"));
            if let Err(e) = std::fs::create_dir_all(&dir)
                .and_then(|_| std::fs::File::create(&path))
                .and_then(|mut f| f.write_all(self.to_tsv().as_bytes()))
            {
                eprintln!("warn: could not write {}: {e}", path.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "longheader"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100".into(), "2000".into()]);
        let r = t.render();
        assert!(r.contains("# demo"));
        assert!(r.contains("longheader"));
        // each data line has same length
        let lines: Vec<&str> = r.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn tsv_roundtrip_shape() {
        let mut t = Table::new("x", &["c1", "c2", "c3"]);
        t.rowf(&[1.0, 2.5, 3.25]);
        let tsv = t.to_tsv();
        let data_line = tsv.lines().nth(2).unwrap();
        assert_eq!(data_line.split('\t').count(), 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
