//! Hashing primitives.
//!
//! The paper generates word tokens with MurmurHash3 (§5, Spark evaluation)
//! and both Spark and Flink use murmur-style finalizers in their default
//! partitioners, so we implement MurmurHash3 x86_32 (for string keys) and
//! the 64-bit fmix finalizer (for integer keys) from scratch.

/// MurmurHash3 x86_32 over arbitrary bytes (Austin Appleby's reference).
pub fn murmur3_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let mut h1 = seed;
    let n_blocks = data.len() / 4;

    for i in 0..n_blocks {
        let mut k1 = u32::from_le_bytes([
            data[i * 4],
            data[i * 4 + 1],
            data[i * 4 + 2],
            data[i * 4 + 3],
        ]);
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xe654_6b64);
    }

    let tail = &data[n_blocks * 4..];
    let mut k1: u32 = 0;
    if !tail.is_empty() {
        if tail.len() >= 3 {
            k1 ^= (tail[2] as u32) << 16;
        }
        if tail.len() >= 2 {
            k1 ^= (tail[1] as u32) << 8;
        }
        k1 ^= tail[0] as u32;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }

    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// Murmur3 32-bit finalizer.
#[inline]
pub fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

/// Murmur3 64-bit finalizer — the fast path for integer keys.
#[inline]
pub fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xff51_afd7_ed55_8ccd);
    k ^= k >> 33;
    k = k.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    k ^= k >> 33;
    k
}

/// Hash a u64 key with a seed (seed folds into the finalizer input).
#[inline]
pub fn hash_u64(key: u64, seed: u64) -> u64 {
    fmix64(key ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Map a hash uniformly onto `[0, n)` without modulo bias.
#[inline]
pub fn bucket(hash: u64, n: usize) -> usize {
    (((hash as u128) * (n as u128)) >> 64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn murmur3_reference_vectors() {
        // Canonical test vectors for MurmurHash3 x86_32.
        assert_eq!(murmur3_32(b"", 0), 0);
        assert_eq!(murmur3_32(b"", 1), 0x514E_28B7);
        assert_eq!(murmur3_32(b"test", 0), 0xba6b_d213);
        assert_eq!(murmur3_32(b"Hello, world!", 0), 0xc036_3e43);
        assert_eq!(murmur3_32(b"The quick brown fox jumps over the lazy dog", 0), 0x2e4f_f723);
    }

    #[test]
    fn fmix64_bijective_sample() {
        // fmix64 is a bijection; distinct inputs give distinct outputs.
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(fmix64(i)));
        }
    }

    #[test]
    fn bucket_in_range_and_covers() {
        let n = 7;
        let mut seen = vec![false; n];
        for i in 0..100_000u64 {
            let b = bucket(hash_u64(i, 0), n);
            assert!(b < n);
            seen[b] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bucket_roughly_uniform() {
        let n = 16;
        let mut counts = vec![0f64; n];
        let trials = 160_000u64;
        for i in 0..trials {
            counts[bucket(hash_u64(i, 42), n)] += 1.0;
        }
        let exp = trials as f64 / n as f64;
        for c in counts {
            assert!((c - exp).abs() / exp < 0.05, "c={c} exp={exp}");
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let a: Vec<usize> = (0..1000).map(|i| bucket(hash_u64(i, 1), 10)).collect();
        let b: Vec<usize> = (0..1000).map(|i| bucket(hash_u64(i, 2), 10)).collect();
        let same = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        assert!(same < 200, "same={same}"); // ~10% expected
    }
}
