//! Production scenario harness — drift, elasticity and failure scripts
//! with checkpoint-restore verification.
//!
//! A scenario is one operational story told end to end: a *workload
//! script* (how the key distribution evolves — [`script`]) composed with
//! *runtime events* (scale-out/in, worker slowdown, worker failure with
//! checkpoint restore — [`config::EventKind`]) over a live engine, driven
//! by the [`runner`]. Scenarios load from `key = value` conf files shaped
//! like the original system's `repartitioning.conf` (see `scenarios/` at
//! the repo root) or are built programmatically, and every run emits one
//! standard report table whose rows are bitwise-deterministic given the
//! seed — at any thread count. That makes each scenario simultaneously a
//! demo (`dynrepart scenario scenarios/hotspot_flip.conf`) and a seeded
//! e2e test fixture (`tests/prop_scenarios.rs`, `tests/e2e_recovery.rs`).
//!
//! See DESIGN.md "Scenario harness" for where the event hooks sit in the
//! engine loop and why restore preserves determinism.

pub mod config;
pub mod runner;
pub mod script;

pub use config::{EngineKind, EventKind, ScenarioConfig, WorkloadScript};
pub use runner::{ClusterRunOptions, Scenario, ScenarioReport, ScenarioRow};
pub use script::ScriptedSource;
