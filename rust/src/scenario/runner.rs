//! The scenario runner — composes a scripted workload with runtime
//! events over a live engine and emits one standard report table.
//!
//! The runner drives the engine through [`run_stream`] in *segments*
//! delimited by the event schedule, so the pipelined drive loop (source
//! prefetch ∥ decision ∥ stage) is exercised exactly as in production;
//! events are applied at the barrier between segments, where no stage is
//! in flight:
//!
//! - `scale n` goes through the cross-count epoch machinery
//!   ([`EngineCore::rescale`](crate::ddps::EngineCore::rescale)): new
//!   epoch, migration plan over the changed partition count, state moves;
//! - `slowdown` / `restore-speed` set per-partition service-rate
//!   multipliers that feed only virtual time;
//! - `fail-restore gap` *verifies* crash recovery: the engine is dropped,
//!   rebuilt from the recovery point taken `gap` intervals earlier, the
//!   gap is replayed from retained batches through a
//!   [`ReplaySource`], and the replayed reports must match the pre-crash
//!   rows **bitwise** — any divergence fails the scenario.
//!
//! Every row carries only deterministic virtual-time columns, so the
//! rendered table is bitwise-stable across thread counts and doubles as
//! a seeded e2e fixture (`tests/prop_scenarios.rs`).
//!
//! [`run_stream`]: crate::ddps::StreamingEngine::run_stream

use super::config::{EngineKind, EventKind, ScenarioConfig};
use super::script::ScriptedSource;
use crate::ddps::{
    ClusterMaster, ClusterOptions, ClusterStats, EngineConfig, IntervalReport, MicroBatchEngine,
    RecoveryPoint, StreamingEngine,
};
use crate::dr::DrConfig;
use crate::util::Table;
use crate::workload::{Record, ReplaySource, Source};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// One interval/batch of a scenario run — the deterministic subset of the
/// engine reports (virtual-time model only; no measured wall-clock
/// columns), plus the label of the event that fired before it.
#[derive(Debug, Clone)]
pub struct ScenarioRow {
    pub interval: u64,
    /// Label of the event applied at the barrier before this interval
    /// (empty for most rows).
    pub event: String,
    pub epoch: u64,
    pub repartitioned: bool,
    pub migrated_fraction: f64,
    pub imbalance: f64,
    /// Interval makespan in virtual seconds.
    pub elapsed: f64,
    /// Records per virtual second.
    pub throughput: f64,
    /// Cumulative proposals adopted by the decider up to this interval.
    pub adopted: u64,
    /// Cumulative worthwhile proposals the decider held back.
    pub deferred: u64,
    /// Cumulative state fraction migrated across all adopted swaps — the
    /// restraint column the decider matrix compares policies on.
    pub cum_migrated: f64,
    /// Per-partition backlog (work units of arrivals beyond service
    /// capacity, streaming only — empty for micro-batch rows). See
    /// [`backlog_step`].
    pub backlog: Vec<f64>,
}

impl ScenarioRow {
    /// The worst per-partition backlog — the table's `backlog` column.
    pub fn max_backlog(&self) -> f64 {
        self.backlog.iter().copied().fold(0.0, f64::max)
    }
}

/// The outcome of one scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    pub rows: Vec<ScenarioRow>,
    /// Intervals whose post-restore replay was verified bitwise against
    /// the pre-crash run (0 when the scenario has no fail-restore event).
    pub recoveries_verified: usize,
    pub final_epoch: u64,
    pub total_vtime: f64,
    pub total_state_weight: f64,
}

impl ScenarioReport {
    /// Render as a standard report table (emit with
    /// [`Table::emit`] to honor `DYNREPART_OUT`).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("scenario: {}", self.name),
            &[
                "interval", "event", "epoch", "repart", "migrated", "imbalance", "elapsed_vt",
                "throughput", "adopted", "deferred", "cum_migr", "backlog",
            ],
        );
        for r in &self.rows {
            t.row(&[
                r.interval.to_string(),
                r.event.clone(),
                r.epoch.to_string(),
                if r.repartitioned { "yes" } else { "-" }.to_string(),
                format!("{:.4}", r.migrated_fraction),
                format!("{:.4}", r.imbalance),
                format!("{:.4}", r.elapsed),
                format!("{:.1}", r.throughput),
                r.adopted.to_string(),
                r.deferred.to_string(),
                format!("{:.4}", r.cum_migrated),
                format!("{:.1}", r.max_backlog()),
            ]);
        }
        t
    }
}

/// Wraps the scripted source, retaining a copy of every produced batch
/// when a fail-restore event will need them for gap replay.
struct RecordingSource {
    inner: ScriptedSource,
    retain: bool,
    batches: Vec<Vec<Record>>,
}

impl Source for RecordingSource {
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool {
        let ok = self.inner.next_batch_into(n, buf);
        if ok && self.retain {
            self.batches.push(buf.clone());
        }
        ok
    }
}

/// Host-side knobs for a cluster scenario run — everything the conf file
/// deliberately does *not* control (binary paths, socket placement, the
/// crash-injection test hook). Forwarded into
/// [`ClusterOptions`] by [`Scenario::run_cluster_with`].
#[derive(Debug, Clone, Default)]
pub struct ClusterRunOptions {
    /// Binary to spawn workers from; `None` means the current executable.
    /// Tests must pass `env!("CARGO_BIN_EXE_dynrepart")` — the test
    /// harness binary has no `worker` subcommand.
    pub worker_bin: Option<PathBuf>,
    /// Directory for the master's Unix socket (defaults to the system
    /// temp dir).
    pub socket_dir: Option<PathBuf>,
    /// Test hook: worker `id` crashes right after receiving the batch of
    /// `interval`, exercising the wire-level restore path.
    pub fail_at: Option<(u32, u64)>,
}

/// A configured scenario, ready to run.
pub struct Scenario {
    cfg: ScenarioConfig,
}

impl Scenario {
    pub fn new(cfg: ScenarioConfig) -> Result<Self, String> {
        cfg.validate()?;
        Ok(Self { cfg })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        Ok(Self { cfg: ScenarioConfig::from_file(path)? })
    }

    pub fn config(&self) -> &ScenarioConfig {
        &self.cfg
    }

    fn engine_config(&self) -> EngineConfig {
        // base costs and the sketch bounding knobs come from the
        // environment (so CI legs exercise scenarios under every
        // executor/sketch combination); the scenario pins the topology
        let mut ecfg = EngineConfig::from_env();
        ecfg.n_partitions = self.cfg.n_partitions;
        ecfg.n_slots = self.cfg.n_slots;
        if let Some(t) = self.cfg.threads {
            ecfg.num_threads = t;
        }
        ecfg
    }

    /// The DR config handed to the engine. The `DYNREPART_DECIDER*` env
    /// knobs apply only when the conf left every `decider.*` key at its
    /// default — an explicit conf always wins over the environment (same
    /// precedence as `engine.threads` over `DYNREPART_THREADS`).
    fn dr_config(&self) -> DrConfig {
        let mut dr = self.cfg.dr;
        if !self.cfg.decider_explicit {
            dr.decider = dr.decider.with_env();
        }
        dr
    }

    /// Events keyed by the interval they fire before.
    fn schedule(&self) -> BTreeMap<u64, EventKind> {
        self.cfg.events.iter().copied().collect()
    }

    /// Run the scenario end to end. `Err` means the scenario itself
    /// failed — including a fail-restore replay that did not reproduce
    /// the pre-crash run bitwise.
    pub fn run(&self) -> Result<ScenarioReport, String> {
        if self.cfg.cluster_workers.is_some() {
            return self
                .run_cluster_with(&ClusterRunOptions::default())
                .map(|(report, _)| report);
        }
        match self.cfg.engine {
            EngineKind::Streaming => self.run_streaming(),
            EngineKind::MicroBatch => self.run_microbatch(),
        }
    }

    /// Run a `cluster.workers` scenario through the distributed engine:
    /// launch a [`ClusterMaster`], spawn the worker processes, feed the
    /// scripted batches over the wire one interval at a time and collect
    /// the same deterministic rows the in-process streaming path emits —
    /// the cluster's interval reports are bitwise-identical to
    /// [`StreamingEngine`]'s, so the rendered table doubles as a
    /// distributed-vs-single-process equivalence fixture
    /// (`tests/prop_cluster.rs`).
    pub fn run_cluster_with(
        &self,
        opts: &ClusterRunOptions,
    ) -> Result<(ScenarioReport, ClusterStats), String> {
        let cfg = &self.cfg;
        let workers = cfg
            .cluster_workers
            .ok_or("scenario has no cluster.workers key")?;
        let copts = ClusterOptions {
            n_workers: workers,
            worker_bin: opts.worker_bin.clone(),
            socket_dir: opts.socket_dir.clone(),
            fail_at: opts.fail_at,
        };
        let mut master = ClusterMaster::launch(
            self.engine_config(),
            self.dr_config(),
            cfg.choice,
            cfg.seed,
            &copts,
        )
        .map_err(|e| format!("cluster launch failed: {e}"))?;
        let mut src = ScriptedSource::new(cfg);
        let mut buf: Vec<Record> = Vec::new();
        let mut rows: Vec<ScenarioRow> = Vec::with_capacity(cfg.intervals);
        // same runner-side backlog recurrence as the in-process path;
        // cluster runs model no slowdowns, so every rate is 1.0
        let mut backlog: Vec<f64> = vec![0.0; cfg.n_partitions];
        let rates: Vec<f64> = vec![1.0; cfg.n_partitions];
        let mut cum_migrated = 0.0f64;
        for _ in 0..cfg.intervals {
            if !src.next_batch_into(cfg.batch_size, &mut buf) {
                return Err("scripted source exhausted early".into());
            }
            let r = master
                .run_interval(&buf)
                .map_err(|e| format!("cluster interval {} failed: {e}", master.interval_no()))?;
            backlog_step(&mut backlog, &r.loads, &rates, None);
            cum_migrated += r.migrated_fraction;
            let mut row = streaming_row(&r, String::new());
            row.cum_migrated = cum_migrated;
            row.backlog = backlog.clone();
            rows.push(row);
        }
        let fin = master
            .finish()
            .map_err(|e| format!("cluster shutdown failed: {e}"))?;
        let stats = master.stats().clone();
        Ok((
            ScenarioReport {
                name: cfg.name.clone(),
                rows,
                recoveries_verified: stats.worker_restores as usize,
                final_epoch: master.epoch(),
                total_vtime: master.vtime(),
                total_state_weight: fin.total_state_weight,
            },
            stats,
        ))
    }

    fn run_streaming(&self) -> Result<ScenarioReport, String> {
        let cfg = &self.cfg;
        let events = self.schedule();
        // barriers (= completed-interval counts) where a later
        // fail-restore will want a recovery point
        let snap_at: std::collections::BTreeSet<u64> = events
            .iter()
            .filter_map(|(&at, &ev)| match ev {
                EventKind::FailRestore(g) => Some(at - 1 - g as u64),
                _ => None,
            })
            .collect();
        let need_batches = !snap_at.is_empty();

        let mut engine =
            StreamingEngine::new(self.engine_config(), self.dr_config(), cfg.choice, cfg.seed);
        let mut src = RecordingSource {
            inner: ScriptedSource::new(cfg),
            retain: need_batches,
            batches: Vec::new(),
        };
        let mut snaps: BTreeMap<u64, RecoveryPoint> = BTreeMap::new();
        if snap_at.contains(&0) {
            snaps.insert(0, engine.recovery_point());
        }

        let total = cfg.intervals as u64;
        let mut rows: Vec<ScenarioRow> = Vec::with_capacity(cfg.intervals);
        let mut recoveries = 0usize;
        let mut done = 0u64;
        // backlog model state — runner-side bookkeeping over the reports,
        // never fed back into the engine (rows stay bitwise-deterministic)
        let mut backlog: Vec<f64> = vec![0.0; cfg.n_partitions];
        let mut rates: Vec<f64> = vec![1.0; cfg.n_partitions];
        let mut burst_pending: Option<(usize, f64)> = None;
        let mut cum_migrated = 0.0f64;
        while done < total {
            let mut label = String::new();
            if let Some(&ev) = events.get(&(done + 1)) {
                label = ev.label();
                match ev {
                    EventKind::Scale(n) => {
                        engine.scale_to(n);
                        rates.resize(n, 1.0);
                        backlog.resize(n, 0.0);
                    }
                    EventKind::Slowdown(p, f) => {
                        self.check_partition(p, engine.partitioner().n_partitions())?;
                        engine.set_service_rate(p, f);
                        rates[p] = f;
                    }
                    EventKind::RestoreSpeed(p) => {
                        self.check_partition(p, engine.partitioner().n_partitions())?;
                        engine.set_service_rate(p, 1.0);
                        rates[p] = 1.0;
                    }
                    EventKind::Burst(p, f) => {
                        self.check_partition(p, engine.partitioner().n_partitions())?;
                        burst_pending = Some((p, f));
                    }
                    EventKind::FailRestore(g) => {
                        let snap_no = done - g as u64;
                        let point = snaps
                            .get(&snap_no)
                            .ok_or_else(|| format!("internal: no recovery point at {snap_no}"))?;
                        recoveries += self.fail_and_restore(
                            &mut engine,
                            point,
                            &src.batches[snap_no as usize..done as usize],
                            &rows[snap_no as usize..done as usize],
                        )?;
                    }
                }
            }
            // run up to the next event boundary or snapshot point
            let next_event = events.range(done + 2..).next().map(|(&at, _)| at - 1);
            let next_snap = snap_at.range(done + 1..).next().copied();
            let stop = [next_event, next_snap, Some(total)]
                .into_iter()
                .flatten()
                .min()
                .unwrap();
            let reports = engine.run_stream(&mut src, cfg.batch_size, (stop - done) as usize);
            if reports.len() != (stop - done) as usize {
                return Err("scripted source exhausted early".into());
            }
            for r in reports {
                // a burst event applies to the first interval of its segment
                backlog_step(&mut backlog, &r.loads, &rates, burst_pending.take());
                cum_migrated += r.migrated_fraction;
                let mut row = streaming_row(&r, std::mem::take(&mut label));
                row.cum_migrated = cum_migrated;
                row.backlog = backlog.clone();
                rows.push(row);
            }
            done = stop;
            if snap_at.contains(&done) {
                snaps.insert(done, engine.recovery_point());
            }
        }
        Ok(ScenarioReport {
            name: cfg.name.clone(),
            rows,
            recoveries_verified: recoveries,
            final_epoch: engine.epoch(),
            total_vtime: engine.vtime(),
            total_state_weight: engine.total_state_weight(),
        })
    }

    /// The crash: drop the live engine, restore from `point`, replay the
    /// gap batches and verify the replayed reports reproduce the
    /// pre-crash rows bitwise. Returns the number of verified intervals;
    /// on success `engine` *is* the restored engine.
    fn fail_and_restore(
        &self,
        engine: &mut StreamingEngine,
        point: &RecoveryPoint,
        gap_batches: &[Vec<Record>],
        gap_rows: &[ScenarioRow],
    ) -> Result<usize, String> {
        let mut resumed = StreamingEngine::restore(point);
        let mut replay = ReplaySource::new(gap_batches.to_vec());
        let replayed = resumed.run_stream(&mut replay, self.cfg.batch_size, gap_batches.len());
        if replayed.len() != gap_rows.len() {
            return Err(format!(
                "recovery replay produced {} intervals, expected {}",
                replayed.len(),
                gap_rows.len()
            ));
        }
        for (orig, rep) in gap_rows.iter().zip(&replayed) {
            let rep = streaming_row(rep, String::new());
            let diverged = |what: &str| {
                Err(format!(
                    "recovery replay diverged at interval {}: {what} (restored run is not \
                     bitwise-identical to the uninterrupted run)",
                    orig.interval
                ))
            };
            if rep.interval != orig.interval {
                return diverged("interval numbering");
            }
            if rep.epoch != orig.epoch || rep.repartitioned != orig.repartitioned {
                return diverged("epoch/decision");
            }
            if rep.adopted != orig.adopted || rep.deferred != orig.deferred {
                return diverged("decider tallies");
            }
            if rep.elapsed.to_bits() != orig.elapsed.to_bits()
                || rep.throughput.to_bits() != orig.throughput.to_bits()
                || rep.imbalance.to_bits() != orig.imbalance.to_bits()
                || rep.migrated_fraction.to_bits() != orig.migrated_fraction.to_bits()
            {
                return diverged("virtual-time columns");
            }
        }
        // the failed engine is discarded; the verified restore takes over
        *engine = resumed;
        Ok(gap_rows.len())
    }

    fn run_microbatch(&self) -> Result<ScenarioReport, String> {
        let cfg = &self.cfg;
        let events = self.schedule();
        let mut engine =
            MicroBatchEngine::new(self.engine_config(), self.dr_config(), cfg.choice, cfg.seed);
        let mut src = RecordingSource {
            inner: ScriptedSource::new(cfg),
            retain: false,
            batches: Vec::new(),
        };
        let total = cfg.intervals as u64;
        let mut rows: Vec<ScenarioRow> = Vec::with_capacity(cfg.intervals);
        let mut cum_migrated = 0.0f64;
        let mut done = 0u64;
        while done < total {
            let mut label = String::new();
            if let Some(&ev) = events.get(&(done + 1)) {
                label = ev.label();
                match ev {
                    EventKind::Scale(n) => {
                        // executor slots are the cluster size — fixed;
                        // only the partition count changes
                        engine.scale_to(n, cfg.n_slots);
                    }
                    EventKind::Slowdown(p, f) => {
                        self.check_partition(p, engine.partitioner().n_partitions())?;
                        engine.set_service_rate(p, f);
                    }
                    EventKind::RestoreSpeed(p) => {
                        self.check_partition(p, engine.partitioner().n_partitions())?;
                        engine.set_service_rate(p, 1.0);
                    }
                    EventKind::FailRestore(_) | EventKind::Burst(..) => {
                        unreachable!("rejected by validate()")
                    }
                }
            }
            let next_event = events.range(done + 2..).next().map(|(&at, _)| at - 1);
            let stop = next_event.unwrap_or(total).min(total);
            let reports = engine.run_stream(&mut src, cfg.batch_size, (stop - done) as usize);
            if reports.len() != (stop - done) as usize {
                return Err("scripted source exhausted early".into());
            }
            for r in reports {
                let records: f64 = r.loads.iter().sum();
                cum_migrated += r.migrated_fraction;
                rows.push(ScenarioRow {
                    interval: r.batch_no,
                    event: std::mem::take(&mut label),
                    epoch: r.epoch,
                    repartitioned: r.repartitioned,
                    migrated_fraction: r.migrated_fraction,
                    imbalance: r.imbalance,
                    elapsed: r.makespan,
                    throughput: if r.makespan > 0.0 { records / r.makespan } else { 0.0 },
                    adopted: r.decisions_adopted,
                    deferred: r.decisions_deferred,
                    cum_migrated,
                    // micro-batches drain fully by construction: no
                    // standing backlog model
                    backlog: Vec::new(),
                });
            }
            done = stop;
        }
        Ok(ScenarioReport {
            name: cfg.name.clone(),
            rows,
            recoveries_verified: 0,
            final_epoch: engine.epoch(),
            total_vtime: engine.metrics().total_vtime,
            total_state_weight: engine.total_state_weight(),
        })
    }

    fn check_partition(&self, p: usize, n: usize) -> Result<(), String> {
        if p < n {
            Ok(())
        } else {
            Err(format!("event targets partition {p} but only {n} exist"))
        }
    }
}

fn streaming_row(r: &IntervalReport, event: String) -> ScenarioRow {
    ScenarioRow {
        interval: r.interval_no,
        event,
        epoch: r.epoch,
        repartitioned: r.repartitioned,
        migrated_fraction: r.migrated_fraction,
        imbalance: r.imbalance,
        elapsed: r.elapsed,
        throughput: r.throughput,
        adopted: r.decisions_adopted,
        deferred: r.decisions_deferred,
        // run_streaming's segment loop fills these in; the fail-restore
        // replay comparison deliberately ignores them (runner-side
        // bookkeeping, not engine state)
        cum_migrated: 0.0,
        backlog: Vec::new(),
    }
}

/// One step of the runner-side backlog recurrence: partition `p` receives
/// `loads[p]` work units (×`factor` under a burst), services them at
/// `1/rates[p]` speed, against a fixed provisioned capacity of 1.5× the
/// mean nominal load (the engine's spill budget, [`EngineConfig`]
/// `spill_threshold_factor`). Whatever exceeds capacity carries over:
/// `backlog_p ← max(0, backlog_p + work_p − capacity)`. Skewed routing
/// keeps a hot partition persistently above capacity (backlog grows
/// without bound — the Pinned-path backpressure failure mode); balanced
/// routing leaves headroom everywhere and drains it.
fn backlog_step(backlog: &mut Vec<f64>, loads: &[f64], rates: &[f64], burst: Option<(usize, f64)>) {
    backlog.resize(loads.len(), 0.0);
    let n = loads.len().max(1);
    let capacity = 1.5 * loads.iter().sum::<f64>() / n as f64;
    for (p, b) in backlog.iter_mut().enumerate() {
        let mut work = loads[p] * rates.get(p).copied().unwrap_or(1.0);
        if let Some((bp, f)) = burst {
            if bp == p {
                work *= f;
            }
        }
        *b = (*b + work - capacity).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::config::WorkloadScript;

    fn base() -> ScenarioConfig {
        ScenarioConfig {
            name: "test".into(),
            seed: 5,
            intervals: 6,
            batch_size: 8_000,
            n_partitions: 6,
            n_slots: 6,
            n_keys: 4_000,
            exponent: 1.2,
            dr: crate::dr::DrConfig::forced(),
            ..Default::default()
        }
    }

    #[test]
    fn stationary_scenario_runs_and_tables() {
        let rep = Scenario::new(base()).unwrap().run().unwrap();
        assert_eq!(rep.rows.len(), 6);
        assert_eq!(rep.rows.last().unwrap().interval, 6);
        assert!(rep.final_epoch >= 1, "forced DR must repartition");
        assert!(rep.total_state_weight > 0.0);
        let t = rep.table();
        assert_eq!(t.n_rows(), 6);
        assert!(t.render().contains("scenario: test"));
    }

    #[test]
    fn scale_event_changes_partition_count_mid_run() {
        let mut cfg = base();
        cfg.events = vec![(3, EventKind::Scale(10))];
        let rep = Scenario::new(cfg).unwrap().run().unwrap();
        assert_eq!(rep.rows[2].event, "scale=10");
        assert!(rep.rows[1].epoch < rep.rows[2].epoch, "scale is an epoch bump");
        assert!(rep.rows[2].migrated_fraction >= 0.0);
    }

    #[test]
    fn fail_restore_event_verifies_recovery_bitwise() {
        let mut cfg = base();
        cfg.events = vec![(5, EventKind::FailRestore(2))];
        let rep = Scenario::new(cfg.clone()).unwrap().run().unwrap();
        assert_eq!(rep.recoveries_verified, 2);
        assert_eq!(rep.rows.len(), 6);
        assert_eq!(rep.rows[4].event, "fail-restore gap=2");
        // the run with a verified recovery matches the run without one
        cfg.events.clear();
        let plain = Scenario::new(cfg).unwrap().run().unwrap();
        for (a, b) in rep.rows.iter().zip(&plain.rows) {
            assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits());
            assert_eq!(a.epoch, b.epoch);
        }
        assert_eq!(rep.total_vtime.to_bits(), plain.total_vtime.to_bits());
    }

    #[test]
    fn slowdown_and_restore_events_shape_virtual_time() {
        let mut cfg = base();
        cfg.dr = crate::dr::DrConfig::disabled();
        cfg.choice = crate::dr::PartitionerChoice::Uhp;
        cfg.script = WorkloadScript::Stationary;
        cfg.events = vec![(3, EventKind::Slowdown(1, 4.0)), (5, EventKind::RestoreSpeed(1))];
        let rep = Scenario::new(cfg).unwrap().run().unwrap();
        // stationary + hash routing: elapsed is flat except the slowdown
        assert!(rep.rows[2].elapsed > rep.rows[1].elapsed * 1.2, "{:?}", rep.rows);
        assert!(rep.rows[4].elapsed < rep.rows[2].elapsed);
    }

    #[test]
    fn microbatch_scenarios_run_with_events() {
        let mut cfg = base();
        cfg.engine = EngineKind::MicroBatch;
        cfg.n_partitions = 8;
        cfg.n_slots = 4;
        cfg.events = vec![(3, EventKind::Scale(12))];
        let rep = Scenario::new(cfg).unwrap().run().unwrap();
        assert_eq!(rep.rows.len(), 6);
        assert_eq!(rep.rows[2].event, "scale=12");
        assert!(rep.rows[2].epoch > rep.rows[1].epoch);
    }

    #[test]
    fn backlog_recurrence_grows_and_drains() {
        let mut b = vec![0.0; 2];
        // balanced arrivals fit inside the 1.5× capacity
        backlog_step(&mut b, &[100.0, 100.0], &[1.0, 1.0], None);
        assert_eq!(b, vec![0.0, 0.0]);
        // a 4× burst on p0 exceeds capacity (150): 400 − 150 carries over
        backlog_step(&mut b, &[100.0, 100.0], &[1.0, 1.0], Some((0, 4.0)));
        assert_eq!(b, vec![250.0, 0.0]);
        // ...and drains by the 50-unit headroom each interval after
        backlog_step(&mut b, &[100.0, 100.0], &[1.0, 1.0], None);
        assert_eq!(b, vec![200.0, 0.0]);
        // a slowdown charges rate-inflated work against the same capacity
        backlog_step(&mut b, &[100.0, 100.0], &[2.0, 1.0], None);
        assert_eq!(b, vec![250.0, 0.0]);
        // rescale resizes in place, keeping accumulated backlog
        backlog_step(&mut b, &[0.0, 0.0, 0.0], &[1.0; 3], None);
        assert_eq!(b, vec![250.0, 0.0, 0.0]);
    }

    #[test]
    fn decider_columns_track_adoptions_and_cumulative_migration() {
        let rep = Scenario::new(base()).unwrap().run().unwrap();
        let last = rep.rows.last().unwrap();
        assert!(last.adopted >= 1, "forced DR under Naive adopts");
        assert_eq!(last.deferred, 0, "naive never defers");
        let sum: f64 = rep.rows.iter().map(|r| r.migrated_fraction).sum();
        assert_eq!(last.cum_migrated.to_bits(), sum.to_bits());
        assert!(rep.rows.iter().all(|r| r.backlog.len() == 6), "streaming rows carry backlog");
        let t = rep.table();
        assert!(t.render().contains("cum_migr"));
    }

    #[test]
    fn burst_event_charges_the_target_partition() {
        let mut cfg = base();
        cfg.dr = crate::dr::DrConfig::disabled();
        cfg.choice = crate::dr::PartitionerChoice::Uhp;
        cfg.events = vec![(3, EventKind::Burst(1, 6.0))];
        let rep = Scenario::new(cfg).unwrap().run().unwrap();
        assert_eq!(rep.rows[2].event, "burst p1 x6");
        assert!(
            rep.rows[2].backlog[1] > rep.rows[1].backlog[1],
            "a 6x burst must push partition 1 past capacity: {:?}",
            rep.rows[2].backlog
        );
    }

    #[test]
    fn bad_event_target_is_an_error_not_a_panic() {
        let mut cfg = base();
        cfg.events = vec![(2, EventKind::Slowdown(99, 2.0))];
        let err = Scenario::new(cfg).unwrap().run().unwrap_err();
        assert!(err.contains("partition 99"), "{err}");
    }
}
