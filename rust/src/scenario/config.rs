//! `key = value` scenario configuration — shaped like the original
//! system's `repartitioning.conf`.
//!
//! A scenario file is a flat list of `key = value` lines (full-line `#`
//! comments, blank lines ignored) describing one end-to-end run: the
//! engine under test, the DR settings, a *workload script* (how the key
//! distribution evolves over the run) and a sparse schedule of *runtime
//! events* (elasticity, slowdown, failure) keyed by the checkpoint
//! interval they fire before. Unknown keys, malformed values and
//! inconsistent event schedules are **errors**, never silent defaults —
//! the same strictness contract as the `DYNREPART_*` env knobs
//! ([`crate::util::env`]).
//!
//! ```text
//! scenario.name     = hotspot-flip
//! scenario.seed     = 42
//! scenario.intervals = 12
//! workload.script   = hotspot-flip
//! workload.flip-every = 4
//! event.7 = scale 12
//! ```

use crate::dr::DeciderPolicy;
use crate::dr::DrConfig;
use crate::dr::PartitionerChoice;
use crate::partitioner::GedikStrategy;

/// Which engine drives the scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Continuous streaming ([`crate::ddps::StreamingEngine`]) — the only
    /// kind that supports `fail-restore` events (checkpoint-restore is a
    /// barrier mechanism).
    Streaming,
    /// Micro-batch ([`crate::ddps::MicroBatchEngine`]).
    MicroBatch,
}

/// How the key distribution evolves across intervals — the drift models
/// of the paper's evaluation, made reproducible as scripts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadScript {
    /// Fixed Zipf — the control.
    Stationary,
    /// Every `flip_every` intervals the heaviest `flip_head` ranks move
    /// to brand-new key ids (sudden hotspot change).
    HotspotFlip { flip_every: usize, flip_head: usize },
    /// The Zipf exponent interpolates linearly from `workload.exponent`
    /// to `exponent_to` over the first `drift_over` intervals (gradual
    /// concept drift).
    ZipfDrift { exponent_to: f64, drift_over: usize },
    /// Batch volume follows a triangle wave with period `period`
    /// intervals between the full batch size and `trough` × it (diurnal
    /// load curve); the distribution itself stays fixed.
    Diurnal { period: usize, trough: f64 },
    /// The key universe grows by `growth`× per interval (new keys keep
    /// arriving, as in the crawl frontier).
    KeyGrowth { growth: f64 },
}

/// One runtime event, fired at the barrier *before* its interval runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Repartition to `n` partitions: new epoch, cross-count migration
    /// plan, state moves along the epoch diff.
    Scale(usize),
    /// Partition `p` starts servicing `factor`× slower (virtual time
    /// only — routing and state are untouched).
    Slowdown(usize, f64),
    /// Partition `p` returns to full speed.
    RestoreSpeed(usize),
    /// The worker crashes before this interval, losing the last `gap`
    /// intervals of progress; the runner restores the engine from the
    /// recovery point `gap` intervals back, replays the gap from
    /// retained batches, and **verifies the replayed reports bitwise**
    /// against the pre-crash run before continuing. Streaming only.
    FailRestore(usize),
    /// Partition `p` receives `factor`× its arrivals for this one
    /// interval (a one-shot input burst — the backpressure probe). The
    /// runner's backlog model charges the extra arrivals against the
    /// partition's service capacity. Streaming only.
    Burst(usize, f64),
}

impl EventKind {
    /// Short label for the scenario table's `event` column.
    pub fn label(&self) -> String {
        match self {
            EventKind::Scale(n) => format!("scale={n}"),
            EventKind::Slowdown(p, f) => format!("slow p{p} x{f}"),
            EventKind::RestoreSpeed(p) => format!("restore p{p}"),
            EventKind::FailRestore(g) => format!("fail-restore gap={g}"),
            EventKind::Burst(p, f) => format!("burst p{p} x{f}"),
        }
    }
}

/// A fully validated scenario: engine, DR, workload script and event
/// schedule. Build programmatically or parse from a conf file.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub name: String,
    pub seed: u64,
    /// Checkpoint intervals (streaming) / micro-batches to run.
    pub intervals: usize,
    /// Records per interval (the diurnal script modulates this).
    pub batch_size: usize,
    pub engine: EngineKind,
    pub n_partitions: usize,
    pub n_slots: usize,
    pub choice: PartitionerChoice,
    /// Executor threads; `None` defers to `DYNREPART_THREADS`.
    pub threads: Option<usize>,
    pub dr: DrConfig,
    /// `true` when the conf set any `decider.*` key. The runner applies
    /// the `DYNREPART_DECIDER*` env knobs only when the conf left the
    /// decider untouched — an explicit conf always wins over the
    /// environment.
    pub decider_explicit: bool,
    pub script: WorkloadScript,
    pub n_keys: usize,
    pub exponent: f64,
    /// `(interval, event)` pairs, sorted by interval; each fires at the
    /// barrier before its interval.
    pub events: Vec<(u64, EventKind)>,
    /// Run the scenario through the distributed engine
    /// ([`crate::ddps::ClusterMaster`]) with this many worker processes.
    /// Cluster runs are streaming-only and event-free: runtime events are
    /// in-process engine hooks, while worker failure is exercised by the
    /// cluster's own crash-restore path.
    pub cluster_workers: Option<usize>,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self {
            name: "scenario".to_string(),
            seed: 1,
            intervals: 8,
            batch_size: 20_000,
            engine: EngineKind::Streaming,
            n_partitions: 8,
            n_slots: 8,
            choice: PartitionerChoice::Kip,
            threads: None,
            dr: DrConfig::default(),
            decider_explicit: false,
            script: WorkloadScript::Stationary,
            n_keys: 50_000,
            exponent: 1.1,
            events: Vec::new(),
            cluster_workers: None,
        }
    }
}

fn parse_usize(key: &str, v: &str) -> Result<usize, String> {
    v.parse::<usize>()
        .map_err(|_| format!("{key} = {v:?} is not a valid non-negative integer"))
}

fn parse_u64(key: &str, v: &str) -> Result<u64, String> {
    v.parse::<u64>()
        .map_err(|_| format!("{key} = {v:?} is not a valid non-negative integer"))
}

fn parse_f64(key: &str, v: &str) -> Result<f64, String> {
    v.parse::<f64>()
        .ok()
        .filter(|x| x.is_finite())
        .ok_or_else(|| format!("{key} = {v:?} is not a valid finite number"))
}

fn parse_bool(key: &str, v: &str) -> Result<bool, String> {
    match v {
        "true" | "on" | "yes" => Ok(true),
        "false" | "off" | "no" => Ok(false),
        _ => Err(format!("{key} = {v:?} is not a boolean (true/false)")),
    }
}

/// Raw per-script parameters collected during the line pass, resolved
/// against `workload.script` afterwards so a parameter on the wrong
/// script is an error, not silently ignored.
#[derive(Default)]
struct ScriptParams {
    flip_every: Option<usize>,
    flip_head: Option<usize>,
    exponent_to: Option<f64>,
    drift_over: Option<usize>,
    period: Option<usize>,
    trough: Option<f64>,
    growth: Option<f64>,
}

impl ScenarioConfig {
    /// Parse a scenario from conf text. Every problem is an `Err` naming
    /// the offending key; nothing falls back silently.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = Self::default();
        let mut slots_explicit = false;
        let mut script_name: Option<String> = None;
        let mut p = ScriptParams::default();

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(format!("line {}: expected `key = value`, got {raw:?}", lineno + 1));
            };
            let (key, value) = (key.trim(), value.trim());
            if value.is_empty() {
                return Err(format!("line {}: {key} has an empty value", lineno + 1));
            }
            match key {
                "scenario.name" => cfg.name = value.to_string(),
                "scenario.seed" => cfg.seed = parse_u64(key, value)?,
                "scenario.intervals" => cfg.intervals = parse_usize(key, value)?,
                "scenario.batch-size" => cfg.batch_size = parse_usize(key, value)?,
                "engine.discipline" => {
                    cfg.engine = match value {
                        "streaming" => EngineKind::Streaming,
                        "microbatch" => EngineKind::MicroBatch,
                        _ => {
                            return Err(format!(
                                "{key} = {value:?}: expected streaming or microbatch"
                            ))
                        }
                    }
                }
                "engine.partitions" => cfg.n_partitions = parse_usize(key, value)?,
                "engine.slots" => {
                    cfg.n_slots = parse_usize(key, value)?;
                    slots_explicit = true;
                }
                "engine.partitioner" => {
                    cfg.choice = match value {
                        "kip" => PartitionerChoice::Kip,
                        "gedik-readj" => PartitionerChoice::Gedik(GedikStrategy::Readj),
                        "gedik-redist" => PartitionerChoice::Gedik(GedikStrategy::Redist),
                        "gedik-scan" => PartitionerChoice::Gedik(GedikStrategy::Scan),
                        "mixed" => PartitionerChoice::Mixed,
                        "hash" => PartitionerChoice::Uhp,
                        _ => {
                            return Err(format!(
                                "{key} = {value:?}: expected kip, gedik-readj, gedik-redist, \
                                 gedik-scan, mixed or hash"
                            ))
                        }
                    }
                }
                "engine.threads" => cfg.threads = Some(parse_usize(key, value)?),
                "cluster.workers" => cfg.cluster_workers = Some(parse_usize(key, value)?),
                "dr.enabled" => cfg.dr.enabled = parse_bool(key, value)?,
                "dr.force-updates" => cfg.dr.force_updates = parse_bool(key, value)?,
                "dr.min-gain" => cfg.dr.min_gain = parse_f64(key, value)?,
                "dr.lambda" => cfg.dr.lambda = parse_usize(key, value)?,
                "dr.epsilon" => cfg.dr.epsilon = parse_f64(key, value)?,
                "dr.histogram-memory" => cfg.dr.histogram_memory = parse_usize(key, value)?,
                "dr.sample-rate" => cfg.dr.sample_rate = parse_f64(key, value)?,
                "decider.policy" => {
                    cfg.dr.decider.policy = DeciderPolicy::parse(value).map_err(|_| {
                        format!(
                            "{key} = {value:?}: expected one of {}",
                            DeciderPolicy::NAMES.join(", ")
                        )
                    })?;
                    cfg.decider_explicit = true;
                }
                "decider.histogram-threshold" => {
                    cfg.dr.decider.histogram_threshold = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.significant-change" => {
                    cfg.dr.decider.significant_change = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.max-migration" => {
                    cfg.dr.decider.max_migration = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.retentive-weight" => {
                    cfg.dr.decider.retentive_weight = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.drift-boundary" => {
                    cfg.dr.decider.drift_boundary = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.drift-history-weight" => {
                    cfg.dr.decider.drift_history_weight = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.backoff-factor" => {
                    cfg.dr.decider.backoff_factor = parse_u64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "decider.horizon" => {
                    cfg.dr.decider.horizon = parse_f64(key, value)?;
                    cfg.decider_explicit = true;
                }
                "workload.script" => script_name = Some(value.to_string()),
                "workload.keys" => cfg.n_keys = parse_usize(key, value)?,
                "workload.exponent" => cfg.exponent = parse_f64(key, value)?,
                "workload.flip-every" => p.flip_every = Some(parse_usize(key, value)?),
                "workload.flip-head" => p.flip_head = Some(parse_usize(key, value)?),
                "workload.exponent-to" => p.exponent_to = Some(parse_f64(key, value)?),
                "workload.drift-over" => p.drift_over = Some(parse_usize(key, value)?),
                "workload.period" => p.period = Some(parse_usize(key, value)?),
                "workload.trough" => p.trough = Some(parse_f64(key, value)?),
                "workload.growth" => p.growth = Some(parse_f64(key, value)?),
                _ if key.starts_with("event.") => {
                    let at = parse_u64(key, &key["event.".len()..])
                        .map_err(|_| format!("{key}: event interval must be an integer"))?;
                    cfg.events.push((at, Self::parse_event(key, value)?));
                }
                _ => return Err(format!("unknown configuration key {key:?}")),
            }
        }
        if !slots_explicit {
            cfg.n_slots = cfg.n_partitions;
        }
        cfg.script = Self::resolve_script(script_name.as_deref(), &p)?;
        cfg.events.sort_by_key(|&(at, _)| at);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse a scenario conf file from disk.
    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    fn parse_event(key: &str, value: &str) -> Result<EventKind, String> {
        let parts: Vec<&str> = value.split_whitespace().collect();
        match parts.as_slice() {
            ["scale", n] => Ok(EventKind::Scale(parse_usize(key, n)?)),
            ["slowdown", p, f] => {
                Ok(EventKind::Slowdown(parse_usize(key, p)?, parse_f64(key, f)?))
            }
            ["restore-speed", p] => Ok(EventKind::RestoreSpeed(parse_usize(key, p)?)),
            ["fail-restore", g] => Ok(EventKind::FailRestore(parse_usize(key, g)?)),
            ["burst", p, f] => Ok(EventKind::Burst(parse_usize(key, p)?, parse_f64(key, f)?)),
            _ => Err(format!(
                "{key} = {value:?}: expected `scale <n>`, `slowdown <p> <factor>`, \
                 `restore-speed <p>`, `fail-restore <gap>` or `burst <p> <factor>`"
            )),
        }
    }

    fn resolve_script(name: Option<&str>, p: &ScriptParams) -> Result<WorkloadScript, String> {
        // a parameter belonging to a different script is a config error
        let forbid = |cond: bool, what: &str, script: &str| {
            if cond {
                Err(format!("workload.{what} only applies to workload.script = {script}"))
            } else {
                Ok(())
            }
        };
        let script = name.unwrap_or("stationary");
        if script != "hotspot-flip" {
            forbid(p.flip_every.is_some(), "flip-every", "hotspot-flip")?;
            forbid(p.flip_head.is_some(), "flip-head", "hotspot-flip")?;
        }
        if script != "zipf-drift" {
            forbid(p.exponent_to.is_some(), "exponent-to", "zipf-drift")?;
            forbid(p.drift_over.is_some(), "drift-over", "zipf-drift")?;
        }
        if script != "diurnal" {
            forbid(p.period.is_some(), "period", "diurnal")?;
            forbid(p.trough.is_some(), "trough", "diurnal")?;
        }
        if script != "key-growth" {
            forbid(p.growth.is_some(), "growth", "key-growth")?;
        }
        match script {
            "stationary" => Ok(WorkloadScript::Stationary),
            "hotspot-flip" => Ok(WorkloadScript::HotspotFlip {
                flip_every: p.flip_every.unwrap_or(4),
                flip_head: p.flip_head.unwrap_or(8),
            }),
            "zipf-drift" => Ok(WorkloadScript::ZipfDrift {
                exponent_to: p
                    .exponent_to
                    .ok_or("workload.script = zipf-drift requires workload.exponent-to")?,
                drift_over: p.drift_over.unwrap_or(8),
            }),
            "diurnal" => Ok(WorkloadScript::Diurnal {
                period: p.period.unwrap_or(8),
                trough: p.trough.unwrap_or(0.25),
            }),
            "key-growth" => Ok(WorkloadScript::KeyGrowth {
                growth: p.growth.unwrap_or(1.2),
            }),
            _ => Err(format!(
                "workload.script = {script:?}: expected stationary, hotspot-flip, zipf-drift, \
                 diurnal or key-growth"
            )),
        }
    }

    /// Structural validation shared by [`ScenarioConfig::parse`] and
    /// programmatic construction.
    pub fn validate(&self) -> Result<(), String> {
        if self.intervals == 0 || self.batch_size == 0 {
            return Err("scenario.intervals and scenario.batch-size must be >= 1".into());
        }
        if self.n_partitions == 0 {
            return Err("engine.partitions must be >= 1".into());
        }
        if self.engine == EngineKind::Streaming && self.n_slots < self.n_partitions {
            return Err(
                "streaming tasks are pinned: engine.slots must be >= engine.partitions".into(),
            );
        }
        if let Some(0) = self.threads {
            return Err("engine.threads must be >= 1".into());
        }
        if let Some(w) = self.cluster_workers {
            if w == 0 {
                return Err("cluster.workers must be >= 1".into());
            }
            if w > self.n_partitions {
                return Err(format!(
                    "cluster.workers = {w} exceeds engine.partitions = {}: every worker \
                     needs a partition shard",
                    self.n_partitions
                ));
            }
            if self.engine != EngineKind::Streaming {
                return Err(
                    "cluster.workers requires engine.discipline = streaming (the \
                     distributed engine runs the checkpoint-barrier loop)"
                        .into(),
                );
            }
            if !self.events.is_empty() {
                return Err(
                    "cluster.workers scenarios cannot schedule events: runtime events are \
                     in-process engine hooks; worker failure is the cluster's own \
                     crash-restore path"
                        .into(),
                );
            }
        }
        let d = &self.dr.decider;
        if !(0.0..=1.0).contains(&d.histogram_threshold) {
            return Err("decider.histogram-threshold must be in [0, 1]".into());
        }
        if d.significant_change < 0.0 {
            return Err("decider.significant-change must be >= 0".into());
        }
        if !(d.max_migration > 0.0 && d.max_migration <= 1.0) {
            return Err("decider.max-migration must be in (0, 1]".into());
        }
        if d.retentive_weight < 0.0 {
            return Err("decider.retentive-weight must be >= 0".into());
        }
        if d.drift_boundary < 0.0 {
            return Err("decider.drift-boundary must be >= 0".into());
        }
        if !(0.0..1.0).contains(&d.drift_history_weight) {
            return Err("decider.drift-history-weight must be in [0, 1)".into());
        }
        if d.horizon <= 0.0 {
            return Err("decider.horizon must be > 0".into());
        }
        match self.script {
            WorkloadScript::HotspotFlip { flip_every, flip_head } => {
                if flip_every == 0 || flip_head == 0 {
                    return Err("workload.flip-every and workload.flip-head must be >= 1".into());
                }
            }
            WorkloadScript::ZipfDrift { drift_over, .. } if drift_over == 0 => {
                return Err("workload.drift-over must be >= 1".into());
            }
            WorkloadScript::Diurnal { period, trough } => {
                if period < 2 || !(0.0..=1.0).contains(&trough) {
                    return Err(
                        "diurnal needs workload.period >= 2 and workload.trough in [0, 1]".into()
                    );
                }
            }
            WorkloadScript::KeyGrowth { growth } if growth < 1.0 => {
                return Err("workload.growth must be >= 1.0".into());
            }
            _ => {}
        }
        for &(at, ev) in &self.events {
            if at < 1 || at > self.intervals as u64 {
                return Err(format!(
                    "event.{at}: events fire before their interval; need 1 <= interval <= {}",
                    self.intervals
                ));
            }
            match ev {
                EventKind::Scale(0) => return Err(format!("event.{at}: scale target must be >= 1")),
                EventKind::Slowdown(_, f) if f <= 0.0 => {
                    return Err(format!("event.{at}: slowdown factor must be > 0"))
                }
                EventKind::Burst(_, f) => {
                    if self.engine != EngineKind::Streaming {
                        return Err(format!(
                            "event.{at}: burst drives the backlog model and requires \
                             engine.discipline = streaming"
                        ));
                    }
                    if f <= 0.0 {
                        return Err(format!("event.{at}: burst factor must be > 0"));
                    }
                }
                EventKind::FailRestore(g) => {
                    if self.engine != EngineKind::Streaming {
                        return Err(format!(
                            "event.{at}: fail-restore rides the checkpoint barrier and \
                             requires engine.discipline = streaming"
                        ));
                    }
                    if g == 0 || (g as u64) >= at {
                        return Err(format!(
                            "event.{at}: fail-restore gap must be in 1..{at} (the snapshot \
                             must predate the crash)"
                        ));
                    }
                    // the replay window must be event-free: the recovery
                    // point captures engine state, not the event schedule
                    let window = (at - g as u64)..at;
                    for &(other, oev) in &self.events {
                        if window.contains(&other) && (other, oev) != (at, ev) {
                            return Err(format!(
                                "event.{other} falls inside the fail-restore replay window \
                                 [{}, {}] of event.{at}",
                                at - g as u64,
                                at - 1
                            ));
                        }
                    }
                }
                _ => {}
            }
        }
        // at most one event per interval keeps apply order unambiguous
        for w in self.events.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(format!("event.{}: at most one event per interval", w[0].0));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_conf() {
        let cfg = ScenarioConfig::parse(
            "# comment\n\
             scenario.name = flip\n\
             scenario.seed = 42\n\
             scenario.intervals = 12\n\
             scenario.batch-size = 9000\n\
             engine.discipline = streaming\n\
             engine.partitions = 10\n\
             engine.partitioner = kip\n\
             dr.force-updates = true\n\
             workload.script = hotspot-flip\n\
             workload.keys = 4000\n\
             workload.exponent = 1.3\n\
             workload.flip-every = 4\n\
             workload.flip-head = 6\n\
             event.5 = scale 14\n\
             event.9 = fail-restore 2\n",
        )
        .unwrap();
        assert_eq!(cfg.name, "flip");
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.intervals, 12);
        assert_eq!(cfg.n_partitions, 10);
        assert_eq!(cfg.n_slots, 10, "slots default to partitions");
        assert!(cfg.dr.force_updates);
        assert_eq!(
            cfg.script,
            WorkloadScript::HotspotFlip { flip_every: 4, flip_head: 6 }
        );
        assert_eq!(
            cfg.events,
            vec![(5, EventKind::Scale(14)), (9, EventKind::FailRestore(2))]
        );
    }

    #[test]
    fn unknown_keys_and_garbage_are_errors() {
        assert!(ScenarioConfig::parse("scenario.nmae = x").is_err());
        assert!(ScenarioConfig::parse("scenario.seed = twelve").is_err());
        assert!(ScenarioConfig::parse("no equals sign here").is_err());
        assert!(ScenarioConfig::parse("scenario.seed =").is_err());
        assert!(ScenarioConfig::parse("workload.script = weekly").is_err());
        assert!(ScenarioConfig::parse("event.3 = reboot").is_err());
        assert!(ScenarioConfig::parse("event.x = scale 4").is_err());
        let err = ScenarioConfig::parse("engine.partitioner = quantum").unwrap_err();
        assert!(err.contains("engine.partitioner"), "{err}");
    }

    #[test]
    fn wrong_script_parameter_is_an_error() {
        let err = ScenarioConfig::parse(
            "workload.script = zipf-drift\n\
             workload.exponent-to = 1.8\n\
             workload.flip-every = 3\n",
        )
        .unwrap_err();
        assert!(err.contains("flip-every"), "{err}");
        // and required parameters are required
        assert!(ScenarioConfig::parse("workload.script = zipf-drift").is_err());
    }

    #[test]
    fn fail_restore_needs_streaming_and_a_sane_gap() {
        let base = "scenario.intervals = 10\n";
        let mb = format!("{base}engine.discipline = microbatch\nevent.5 = fail-restore 2\n");
        assert!(ScenarioConfig::parse(&mb).unwrap_err().contains("streaming"));
        let wide = format!("{base}event.3 = fail-restore 5\n");
        assert!(ScenarioConfig::parse(&wide).is_err(), "gap reaches before interval 1");
        let overlapped = format!("{base}event.4 = scale 6\nevent.6 = fail-restore 3\n");
        assert!(
            ScenarioConfig::parse(&overlapped).unwrap_err().contains("replay window"),
            "events inside the replay window must be rejected"
        );
        let ok = format!("{base}event.3 = scale 6\nevent.6 = fail-restore 2\n");
        assert!(ScenarioConfig::parse(&ok).is_ok(), "disjoint windows are fine");
    }

    #[test]
    fn event_schedule_is_bounded_and_unique() {
        assert!(ScenarioConfig::parse("scenario.intervals = 4\nevent.9 = scale 4\n").is_err());
        assert!(ScenarioConfig::parse("event.0 = scale 4\n").is_err());
        assert!(ScenarioConfig::parse(
            "scenario.intervals = 6\nevent.2 = scale 4\nevent.2 = slowdown 1 2.0\n"
        )
        .is_err());
        let zero = "scenario.intervals = 6\nevent.2 = slowdown 1 0.0\n";
        assert!(ScenarioConfig::parse(zero).is_err());
    }

    #[test]
    fn decider_keys_parse_and_mark_explicit() {
        let cfg = ScenarioConfig::parse(
            "decider.policy = cost-model\n\
             decider.histogram-threshold = 0.4\n\
             decider.significant-change = 0.05\n\
             decider.max-migration = 0.15\n\
             decider.retentive-weight = 2.0\n\
             decider.drift-boundary = 0.02\n\
             decider.drift-history-weight = 0.6\n\
             decider.backoff-factor = 3\n\
             decider.horizon = 16\n",
        )
        .unwrap();
        assert_eq!(cfg.dr.decider.policy, DeciderPolicy::CostModel);
        assert_eq!(cfg.dr.decider.histogram_threshold, 0.4);
        assert_eq!(cfg.dr.decider.max_migration, 0.15);
        assert_eq!(cfg.dr.decider.backoff_factor, 3);
        assert!(cfg.decider_explicit, "any decider.* key marks the conf explicit");
        // untouched confs stay implicit (env fallback applies) and default Naive
        let plain = ScenarioConfig::parse("scenario.seed = 7\n").unwrap();
        assert!(!plain.decider_explicit);
        assert_eq!(plain.dr.decider.policy, DeciderPolicy::Naive);
    }

    #[test]
    fn decider_keys_are_range_checked() {
        assert!(ScenarioConfig::parse("decider.policy = eager").is_err());
        assert!(ScenarioConfig::parse("decider.histogram-threshold = 1.5").is_err());
        assert!(ScenarioConfig::parse("decider.significant-change = -0.1").is_err());
        assert!(ScenarioConfig::parse("decider.max-migration = 0.0").is_err());
        assert!(ScenarioConfig::parse("decider.max-migration = 1.5").is_err());
        assert!(ScenarioConfig::parse("decider.drift-boundary = -1").is_err());
        assert!(ScenarioConfig::parse("decider.drift-history-weight = 1.0").is_err());
        assert!(ScenarioConfig::parse("decider.horizon = 0").is_err());
        assert!(ScenarioConfig::parse("decider.backoff-factor = two").is_err());
        assert!(ScenarioConfig::parse("decider.cooldown = 2").is_err(), "unknown decider key");
    }

    #[test]
    fn burst_needs_streaming_and_a_positive_factor() {
        let ok = ScenarioConfig::parse("scenario.intervals = 6\nevent.3 = burst 2 4.0\n").unwrap();
        assert_eq!(ok.events, vec![(3, EventKind::Burst(2, 4.0))]);
        assert_eq!(ok.events[0].1.label(), "burst p2 x4");
        let mb = "engine.discipline = microbatch\nevent.3 = burst 2 4.0\n";
        assert!(ScenarioConfig::parse(mb).unwrap_err().contains("streaming"));
        assert!(ScenarioConfig::parse("event.3 = burst 2 0.0\n").is_err());
        assert!(ScenarioConfig::parse("event.3 = burst 2\n").is_err(), "factor is required");
    }

    #[test]
    fn cluster_workers_parse_and_are_bounded() {
        let cfg = ScenarioConfig::parse("engine.partitions = 8\ncluster.workers = 2\n").unwrap();
        assert_eq!(cfg.cluster_workers, Some(2));
        // untouched confs stay single-process
        let plain = ScenarioConfig::parse("scenario.seed = 3\n").unwrap();
        assert_eq!(plain.cluster_workers, None);
        assert!(ScenarioConfig::parse("cluster.workers = 0\n").is_err());
        assert!(ScenarioConfig::parse("cluster.workers = two\n").is_err());
        let wide = "engine.partitions = 4\ncluster.workers = 5\n";
        assert!(ScenarioConfig::parse(wide).unwrap_err().contains("partition shard"));
    }

    #[test]
    fn cluster_workers_need_streaming_and_no_events() {
        let mb = "engine.discipline = microbatch\ncluster.workers = 2\n";
        assert!(ScenarioConfig::parse(mb).unwrap_err().contains("streaming"));
        let ev = "scenario.intervals = 6\ncluster.workers = 2\nevent.3 = scale 10\n";
        assert!(ScenarioConfig::parse(ev).unwrap_err().contains("events"));
    }

    #[test]
    fn streaming_slots_must_cover_partitions() {
        let err = ScenarioConfig::parse("engine.partitions = 8\nengine.slots = 4\n").unwrap_err();
        assert!(err.contains("slots"), "{err}");
        // microbatch over-partitions freely
        assert!(ScenarioConfig::parse(
            "engine.discipline = microbatch\nengine.partitions = 8\nengine.slots = 4\n"
        )
        .is_ok());
    }
}
