//! Scripted workload sources — key distributions that *evolve* over a
//! run, per the scenario's [`WorkloadScript`].
//!
//! A [`ScriptedSource`] is an ordinary pull-based [`Source`]: the engine's
//! prefetch lane asks for the next interval and the script decides what
//! that interval looks like — a sudden hotspot flip, a gradually drifting
//! Zipf exponent, a diurnal volume wave, or a growing key universe. All
//! state lives in the struct and every draw comes from seeded generators,
//! so the same `(script, seed)` pair produces the identical batch
//! sequence on every run and at every thread count — which is what lets
//! the scenario tests pin report tables bitwise.

use super::config::{ScenarioConfig, WorkloadScript};
use crate::hash::fmix64;
use crate::workload::{Record, Source};
use crate::workload::zipf::Zipf;

/// A [`Source`] that replays one [`WorkloadScript`] deterministically.
#[derive(Debug, Clone)]
pub struct ScriptedSource {
    script: WorkloadScript,
    base_keys: usize,
    base_exponent: f64,
    seed: u64,
    /// The sampler for the current interval. Stationary scripts keep one
    /// sampler for the whole run (its RNG stream persists across
    /// intervals); rebuilding scripts replace it per interval with a
    /// seed derived from `(seed, interval)`.
    zipf: Zipf,
    /// Intervals produced so far (0-based index of the *next* one).
    interval: usize,
    ts: u64,
}

impl ScriptedSource {
    pub fn new(cfg: &ScenarioConfig) -> Self {
        Self::with_params(cfg.script, cfg.n_keys, cfg.exponent, cfg.seed)
    }

    pub fn with_params(script: WorkloadScript, n_keys: usize, exponent: f64, seed: u64) -> Self {
        Self {
            script,
            base_keys: n_keys,
            base_exponent: exponent,
            seed,
            zipf: Zipf::new(n_keys, exponent, seed),
            interval: 0,
            ts: 0,
        }
    }

    /// Per-interval sampler seed — decorrelated from the base seed so a
    /// rebuilt sampler never replays the stationary stream.
    fn interval_seed(&self, i: usize) -> u64 {
        self.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// How many records interval `i` carries, given the engine asked for
    /// `n`: only the diurnal script modulates volume, as a triangle wave
    /// between `trough × n` and `n` (integer arithmetic — deterministic).
    fn volume(&self, i: usize, n: usize) -> usize {
        match self.script {
            WorkloadScript::Diurnal { period, trough } => {
                let half = period / 2;
                let pos = i % period;
                // distance from the peak, folded: 0 at peak, half at trough
                let dist = if pos <= half { pos } else { period - pos };
                let lo = (n as f64 * trough) as usize;
                let span = n - lo;
                (n - span * dist / half.max(1)).max(lo.max(1))
            }
            _ => n,
        }
    }

    /// Prepare the sampler for interval `i` (called once per pull).
    fn retune(&mut self, i: usize) {
        match self.script {
            WorkloadScript::Stationary
            | WorkloadScript::HotspotFlip { .. }
            | WorkloadScript::Diurnal { .. } => {
                // one persistent sampler; nothing to rebuild
            }
            WorkloadScript::ZipfDrift { exponent_to, drift_over } => {
                let t = (i as f64 / drift_over as f64).min(1.0);
                let exp = self.base_exponent + (exponent_to - self.base_exponent) * t;
                self.zipf = Zipf::new(self.base_keys, exp, self.interval_seed(i));
            }
            WorkloadScript::KeyGrowth { growth } => {
                let keys = ((self.base_keys as f64) * growth.powi(i as i32)).round() as usize;
                self.zipf = Zipf::new(keys.max(1), self.base_exponent, self.interval_seed(i));
            }
        }
    }

    /// Map a sampled popularity rank to a key id for interval `i`. The
    /// hotspot-flip script re-identifies the heaviest `flip_head` ranks
    /// every `flip_every` intervals by salting the rank→key mix with the
    /// phase number: the hot *load* persists but lands on brand-new keys,
    /// which is exactly the event KIP's explicit routes must chase.
    fn key_for(&self, i: usize, rank: usize) -> u64 {
        if let WorkloadScript::HotspotFlip { flip_every, flip_head } = self.script {
            if rank < flip_head {
                let phase = (i / flip_every) as u64;
                let salt = fmix64(self.seed ^ (phase << 32)).rotate_left(17);
                return fmix64((rank as u64 + 1) ^ salt);
            }
        }
        self.zipf.key_of_rank(rank)
    }
}

impl Source for ScriptedSource {
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool {
        let i = self.interval;
        self.interval += 1;
        self.retune(i);
        let count = self.volume(i, n.max(1));
        buf.clear();
        buf.reserve(count);
        for _ in 0..count {
            let rank = self.zipf.sample_rank();
            self.ts += 1;
            buf.push(Record::unit(self.key_for(i, rank), self.ts));
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Key;
    use std::collections::HashSet;

    fn keys_of(batch: &[Record]) -> HashSet<Key> {
        batch.iter().map(|r| r.key).collect()
    }

    fn pull(src: &mut ScriptedSource, n: usize) -> Vec<Record> {
        let mut buf = Vec::new();
        assert!(src.next_batch_into(n, &mut buf));
        buf
    }

    #[test]
    fn scripted_sources_are_deterministic() {
        for script in [
            WorkloadScript::Stationary,
            WorkloadScript::HotspotFlip { flip_every: 2, flip_head: 4 },
            WorkloadScript::ZipfDrift { exponent_to: 1.9, drift_over: 4 },
            WorkloadScript::Diurnal { period: 4, trough: 0.5 },
            WorkloadScript::KeyGrowth { growth: 1.5 },
        ] {
            let mut a = ScriptedSource::with_params(script, 1000, 1.0, 7);
            let mut b = a.clone();
            for _ in 0..6 {
                assert_eq!(pull(&mut a, 2000), pull(&mut b, 2000), "{script:?}");
            }
        }
    }

    #[test]
    fn hotspot_flip_moves_the_head_keys() {
        let mut src = ScriptedSource::with_params(
            WorkloadScript::HotspotFlip { flip_every: 2, flip_head: 4 },
            500,
            1.4,
            3,
        );
        let phase0 = keys_of(&pull(&mut src, 5000));
        let phase0b = keys_of(&pull(&mut src, 5000));
        let phase1 = keys_of(&pull(&mut src, 5000));
        // within a phase the hot keys repeat; across the flip the head
        // re-identifies (old hot keys mostly vanish, new ones appear)
        let hot0: Vec<Key> = (0..4).map(|r| src.key_for(0, r)).collect();
        let hot1: Vec<Key> = (0..4).map(|r| src.key_for(2, r)).collect();
        assert_ne!(hot0, hot1, "flip must re-identify the head");
        for k in &hot0 {
            assert!(phase0.contains(k) && phase0b.contains(k));
            assert!(!phase1.contains(k), "old hotspot key {k} survived the flip");
        }
        for k in &hot1 {
            assert!(phase1.contains(k));
        }
        // the tail is stable across the flip
        let tail = src.key_for(0, 100);
        assert_eq!(tail, src.key_for(2, 100));
    }

    #[test]
    fn zipf_drift_sharpens_the_head() {
        let mut src = ScriptedSource::with_params(
            WorkloadScript::ZipfDrift { exponent_to: 2.5, drift_over: 4 },
            2000,
            0.2,
            5,
        );
        let head_share = |batch: &[Record]| {
            let mut counts = std::collections::HashMap::new();
            for r in batch {
                *counts.entry(r.key).or_insert(0usize) += 1;
            }
            let max = counts.values().copied().max().unwrap_or(0);
            max as f64 / batch.len() as f64
        };
        let early = head_share(&pull(&mut src, 20_000));
        for _ in 0..4 {
            pull(&mut src, 20_000);
        }
        let late = head_share(&pull(&mut src, 20_000));
        assert!(late > early + 0.1, "drift must concentrate mass: {early} → {late}");
    }

    #[test]
    fn diurnal_volume_waves_and_others_hold_n() {
        let mut src = ScriptedSource::with_params(
            WorkloadScript::Diurnal { period: 4, trough: 0.5 },
            100,
            1.0,
            9,
        );
        let sizes: Vec<usize> = (0..8).map(|_| pull(&mut src, 1000).len()).collect();
        assert_eq!(sizes[0], 1000, "peak at the period start");
        assert!(sizes[2] <= 600, "trough mid-period: {sizes:?}");
        assert_eq!(sizes[..4], sizes[4..], "wave repeats each period");
        let mut flat = ScriptedSource::with_params(WorkloadScript::Stationary, 100, 1.0, 9);
        assert_eq!(pull(&mut flat, 1234).len(), 1234);
    }

    #[test]
    fn key_growth_expands_the_universe() {
        let mut src = ScriptedSource::with_params(
            WorkloadScript::KeyGrowth { growth: 2.0 },
            50,
            0.0,
            11,
        );
        let early = keys_of(&pull(&mut src, 10_000));
        for _ in 0..3 {
            pull(&mut src, 10_000);
        }
        let late = keys_of(&pull(&mut src, 10_000));
        assert!(early.len() <= 50);
        assert!(
            late.len() > early.len() * 4,
            "universe must grow: {} → {}",
            early.len(),
            late.len()
        );
    }
}
