//! Workload generators for every dataset in the paper's evaluation.
//!
//! - [`zipf`] — the **ZIPF** dataset family (§5): parametrized Zipfian key
//!   distributions, exponents 1–3, 100K–1M distinct keys.
//! - [`lfm`] — a synthetic stand-in for the **LFM** LastFM tag dataset
//!   (§5, Fig 3): 4M records, ~100K distinct keys, power-law popularity
//!   with concept drift across batches.
//! - [`webcrawl`] — the §6 web-crawl frontier simulator: 64 seed news
//!   hosts, 7 crawl rounds, heavy-tailed per-host page counts and
//!   dynamic-page parse costs.
//! - [`ner`] — variable-length text records for the §6 NER streaming
//!   application (token ids consumed by the AOT-compiled scorer).

pub mod lfm;
pub mod ner;
pub mod webcrawl;
pub mod zipf;

/// Keys are 64-bit ids. String keys (word tokens, host names) are hashed to
/// ids at the source with murmur3, exactly as the paper generates tokens.
pub type Key = u64;

/// A data record flowing through the DDPS.
///
/// `weight` is the record's processing-cost proxy in the reducer (e.g. text
/// length for NER); the engines multiply it by the calibrated per-unit cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub key: Key,
    pub ts: u64,
    pub weight: f64,
}

impl Record {
    pub fn new(key: Key, ts: u64, weight: f64) -> Self {
        Self { key, ts, weight }
    }

    /// A unit-cost record (counting workloads).
    pub fn unit(key: Key, ts: u64) -> Self {
        Self::new(key, ts, 1.0)
    }
}

/// Anything that can produce a finite batch or an unbounded stream of records.
pub trait Generator {
    /// Produce the next record, advancing internal state (time, drift).
    fn next_record(&mut self) -> Record;

    /// Produce `n` records into a vector.
    fn batch(&mut self, n: usize) -> Vec<Record> {
        (0..n).map(|_| self.next_record()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Constant(u64);
    impl Generator for Constant {
        fn next_record(&mut self) -> Record {
            self.0 += 1;
            Record::unit(7, self.0)
        }
    }

    #[test]
    fn batch_draws_n() {
        let mut g = Constant(0);
        let b = g.batch(5);
        assert_eq!(b.len(), 5);
        assert_eq!(b[4].ts, 5);
        assert!(b.iter().all(|r| r.key == 7 && r.weight == 1.0));
    }
}
