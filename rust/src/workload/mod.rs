//! Workload generators for every dataset in the paper's evaluation.
//!
//! - [`zipf`] — the **ZIPF** dataset family (§5): parametrized Zipfian key
//!   distributions, exponents 1–3, 100K–1M distinct keys.
//! - [`lfm`] — a synthetic stand-in for the **LFM** LastFM tag dataset
//!   (§5, Fig 3): 4M records, ~100K distinct keys, power-law popularity
//!   with concept drift across batches.
//! - [`webcrawl`] — the §6 web-crawl frontier simulator: 64 seed news
//!   hosts, 7 crawl rounds, heavy-tailed per-host page counts and
//!   dynamic-page parse costs.
//! - [`ner`] — variable-length text records for the §6 NER streaming
//!   application (token ids consumed by the AOT-compiled scorer).
//!
//! Records are produced one at a time by a [`Generator`] or pulled in
//! batches through the [`Source`] trait, which is what the pipelined
//! engine loop ([`crate::ddps::pipeline`]) drives: every generator is an
//! unbounded source via the blanket impl, [`Bounded`] caps one at a record
//! budget, [`ReplaySource`] / [`SliceSource`] replay pre-materialized
//! batches (owned / borrowed), and workload-specific adapters
//! ([`lfm::DriftingLfm`], [`webcrawl::CrawlSource`]) batch with their own
//! boundary semantics.

pub mod lfm;
pub mod ner;
pub mod socket;
pub mod webcrawl;
pub mod zipf;

pub use socket::SocketSource;

/// Keys are 64-bit ids. String keys (word tokens, host names) are hashed to
/// ids at the source with murmur3, exactly as the paper generates tokens.
pub type Key = u64;

/// A data record flowing through the DDPS.
///
/// `weight` is the record's processing-cost proxy in the reducer (e.g. text
/// length for NER); the engines multiply it by the calibrated per-unit cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub key: Key,
    pub ts: u64,
    pub weight: f64,
}

impl Record {
    pub fn new(key: Key, ts: u64, weight: f64) -> Self {
        Self { key, ts, weight }
    }

    /// A unit-cost record (counting workloads).
    pub fn unit(key: Key, ts: u64) -> Self {
        Self::new(key, ts, 1.0)
    }
}

/// Anything that can produce a finite batch or an unbounded stream of records.
pub trait Generator {
    /// Produce the next record, advancing internal state (time, drift).
    fn next_record(&mut self) -> Record;

    /// Produce `n` records into `out`, reusing its allocation (`out` is
    /// cleared first). The pipelined engine loop and the figure drivers
    /// call this in steady state so per-batch buffers are allocated once.
    fn batch_into(&mut self, n: usize, out: &mut Vec<Record>) {
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            out.push(self.next_record());
        }
    }

    /// Produce `n` records into a fresh vector ([`Generator::batch_into`]
    /// with a new allocation).
    fn batch(&mut self, n: usize) -> Vec<Record> {
        let mut out = Vec::new();
        self.batch_into(n, &mut out);
        out
    }
}

/// A pull-based batch source feeding the pipelined engine loop
/// ([`crate::ddps::pipeline`]): the engine asks for the *next* batch and
/// the source materializes it — on the loop's prefetch lane, concurrently
/// with the stage executing the previous batch.
///
/// Sources are bounded or unbounded: a bounded source eventually returns
/// `false` (no records produced) and the drive loop stops; the blanket
/// impl below makes every [`Generator`] an unbounded source.
pub trait Source {
    /// Fill `buf` (cleared first) with the next batch of up to `n`
    /// records. Returns `true` if any records were produced; `false`
    /// means the source is exhausted (`buf` is left empty).
    ///
    /// Adapters over naturally-batched inputs (a crawl round, a replayed
    /// batch sequence) may ignore `n` and produce their own batch size.
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool;
}

/// Every generator is an unbounded source: each pull materializes exactly
/// `n` fresh records.
impl<G: Generator> Source for G {
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool {
        self.batch_into(n, buf);
        !buf.is_empty()
    }
}

/// Caps any source at a total record budget, turning an unbounded
/// generator into a bounded source (the last batch may be partial).
pub struct Bounded<S> {
    inner: S,
    remaining: usize,
}

impl<S: Source> Bounded<S> {
    pub fn new(inner: S, total_records: usize) -> Self {
        Self {
            inner,
            remaining: total_records,
        }
    }

    pub fn remaining(&self) -> usize {
        self.remaining
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Source> Source for Bounded<S> {
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool {
        if self.remaining == 0 {
            buf.clear();
            return false;
        }
        self.inner.next_batch_into(n.min(self.remaining), buf);
        // an inner source that ignores `n` (a crawl round, a replayed
        // batch) may overfill: the budget truncates, never overruns
        buf.truncate(self.remaining);
        self.remaining -= buf.len();
        !buf.is_empty()
    }
}

/// Replays pre-materialized batches in order — how tests and drivers feed
/// the pipelined loop the *exact* batch sequence a lockstep loop consumed
/// (`n` is ignored; each pull yields one stored batch verbatim).
pub struct ReplaySource {
    batches: std::collections::VecDeque<Vec<Record>>,
}

impl ReplaySource {
    pub fn new<I: IntoIterator<Item = Vec<Record>>>(batches: I) -> Self {
        Self {
            batches: batches.into_iter().collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

impl Source for ReplaySource {
    fn next_batch_into(&mut self, _n: usize, buf: &mut Vec<Record>) -> bool {
        match self.batches.pop_front() {
            // hand over the already-materialized allocation instead of
            // copying it; the caller's old buffer is dropped in its place
            Some(mut b) => {
                std::mem::swap(buf, &mut b);
                !buf.is_empty()
            }
            None => {
                buf.clear();
                false
            }
        }
    }
}

/// Replays *borrowed* record slices in order without copying the data up
/// front (each pull copies one slice into the caller's buffer). Use this
/// to stream pre-materialized records that must stay shared — e.g. the
/// same record set driven through a DR and a hash engine.
pub struct SliceSource<'a> {
    slices: std::collections::VecDeque<&'a [Record]>,
}

impl<'a> SliceSource<'a> {
    pub fn new<I: IntoIterator<Item = &'a [Record]>>(slices: I) -> Self {
        Self {
            slices: slices.into_iter().collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.slices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slices.is_empty()
    }
}

impl Source for SliceSource<'_> {
    fn next_batch_into(&mut self, _n: usize, buf: &mut Vec<Record>) -> bool {
        buf.clear();
        match self.slices.pop_front() {
            Some(s) => {
                buf.extend_from_slice(s);
                !buf.is_empty()
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Constant(u64);
    impl Generator for Constant {
        fn next_record(&mut self) -> Record {
            self.0 += 1;
            Record::unit(7, self.0)
        }
    }

    #[test]
    fn batch_draws_n() {
        let mut g = Constant(0);
        let b = g.batch(5);
        assert_eq!(b.len(), 5);
        assert_eq!(b[4].ts, 5);
        assert!(b.iter().all(|r| r.key == 7 && r.weight == 1.0));
    }

    #[test]
    fn batch_into_reuses_allocation_and_matches_batch() {
        let mut a = Constant(0);
        let mut b = Constant(0);
        let mut buf = Vec::new();
        a.batch_into(5, &mut buf);
        assert_eq!(buf, b.batch(5));
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        a.batch_into(5, &mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf.capacity(), cap, "steady-state batch must not reallocate");
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(buf[0].ts, 6, "generator state advances across refills");
    }

    #[test]
    fn generators_are_unbounded_sources() {
        let mut g = Constant(0);
        let mut buf = Vec::new();
        for pull in 1..=3u64 {
            assert!(Source::next_batch_into(&mut g, 4, &mut buf));
            assert_eq!(buf.len(), 4);
            assert_eq!(buf[0].ts, (pull - 1) * 4 + 1);
        }
    }

    #[test]
    fn bounded_source_exhausts_at_budget() {
        let mut s = Bounded::new(Constant(0), 10);
        let mut buf = Vec::new();
        assert!(s.next_batch_into(4, &mut buf));
        assert_eq!(buf.len(), 4);
        assert!(s.next_batch_into(4, &mut buf));
        assert!(s.next_batch_into(4, &mut buf));
        assert_eq!(buf.len(), 2, "final batch is partial");
        assert!(!s.next_batch_into(4, &mut buf));
        assert!(buf.is_empty());
    }

    #[test]
    fn bounded_caps_sources_that_ignore_n() {
        // an inner source that produces its own batch size (ReplaySource
        // ignores n) must be truncated at the budget, never overrun —
        // including under the batch_size = 0 pull convention
        let batches = vec![vec![Record::unit(1, 1); 6], vec![Record::unit(2, 2); 6]];
        let mut s = Bounded::new(ReplaySource::new(batches), 8);
        let mut buf = Vec::new();
        assert!(s.next_batch_into(0, &mut buf));
        assert_eq!(buf.len(), 6);
        assert!(s.next_batch_into(0, &mut buf));
        assert_eq!(buf.len(), 2, "second batch truncated at the budget");
        assert!(!s.next_batch_into(0, &mut buf));
        assert!(buf.is_empty());
    }

    #[test]
    fn slice_source_replays_borrowed_slices() {
        let records: Vec<Record> = (0..10u64).map(|k| Record::unit(k, k)).collect();
        let mut s = SliceSource::new(records.chunks(4));
        assert_eq!(s.len(), 3);
        let mut buf = Vec::new();
        assert!(s.next_batch_into(0, &mut buf));
        assert_eq!(buf, &records[..4]);
        assert!(s.next_batch_into(0, &mut buf));
        assert!(s.next_batch_into(0, &mut buf));
        assert_eq!(buf, &records[8..]);
        assert!(!s.next_batch_into(0, &mut buf));
        assert!(buf.is_empty());
    }

    #[test]
    fn replay_source_replays_verbatim_and_ignores_n() {
        let batches = vec![
            vec![Record::unit(1, 1), Record::unit(2, 2)],
            vec![Record::unit(3, 3)],
        ];
        let mut s = ReplaySource::new(batches.clone());
        assert_eq!(s.len(), 2);
        let mut buf = Vec::new();
        assert!(s.next_batch_into(999, &mut buf));
        assert_eq!(buf, batches[0]);
        assert!(s.next_batch_into(0, &mut buf));
        assert_eq!(buf, batches[1]);
        assert!(!s.next_batch_into(10, &mut buf));
        assert!(buf.is_empty());
    }
}
