//! Web-crawl frontier simulator — the §6 use case.
//!
//! The paper injects 64 news sites, allows depth-1 discovery of referenced
//! domains, partitions fetch lists **by host** (crawler politeness), renders
//! dynamic pages with a browser-driver pool (heavy, content-management-
//! dependent parse costs), and runs 7 crawl rounds; round 7 processes
//! 230 GB. The per-host page counts are heavily skewed and *unknown before
//! the crawl* — exactly the situation DR targets.
//!
//! The simulator reproduces the structural properties (see DESIGN.md):
//! - 64 seed hosts with Pareto-distributed site sizes;
//! - each round, every crawled page links to in-site pages (frontier
//!   growth ∝ site size) and occasionally discovers new depth-1 hosts;
//! - per-page parse cost is heavy-tailed (dynamic rendering) with a
//!   host-specific scale (content-management technology).

use super::{Key, Record};
use crate::hash::fmix64;
use crate::util::Rng;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct CrawlConfig {
    pub n_seed_hosts: usize,
    /// Pareto shape for site size; smaller = more skew. News sites vary from
    /// tiny local outlets to wire-service giants — shape ≈ 0.8–1.2.
    pub site_size_shape: f64,
    /// Mean pages fetched per host per round for an average host.
    pub base_pages_per_round: f64,
    /// Probability per crawled page of discovering a new depth-1 host.
    pub discovery_prob: f64,
    /// Pareto shape of per-page parse cost.
    pub page_cost_shape: f64,
    pub rounds: usize,
    /// Politeness cap: max pages fetched from one host per round, as a
    /// multiple of `base_pages_per_round`. Crawlers bound per-host request
    /// rates [27], which also bounds fetch-list *record* skew — the
    /// remaining imbalance (and what DR fixes) comes from per-page parse
    /// cost differences across hosts (CMS technology, dynamic rendering).
    pub politeness_cap: f64,
}

impl Default for CrawlConfig {
    fn default() -> Self {
        Self {
            n_seed_hosts: 64,
            site_size_shape: 1.1,
            base_pages_per_round: 300.0,
            discovery_prob: 0.008,
            page_cost_shape: 1.5,
            rounds: 7,
            politeness_cap: 4.0,
        }
    }
}

/// One host in the crawl frontier.
#[derive(Debug, Clone)]
pub struct Host {
    pub key: Key,
    /// Relative size of the site — drives frontier growth.
    pub size: f64,
    /// Host-specific parse-cost scale (CMS technology).
    pub cost_scale: f64,
    /// Whether this is a depth-1 discovered host (not crawled further).
    pub depth1: bool,
}

/// The fetch list of one crawl round: per-host page batches.
#[derive(Debug, Clone)]
pub struct FetchList {
    pub round: usize,
    /// (host key, number of pages, total parse cost of those pages).
    pub entries: Vec<(Key, u64, f64)>,
}

impl FetchList {
    pub fn total_pages(&self) -> u64 {
        self.entries.iter().map(|e| e.1).sum()
    }

    pub fn total_cost(&self) -> f64 {
        self.entries.iter().map(|e| e.2).sum()
    }

    /// Expand into per-page records (key = host, weight = page parse cost).
    ///
    /// Pages are emitted **interleaved round-robin across hosts** — the
    /// order a polite crawler actually issues fetches (bounded per-host
    /// request rate). This matters to DR: the mappers' sampling prefix
    /// sees every host, as it does in the real system. Costs within a host
    /// are spread deterministically around the mean so the expansion is
    /// cheap and reproducible.
    pub fn records(&self) -> Vec<Record> {
        let mut out = Vec::new();
        self.records_into(&mut out);
        out
    }

    /// [`FetchList::records`] into a reused buffer (cleared first) — the
    /// per-round expansion is the crawl's steady-state allocation, so the
    /// pipelined prefetcher and the figure drivers recycle it.
    pub fn records_into(&self, out: &mut Vec<Record>) {
        out.clear();
        out.reserve(self.total_pages() as usize);
        let mut ts = (self.round as u64) << 32;
        let max_pages = self.entries.iter().map(|e| e.1).max().unwrap_or(0);
        for i in 0..max_pages {
            for &(key, pages, cost) in &self.entries {
                if i >= pages {
                    continue;
                }
                let mean = cost / pages as f64;
                // deterministic ±50% triangular spread around the mean
                let f = 0.5 + (fmix64(key ^ i) % 1000) as f64 / 1000.0;
                ts += 1;
                out.push(Record::new(key, ts, mean * f));
            }
        }
    }
}

#[derive(Debug)]
pub struct Crawl {
    cfg: CrawlConfig,
    hosts: Vec<Host>,
    rng: Rng,
    next_host_id: u64,
}

impl Crawl {
    pub fn new(cfg: CrawlConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut hosts = Vec::with_capacity(cfg.n_seed_hosts);
        let mut next_host_id = 0u64;
        for _ in 0..cfg.n_seed_hosts {
            next_host_id += 1;
            hosts.push(Host {
                key: fmix64(next_host_id),
                size: rng.next_pareto(cfg.site_size_shape),
                // CMS rendering cost varies ~3× across hosts (bounded)
                cost_scale: rng.next_pareto(2.0).min(3.0),
                depth1: false,
            });
        }
        Self {
            cfg,
            hosts,
            rng,
            next_host_id,
        }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(CrawlConfig::default(), seed)
    }

    pub fn n_hosts(&self) -> usize {
        self.hosts.len()
    }

    pub fn hosts(&self) -> &[Host] {
        &self.hosts
    }

    /// Run one crawl round: build the fetch list from the current frontier,
    /// then grow the frontier (discovery) for the next round.
    pub fn next_round(&mut self, round: usize) -> FetchList {
        let mut entries = Vec::with_capacity(self.hosts.len());
        let growth = 1.0 + 0.6 * round as f64; // frontier deepens each round
        let mut discovered = Vec::new();
        for h in &self.hosts {
            let mean_pages = if h.depth1 {
                // depth-1 hosts: fetched once, shallow
                self.cfg.base_pages_per_round * 0.05
            } else {
                self.cfg.base_pages_per_round * h.size * growth
            };
            // Poisson-ish: exponential spread around the mean, bounded by
            // the politeness cap
            let cap = self.cfg.base_pages_per_round * self.cfg.politeness_cap;
            let pages = (mean_pages * self.rng.next_exp()).min(cap).ceil().max(0.0) as u64;
            if pages == 0 {
                continue;
            }
            let mut cost = 0.0;
            // total parse cost: heavy-tailed per page, host CMS scale
            for _ in 0..pages.min(64) {
                cost += self.rng.next_pareto(self.cfg.page_cost_shape);
            }
            // extrapolate sampled cost to all pages (bounded sampling keeps
            // generation O(hosts) instead of O(pages))
            cost *= h.cost_scale * pages as f64 / pages.min(64) as f64;
            entries.push((h.key, pages, cost));

            // depth-1 discovery from crawled pages
            if !h.depth1 {
                let expected = pages as f64 * self.cfg.discovery_prob;
                let n_new = (expected * self.rng.next_exp()).round() as u64;
                for _ in 0..n_new {
                    self.next_host_id += 1;
                    discovered.push(Host {
                        key: fmix64(self.next_host_id),
                        size: self.rng.next_pareto(self.cfg.site_size_shape),
                        cost_scale: self.rng.next_pareto(2.0).min(3.0),
                        depth1: true,
                    });
                }
            }
        }
        self.hosts.extend(discovered);
        FetchList { round, entries }
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Vec<FetchList> {
        (0..self.cfg.rounds).map(|r| self.next_round(r)).collect()
    }

    /// Configured number of crawl rounds.
    pub fn rounds(&self) -> usize {
        self.cfg.rounds
    }

    /// Turn the crawl into a bounded [`Source`](super::Source) of
    /// per-round fetch-list records (one pull = one round, exhausting
    /// after the configured rounds).
    pub fn into_source(self) -> CrawlSource {
        CrawlSource {
            crawl: self,
            round: 0,
        }
    }

    /// Exact per-host frequency map of a fetch list (for oracle tests).
    pub fn host_freqs(list: &FetchList) -> HashMap<Key, f64> {
        let total = list.total_pages() as f64;
        list.entries
            .iter()
            .map(|&(k, p, _)| (k, p as f64 / total))
            .collect()
    }
}

/// The crawl as a bounded [`Source`](super::Source): each pull expands
/// the next round's fetch list into records (`n` is ignored — a round's
/// size is set by the frontier, not the caller) and the source exhausts
/// after the configured number of rounds. This is what feeds
/// [`BatchJob::run_stream`](crate::ddps::BatchJob::run_stream): round
/// k+1's frontier materializes while round k's job is still shuffling.
#[derive(Debug)]
pub struct CrawlSource {
    crawl: Crawl,
    round: usize,
}

impl CrawlSource {
    /// Rounds already pulled.
    pub fn rounds_done(&self) -> usize {
        self.round
    }

    pub fn crawl(&self) -> &Crawl {
        &self.crawl
    }
}

impl super::Source for CrawlSource {
    fn next_batch_into(&mut self, _n: usize, buf: &mut Vec<Record>) -> bool {
        if self.round >= self.crawl.rounds() {
            buf.clear();
            return false;
        }
        let list = self.crawl.next_round(self.round);
        self.round += 1;
        list.records_into(buf);
        !buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::load_imbalance;

    #[test]
    fn seeds_are_64_hosts() {
        let c = Crawl::with_defaults(1);
        assert_eq!(c.n_hosts(), 64);
        assert!(c.hosts().iter().all(|h| !h.depth1));
    }

    #[test]
    fn rounds_grow() {
        let mut c = Crawl::with_defaults(2);
        let lists = c.run();
        assert_eq!(lists.len(), 7);
        let first = lists[0].total_pages();
        let last = lists[6].total_pages();
        assert!(last > 2 * first, "first={first} last={last}");
    }

    #[test]
    fn discovery_adds_depth1_hosts() {
        let mut c = Crawl::with_defaults(3);
        let _ = c.run();
        assert!(c.n_hosts() > 64);
        assert!(c.hosts().iter().any(|h| h.depth1));
    }

    #[test]
    fn host_sizes_heavily_skewed() {
        let mut c = Crawl::with_defaults(4);
        let lists = c.run();
        let last = &lists[6];
        // hashing hosts into 8 partitions must show real imbalance
        let mut loads = vec![0.0; 8];
        for &(k, _, cost) in &last.entries {
            loads[crate::hash::bucket(crate::hash::hash_u64(k, 0), 8)] += cost;
        }
        assert!(load_imbalance(&loads) > 1.3, "imb={}", load_imbalance(&loads));
    }

    #[test]
    fn records_expand_consistently() {
        let mut c = Crawl::with_defaults(5);
        let list = c.next_round(0);
        let recs = list.records();
        assert_eq!(recs.len() as u64, list.total_pages());
        let total_w: f64 = recs.iter().map(|r| r.weight).sum();
        // triangular spread preserves the mean to ~1%
        assert!(
            (total_w - list.total_cost()).abs() / list.total_cost() < 0.05,
            "w={total_w} cost={}",
            list.total_cost()
        );
    }

    #[test]
    fn freqs_sum_to_one() {
        let mut c = Crawl::with_defaults(6);
        let list = c.next_round(0);
        let s: f64 = Crawl::host_freqs(&list).values().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Crawl::with_defaults(7);
        let mut b = Crawl::with_defaults(7);
        let la = a.run();
        let lb = b.run();
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.entries, y.entries);
        }
    }

    #[test]
    fn crawl_source_replays_the_rounds_then_exhausts() {
        use crate::workload::Source;
        let mut direct = Crawl::with_defaults(8);
        let mut src = Crawl::with_defaults(8).into_source();
        let mut buf = Vec::new();
        for round in 0..7 {
            assert!(src.next_batch_into(0, &mut buf), "round {round}");
            assert_eq!(buf, direct.next_round(round).records(), "round {round}");
        }
        assert!(!src.next_batch_into(0, &mut buf));
        assert!(buf.is_empty());
        assert_eq!(src.rounds_done(), 7);
    }
}
