//! Exact Zipfian sampling — the paper's ZIPF dataset family.
//!
//! "**ZIPF** of 4M element parametrized Zipfian datasets of 100K distinct
//! items, with an exponent between 1–3" (§5); the Spark/Flink experiments
//! use 1M keys and exponents 1–2 (§5, Figs 4–6).
//!
//! We sample from the exact distribution: P(rank i) ∝ i^(−s) over ranks
//! 1..=K, via an inverse-CDF binary search on the precomputed cumulative
//! weights (8 MB for 1M keys — fine). Rank→key-id mapping goes through the
//! murmur finalizer so key ids are uncorrelated with popularity rank, like
//! the paper's murmur-hashed word tokens.

use super::{Generator, Key, Record};
use crate::hash::fmix64;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
    exponent: f64,
    rng: Rng,
    ts: u64,
    /// Mixed into the rank→key mapping so two generators over the same K
    /// produce disjoint key universes (used for drift experiments).
    key_salt: u64,
}

impl Zipf {
    pub fn new(n_keys: usize, exponent: f64, seed: u64) -> Self {
        assert!(n_keys > 0);
        assert!(exponent >= 0.0);
        let mut cdf = Vec::with_capacity(n_keys);
        let mut acc = 0.0f64;
        for i in 1..=n_keys {
            acc += (i as f64).powf(-exponent);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self {
            cdf,
            exponent,
            rng: Rng::new(seed),
            ts: 0,
            key_salt: 0,
        }
    }

    pub fn with_key_salt(mut self, salt: u64) -> Self {
        self.key_salt = salt;
        self
    }

    pub fn n_keys(&self) -> usize {
        self.cdf.len()
    }

    pub fn exponent(&self) -> f64 {
        self.exponent
    }

    /// Sample a popularity rank in `[0, K)` (0 = heaviest).
    #[inline]
    pub fn sample_rank(&mut self) -> usize {
        let u = self.rng.next_f64();
        // first index with cdf[i] >= u
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// The key id of a rank.
    #[inline]
    pub fn key_of_rank(&self, rank: usize) -> Key {
        fmix64((rank as u64 + 1) ^ self.key_salt.rotate_left(17))
    }

    /// Exact relative frequency of a rank.
    pub fn freq_of_rank(&self, rank: usize) -> f64 {
        let lo = if rank == 0 { 0.0 } else { self.cdf[rank - 1] };
        self.cdf[rank] - lo
    }
}

impl Generator for Zipf {
    fn next_record(&mut self) -> Record {
        let rank = self.sample_rank();
        self.ts += 1;
        Record::unit(self.key_of_rank(rank), self.ts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn exponent_zero_is_uniform() {
        let mut z = Zipf::new(10, 0.0, 1);
        let mut counts = HashMap::new();
        for _ in 0..100_000 {
            *counts.entry(z.next_record().key).or_insert(0u32) += 1;
        }
        assert_eq!(counts.len(), 10);
        for &c in counts.values() {
            assert!((c as f64 - 10_000.0).abs() < 700.0, "c={c}");
        }
    }

    #[test]
    fn heavy_head_matches_theory() {
        // exponent 1, K=1000: P(rank1) = 1/H(1000) ≈ 0.1336
        let mut z = Zipf::new(1000, 1.0, 2);
        let top_key = z.key_of_rank(0);
        let n = 200_000;
        let mut hits = 0u32;
        for _ in 0..n {
            if z.next_record().key == top_key {
                hits += 1;
            }
        }
        let p = hits as f64 / n as f64;
        let h1000: f64 = (1..=1000).map(|i| 1.0 / i as f64).sum();
        let expected = 1.0 / h1000;
        assert!((p - expected).abs() < 0.01, "p={p} expected={expected}");
    }

    #[test]
    fn freq_of_rank_sums_to_one() {
        let z = Zipf::new(500, 1.5, 3);
        let s: f64 = (0..500).map(|r| z.freq_of_rank(r)).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn freq_decreasing_in_rank() {
        let z = Zipf::new(100, 2.0, 4);
        for r in 1..100 {
            assert!(z.freq_of_rank(r) <= z.freq_of_rank(r - 1) + 1e-15);
        }
    }

    #[test]
    fn high_exponent_concentrates() {
        // exponent 3: top key takes ~83% of mass (1/zeta(3)).
        let z = Zipf::new(100_000, 3.0, 5);
        assert!(z.freq_of_rank(0) > 0.8);
    }

    #[test]
    fn key_ids_uncorrelated_with_rank() {
        let z = Zipf::new(1000, 1.0, 6);
        // adjacent ranks should not map to adjacent ids
        let mut adjacent = 0;
        for r in 1..1000 {
            if z.key_of_rank(r).abs_diff(z.key_of_rank(r - 1)) < 1000 {
                adjacent += 1;
            }
        }
        assert!(adjacent < 5);
    }

    #[test]
    fn salt_disjoint_universes() {
        let a = Zipf::new(100, 1.0, 7).with_key_salt(1);
        let b = Zipf::new(100, 1.0, 7).with_key_salt(2);
        let ka: std::collections::HashSet<_> = (0..100).map(|r| a.key_of_rank(r)).collect();
        let overlap = (0..100).filter(|&r| ka.contains(&b.key_of_rank(r))).count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Zipf::new(100, 1.2, 42);
        let mut b = Zipf::new(100, 1.2, 42);
        for _ in 0..1000 {
            assert_eq!(a.next_record(), b.next_record());
        }
    }
}
