//! [`SocketSource`] — a real asynchronous I/O source: record batches
//! arrive on a worker's feed connection and a background prefetch thread
//! keeps up to `prefetch_depth` decoded batches ready ahead of the
//! consumer, so network reads overlap compute beyond plain
//! double-buffering.
//!
//! The depth comes from `DYNREPART_PREFETCH` (integer ≥ 1, default 2),
//! parsed with the same strict [`util::env`](crate::util::env)
//! discipline as every other knob: unset/empty means the default,
//! malformed values panic with the offending variable named. Prefetch
//! depth changes only *when* bytes are read, never what they decode to,
//! so results are bitwise independent of the knob.

use crate::ddps::cluster::transport::Stream;
use crate::ddps::cluster::wire::{self, Message};
use crate::ddps::cluster::ClusterError;
use crate::util::env::knob_from_env;
use crate::workload::{Record, Source};
use std::sync::mpsc::{sync_channel, Receiver};

pub const PREFETCH_ENV: &str = "DYNREPART_PREFETCH";
pub const DEFAULT_PREFETCH: usize = 2;

/// `DYNREPART_PREFETCH`, strictly parsed (≥ 1; default
/// [`DEFAULT_PREFETCH`]).
pub fn prefetch_depth_from_env() -> usize {
    knob_from_env(PREFETCH_ENV, 1).unwrap_or(DEFAULT_PREFETCH)
}

enum Feed {
    Batch { interval: u64, records: Vec<Record> },
    Eof,
}

/// Pulls [`Message::Batch`] frames from a feed connection through a
/// bounded prefetch channel. [`Message::Eof`] ends the stream cleanly;
/// any transport or codec error surfaces once from
/// [`SocketSource::try_next`] and the source is exhausted after it.
pub struct SocketSource {
    rx: Receiver<Result<Feed, ClusterError>>,
    last_interval: u64,
    done: bool,
}

impl SocketSource {
    /// Spawn the prefetch thread over `stream`, keeping up to `depth`
    /// decoded batches in flight.
    pub fn new(stream: Stream, depth: usize) -> Self {
        assert!(depth >= 1, "prefetch depth must be at least 1");
        let (tx, rx) = sync_channel(depth);
        let mut stream = stream;
        std::thread::spawn(move || loop {
            let out = match wire::read_frame(&mut stream) {
                Ok((Message::Batch { interval, records }, _)) => {
                    Ok(Feed::Batch { interval, records })
                }
                Ok((Message::Eof, _)) => Ok(Feed::Eof),
                Ok((other, _)) => Err(ClusterError::Protocol(format!(
                    "unexpected {} on the feed connection",
                    other.name()
                ))),
                Err(e) => Err(e),
            };
            let stop = !matches!(out, Ok(Feed::Batch { .. }));
            if tx.send(out).is_err() || stop {
                return;
            }
        });
        Self {
            rx,
            last_interval: 0,
            done: false,
        }
    }

    /// [`SocketSource::new`] with the depth from `DYNREPART_PREFETCH`.
    pub fn from_env(stream: Stream) -> Self {
        Self::new(stream, prefetch_depth_from_env())
    }

    /// The interval tag of the most recently returned batch.
    pub fn last_interval(&self) -> u64 {
        self.last_interval
    }

    /// Fill `buf` with the next batch. `Ok(false)` is a clean
    /// end-of-feed; errors exhaust the source.
    pub fn try_next(&mut self, buf: &mut Vec<Record>) -> Result<bool, ClusterError> {
        buf.clear();
        if self.done {
            return Ok(false);
        }
        match self.rx.recv() {
            Ok(Ok(Feed::Batch { interval, records })) => {
                self.last_interval = interval;
                buf.extend_from_slice(&records);
                Ok(true)
            }
            Ok(Ok(Feed::Eof)) => {
                self.done = true;
                Ok(false)
            }
            Ok(Err(e)) => {
                self.done = true;
                Err(e)
            }
            Err(_) => {
                self.done = true;
                Err(ClusterError::Disconnected(
                    "feed prefetch thread exited".into(),
                ))
            }
        }
    }
}

impl Source for SocketSource {
    /// Batch sizes are fixed by the sender, so `_n` is advisory here.
    fn next_batch_into(&mut self, _n: usize, buf: &mut Vec<Record>) -> bool {
        matches!(self.try_next(buf), Ok(true))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::net::UnixStream;

    fn batch(interval: u64, keys: &[u64]) -> Message {
        Message::Batch {
            interval,
            records: keys
                .iter()
                .map(|&k| Record {
                    key: k,
                    ts: interval,
                    weight: 0.1 + k as f64,
                })
                .collect(),
        }
    }

    #[test]
    fn batches_arrive_in_order_and_eof_ends_cleanly() {
        let (mut tx, rx) = UnixStream::pair().unwrap();
        tx.write_all(&wire::encode_frame(&batch(1, &[5, 6])).unwrap())
            .unwrap();
        tx.write_all(&wire::encode_frame(&batch(2, &[7])).unwrap())
            .unwrap();
        tx.write_all(&wire::encode_frame(&Message::Eof).unwrap())
            .unwrap();
        let mut src = SocketSource::new(Stream::Unix(rx), 2);
        let mut buf = Vec::new();
        assert!(src.try_next(&mut buf).unwrap());
        assert_eq!(src.last_interval(), 1);
        assert_eq!(buf.iter().map(|r| r.key).collect::<Vec<_>>(), vec![5, 6]);
        assert_eq!(buf[0].weight.to_bits(), (0.1 + 5.0f64).to_bits());
        assert!(src.try_next(&mut buf).unwrap());
        assert_eq!(src.last_interval(), 2);
        assert!(!src.try_next(&mut buf).unwrap());
        assert!(buf.is_empty());
        // exhausted stays exhausted
        assert!(!src.try_next(&mut buf).unwrap());
    }

    #[test]
    fn source_trait_drives_the_same_feed() {
        let (mut tx, rx) = UnixStream::pair().unwrap();
        tx.write_all(&wire::encode_frame(&batch(1, &[9])).unwrap())
            .unwrap();
        tx.write_all(&wire::encode_frame(&Message::Eof).unwrap())
            .unwrap();
        let mut src = SocketSource::new(Stream::Unix(rx), 1);
        let mut buf = Vec::new();
        assert!(Source::next_batch_into(&mut src, 999, &mut buf));
        assert_eq!(buf.len(), 1);
        assert!(!Source::next_batch_into(&mut src, 999, &mut buf));
    }

    #[test]
    fn feed_disconnect_surfaces_once_then_exhausts() {
        let (mut tx, rx) = UnixStream::pair().unwrap();
        tx.write_all(&wire::encode_frame(&batch(1, &[3])).unwrap())
            .unwrap();
        drop(tx);
        let mut src = SocketSource::new(Stream::Unix(rx), 2);
        let mut buf = Vec::new();
        assert!(src.try_next(&mut buf).unwrap());
        assert!(matches!(
            src.try_next(&mut buf),
            Err(ClusterError::Disconnected(_))
        ));
        assert!(!src.try_next(&mut buf).unwrap());
    }

    #[test]
    fn non_batch_frame_on_the_feed_is_a_protocol_error() {
        let (mut tx, rx) = UnixStream::pair().unwrap();
        tx.write_all(&wire::encode_frame(&Message::Finish).unwrap())
            .unwrap();
        let mut src = SocketSource::new(Stream::Unix(rx), 1);
        let mut buf = Vec::new();
        assert!(matches!(
            src.try_next(&mut buf),
            Err(ClusterError::Protocol(_))
        ));
    }
}
