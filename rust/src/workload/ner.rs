//! Text records for the §6 NER streaming application.
//!
//! The paper partitions crawl output by host and runs a NER model per
//! keygroup; "NLP tools such as named entity recognition are sensitive to
//! the length of text, therefore certain domains require increased
//! processing time". We generate variable-length token sequences whose
//! length distribution is heavy-tailed **per host**, and whose token ids
//! feed the AOT-compiled scorer (L1/L2) directly.

use super::{Key, Record};
use crate::hash::fmix64;
use crate::util::Rng;

/// Vocabulary size baked into the L1 kernel (must match python/compile).
pub const VOCAB: usize = 8192;
/// Max sequence length accepted by the scorer (fixed-shape AOT artifact).
pub const MAX_LEN: usize = 128;

/// A text document flowing to the NER reducer.
#[derive(Debug, Clone)]
pub struct Doc {
    pub host: Key,
    pub ts: u64,
    /// Token ids, padded/truncated to MAX_LEN by the batcher.
    pub tokens: Vec<i32>,
}

impl Doc {
    /// Processing-cost proxy: NER cost is ~linear in text length.
    pub fn weight(&self) -> f64 {
        self.tokens.len() as f64
    }

    pub fn to_record(&self) -> Record {
        Record::new(self.host, self.ts, self.weight())
    }
}

#[derive(Debug)]
pub struct NerGen {
    rng: Rng,
    hosts: Vec<(Key, f64, f64)>, // (key, popularity weight, mean doc length)
    cum: Vec<f64>,
    ts: u64,
}

impl NerGen {
    /// `host_weights`: (host key, relative record frequency). Mean document
    /// length per host is drawn heavy-tailed — some domains publish
    /// long-form articles.
    pub fn new(host_weights: &[(Key, f64)], seed: u64) -> Self {
        assert!(!host_weights.is_empty());
        let mut rng = Rng::new(seed);
        let mut hosts = Vec::with_capacity(host_weights.len());
        for &(k, w) in host_weights {
            // long-form sites publish ~3× longer articles than wire feeds
            let mean_len = 16.0 + 48.0 * rng.next_pareto(1.5).min(3.0);
            hosts.push((k, w, mean_len));
        }
        let mut cum = Vec::with_capacity(hosts.len());
        let mut acc = 0.0;
        for &(_, w, _) in &hosts {
            acc += w;
            cum.push(acc);
        }
        for c in &mut cum {
            *c /= acc;
        }
        Self {
            rng,
            hosts,
            cum,
            ts: 0,
        }
    }

    /// Generate one document.
    pub fn next_doc(&mut self) -> Doc {
        let u = self.rng.next_f64();
        let i = self.cum.partition_point(|&c| c < u).min(self.cum.len() - 1);
        let (host, _, mean_len) = self.hosts[i];
        let len = ((mean_len * self.rng.next_exp()).ceil() as usize).clamp(4, MAX_LEN);
        // tokens: host-flavoured vocabulary region + common words
        let mut tokens = Vec::with_capacity(len);
        for j in 0..len {
            let t = if self.rng.next_f64() < 0.3 {
                // common tokens: low ids
                self.rng.next_below(256) as i32
            } else {
                (fmix64(host ^ (j as u64) ^ self.rng.next_u64()) % VOCAB as u64) as i32
            };
            tokens.push(t);
        }
        self.ts += 1;
        Doc {
            host,
            ts: self.ts,
            tokens,
        }
    }

    pub fn docs(&mut self, n: usize) -> Vec<Doc> {
        (0..n).map(|_| self.next_doc()).collect()
    }
}

/// Pad/truncate a batch of docs to a fixed `[batch, MAX_LEN]` i32 buffer
/// (row-major), the input layout of the AOT scorer. Returns the flat buffer
/// and the true lengths.
pub fn pad_batch(docs: &[&Doc], batch: usize) -> (Vec<i32>, Vec<i32>) {
    assert!(docs.len() <= batch);
    let mut flat = vec![0i32; batch * MAX_LEN];
    let mut lens = vec![0i32; batch];
    for (i, d) in docs.iter().enumerate() {
        let l = d.tokens.len().min(MAX_LEN);
        flat[i * MAX_LEN..i * MAX_LEN + l].copy_from_slice(&d.tokens[..l]);
        lens[i] = l as i32;
    }
    (flat, lens)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hosts() -> Vec<(Key, f64)> {
        vec![(10, 0.7), (20, 0.2), (30, 0.1)]
    }

    #[test]
    fn host_frequencies_respected() {
        let mut g = NerGen::new(&hosts(), 1);
        let docs = g.docs(20_000);
        let h10 = docs.iter().filter(|d| d.host == 10).count() as f64 / 20_000.0;
        assert!((h10 - 0.7).abs() < 0.03, "h10={h10}");
    }

    #[test]
    fn token_ids_in_vocab() {
        let mut g = NerGen::new(&hosts(), 2);
        for d in g.docs(500) {
            assert!(d.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
            assert!(d.tokens.len() <= MAX_LEN && d.tokens.len() >= 4);
        }
    }

    #[test]
    fn lengths_vary_by_host() {
        let mut g = NerGen::new(&[(1, 0.5), (2, 0.5)], 3);
        let docs = g.docs(5_000);
        let mean = |h: Key| {
            let v: Vec<f64> = docs
                .iter()
                .filter(|d| d.host == h)
                .map(|d| d.tokens.len() as f64)
                .collect();
            crate::util::mean(&v)
        };
        // per-host means are host-specific; not asserting which is longer,
        // just that docs exist for both
        assert!(mean(1) > 0.0 && mean(2) > 0.0);
    }

    #[test]
    fn pad_batch_layout() {
        let mut g = NerGen::new(&hosts(), 4);
        let docs = g.docs(3);
        let refs: Vec<&Doc> = docs.iter().collect();
        let (flat, lens) = pad_batch(&refs, 8);
        assert_eq!(flat.len(), 8 * MAX_LEN);
        assert_eq!(lens.len(), 8);
        for (i, d) in docs.iter().enumerate() {
            let l = lens[i] as usize;
            assert_eq!(l, d.tokens.len().min(MAX_LEN));
            assert_eq!(&flat[i * MAX_LEN..i * MAX_LEN + l], &d.tokens[..l]);
            assert!(flat[i * MAX_LEN + l..(i + 1) * MAX_LEN].iter().all(|&t| t == 0));
        }
        // empty rows zero
        assert!(flat[3 * MAX_LEN..].iter().all(|&t| t == 0));
        assert_eq!(&lens[3..], &[0, 0, 0, 0, 0]);
    }

    #[test]
    fn record_weight_is_length() {
        let mut g = NerGen::new(&hosts(), 5);
        let d = g.next_doc();
        assert_eq!(d.to_record().weight, d.tokens.len() as f64);
        assert_eq!(d.to_record().key, d.host);
    }
}
