//! Synthetic **LFM** — a drifting power-law stream standing in for the
//! paper's 4M-tag LastFM dataset (not redistributable; see DESIGN.md
//! "Substitutions").
//!
//! Fig 3 splits LFM into 20 batches of 100K records over 20 partitions and
//! forces a partitioner update per batch, measuring how each method tracks
//! *fluctuations in the key distribution*. What matters is therefore:
//! realistic cardinality (~100K distinct tags), power-law popularity
//! (music-tag frequency follows a Zipf-like law with exponent ≈ 0.9–1.0),
//! and drift: the set of heavy tags churns over time (album releases,
//! charting songs). We model drift with two mechanisms, both per batch:
//!
//! 1. **rank churn** — a fraction of popularity ranks swap with a nearby
//!    rank (gradual drift);
//! 2. **head replacement** — with some probability a top-R rank is handed
//!    to a brand-new key (sudden drift — matches the paper's "replacing
//!    keys with randomly generated strings in each round").

use super::{Generator, Key, Record};
use crate::hash::fmix64;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct LfmConfig {
    pub n_keys: usize,
    pub exponent: f64,
    /// Fraction of ranks that swap with a neighbour at each batch boundary.
    pub churn_frac: f64,
    /// Max distance of a churn swap in rank space.
    pub churn_radius: usize,
    /// Probability that each of the top `head_size` ranks is replaced by a
    /// fresh key at a batch boundary.
    pub head_replace_prob: f64,
    pub head_size: usize,
}

impl Default for LfmConfig {
    fn default() -> Self {
        Self {
            n_keys: 100_000,
            exponent: 0.9,
            churn_frac: 0.02,
            churn_radius: 1000,
            head_replace_prob: 0.15,
            head_size: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Lfm {
    cfg: LfmConfig,
    cdf: Vec<f64>,
    /// rank -> key id; mutated at batch boundaries to model drift.
    rank_to_key: Vec<Key>,
    rng: Rng,
    ts: u64,
    fresh_counter: u64,
    batch_no: u64,
}

impl Lfm {
    pub fn new(cfg: LfmConfig, seed: u64) -> Self {
        let mut acc = 0.0;
        let mut cdf = Vec::with_capacity(cfg.n_keys);
        for i in 1..=cfg.n_keys {
            acc += (i as f64).powf(-cfg.exponent);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        let rank_to_key = (0..cfg.n_keys as u64).map(|r| fmix64(r + 1)).collect();
        Self {
            cfg,
            cdf,
            rank_to_key,
            rng: Rng::new(seed),
            ts: 0,
            fresh_counter: 1 << 60,
            batch_no: 0,
        }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(LfmConfig::default(), seed)
    }

    pub fn batch_no(&self) -> u64 {
        self.batch_no
    }

    /// Apply one step of concept drift. Call at each batch boundary
    /// (`next_batch` does this for you).
    pub fn drift(&mut self) {
        self.batch_no += 1;
        let n = self.cfg.n_keys;
        // 1. rank churn: nearby-rank swaps
        let swaps = ((n as f64) * self.cfg.churn_frac) as usize;
        for _ in 0..swaps {
            let a = self.rng.range(0, n);
            let lo = a.saturating_sub(self.cfg.churn_radius);
            let hi = (a + self.cfg.churn_radius + 1).min(n);
            let b = self.rng.range(lo, hi);
            self.rank_to_key.swap(a, b);
        }
        // 2. sudden head replacement: a heavy tag dies, a new one is born
        for r in 0..self.cfg.head_size.min(n) {
            if self.rng.next_f64() < self.cfg.head_replace_prob {
                self.fresh_counter += 1;
                self.rank_to_key[r] = fmix64(self.fresh_counter);
            }
        }
    }

    /// Generate one batch of `n` records, then drift.
    pub fn next_batch(&mut self, n: usize) -> Vec<Record> {
        let mut out = Vec::new();
        self.next_batch_into(n, &mut out);
        out
    }

    /// [`Lfm::next_batch`] into a reused buffer (cleared first): batch,
    /// then one drift step.
    pub fn next_batch_into(&mut self, n: usize, out: &mut Vec<Record>) {
        self.batch_into(n, out);
        self.drift();
    }

    /// Wrap into a [`DriftingLfm`] source whose batch boundaries drift.
    pub fn drifting(self) -> DriftingLfm {
        DriftingLfm(self)
    }

    #[inline]
    fn sample_rank(&mut self) -> usize {
        let u = self.rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

impl Generator for Lfm {
    fn next_record(&mut self) -> Record {
        let rank = self.sample_rank();
        self.ts += 1;
        Record::unit(self.rank_to_key[rank], self.ts)
    }
}

/// [`Lfm`] as a drifting [`Source`](super::Source): every pulled batch is
/// followed by one [`Lfm::drift`] step, exactly like [`Lfm::next_batch`].
/// (The blanket `Generator` source impl never drifts — use this wherever
/// the Fig 3 protocol's per-batch concept drift is wanted.)
#[derive(Debug, Clone)]
pub struct DriftingLfm(pub Lfm);

impl super::Source for DriftingLfm {
    fn next_batch_into(&mut self, n: usize, buf: &mut Vec<Record>) -> bool {
        self.0.next_batch_into(n, buf);
        !buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::{HashMap, HashSet};

    fn head_keys(l: &Lfm, k: usize) -> HashSet<Key> {
        l.rank_to_key[..k].iter().cloned().collect()
    }

    #[test]
    fn power_law_head_is_heavy() {
        let mut l = Lfm::with_defaults(1);
        let recs = l.batch(100_000);
        let mut counts: HashMap<Key, u32> = HashMap::new();
        for r in &recs {
            *counts.entry(r.key).or_insert(0) += 1;
        }
        let mut v: Vec<u32> = counts.values().cloned().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        // top key should be far above the mean count
        let mean = 100_000.0 / counts.len() as f64;
        assert!(v[0] as f64 > 20.0 * mean, "top={} mean={mean}", v[0]);
    }

    #[test]
    fn drift_churns_the_head_eventually() {
        let mut l = Lfm::with_defaults(2);
        let before = head_keys(&l, 10);
        for _ in 0..20 {
            l.drift();
        }
        let after = head_keys(&l, 10);
        let kept = before.intersection(&after).count();
        assert!(kept < 10, "head never churned across 20 drifts");
    }

    #[test]
    fn no_drift_config_is_stationary() {
        let cfg = LfmConfig {
            churn_frac: 0.0,
            head_replace_prob: 0.0,
            ..Default::default()
        };
        let mut l = Lfm::new(cfg, 3);
        let before = l.rank_to_key.clone();
        l.drift();
        assert_eq!(before, l.rank_to_key);
    }

    #[test]
    fn next_batch_advances_batch_no() {
        let mut l = Lfm::with_defaults(4);
        let _ = l.next_batch(10);
        let _ = l.next_batch(10);
        assert_eq!(l.batch_no(), 2);
    }

    #[test]
    fn rank_to_key_stays_injective_under_drift() {
        let mut l = Lfm::with_defaults(5);
        for _ in 0..50 {
            l.drift();
        }
        let set: HashSet<_> = l.rank_to_key.iter().collect();
        assert_eq!(set.len(), l.rank_to_key.len());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Lfm::with_defaults(42);
        let mut b = Lfm::with_defaults(42);
        assert_eq!(a.next_batch(1000), b.next_batch(1000));
        assert_eq!(a.next_batch(1000), b.next_batch(1000));
    }

    #[test]
    fn drifting_source_matches_next_batch() {
        use crate::workload::Source;
        let mut direct = Lfm::with_defaults(6);
        let mut src = Lfm::with_defaults(6).drifting();
        let mut buf = Vec::new();
        for _ in 0..3 {
            assert!(src.next_batch_into(2_000, &mut buf));
            assert_eq!(buf, direct.next_batch(2_000));
        }
        assert_eq!(src.0.batch_no(), direct.batch_no());
    }
}
