//! # dynrepart — System-aware dynamic partitioning for batch and streaming
//!
//! A from-scratch reproduction of Zvara et al., *"System-aware dynamic
//! partitioning for batch and streaming workloads"* (2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the Dynamic Repartitioning framework ([`dr`]),
//!   the Key Isolator Partitioner and baselines ([`partitioner`]), the
//!   heavy-hitter sketches ([`sketch`]), and the mini-DDPS substrate
//!   ([`ddps`]) with batch, micro-batch (spark-like) and continuous
//!   (flink-like) engines driven by one pipelined loop
//!   ([`ddps::pipeline`]: source prefetch ∥ DRM decision ∥ stage), keyed
//!   state with migration ([`state`]), the pull-based sources /
//!   workload generators of the paper's evaluation ([`workload`]), and
//!   the config-driven operational scenario harness ([`scenario`]:
//!   drift/elasticity/failure scripts with checkpoint-restore
//!   verification).
//! - **L2/L1 (python, build-time only)** — the §6 NER reducer compute,
//!   AOT-lowered to HLO text and executed from rust through [`runtime`]
//!   (PJRT CPU via the `xla` crate).
//!
//! Every figure of the paper's evaluation has a driver in [`figures`] and
//! a bench target (`cargo bench --bench fig…`); see `DESIGN.md` for the
//! per-experiment index and `EXPERIMENTS.md` for paper-vs-measured.

pub mod bench;
pub mod ddps;
pub mod dr;
pub mod figures;
pub mod hash;
pub mod ner;
pub mod partitioner;
pub mod prop;
pub mod runtime;
pub mod scenario;
pub mod sketch;
pub mod state;
pub mod util;
pub mod workload;
