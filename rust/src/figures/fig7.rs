//! Fig 7 — web-crawl fetch-list balancing (§6): per-partition record
//! balance (left) and processing time (right) of Spark ± DR in the 7th
//! crawl round. 8 executors × 8 cores; fetch lists partitioned by host
//! (crawler politeness), per-page parse cost heavy-tailed (browser-driver
//! rendering).

use crate::ddps::{BatchJob, EngineConfig, JobReport};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::Table;
use crate::workload::webcrawl::{Crawl, CrawlConfig};

pub const EXECUTORS: usize = 8;
pub const CORES: usize = 8;

pub fn engine_config(n_partitions: usize) -> EngineConfig {
    EngineConfig {
        n_partitions,
        n_slots: EXECUTORS * CORES,
        // page parsing dominates: heavier reduce cost per weight unit
        reduce_cost: 50e-6,
        // executor threads from DYNREPART_THREADS (1 = sequential)
        ..EngineConfig::from_env()
    }
}

/// Run the full 7-round crawl, returning per-round (with-DR, without-DR)
/// job reports. Partition count defaults to the slot count (one fetch
/// task per core, like the paper's politeness-bound crawl).
pub fn run_crawl(scale: f64, n_partitions: usize, seed: u64) -> Vec<(JobReport, JobReport)> {
    let cfg = CrawlConfig {
        base_pages_per_round: 300.0 * scale.max(0.05),
        ..Default::default()
    };
    let mut crawl = Crawl::new(cfg, seed);
    // The crawl has O(1000) hosts but the DRWs sample only the mapped
    // prefix, so (a) give each worker a counter budget covering the host
    // universe (a few KiB — still "low memory footprint"), and (b) track a
    // larger global histogram: with λ=4 the top 4N hosts are isolated
    // explicitly, covering most of the fetch mass (the paper observes
    // "KIP reaches better load balance for higher values of λ").
    let dr = DrConfig {
        counter_capacity_factor: 16,
        lambda: 4,
        ..Default::default()
    };
    let mut job = BatchJob::new(
        engine_config(n_partitions),
        dr,
        PartitionerChoice::Kip,
        seed,
    );
    // decide after 20% of the fetch list: still early (replay stays cheap)
    // but the host sample is dense enough for a faithful histogram
    job.decision_at = 0.2;
    // DR and hash must see the same records, so the rounds are expanded
    // here (into one reused buffer) rather than pulled per-job through a
    // CrawlSource; each job still drives the unified loop.
    let mut records = Vec::new();
    (0..7)
        .map(|round| {
            crawl.next_round(round).records_into(&mut records);
            job.compare(&records)
        })
        .collect()
}

/// Fig 7 left: sorted per-partition record counts in round 7, ± DR.
pub fn left(scale: f64) -> Table {
    let rounds = run_crawl(scale, EXECUTORS * CORES, 99);
    let (with, without) = &rounds[6];
    let mut t = Table::new(
        "Fig 7 (left): per-partition record counts, crawl round 7 (sorted desc)",
        &["rank", "Spark DR", "Spark hash"],
    );
    let mut a = with.record_counts.clone();
    let mut b = without.record_counts.clone();
    a.sort_unstable_by(|x, y| y.cmp(x));
    b.sort_unstable_by(|x, y| y.cmp(x));
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        t.rowf(&[i as f64, *x as f64, *y as f64]);
    }
    t
}

/// Fig 7 right: processing time of round 7, ± DR.
pub fn right(scale: f64) -> Table {
    let mut t = Table::new(
        "Fig 7 (right): processing time of crawl round 7 [virtual s]",
        &["partitions", "Spark DR", "Spark hash", "speedup"],
    );
    for n in [32, 64, 128] {
        let rounds = run_crawl(scale, n, 99);
        let (with, without) = &rounds[6];
        t.rowf(&[
            n as f64,
            with.makespan,
            without.makespan,
            without.makespan / with.makespan,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::load_imbalance;

    #[test]
    fn round7_dr_improves_balance_and_time() {
        let rounds = run_crawl(1.0, 64, 99);
        let (with, without) = &rounds[6];
        assert!(with.repartitioned);
        assert!(
            with.imbalance < without.imbalance,
            "{} vs {}",
            with.imbalance,
            without.imbalance
        );
        assert!(
            with.makespan < without.makespan,
            "{} vs {}",
            with.makespan,
            without.makespan
        );
    }

    #[test]
    fn record_balance_visibly_flatter_with_dr() {
        let rounds = run_crawl(1.0, 64, 99);
        let (with, without) = &rounds[6];
        let imb = |counts: &[u64]| {
            load_imbalance(&counts.iter().map(|&c| c as f64).collect::<Vec<_>>())
        };
        assert!(
            imb(&with.record_counts) < imb(&without.record_counts),
            "records with {} vs without {}",
            imb(&with.record_counts),
            imb(&without.record_counts)
        );
    }

    #[test]
    fn tables_render() {
        let l = left(0.2);
        assert_eq!(l.n_rows(), EXECUTORS * CORES);
        let r = right(0.2);
        assert_eq!(r.n_rows(), 3);
    }
}
