//! Fig 4 — Spark DR vs plain Spark over the Zipf exponent (1.0–2.0):
//! load imbalance (left) and total processing time for 10M records
//! (right). 1M keys, 35 partitions, 40 executor slots (§5).
//!
//! "DR is beneficial for the moderate values of the Zipf exponent. For an
//! exponent near 1, DR is not required ... for very large exponents, the
//! heaviest key dominates the processing time."

use super::setup;
use crate::ddps::{EngineConfig, MicroBatchEngine};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::Table;
use crate::workload::zipf::Zipf;

/// NB: our exact-Zipf sampler parametrizes skew more aggressively than the
/// paper's generator — a single key already takes ≥18% of the stream at
/// exponent 1.2 with 1M keys. The paper's "moderate exponent" sweet spot
/// (~1.5) corresponds to ≈1.0–1.2 here; we sweep from 0.8 so the full
/// inverted-U (no gain → max gain → heavy-key-pinned decay) is visible.
/// See EXPERIMENTS.md.
pub const EXPONENTS: [f64; 7] = [0.8, 1.0, 1.2, 1.4, 1.6, 1.8, 2.0];

#[derive(Debug, Clone)]
pub struct Fig4Point {
    pub exponent: f64,
    pub imbalance_dr: f64,
    pub imbalance_hash: f64,
    pub time_dr: f64,
    pub time_hash: f64,
}

fn engine_config() -> EngineConfig {
    EngineConfig {
        n_partitions: setup::SPARK_PARTITIONS,
        n_slots: setup::SPARK_SLOTS,
        // executor threads from DYNREPART_THREADS (1 = sequential)
        ..EngineConfig::from_env()
    }
}

/// Run the 10M-record job as a stream of micro-batches and report the
/// steady-state imbalance (last batch) and total processing time. The
/// engine pulls the batches straight from the Zipf source through the
/// unified loop (`run_stream`), so with `DYNREPART_THREADS > 1` batch
/// generation overlaps stage execution.
pub fn run_point(exponent: f64, scale: f64, with_dr: bool) -> (f64, f64) {
    let total_records = ((10_000_000 as f64) * scale).max(100_000.0) as usize;
    let n_batches = 10usize;
    let per_batch = total_records / n_batches;
    let keys = ((setup::ZIPF_KEYS_SYSTEM as f64) * scale.max(0.1)) as usize;

    let (dr, choice) = if with_dr {
        (DrConfig::default(), PartitionerChoice::Kip)
    } else {
        (DrConfig::disabled(), PartitionerChoice::Uhp)
    };
    let mut engine = MicroBatchEngine::new(engine_config(), dr, choice, 42);
    let mut z = Zipf::new(keys, exponent, 42);
    let reports = engine.run_stream(&mut z, per_batch, n_batches);
    let last_imbalance = reports.last().map_or(1.0, |r| r.imbalance);
    (last_imbalance, engine.metrics().total_vtime)
}

pub fn run(scale: f64) -> Vec<Fig4Point> {
    EXPONENTS
        .iter()
        .map(|&exponent| {
            let (imbalance_dr, time_dr) = run_point(exponent, scale, true);
            let (imbalance_hash, time_hash) = run_point(exponent, scale, false);
            Fig4Point {
                exponent,
                imbalance_dr,
                imbalance_hash,
                time_dr,
                time_hash,
            }
        })
        .collect()
}

pub fn tables(scale: f64) -> (Table, Table) {
    let pts = run(scale);
    let mut left = Table::new(
        "Fig 4 (left): load imbalance vs Zipf exponent (35 partitions, 1M keys)",
        &["exponent", "Spark DR", "Spark hash"],
    );
    let mut right = Table::new(
        "Fig 4 (right): total processing time for 10M ZIPF records [virtual s]",
        &["exponent", "Spark DR", "Spark hash", "speedup"],
    );
    for p in pts {
        left.rowf(&[p.exponent, p.imbalance_dr, p.imbalance_hash]);
        right.rowf(&[
            p.exponent,
            p.time_dr,
            p.time_hash,
            p.time_hash / p.time_dr,
        ]);
    }
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dr_wins_at_moderate_exponents() {
        // paper's headline: 1.5–2× speedup at moderate skew (≈1.0 in our
        // parametrization; see EXPONENTS)
        let (_, t_dr) = run_point(1.0, 0.1, true);
        let (_, t_hash) = run_point(1.0, 0.1, false);
        let speedup = t_hash / t_dr;
        assert!(speedup > 1.3, "speedup {speedup} too small at exp 1.0");
    }

    #[test]
    fn dr_imbalance_below_hash() {
        let (imb_dr, _) = run_point(1.0, 0.1, true);
        let (imb_hash, _) = run_point(1.0, 0.1, false);
        assert!(imb_dr < imb_hash, "{imb_dr} vs {imb_hash}");
    }

    #[test]
    fn gains_shrink_at_extreme_exponent() {
        // at exp 2.0 the heaviest key dominates: speedup must be smaller
        // than at the sweet spot
        let (_, t_dr_m) = run_point(1.0, 0.1, true);
        let (_, t_hash_m) = run_point(1.0, 0.1, false);
        let (_, t_dr_x) = run_point(2.0, 0.1, true);
        let (_, t_hash_x) = run_point(2.0, 0.1, false);
        let mid = t_hash_m / t_dr_m;
        let extreme = t_hash_x / t_dr_x;
        assert!(
            extreme < mid,
            "speedup at exp 2.0 ({extreme}) should be below exp 1.0 ({mid})"
        );
    }

    #[test]
    fn tables_cover_exponent_range() {
        let (l, r) = tables(0.01);
        assert_eq!(l.n_rows(), EXPONENTS.len());
        assert_eq!(r.n_rows(), EXPONENTS.len());
    }
}
