//! Fig 5 — over-partitioning study: processing time (left) and load
//! imbalance (right) of Spark ± DR over ZIPF (sweet-spot exponent), as a function of the
//! number of partitions (40 slots fixed).
//!
//! The paper runs this at its exponent 1.5; in our exact-Zipf
//! parametrization the equivalent moderate-skew regime sits at ≈1.1
//! (see fig4::EXPONENTS and EXPERIMENTS.md).
//!
//! "Over-partitioning is beneficial in both cases; DR performs best when
//! the number of partitions is equal to 2–3 times the number of available
//! compute slots. For DR, a higher number of partitions incurs more
//! overhead, while without DR, processing time keeps improving.
//! Nevertheless, we cannot reach the speedup of DR by over-partitioning."

use super::setup;
use crate::ddps::{EngineConfig, MicroBatchEngine};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::Table;
use crate::workload::zipf::Zipf;

pub const PARTITION_SWEEP: [usize; 7] = [20, 40, 60, 80, 120, 180, 280];
/// Paper: exponent 1.5; ours: the equivalent moderate-skew point.
pub const SWEEP_EXPONENT: f64 = 1.1;

pub fn run_point(n_partitions: usize, scale: f64, with_dr: bool) -> (f64, f64) {
    let total_records = ((10_000_000 as f64) * scale).max(100_000.0) as usize;
    let n_batches = 8usize;
    let per_batch = total_records / n_batches;
    let keys = ((setup::ZIPF_KEYS_SYSTEM as f64) * scale.max(0.1)) as usize;

    let cfg = EngineConfig {
        n_partitions,
        n_slots: setup::SPARK_SLOTS,
        // executor threads from DYNREPART_THREADS (1 = sequential)
        ..EngineConfig::from_env()
    };
    let (dr, choice) = if with_dr {
        (DrConfig::default(), PartitionerChoice::Kip)
    } else {
        (DrConfig::disabled(), PartitionerChoice::Uhp)
    };
    let mut engine = MicroBatchEngine::new(cfg, dr, choice, 7);
    let mut z = Zipf::new(keys, SWEEP_EXPONENT, 7);
    // unified loop: batch generation rides the prefetch lane
    let reports = engine.run_stream(&mut z, per_batch, n_batches);
    let last_imbalance = reports.last().map_or(1.0, |r| r.imbalance);
    (engine.metrics().total_vtime, last_imbalance)
}

pub fn tables(scale: f64) -> (Table, Table) {
    let mut left = Table::new(
        "Fig 5 (left): processing time vs #partitions, ZIPF moderate skew [virtual s]",
        &["partitions", "Spark DR", "Spark hash"],
    );
    let mut right = Table::new(
        "Fig 5 (right): load imbalance vs #partitions, ZIPF moderate skew",
        &["partitions", "Spark DR", "Spark hash"],
    );
    for &n in &PARTITION_SWEEP {
        let (t_dr, imb_dr) = run_point(n, scale, true);
        let (t_hash, imb_hash) = run_point(n, scale, false);
        left.rowf(&[n as f64, t_dr, t_hash]);
        right.rowf(&[n as f64, imb_dr, imb_hash]);
    }
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overpartitioning_helps_hash() {
        // without DR, going from 20 to 120 partitions must improve time
        // (smaller tasks spill less and waves smooth the stragglers)
        let (t20, _) = run_point(20, 0.1, false);
        let (t120, _) = run_point(120, 0.1, false);
        assert!(t120 < t20, "hash: {t120} not better than {t20}");
    }

    #[test]
    fn dr_beats_hash_at_moderate_partitioning() {
        let (t_dr, _) = run_point(20, 0.1, true);
        let (t_hash, _) = run_point(20, 0.1, false);
        assert!(t_dr < t_hash, "{t_dr} vs {t_hash}");
    }

    #[test]
    fn hash_cannot_reach_dr_by_overpartitioning() {
        // best hash over the sweep vs best DR over the sweep
        let best_dr = PARTITION_SWEEP
            .iter()
            .map(|&n| run_point(n, 0.1, true).0)
            .fold(f64::INFINITY, f64::min);
        let best_hash = PARTITION_SWEEP
            .iter()
            .map(|&n| run_point(n, 0.1, false).0)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_dr < best_hash,
            "best DR {best_dr} vs best hash {best_hash}"
        );
    }
}
