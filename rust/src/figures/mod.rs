//! Experiment drivers — one per figure of the paper's evaluation (§5, §6).
//!
//! Each driver regenerates the corresponding figure's series as a
//! [`Table`](crate::util::Table) (printed and optionally dumped as TSV via
//! `DYNREPART_OUT`). The bench targets (`cargo bench --bench figN_…`) are
//! thin wrappers; `EXPERIMENTS.md` records paper-vs-measured for each.
//!
//! Every driver takes a `scale` in (0, 1] that shrinks record counts for
//! quick runs (`cargo test` uses small scales; benches run scale = 1).

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;

/// Shared experiment constants mirroring the paper's setups.
pub mod setup {
    /// §5 component tests: ZIPF of 100K distinct items.
    pub const ZIPF_KEYS_COMPONENT: usize = 100_000;
    /// §5 Spark/Flink system tests: 1M keys.
    pub const ZIPF_KEYS_SYSTEM: usize = 1_000_000;
    /// Fig 3: 20 batches of 100K over 20 partitions, state window 5.
    pub const LFM_BATCHES: usize = 20;
    pub const LFM_BATCH_SIZE: usize = 100_000;
    pub const LFM_PARTITIONS: usize = 20;
    pub const LFM_STATE_WINDOW: usize = 5;
    /// Fig 4: 35 partitions over 4 nodes × 10 cores.
    pub const SPARK_PARTITIONS: usize = 35;
    pub const SPARK_SLOTS: usize = 40;
    /// Fig 6: Flink parallelism levels.
    pub const FLINK_PAR_LOW: usize = 14;
    pub const FLINK_PAR_HIGH: usize = 28;
}
