//! Fig 3 — load imbalance (left) and relative state migration (right)
//! over a drifting LFM stream of 20 batches × 100K records, 20 partitions.
//!
//! "States were assumed to be linear in the size of the corresponding
//! keygroups and were kept in a sliding state window of size 5. We forced
//! a partitioner update on each batch. We averaged measurements over 10
//! iterations, replacing keys with randomly generated strings in each
//! round. All partitioning methods started with a load imbalance of
//! around 2.0 and a relatively heavy migration caused by switching from
//! Hash to one of the dynamic partitioners."

use super::setup;
use crate::partitioner::{
    migration_fraction, partition_loads, GedikConfig, GedikPartitioner, GedikStrategy, Kip,
    KipConfig, Partitioner, Uhp,
};
use crate::sketch::Histogram;
use crate::state::SlidingStateWindow;
use crate::util::{load_imbalance, Table};
use crate::workload::{lfm::Lfm, Key};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Hash,
    Kip,
    Scan,
    Readj,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Hash => "Hash",
            Method::Kip => "KIP",
            Method::Scan => "Scan",
            Method::Readj => "Readj",
        }
    }
    pub const ALL: [Method; 4] = [Method::Hash, Method::Kip, Method::Scan, Method::Readj];
}

/// Per-update series of one method over the LFM stream.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub imbalance: Vec<f64>,
    pub migration: Vec<f64>,
}

enum State {
    Hash(Uhp),
    Kip(Kip),
    Gedik(GedikPartitioner),
}

impl State {
    fn as_dyn(&self) -> &dyn Partitioner {
        match self {
            State::Hash(p) => p,
            State::Kip(p) => p,
            State::Gedik(p) => p,
        }
    }

    fn update(&self, hist: &Histogram) -> State {
        match self {
            State::Hash(p) => State::Hash(p.clone()),
            State::Kip(p) => State::Kip(p.updated(hist)),
            State::Gedik(p) => State::Gedik(p.update(hist)),
        }
    }
}

/// Run the Fig 3 protocol for one method and one iteration seed.
fn run_stream(method: Method, seed: u64, batch_size: usize) -> Series {
    let n = setup::LFM_PARTITIONS;
    let lambda = 2usize;
    let mut lfm = Lfm::with_defaults(seed);
    let mut window = SlidingStateWindow::new(setup::LFM_STATE_WINDOW);
    let mut series = Series::default();

    let mut state = match method {
        Method::Hash => State::Hash(Uhp::with_seed(n, seed)),
        Method::Kip => {
            // first replacement of UHP happens at update 0 (paper): start
            // from the UHP-equivalent initial KIP
            State::Kip(Kip::initial(n, KipConfig { lambda, ..Default::default() }, seed))
        }
        Method::Scan => State::Gedik(GedikPartitioner::initial(
            GedikStrategy::Scan,
            n,
            GedikConfig::default(),
            seed,
        )),
        Method::Readj => State::Gedik(GedikPartitioner::initial(
            GedikStrategy::Readj,
            n,
            GedikConfig::default(),
            seed,
        )),
    };

    // steady-state batches reuse one allocation (Generator::batch_into)
    let mut batch = Vec::new();
    for _batch_no in 0..setup::LFM_BATCHES {
        lfm.next_batch_into(batch_size, &mut batch);

        // keygroup weights of this batch (fmix64-keyed hot-path map)
        let mut kg: crate::util::keymap::KeyMap<f64> = crate::util::keymap::key_map();
        for r in &batch {
            *kg.entry(r.key).or_insert(0.0) += r.weight;
        }

        // measured imbalance of the *current* partitioner on this batch
        let kw: Vec<(Key, f64)> = kg.iter().map(|(&k, &w)| (k, w)).collect();
        series
            .imbalance
            .push(load_imbalance(&partition_loads(state.as_dyn(), &kw)));

        // forced update at the batch boundary
        let hist = Histogram::exact(&batch, lambda * n);
        let new_state = state.update(&hist);

        // state lives in a sliding window of 5 batches
        window.push_batch(kg);
        let sw = window.state_weights();
        series
            .migration
            .push(migration_fraction(state.as_dyn(), new_state.as_dyn(), &sw));
        state = new_state;
    }
    series
}

/// Average Fig 3 series over `iters` iterations (paper: 10).
pub fn run(method: Method, iters: usize, scale: f64) -> Series {
    let batch_size = ((setup::LFM_BATCH_SIZE as f64) * scale).max(5_000.0) as usize;
    let mut acc = Series {
        imbalance: vec![0.0; setup::LFM_BATCHES],
        migration: vec![0.0; setup::LFM_BATCHES],
    };
    for it in 0..iters {
        let s = run_stream(method, 7000 + it as u64, batch_size);
        for i in 0..setup::LFM_BATCHES {
            acc.imbalance[i] += s.imbalance[i] / iters as f64;
            acc.migration[i] += s.migration[i] / iters as f64;
        }
    }
    acc
}

pub fn tables(iters: usize, scale: f64) -> (Table, Table) {
    let all: Vec<(Method, Series)> = Method::ALL
        .iter()
        .map(|&m| (m, run(m, iters, scale)))
        .collect();

    let mut left = Table::new(
        "Fig 3 (left): load imbalance per partitioner update, LFM stream",
        &["update", "Hash", "KIP", "Scan", "Readj"],
    );
    let mut right = Table::new(
        "Fig 3 (right): relative state migration per update, LFM stream",
        &["update", "KIP", "Scan", "Readj"],
    );
    for i in 0..setup::LFM_BATCHES {
        left.rowf(&[
            i as f64,
            all[0].1.imbalance[i],
            all[1].1.imbalance[i],
            all[2].1.imbalance[i],
            all[3].1.imbalance[i],
        ]);
        right.rowf(&[
            i as f64,
            all[1].1.migration[i],
            all[2].1.migration[i],
            all[3].1.migration[i],
        ]);
    }
    (left, right)
}

/// The paper's headline Fig 3 claims, computed from the series: KIP
/// improves mean imbalance vs Hash/Scan/Readj and outmigrates Readj by ~4×.
pub fn summary(iters: usize, scale: f64) -> Table {
    let mut t = Table::new(
        "Fig 3 summary: mean imbalance + mean migration (updates 1..)",
        &["method", "mean_imbalance", "mean_migration"],
    );
    for m in Method::ALL {
        let s = run(m, iters, scale);
        // skip update 0 (the forced switch away from UHP)
        let imb = crate::util::mean(&s.imbalance[1..]);
        let mig = crate::util::mean(&s.migration[1..]);
        t.row(&[m.name().to_string(), format!("{imb:.4}"), format!("{mig:.4}")]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn kip_beats_hash_scan_readj_on_imbalance() {
        let series: HashMap<&str, Series> = Method::ALL
            .iter()
            .map(|&m| (m.name(), run(m, 2, 0.2)))
            .collect();
        let mean_imb =
            |m: &str| crate::util::mean(&series[m].imbalance[1..]);
        let kip = mean_imb("KIP");
        assert!(kip < mean_imb("Hash"), "KIP {kip} vs Hash {}", mean_imb("Hash"));
        assert!(kip < mean_imb("Scan"), "KIP {kip} vs Scan {}", mean_imb("Scan"));
        assert!(kip < mean_imb("Readj"), "KIP {kip} vs Readj {}", mean_imb("Readj"));
    }

    #[test]
    fn kip_migration_below_readj() {
        // paper: "KIP outperforms Readj by a factor of 4" on migration
        let kip = run(Method::Kip, 2, 0.2);
        let readj = run(Method::Readj, 2, 0.2);
        let m_kip = crate::util::mean(&kip.migration[1..]);
        let m_readj = crate::util::mean(&readj.migration[1..]);
        assert!(
            m_kip < m_readj,
            "KIP migration {m_kip} not below Readj {m_readj}"
        );
    }

    #[test]
    fn hash_never_migrates() {
        let s = run(Method::Hash, 1, 0.1);
        assert!(s.migration.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn initial_imbalance_around_two() {
        // paper: "All partitioning methods started with a load imbalance of
        // around 2.0"
        let s = run(Method::Kip, 2, 0.2);
        assert!(
            s.imbalance[0] > 1.4 && s.imbalance[0] < 3.0,
            "update-0 imbalance {}",
            s.imbalance[0]
        );
    }

    #[test]
    fn tables_have_20_updates() {
        let (l, r) = tables(1, 0.1);
        assert_eq!(l.n_rows(), 20);
        assert_eq!(r.n_rows(), 20);
    }
}
