//! Fig 6 — Flink: relative throughput increase by DR at parallelism 14
//! and 28 (left), and running-time improvement for 10M records at
//! parallelism 28 (right), over Zipf exponents.
//!
//! The paper's Flink job uses "a reducer that simply stores a count for
//! each key as task state", 1M keys, sources generating ~57,500 rec/s
//! each; throughput measured over the first 10 minutes.

use super::setup;
use crate::ddps::{EngineConfig, StreamingEngine};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::Table;
use crate::workload::zipf::Zipf;

/// See fig4::EXPONENTS on the parametrization shift vs the paper.
pub const EXPONENTS: [f64; 7] = [0.8, 1.0, 1.2, 1.4, 1.6, 1.8, 2.0];

fn engine(parallelism: usize, with_dr: bool, seed: u64) -> StreamingEngine {
    let cfg = EngineConfig {
        n_partitions: parallelism,
        n_slots: parallelism,
        task_overhead: 0.0,
        // executor threads from DYNREPART_THREADS (1 = sequential)
        ..EngineConfig::from_env()
    };
    let (dr, choice) = if with_dr {
        (DrConfig::default(), PartitionerChoice::Kip)
    } else {
        (DrConfig::disabled(), PartitionerChoice::Uhp)
    };
    StreamingEngine::new(cfg, dr, choice, seed)
}

/// Steady-state throughput (records / virtual second) over a 10-interval
/// run, excluding the warmup interval (the paper measures the first 10
/// wall-clock minutes; we measure the equivalent steady window).
pub fn throughput(parallelism: usize, exponent: f64, scale: f64, with_dr: bool) -> f64 {
    let keys = ((setup::ZIPF_KEYS_SYSTEM as f64) * scale.max(0.1)) as usize;
    let per_interval = ((1_000_000 as f64) * scale).max(50_000.0) as usize;
    let mut e = engine(parallelism, with_dr, 11);
    let mut z = Zipf::new(keys, exponent, 11);
    // unified loop: interval generation rides the prefetch lane
    let reports = e.run_stream(&mut z, per_interval, 10);
    let mut records = 0u64;
    let mut elapsed = 0.0;
    for r in reports.iter().skip(2) {
        // skip warmup + first repartition
        records += per_interval as u64;
        elapsed += r.elapsed;
    }
    records as f64 / elapsed
}

/// Time to process 10M records (Fig 6 right).
pub fn running_time(parallelism: usize, exponent: f64, scale: f64, with_dr: bool) -> f64 {
    let keys = ((setup::ZIPF_KEYS_SYSTEM as f64) * scale.max(0.1)) as usize;
    let total = ((10_000_000 as f64) * scale).max(200_000.0) as usize;
    let intervals = 10usize;
    let mut e = engine(parallelism, with_dr, 13);
    let mut z = Zipf::new(keys, exponent, 13);
    e.run_stream(&mut z, total / intervals, intervals);
    e.vtime()
}

pub fn tables(scale: f64) -> (Table, Table) {
    let mut left = Table::new(
        "Fig 6 (left): relative Flink throughput increase by DR [%]",
        &["exponent", "par=14", "par=28"],
    );
    for &exp in &EXPONENTS {
        let mut row = vec![exp];
        for par in [setup::FLINK_PAR_LOW, setup::FLINK_PAR_HIGH] {
            let with = throughput(par, exp, scale, true);
            let without = throughput(par, exp, scale, false);
            row.push((with / without - 1.0) * 100.0);
        }
        left.rowf(&row);
    }

    let mut right = Table::new(
        "Fig 6 (right): Flink running time for 10M records, par=28 [virtual s]",
        &["exponent", "Flink DR", "Flink hash", "improvement_%"],
    );
    for &exp in &EXPONENTS {
        let with = running_time(setup::FLINK_PAR_HIGH, exp, scale, true);
        let without = running_time(setup::FLINK_PAR_HIGH, exp, scale, false);
        right.rowf(&[exp, with, without, (without / with - 1.0) * 100.0]);
    }
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dr_increases_throughput_at_moderate_skew() {
        let with = throughput(14, 1.0, 0.1, true);
        let without = throughput(14, 1.0, 0.1, false);
        assert!(
            with > without * 1.15,
            "throughput with DR {with} vs without {without}"
        );
    }

    #[test]
    fn improvement_follows_inverted_u() {
        // moderate exponents benefit more than the extreme (paper: "we
        // observe improvement for the moderate exponents")
        let gain = |exp: f64| {
            let w = throughput(14, exp, 0.1, true);
            let wo = throughput(14, exp, 0.1, false);
            w / wo
        };
        let mid = gain(1.0);
        let extreme = gain(2.0);
        assert!(mid > extreme, "mid {mid} vs extreme {extreme}");
    }

    #[test]
    fn running_time_improves() {
        let with = running_time(28, 1.0, 0.1, true);
        let without = running_time(28, 1.0, 0.1, false);
        assert!(with < without, "{with} vs {without}");
    }
}
