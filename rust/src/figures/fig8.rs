//! Fig 8 — (left) speedup of Spark DR over consecutive crawl rounds;
//! (right) processing time of the §6 NER streaming application ± DR.
//!
//! The NER run is the end-to-end three-layer experiment: documents flow
//! through the micro-batch engine partitioned by host, and the reducer
//! cost is *calibrated from the real PJRT scorer* when artifacts are
//! available (`calibrated_reduce_cost`), anchoring the virtual timeline to
//! measured compute. Paper: "DR was capable of speeding up the completion
//! of the NER task by a factor of 6 for all partition configurations"
//! (40K records, 6 executors × 6 cores).

use super::fig7;
use crate::ddps::{BatchJob, EngineConfig, MicroBatchEngine};
use crate::dr::{DrConfig, PartitionerChoice};
use crate::util::Table;
use crate::workload::webcrawl::Crawl;
use crate::workload::{ner::NerGen, Record, SliceSource};

pub const NER_EXECUTORS: usize = 6;
pub const NER_CORES: usize = 6;

/// Fig 8 left: per-round speedup of DR over hash across the 7 rounds.
pub fn left(scale: f64) -> Table {
    let rounds = fig7::run_crawl(scale, fig7::EXECUTORS * fig7::CORES, 99);
    let mut t = Table::new(
        "Fig 8 (left): speedup of Spark DR per crawl round",
        &["round", "speedup", "time_DR", "time_hash"],
    );
    for (i, (with, without)) in rounds.iter().enumerate() {
        t.rowf(&[
            (i + 1) as f64,
            without.makespan / with.makespan,
            with.makespan,
            without.makespan,
        ]);
    }
    t
}

/// Mean seconds of NER compute per unit of document weight (token). Uses
/// the real PJRT scorer if artifacts are built; falls back to a measured
/// constant otherwise (recorded in EXPERIMENTS.md).
pub fn calibrated_reduce_cost() -> f64 {
    if let Ok(arts) = crate::runtime::Artifacts::open_default() {
        if let Ok(rt) = crate::runtime::Runtime::cpu() {
            if let Ok(exe) = crate::runtime::NerExecutable::load(&rt, &arts, 128) {
                if let Ok(per_doc) = exe.calibrate_per_doc_cost(3) {
                    // weight is tokens; docs in calibration are MAX_LEN long
                    return per_doc / crate::workload::ner::MAX_LEN as f64;
                }
            }
        }
    }
    // fallback: a previously measured interpret-mode cost (~60 µs/doc at
    // L=128 → ~0.5 µs/token)
    0.5e-6
}

/// NER records from round-7 crawl hosts: heavy-tailed host mix.
pub fn ner_records(n: usize, seed: u64) -> Vec<Record> {
    let mut crawl = Crawl::with_defaults(seed);
    let lists = crawl.run();
    let mut freqs: Vec<(u64, f64)> = Crawl::host_freqs(&lists[6]).into_iter().collect();
    // HashMap iteration order is process-random; sort for reproducibility
    freqs.sort_unstable_by_key(|e| e.0);
    let mut gen = NerGen::new(&freqs, seed);
    (0..n).map(|_| gen.next_doc().to_record()).collect()
}

/// Fig 8 right: NER streaming processing time ± DR for several partition
/// configurations. `reduce_cost` from [`calibrated_reduce_cost`].
pub fn right(scale: f64, reduce_cost: f64) -> Table {
    let n_records = ((40_000 as f64) * scale.max(0.05)) as usize;
    let mut t = Table::new(
        "Fig 8 (right): NER streaming processing time, 40K records [virtual s]",
        &["partitions", "Spark DR", "Spark hash", "speedup"],
    );
    let slots = NER_EXECUTORS * NER_CORES;
    for n_partitions in [slots, 2 * slots, 4 * slots] {
        let cfg = EngineConfig {
            n_partitions,
            n_slots: NER_EXECUTORS * NER_CORES,
            reduce_cost,
            // migration of NER window state is cheap relative to the model
            migration_cost: reduce_cost * 0.1,
            // Spark Streaming reuses executors across micro-batches:
            // per-task overhead is small next to the NLP compute
            task_overhead: 5e-3,
            // executor threads from DYNREPART_THREADS (1 = sequential)
            ..EngineConfig::from_env()
        };
        let records = ner_records(n_records, 77);
        let run = |with_dr: bool| -> f64 {
            // same sketch budget as the crawl jobs: the host universe is
            // O(1000), so track λ=4·N hosts with roomy worker counters
            let (dr, choice) = if with_dr {
                (
                    DrConfig {
                        lambda: 4,
                        counter_capacity_factor: 16,
                        ..Default::default()
                    },
                    PartitionerChoice::Kip,
                )
            } else {
                (DrConfig::disabled(), PartitionerChoice::Uhp)
            };
            let mut engine = MicroBatchEngine::new(cfg, dr, choice, 77);
            // stream as 8 micro-batches through the unified loop — the
            // same pre-materialized records for DR and hash, borrowed
            // (not copied) into the prefetch lane
            let mut src = SliceSource::new(records.chunks(records.len().div_ceil(8)));
            engine.run_stream(&mut src, 0, 8);
            engine.metrics().total_vtime
        };
        let with = run(true);
        let without = run(false);
        t.rowf(&[n_partitions as f64, with, without, without / with]);
    }
    t
}

/// One-shot batch variant used by the webcrawl example for quick output.
pub fn ner_batch_speedup(scale: f64, reduce_cost: f64) -> (f64, f64, f64) {
    let n_records = ((40_000 as f64) * scale.max(0.05)) as usize;
    let cfg = EngineConfig {
        n_partitions: NER_EXECUTORS * NER_CORES,
        n_slots: NER_EXECUTORS * NER_CORES,
        reduce_cost,
        // executor threads from DYNREPART_THREADS (1 = sequential)
        ..EngineConfig::from_env()
    };
    let records = ner_records(n_records, 78);
    let job = BatchJob::new(cfg, DrConfig::default(), PartitionerChoice::Kip, 78);
    let (with, without) = job.compare(&records);
    (
        with.makespan,
        without.makespan,
        without.makespan / with.makespan,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_across_rounds() {
        let t = left(1.0);
        assert_eq!(t.n_rows(), 7);
        let rows: Vec<Vec<f64>> = t
            .to_tsv()
            .lines()
            .skip(2)
            .map(|l| l.split('\t').map(|c| c.parse().unwrap()).collect())
            .collect();
        // last round should show a clear speedup
        assert!(rows[6][1] > 1.3, "round-7 speedup {}", rows[6][1]);
    }

    #[test]
    fn ner_dr_speedup_substantial() {
        // paper reports ~6× for all partition configurations; our linear
        // cost model (no NLP superlinearity / memory thrash) lands at
        // ~1.5–2× — DR must clearly win at ≤2× slots, and never lose at
        // 4× slots where per-batch Poisson noise dominates (40K records
        // over 144 partitions ≈ 35 docs/partition/batch). Deviation
        // recorded in EXPERIMENTS.md. 1e-4 s/token ≈ 10 ms per 100-token
        // doc, a representative real-NER cost.
        let t = right(1.0, 1e-4);
        let rows: Vec<Vec<f64>> = t
            .to_tsv()
            .lines()
            .skip(2)
            .map(|l| l.split('\t').map(|c| c.parse().unwrap()).collect())
            .collect();
        assert!(rows[0][3] > 1.3, "speedup {} at partitions {}", rows[0][3], rows[0][0]);
        assert!(rows[1][3] > 1.05, "speedup {} at partitions {}", rows[1][3], rows[1][0]);
        assert!(rows[2][3] > 0.95, "speedup {} at partitions {}", rows[2][3], rows[2][0]);
    }

    #[test]
    fn batch_variant_consistent() {
        let (with, without, speedup) = ner_batch_speedup(0.25, 1e-4);
        assert!(with < without);
        assert!((speedup - without / with).abs() < 1e-9);
    }
}
