//! Fig 2 — effect of parallelism on load imbalance over ZIPF(1.0).
//!
//! Left: Hash / Readj / Redist / Scan / Mixed / KIP across partition
//! counts, averaged over independent experiments (paper: 100 runs).
//! Right: KIP with λ ∈ {1, 2, 3, 4}.
//!
//! This is a *component* experiment: partitioners are built from the exact
//! histogram of each sample (isolating partitioning quality from sketch
//! error, as the paper's §5 hashing evaluation does) and imbalance is the
//! measured max/mean load over the sampled records.

use crate::partitioner::{
    partition_loads, GedikConfig, GedikPartitioner, GedikStrategy, Kip, KipConfig, Mixed,
    Partitioner, Uhp, WeightedHash,
};
use crate::sketch::Histogram;
use crate::util::{load_imbalance, Table};
use crate::workload::{zipf::Zipf, Generator, Key};
use std::collections::HashMap;

pub const PARALLELISMS: [usize; 7] = [2, 4, 6, 8, 10, 12, 14];

fn key_weights(recs: &[crate::workload::Record]) -> Vec<(Key, f64)> {
    let mut m: HashMap<Key, f64> = HashMap::new();
    for r in recs {
        *m.entry(r.key).or_insert(0.0) += r.weight;
    }
    m.into_iter().collect()
}

fn imbalance_of(p: &dyn Partitioner, kw: &[(Key, f64)]) -> f64 {
    load_imbalance(&partition_loads(p, kw))
}

/// One experiment repetition: per-method imbalance at partition count `n`.
fn run_once(n: usize, lambda: usize, seed: u64, n_records: usize) -> HashMap<&'static str, f64> {
    let mut z = Zipf::new(super::setup::ZIPF_KEYS_COMPONENT, 1.0, seed);
    let recs = z.batch(n_records);
    let kw = key_weights(&recs);
    let hist = Histogram::exact(&recs, lambda * n);
    let mut out = HashMap::new();

    let uhp = Uhp::with_seed(n, seed);
    out.insert("Hash", imbalance_of(&uhp, &kw));

    for strat in [GedikStrategy::Readj, GedikStrategy::Redist, GedikStrategy::Scan] {
        let g = GedikPartitioner::initial(strat, n, GedikConfig::default(), seed).update(&hist);
        out.insert(strat.name(), imbalance_of(&g, &kw));
    }

    let m = Mixed::initial(n, seed).update(&hist);
    out.insert("Mixed", imbalance_of(&m, &kw));

    let cfg = KipConfig {
        lambda,
        ..Default::default()
    };
    let kip = Kip::update(
        &uhp,
        &WeightedHash::with_default_hosts(n, seed ^ 0xA5),
        &hist,
        cfg,
    );
    out.insert("KIP", imbalance_of(&kip, &kw));
    out
}

/// Fig 2 left: method comparison. `repeats` ~ the paper's 100 runs.
pub fn left(repeats: usize, scale: f64) -> Table {
    let n_records = ((400_000 as f64) * scale).max(10_000.0) as usize;
    let mut t = Table::new(
        "Fig 2 (left): load imbalance vs parallelism, ZIPF exp 1.0, lambda=2",
        &["partitions", "Hash", "Readj", "Redist", "Scan", "Mixed", "KIP"],
    );
    for &n in &PARALLELISMS {
        let mut acc: HashMap<&str, f64> = HashMap::new();
        for rep in 0..repeats {
            for (k, v) in run_once(n, 2, 1000 + rep as u64, n_records) {
                *acc.entry(k).or_insert(0.0) += v / repeats as f64;
            }
        }
        t.rowf(&[
            n as f64,
            acc["Hash"],
            acc["Readj"],
            acc["Redist"],
            acc["Scan"],
            acc["Mixed"],
            acc["KIP"],
        ]);
    }
    t
}

/// Fig 2 right: KIP with λ ∈ {1,2,3,4}.
pub fn right(repeats: usize, scale: f64) -> Table {
    let n_records = ((400_000 as f64) * scale).max(10_000.0) as usize;
    let mut t = Table::new(
        "Fig 2 (right): KIP load imbalance vs parallelism, lambda in {1,2,3,4}",
        &["partitions", "l=1", "l=2", "l=3", "l=4"],
    );
    for &n in &PARALLELISMS {
        let mut row = vec![n as f64];
        for lambda in 1..=4usize {
            let mut acc = 0.0;
            for rep in 0..repeats {
                acc += run_once(n, lambda, 2000 + rep as u64, n_records)["KIP"] / repeats as f64;
            }
            row.push(acc);
        }
        t.rowf(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kip_flat_while_hash_grows() {
        let t = left(2, 0.25);
        assert_eq!(t.n_rows(), PARALLELISMS.len());
        // parse back from the table: col 1 = Hash, col 6 = KIP
        let rows: Vec<Vec<f64>> = t
            .to_tsv()
            .lines()
            .skip(2)
            .map(|l| l.split('\t').map(|c| c.parse().unwrap()).collect())
            .collect();
        let (hash_first, hash_last) = (rows[0][1], rows[rows.len() - 1][1]);
        let (kip_first, kip_last) = (rows[0][6], rows[rows.len() - 1][6]);
        assert!(hash_last > hash_first + 0.3, "hash must grow with N");
        assert!(kip_last - kip_first < hash_last - hash_first, "KIP grows slower");
        // paper: KIP stays below ~1.2 in this range
        assert!(kip_last < 1.35, "kip at N=14: {kip_last}");
        // KIP beats every baseline at max parallelism
        for col in 1..=5 {
            assert!(rows[rows.len() - 1][col] >= kip_last - 0.05);
        }
    }

    #[test]
    fn lambda_ordering_roughly_monotone() {
        let t = right(2, 0.25);
        let rows: Vec<Vec<f64>> = t
            .to_tsv()
            .lines()
            .skip(2)
            .map(|l| l.split('\t').map(|c| c.parse().unwrap()).collect())
            .collect();
        // λ=4 no worse than λ=1 on average across the sweep
        let avg1: f64 = rows.iter().map(|r| r[1]).sum::<f64>() / rows.len() as f64;
        let avg4: f64 = rows.iter().map(|r| r[4]).sum::<f64>() / rows.len() as f64;
        assert!(avg4 <= avg1 + 0.02, "λ=4 {avg4} vs λ=1 {avg1}");
    }
}
