//! DRW — the Dynamic Repartitioning Worker (§3, Figure 1).
//!
//! A DRW is embedded in each DDPS worker. On the map/source path it taps
//! every (sampled) key into a bounded [`FreqCounter`]; at a histogram
//! request from the DRM it harvests its local top-k and decays its
//! counters so the next interval tracks the current distribution.
//!
//! DRWs share no state with each other, so the engines tap and harvest
//! them on contiguous shards of persistent pool workers
//! ([`tap_records_sharded`](crate::ddps::exec::tap_records_sharded),
//! [`harvest_sharded`](crate::ddps::exec::parallel::harvest_sharded)) —
//! each DRW sees its exact sequential observation sequence either way:
//!
//! ```
//! use dynrepart::dr::DrWorker;
//!
//! let mut drw = DrWorker::new(16, 1.0, 42); // 16 counters, tap everything
//! for _ in 0..90 {
//!     drw.observe(7, 1.0);
//! }
//! for _ in 0..10 {
//!     drw.observe(8, 1.0);
//! }
//! assert_eq!(drw.observed(), 100);
//! let h = drw.harvest(2); // local top-2 for the DRM; decays the counters
//! assert_eq!(h.entries()[0].key, 7);
//! assert!((h.entries()[0].freq - 0.9).abs() < 1e-9);
//! ```

use crate::sketch::{FreqCounter, HeavyHitter, Histogram, SketchConfig};
use crate::util::Rng;
use crate::workload::Key;

#[derive(Debug, Clone)]
pub struct DrWorker {
    counter: FreqCounter,
    sample_rate: f64,
    rng: Rng,
    observed: u64,
    sampled: u64,
    sketch: SketchConfig,
    /// Observations since the last compaction (the `histogram-compaction`
    /// trigger counts *observations*, not sampled records, so the
    /// schedule is independent of the sampling RNG).
    since_compaction: usize,
}

impl DrWorker {
    pub fn new(capacity: usize, sample_rate: f64, seed: u64) -> Self {
        Self::with_sketch(capacity, sample_rate, seed, SketchConfig::default())
    }

    /// [`DrWorker::new`] with sketch-bounding knobs: every
    /// `sketch.compaction_interval` observations the counter is compacted
    /// down to `sketch.size_boundary` entries (or to `capacity` when no
    /// boundary is set). The default [`SketchConfig`] disables the
    /// compaction branch entirely, reproducing the exact path bit-for-bit.
    /// Compaction is keyed to this DRW's own observation count, and the
    /// sharded tap replays each DRW's exact sequential observation
    /// subsequence, so the schedule is thread-count independent.
    pub fn with_sketch(capacity: usize, sample_rate: f64, seed: u64, sketch: SketchConfig) -> Self {
        assert!((0.0..=1.0).contains(&sample_rate) && sample_rate > 0.0);
        Self {
            counter: FreqCounter::with_capacity(capacity.max(1)),
            sample_rate,
            rng: Rng::new(seed ^ 0xD2_57),
            observed: 0,
            sampled: 0,
            sketch,
            since_compaction: 0,
        }
    }

    /// The map-path tap. Cheap by design: one branch + counter bump.
    #[inline]
    pub fn observe(&mut self, key: Key, weight: f64) {
        self.observed += 1;
        if self.sample_rate >= 1.0 || self.rng.next_f64() < self.sample_rate {
            self.sampled += 1;
            self.counter.observe(key, weight);
        }
        if self.sketch.compaction_interval > 0 {
            self.since_compaction += 1;
            if self.since_compaction >= self.sketch.compaction_interval {
                self.since_compaction = 0;
                let bound = if self.sketch.size_boundary > 0 {
                    self.sketch.size_boundary
                } else {
                    self.counter.capacity()
                };
                self.counter.compact_to(bound);
            }
        }
    }

    /// Records seen on the tap (sampled or not).
    pub fn observed(&self) -> u64 {
        self.observed
    }

    pub fn sampled(&self) -> u64 {
        self.sampled
    }

    /// Harvest the local histogram for the DRM and decay local counters
    /// (interval boundary).
    pub fn harvest(&mut self, top_k: usize) -> Histogram {
        let h = self.counter.harvest(top_k);
        self.counter.decay_now();
        h
    }

    /// Memory footprint in counters (DRW must stay small — §1 "low-memory-
    /// footprint sampling").
    pub fn footprint(&self) -> usize {
        self.counter.footprint()
    }

    /// The bounded counter itself — snapshot side of the wire restore.
    pub fn counter(&self) -> &FreqCounter {
        &self.counter
    }

    pub fn sample_rate(&self) -> f64 {
        self.sample_rate
    }

    /// Raw sampling-RNG state, so a restored DRW continues the exact
    /// draw sequence (bit-relevant whenever `sample_rate < 1`).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    pub fn since_compaction(&self) -> usize {
        self.since_compaction
    }

    /// Rebuild a DRW from a wire snapshot: the counter carries its exact
    /// counts/total bits, the RNG resumes mid-stream, and the compaction
    /// phase counter keeps the bounded-sketch schedule aligned — so the
    /// restored DRW observes/harvests bitwise like the lost one.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        counter: FreqCounter,
        sample_rate: f64,
        rng_state: [u64; 4],
        observed: u64,
        sampled: u64,
        sketch: SketchConfig,
        since_compaction: usize,
    ) -> Self {
        assert!((0.0..=1.0).contains(&sample_rate) && sample_rate > 0.0);
        Self {
            counter,
            sample_rate,
            rng: Rng::from_state(rng_state),
            observed,
            sampled,
            sketch,
            since_compaction,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tap_counts_and_harvests() {
        let mut w = DrWorker::new(16, 1.0, 1);
        for _ in 0..90 {
            w.observe(7, 1.0);
        }
        for _ in 0..10 {
            w.observe(8, 1.0);
        }
        assert_eq!(w.observed(), 100);
        assert_eq!(w.sampled(), 100);
        let h = w.harvest(2);
        assert_eq!(h.entries()[0].key, 7);
        assert!((h.entries()[0].freq - 0.9).abs() < 1e-9);
    }

    #[test]
    fn sampling_rate_respected() {
        let mut w = DrWorker::new(64, 0.1, 2);
        for i in 0..100_000u64 {
            w.observe(i % 50, 1.0);
        }
        let rate = w.sampled() as f64 / w.observed() as f64;
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn sampled_histogram_still_finds_heavy_key() {
        let mut w = DrWorker::new(64, 0.05, 3);
        for i in 0..200_000u64 {
            // 30% of traffic on key 999
            let k = if i % 10 < 3 { 999 } else { i };
            w.observe(k, 1.0);
        }
        let h = w.harvest(4);
        assert_eq!(h.entries()[0].key, 999);
        assert!((h.entries()[0].freq - 0.3).abs() < 0.05);
    }

    #[test]
    fn footprint_bounded() {
        let mut w = DrWorker::new(32, 1.0, 4);
        for i in 0..100_000u64 {
            w.observe(i, 1.0);
        }
        assert!(w.footprint() <= 32);
    }

    #[test]
    fn default_sketch_is_bitwise_exact() {
        let mut plain = DrWorker::new(32, 0.5, 11);
        let mut sketched = DrWorker::with_sketch(32, 0.5, 11, SketchConfig::default());
        for i in 0..50_000u64 {
            plain.observe(i % 400, 1.0);
            sketched.observe(i % 400, 1.0);
        }
        assert_eq!(plain.observed(), sketched.observed());
        assert_eq!(plain.sampled(), sketched.sampled());
        let (hp, hs) = (plain.harvest(8), sketched.harvest(8));
        assert_eq!(hp.entries(), hs.entries());
        assert_eq!(hp.total_weight().to_bits(), hs.total_weight().to_bits());
    }

    #[test]
    fn compaction_bounds_footprint_below_capacity() {
        let sketch = SketchConfig {
            compaction_interval: 100,
            size_boundary: 8,
            ..Default::default()
        };
        let mut w = DrWorker::with_sketch(1024, 1.0, 12, sketch);
        for i in 0..10_000u64 {
            w.observe(i, 1.0);
        }
        // between compactions at most interval new keys can accumulate
        assert!(
            w.footprint() <= 8 + 100,
            "footprint {} exceeds boundary + interval",
            w.footprint()
        );
        w.observe(10_000, 1.0); // unaligned tail, then force the boundary
        for i in 0..99u64 {
            w.observe(i, 1.0);
        }
        assert!(w.footprint() <= 8 + 100);
    }

    #[test]
    fn compaction_keeps_heavy_keys() {
        let sketch = SketchConfig {
            compaction_interval: 500,
            size_boundary: 16,
            ..Default::default()
        };
        let mut w = DrWorker::with_sketch(4096, 1.0, 13, sketch);
        for i in 0..100_000u64 {
            // 30% of traffic on key 999, the rest unique
            let k = if i % 10 < 3 { 999 } else { 1_000_000 + i };
            w.observe(k, 1.0);
        }
        let h = w.harvest(4);
        assert_eq!(h.entries()[0].key, 999);
        assert!((h.entries()[0].freq - 0.3).abs() < 0.05);
    }

    #[test]
    fn snapshot_roundtrip_resumes_bitwise() {
        // sampled tap + bounded sketch: the restore path must resume the
        // RNG mid-stream and keep the compaction phase aligned
        let sketch = SketchConfig {
            compaction_interval: 64,
            size_boundary: 24,
            ..Default::default()
        };
        let mut orig = DrWorker::with_sketch(48, 0.4, 77, sketch);
        for i in 0..10_000u64 {
            orig.observe(i % 300, 1.0);
        }
        let counter = FreqCounter::from_parts(
            orig.counter().capacity(),
            orig.counter().decay(),
            orig.counter().total(),
            &orig.counter().entries_sorted(),
        );
        let mut restored = DrWorker::from_parts(
            counter,
            orig.sample_rate(),
            orig.rng_state(),
            orig.observed(),
            orig.sampled(),
            sketch,
            orig.since_compaction(),
        );
        for i in 0..10_000u64 {
            orig.observe(i * 7 % 500, 1.0);
            restored.observe(i * 7 % 500, 1.0);
        }
        assert_eq!(orig.observed(), restored.observed());
        assert_eq!(orig.sampled(), restored.sampled());
        let (a, b) = (orig.harvest(8), restored.harvest(8));
        assert_eq!(a.entries(), b.entries());
        assert_eq!(a.total_weight().to_bits(), b.total_weight().to_bits());
    }

    #[test]
    fn harvest_decays_for_drift() {
        let mut w = DrWorker::new(32, 1.0, 5);
        for _ in 0..1000 {
            w.observe(1, 1.0);
        }
        let _ = w.harvest(4);
        for _ in 0..600 {
            w.observe(2, 1.0);
        }
        let h = w.harvest(4);
        assert_eq!(h.entries()[0].key, 2, "drift not tracked after decay");
    }
}
