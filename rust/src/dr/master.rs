//! DRM — the Dynamic Repartitioning Master (§3, Figure 1).
//!
//! Integrated into the DDPS driver. At each decision point (micro-batch
//! boundary in Spark, checkpoint barrier in Flink, mid-map in batch jobs)
//! it merges the DRWs' local histograms, blends them with the recent past,
//! constructs a candidate partitioner, and issues a [`DrDecision`]:
//! repartition (with the new function) or keep the current one.

use super::DrConfig;
use crate::partitioner::{
    GedikConfig, GedikPartitioner, GedikStrategy, Kip, KipConfig, Mixed, Partitioner, Uhp,
};
use crate::sketch::Histogram;
use crate::workload::Key;
use std::collections::VecDeque;
use std::sync::Arc;

/// Which partitioning function family DR maintains. KIP is the paper's
/// contribution; the others are the Fig 2/3 baselines, runnable inside the
/// full system for end-to-end ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionerChoice {
    Kip,
    Gedik(GedikStrategy),
    Mixed,
    /// Static uniform hashing — never repartitions (the no-DR baseline).
    Uhp,
}

impl PartitionerChoice {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerChoice::Kip => "KIP",
            PartitionerChoice::Gedik(s) => s.name(),
            PartitionerChoice::Mixed => "Mixed",
            PartitionerChoice::Uhp => "Hash",
        }
    }
}

/// The partitioner state the DRM evolves. Concrete (not boxed) so updates
/// can use each family's own update rule.
#[derive(Debug, Clone)]
enum DynPartitioner {
    Kip(Kip),
    Gedik(GedikPartitioner),
    Mixed(Mixed),
    Uhp(Uhp),
}

impl DynPartitioner {
    fn as_dyn(&self) -> &dyn Partitioner {
        match self {
            DynPartitioner::Kip(p) => p,
            DynPartitioner::Gedik(p) => p,
            DynPartitioner::Mixed(p) => p,
            DynPartitioner::Uhp(p) => p,
        }
    }
}

/// A cheaply-cloneable handle the engines route records through.
#[derive(Clone)]
pub struct PartitionerHandle(Arc<DynPartitioner>);

impl PartitionerHandle {
    #[inline]
    pub fn partition(&self, key: Key) -> usize {
        self.0.as_dyn().partition(key)
    }

    pub fn n_partitions(&self) -> usize {
        self.0.as_dyn().n_partitions()
    }

    pub fn explicit_routes(&self) -> usize {
        self.0.as_dyn().explicit_routes()
    }

    pub fn as_dyn(&self) -> &dyn Partitioner {
        self.0.as_dyn()
    }
}

impl std::fmt::Debug for PartitionerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PartitionerHandle(n={}, explicit={})",
            self.n_partitions(),
            self.explicit_routes()
        )
    }
}

/// Outcome of a DRM decision point.
#[derive(Debug, Clone)]
pub struct DrDecision {
    /// New partitioner to install, or None to keep the current one.
    pub new_partitioner: Option<PartitionerHandle>,
    /// Estimated max load share under the current partitioner.
    pub current_max_share: f64,
    /// Planned max load share under the candidate.
    pub planned_max_share: f64,
    /// The merged histogram the decision was based on.
    pub histogram: Histogram,
}

#[derive(Debug)]
pub struct DrMaster {
    cfg: DrConfig,
    choice: PartitionerChoice,
    n_partitions: usize,
    current: DynPartitioner,
    /// Record of past histograms (§3) blended into each decision.
    past: VecDeque<Histogram>,
    updates_issued: u64,
    decisions_made: u64,
}

impl DrMaster {
    pub fn new(cfg: DrConfig, choice: PartitionerChoice, n_partitions: usize, seed: u64) -> Self {
        let kip_cfg = KipConfig {
            lambda: cfg.lambda,
            epsilon: cfg.epsilon,
            ..Default::default()
        };
        let current = match choice {
            PartitionerChoice::Kip => DynPartitioner::Kip(Kip::initial(n_partitions, kip_cfg, seed)),
            PartitionerChoice::Gedik(s) => DynPartitioner::Gedik(GedikPartitioner::initial(
                s,
                n_partitions,
                GedikConfig::default(),
                seed,
            )),
            PartitionerChoice::Mixed => DynPartitioner::Mixed(Mixed::initial(n_partitions, seed)),
            PartitionerChoice::Uhp => DynPartitioner::Uhp(Uhp::with_seed(n_partitions, seed)),
        };
        Self {
            cfg,
            choice,
            n_partitions,
            current,
            past: VecDeque::new(),
            updates_issued: 0,
            decisions_made: 0,
        }
    }

    pub fn config(&self) -> &DrConfig {
        &self.cfg
    }

    pub fn choice(&self) -> PartitionerChoice {
        self.choice
    }

    pub fn histogram_size(&self) -> usize {
        self.cfg.lambda * self.n_partitions
    }

    /// Per-worker counter capacity the DRWs should be created with.
    pub fn worker_capacity(&self) -> usize {
        self.cfg.counter_capacity_factor * self.histogram_size()
    }

    pub fn handle(&self) -> PartitionerHandle {
        PartitionerHandle(Arc::new(self.current.clone()))
    }

    pub fn updates_issued(&self) -> u64 {
        self.updates_issued
    }

    pub fn decisions_made(&self) -> u64 {
        self.decisions_made
    }

    /// Blend the incoming merged histogram with the recorded past ones.
    fn blended(&mut self, merged: Histogram) -> Histogram {
        self.past.push_back(merged);
        while self.past.len() > self.cfg.histogram_memory.max(1) {
            self.past.pop_front();
        }
        let locals: Vec<Histogram> = self.past.iter().cloned().collect();
        Histogram::merge(&locals, self.histogram_size())
    }

    /// Estimated max load share of `p` under `hist`: tracked heavy keys at
    /// their explicit/hashed locations plus the residual mass spread by the
    /// function's own tail routing (`tail_shares`) — the same model the
    /// partitioners plan with.
    fn max_share(p: &dyn Partitioner, hist: &Histogram) -> f64 {
        let residual = (1.0 - hist.heavy_mass()).max(0.0);
        let mut load: Vec<f64> = p.tail_shares().iter().map(|s| s * residual).collect();
        for e in hist.entries() {
            load[p.partition(e.key)] += e.freq;
        }
        load.iter().cloned().fold(0.0, f64::max)
    }

    /// The DRM decision point: merge worker histograms, maybe construct and
    /// install a new partitioner. This is the paper's central control loop.
    pub fn decide(&mut self, worker_histograms: Vec<Histogram>) -> DrDecision {
        self.decisions_made += 1;
        let merged = Histogram::merge(&worker_histograms, self.histogram_size());
        let hist = self.blended(merged);

        let current_max = Self::max_share(self.current.as_dyn(), &hist);

        if !self.cfg.enabled || matches!(self.choice, PartitionerChoice::Uhp) {
            return DrDecision {
                new_partitioner: None,
                current_max_share: current_max,
                planned_max_share: current_max,
                histogram: hist,
            };
        }

        // Construct the candidate with the family's own update rule.
        let candidate = match &self.current {
            DynPartitioner::Kip(kip) => DynPartitioner::Kip(kip.updated(&hist)),
            DynPartitioner::Gedik(g) => DynPartitioner::Gedik(g.update(&hist)),
            DynPartitioner::Mixed(m) => DynPartitioner::Mixed(m.update(&hist)),
            DynPartitioner::Uhp(_) => unreachable!("handled above"),
        };
        let planned_max = Self::max_share(candidate.as_dyn(), &hist);

        // Decision: is the gain worth it? (Forced in Fig 3's methodology.)
        let worth_it = self.cfg.force_updates
            || planned_max < current_max * (1.0 - self.cfg.min_gain);

        if worth_it {
            self.current = candidate;
            self.updates_issued += 1;
            DrDecision {
                new_partitioner: Some(self.handle()),
                current_max_share: current_max,
                planned_max_share: planned_max,
                histogram: hist,
            }
        } else {
            DrDecision {
                new_partitioner: None,
                current_max_share: current_max,
                planned_max_share: planned_max,
                histogram: hist,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::partition_loads;
    use crate::util::load_imbalance;
    use crate::workload::{zipf::Zipf, Generator, Record};

    fn worker_hists(recs: &[Record], n_workers: usize, k: usize) -> Vec<Histogram> {
        let chunk = recs.len() / n_workers;
        (0..n_workers)
            .map(|w| Histogram::exact(&recs[w * chunk..(w + 1) * chunk], k))
            .collect()
    }

    #[test]
    fn disabled_dr_never_updates() {
        let mut drm = DrMaster::new(DrConfig::disabled(), PartitionerChoice::Kip, 8, 1);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let recs = z.batch(100_000);
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(d.new_partitioner.is_none());
        assert_eq!(drm.updates_issued(), 0);
    }

    #[test]
    fn skew_triggers_update_and_improves() {
        let mut drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 8, 2);
        let mut z = Zipf::new(50_000, 1.2, 2);
        let recs = z.batch(200_000);
        let before = drm.handle();
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(d.new_partitioner.is_some(), "skewed data must repartition");
        assert!(d.planned_max_share < d.current_max_share);
        let after = d.new_partitioner.unwrap();
        // measured imbalance must actually improve
        let kw: Vec<(Key, f64)> = {
            let mut m = std::collections::HashMap::new();
            for r in &recs {
                *m.entry(r.key).or_insert(0.0) += 1.0;
            }
            m.into_iter().collect()
        };
        let imb_before = load_imbalance(&partition_loads(before.as_dyn(), &kw));
        let imb_after = load_imbalance(&partition_loads(after.as_dyn(), &kw));
        assert!(imb_after < imb_before, "{imb_after} vs {imb_before}");
    }

    #[test]
    fn uniform_data_does_not_repartition() {
        let mut drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 8, 3);
        let mut z = Zipf::new(100_000, 0.0, 3); // uniform
        let recs = z.batch(100_000);
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(
            d.new_partitioner.is_none(),
            "uniform data repartitioned: cur={} planned={}",
            d.current_max_share,
            d.planned_max_share
        );
    }

    #[test]
    fn forced_updates_always_fire() {
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 4);
        let mut z = Zipf::new(100_000, 0.0, 4);
        let recs = z.batch(50_000);
        let d = drm.decide(worker_hists(&recs, 2, drm.histogram_size()));
        assert!(d.new_partitioner.is_some());
        assert_eq!(drm.updates_issued(), 1);
    }

    #[test]
    fn all_baseline_choices_construct_and_update() {
        for choice in [
            PartitionerChoice::Kip,
            PartitionerChoice::Gedik(GedikStrategy::Scan),
            PartitionerChoice::Gedik(GedikStrategy::Readj),
            PartitionerChoice::Gedik(GedikStrategy::Redist),
            PartitionerChoice::Mixed,
        ] {
            let mut drm = DrMaster::new(DrConfig::forced(), choice, 6, 5);
            let mut z = Zipf::new(10_000, 1.3, 5);
            let recs = z.batch(50_000);
            let d = drm.decide(worker_hists(&recs, 3, drm.histogram_size()));
            assert!(d.new_partitioner.is_some(), "{} failed", choice.name());
            let h = d.new_partitioner.unwrap();
            for k in 0..1000u64 {
                assert!(h.partition(k) < 6);
            }
        }
    }

    #[test]
    fn histogram_memory_smooths_drift() {
        // A one-batch blip should not dominate the blended histogram.
        let mut drm = DrMaster::new(
            DrConfig {
                histogram_memory: 3,
                force_updates: true,
                ..Default::default()
            },
            PartitionerChoice::Kip,
            4,
            6,
        );
        // two intervals dominated by key 1
        for _ in 0..2 {
            let h = Histogram::from_counts(&[(1, 900.0), (2, 100.0)], 1000.0, 8);
            drm.decide(vec![h]);
        }
        // blip: key 3 spikes for one interval with less data
        let blip = Histogram::from_counts(&[(3, 300.0), (1, 200.0)], 500.0, 8);
        let d = drm.decide(vec![blip]);
        // blended top key must still be 1 (2*900+200 vs 300)
        assert_eq!(d.histogram.entries()[0].key, 1);
    }

    #[test]
    fn handle_is_cheap_to_clone_and_consistent() {
        let drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 16, 7);
        let h1 = drm.handle();
        let h2 = h1.clone();
        for k in 0..1000u64 {
            assert_eq!(h1.partition(k), h2.partition(k));
        }
    }
}
